"""Beyond-paper integration: Revolver places MoE experts on EP devices.

A DeepSeek-style router with clustered co-activation (experts that fire
together) is profiled for a few batches; Revolver partitions the expert
co-activation graph across EP devices; the resulting placement is
compared against the naive contiguous one on cross-device co-activation
(the proxy for EP combine traffic).

The partitioning goes through the algorithm registry
(`repro.core.placement.place_experts(algo=...)` -> `run_partitioner`), so
any registered rule — revolver, spinner, restream, or an out-of-tree one
(docs/authoring-algorithms.md) — can drive the placement.

  PYTHONPATH=src python examples/expert_placement.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import (_cross_fraction, apply_placement,
                                  place_experts)
from repro.models.moe import MoESpec, apply_moe, init_moe, moe_ref

E, DEVICES, TOKENS, TOPK = 64, 8, 4000, 6


def synth_routing(seed=0):
    """Clustered routing with a hidden (shuffled) block structure."""
    rng = np.random.default_rng(seed)
    hidden = rng.permutation(E)                       # shuffle expert ids
    clusters = hidden.reshape(DEVICES, E // DEVICES)  # true co-activation groups
    grp = rng.integers(0, DEVICES, TOKENS)
    cols = rng.integers(0, E // DEVICES, (TOKENS, TOPK))
    return clusters[grp[:, None], cols]


def main():
    top = synth_routing()
    naive = np.arange(E) // (E // DEVICES)
    pl = place_experts(top, E, DEVICES, max_steps=120)
    print(f"cross-device co-activation: naive={_cross_fraction(top, naive):.3f} "
          f"revolver={pl.cross_coactivation:.3f}")
    print(f"partitioner: local_edges={pl.result.local_edges:.3f} "
          f"max_norm_load={pl.result.max_norm_load:.3f} steps={pl.result.steps}")

    # placement is a pure relabeling: module outputs are unchanged
    spec = MoESpec(d_model=16, n_experts=E, top_k=2, d_ff_expert=32)
    params = init_moe(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    np.testing.assert_allclose(moe_ref(params, x, spec),
                               moe_ref(apply_placement(params, pl), x, spec),
                               atol=1e-5, rtol=1e-5)
    print("placement-permuted MoE outputs identical — placement is free "
          "at the model level; it only changes which device owns which expert.")


if __name__ == "__main__":
    main()
