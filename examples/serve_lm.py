"""Serve a small model with batched requests through the Engine
(prefill + streaming decode), across three architecture families.

NOTE: this is **non-partitioner scaffolding** — part of the LM-stack
substrate (see the top-level README's "What else is in here" section), not
a graph-partitioning example. It predates the partitioner registry and
touches none of it; the partitioner-driven LM integration is
examples/expert_placement.py.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import init_lm
from repro.serve import Engine

ARCHS = ["tinyllama-1.1b", "rwkv6-3b", "deepseek-v2-lite-16b"]


def main():
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = init_lm(cfg, key)
        eng = Engine(cfg, params, s_max=96)
        prompts = jax.random.randint(key, (4, 16), 0, cfg.vocab, jnp.int32)
        t0 = time.monotonic()
        res = eng.generate(prompts, max_new=24, temperature=0.8, key=key)
        dt = time.monotonic() - t0
        print(f"{arch:24s} ({cfg.family:6s}) 4x24 tokens in {dt:5.1f}s; "
              f"sample: {res.tokens[0, :8].tolist()}")


if __name__ == "__main__":
    main()
