"""Quickstart: partition a synthetic social graph with every algorithm in
the registry (Revolver, the Spinner and restream rules, and the static
baselines), print the paper's two quality metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import run_partitioner
from repro.graphs import load_dataset, graph_stats

K = 8

def main():
    g = load_dataset("LJ", scale=0.002, seed=0)     # DC-SBM stand-in for LiveJournal
    stats = graph_stats(g)
    print(f"graph: |V|={g.n:,} |E|={g.m:,} density={stats['density']:.2e} "
          f"skew={stats['skewness']:+.2f}")
    print(f"{'algo':10s} {'local_edges':>12s} {'max_norm_load':>14s} {'steps':>6s}")
    for algo in ("revolver", "spinner", "restream", "hash", "range"):
        r = run_partitioner(algo, g, K, seed=0, max_steps=120)
        print(f"{algo:10s} {r.local_edges:12.4f} {r.max_norm_load:14.4f} "
              f"{r.steps:6d}")

if __name__ == "__main__":
    main()
