"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic bigram corpus, with checkpointing and a
simulated mid-run failure + auto-resume.

NOTE: this is **non-partitioner scaffolding** — part of the LM-stack
substrate (see the top-level README's "What else is in here" section), not
a graph-partitioning example. It predates the partitioner registry and
touches none of it; the partitioner-driven LM integration is
examples/expert_placement.py.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 50 --smoke   # CI-sized
"""
import argparse
import shutil
import tempfile

import jax

from repro.data import DataConfig
from repro.models.config import ModelConfig
from repro.optim import OptConfig
from repro.train import SimulatedFailure, Trainer


def model_100m(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="llama-smoke", family="dense", n_layers=2, d_model=128,
            n_heads=4, n_kv=2, d_ff=256, vocab=2048, impl="naive",
            param_dtype="float32", compute_dtype="float32", remat=False,
            logits_chunk=64)
    # ~100M params: 12L x d768 (GPT-2-small-ish with llama blocks)
    return ModelConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv=4, d_ff=2048, vocab=32000, impl="xla",
        block_q=128, block_k=128, param_dtype="float32",
        compute_dtype="float32", remat=False, logits_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step, then auto-resume")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m(args.smoke)
    if args.smoke:
        args.batch, args.seq = 4, 64
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      batch_per_host=args.batch, v_eff=512)
    opt = OptConfig(lr=3e-4, warmup_steps=max(5, args.steps // 20),
                    total_steps=args.steps)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_lm_")

    def make(fail_at):
        return Trainer(cfg, opt, data, ckpt_dir=ckpt,
                       ckpt_every=min(25, max(5, args.steps // 4)),
                       inject_failure_at=fail_at)

    trainer = make(args.fail_at).init_or_resume(jax.random.PRNGKey(0))
    try:
        hist = trainer.run(args.steps)
    except SimulatedFailure as e:
        print(f"!! {e} — restarting and auto-resuming")
        trainer = make(None).init_or_resume(jax.random.PRNGKey(0))
        hist = trainer.run(args.steps)
    print(f"loss: first={hist[0]:.3f} last={hist[-1]:.3f} "
          f"(bigram floor ~ {2.08:.2f})")
    if not args.ckpt_dir:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
