"""Streaming quickstart: grow a dc-SBM graph in 10 deltas, keep the
partition fresh with warm-started Revolver refinement, and watch the
quality metrics after every round.

  PYTHONPATH=src python examples/streaming_quickstart.py
"""
from repro.graphs.generators import dc_sbm
from repro.streaming import StreamConfig, StreamRunner, stream_from_graph

K = 8
N_DELTAS = 10


def main():
    g = dc_sbm(4096, 32768, n_comm=32, mixing=0.25, degree_exponent=0.5, seed=0)
    print(f"graph: |V|={g.n:,} |E|={g.m:,} streamed in {N_DELTAS} deltas, k={K}")
    print(f"{'delta':>5s} {'|E|':>8s} {'steps':>6s} {'local_edges':>12s} "
          f"{'max_load':>9s} {'note':>6s}")

    cfg = StreamConfig(k=K, refine_max_steps=12, refine_patience=2,
                       sync_every=2, warm_sharpen=0.5)
    runner = StreamRunner(g.n, cfg, seed=0)
    for rep in runner.run(stream_from_graph(g, N_DELTAS, seed=0)):
        note = "repad" if rep.repadded else ""
        print(f"{rep.delta_idx:5d} {rep.m:8,d} {rep.steps:6d} "
              f"{rep.local_edges:12.4f} {rep.max_norm_load:9.4f} {note:>6s}")
    print(f"total supersteps across the stream: {runner.total_steps}")


if __name__ == "__main__":
    main()
