"""Async superstep schedule: interior/boundary split + staleness semantics.

The ``chunk_schedule="async"`` contract (docs/async-superstep.md) is pinned
in three layers:

  * **property tests** (hypothesis; the seeded stub in environments without
    it — CI installs the real library): on random SBM / power-law / grid
    graphs across block sizes and shard counts, the `HaloSpec`
    interior/boundary classification is *structurally* correct — every edge
    with a remote (or hub-replicated) endpoint lands in a boundary block,
    interior blocks reference only local vertices, the split partitions
    each shard's blocks, and `interior_first_order` maximizes the common
    interior prefix without changing any block's classification;
  * **schedule-level** (in-process, 1 shard): `staleness_bound=0` is
    bit-identical to `chunk_schedule="halo"` on labels/probs/loads for
    every chunk-kind rule (the 8-device leg lives in
    `tests/sharded_parity_worker.py`);
  * **staleness conformance**: a run with `staleness_bound=s` never reads
    a halo older than `s` supersteps, pinned via the `halo_staleness` obs
    counter — not implementation internals.
"""
from __future__ import annotations

import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.core import engine
from repro.core.device_graph import (
    permute_blocks,
    prepare_device_graph,
    prepare_sharded_device_graph,
)
from repro.core.halo import HubConfig, build_halo_spec, interior_first_order
from repro.core.registry import get_algorithm, superstep_algorithms
from repro.core.runner import run_partitioner
from repro.graphs.generators import dc_sbm, grid_road, rmat
from repro.launch.mesh import make_blocks_mesh


def _graph(kind: str, n: int, seed: int):
    if kind == "sbm":
        return dc_sbm(n, 6 * n, n_comm=4, mixing=0.3, degree_exponent=0.6,
                      seed=seed)
    if kind == "powerlaw":
        return rmat(n, 5 * n, seed=seed)
    return grid_road(n, seed=seed)


def _spec_for(g, n_blocks: int, n_shards: int, hubs):
    """Host-side spec on the prepared slabs (no mesh/devices needed)."""
    dg = prepare_device_graph(g, n_blocks=n_blocks)
    kw = {}
    if hubs is not None:
        kw = dict(hubs=hubs, deg=np.asarray(dg.deg_out),
                  vmask=np.asarray(dg.vmask),
                  blk_row=np.asarray(dg.blk_row))
    spec = build_halo_spec(np.asarray(dg.blk_dst), np.asarray(dg.blk_w),
                           n_shards, dg.block_v, **kw)
    return dg, spec


def _reference_boundary(dg, spec):
    """Independent recomputation of the classification from the raw slabs:
    a block is boundary iff any real edge slot references a vertex owned by
    another shard *or* a hub-replicated vertex (wherever it lives)."""
    blk_dst = np.asarray(dg.blk_dst).astype(np.int64)
    real = np.asarray(dg.blk_w) > 0
    bps = spec.blocks_per_shard
    owner = np.arange(dg.n_blocks, dtype=np.int64) // bps
    dst_owner = (blk_dst // dg.block_v) // bps
    is_hub = np.zeros(dg.n_pad, dtype=bool)
    if spec.hub_ids:
        is_hub[np.asarray(spec.hub_ids, dtype=np.int64)] = True
    escapes = real & ((dst_owner != owner[:, None]) | is_hub[blk_dst])
    return escapes.any(axis=1)


# --------------------------------------------------------------------------
# property tests (hypothesis — real in CI, seeded stub otherwise)
# --------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["sbm", "powerlaw", "road"]),
       n=st.integers(min_value=96, max_value=420),
       n_blocks=st.sampled_from([8, 16, 32]),
       shard_pick=st.sampled_from([2, 4, 8]),
       hub_on=st.booleans(),
       seed=st.integers(min_value=0, max_value=10_000))
def test_boundary_classification_properties(kind, n, n_blocks, shard_pick,
                                            hub_on, seed):
    g = _graph(kind, n, seed)
    hubs = HubConfig() if hub_on else None
    dg, spec = _spec_for(g, n_blocks, 1, hubs)   # probe final block count
    n_shards = max(d for d in (1, 2, shard_pick) if dg.n_blocks % d == 0)
    dg, spec = _spec_for(g, n_blocks, n_shards, hubs)
    if spec.fallback:
        # fallback plans carry no split (the full gather has no interior)
        assert spec.block_is_boundary == ()
        assert spec.interior_split == 0
        assert interior_first_order(spec) is None
        return
    flags = np.asarray(spec.block_is_boundary, dtype=bool)
    bps = spec.blocks_per_shard

    # the split is a partition of each shard's blocks: every block is
    # classified, and interior_counts is exactly the complement count
    assert flags.size == dg.n_blocks
    per_shard = flags.reshape(n_shards, bps)
    assert spec.interior_counts == tuple(
        int(c) for c in (~per_shard).sum(axis=1))
    assert all(i + b == bps for i, b in
               zip(spec.interior_counts, per_shard.sum(axis=1)))

    # edge-level soundness *and* completeness: boundary iff some real edge
    # leaves the shard or touches a hub — interior blocks reference only
    # local (non-replicated) vertices
    np.testing.assert_array_equal(flags, _reference_boundary(dg, spec))

    # the engine's phase-1 scan length is a common interior prefix
    split = spec.interior_split
    assert 0 <= split <= min(spec.interior_counts)
    assert not per_shard[:, :split].any()


@settings(max_examples=10, deadline=None)
@given(kind=st.sampled_from(["sbm", "powerlaw", "road"]),
       n=st.integers(min_value=96, max_value=420),
       n_blocks=st.sampled_from([16, 32]),
       seed=st.integers(min_value=0, max_value=10_000))
def test_interior_first_order_maximizes_split(kind, n, n_blocks, seed):
    g = _graph(kind, n, seed)
    dg, spec = _spec_for(g, n_blocks, 1, None)
    n_shards = max(d for d in (1, 2, 4, 8) if dg.n_blocks % d == 0)
    dg, spec = _spec_for(g, n_blocks, n_shards, None)
    if spec.fallback:
        return
    order = interior_first_order(spec)
    if order is None:   # already interior-first: split is already maximal
        assert spec.interior_split == min(spec.interior_counts)
        return
    # a legal intra-shard permutation: same blocks, same shard, stable
    bps = spec.blocks_per_shard
    for s in range(n_shards):
        shard_slice = order[s * bps:(s + 1) * bps]
        assert sorted(shard_slice) == list(range(s * bps, (s + 1) * bps))
    # boundary-ness depends only on ownership (+ hub set), so the rebuilt
    # spec keeps every per-shard count and reaches the maximal split
    dg2 = permute_blocks(dg, order)
    spec2 = build_halo_spec(np.asarray(dg2.blk_dst), np.asarray(dg2.blk_w),
                            n_shards, dg2.block_v)
    assert spec2.interior_counts == spec.interior_counts
    assert spec2.interior_split == min(spec.interior_counts)
    flags2 = np.asarray(spec2.block_is_boundary, dtype=bool)
    np.testing.assert_array_equal(flags2, _reference_boundary(dg2, spec2))


# --------------------------------------------------------------------------
# schedule-level: s=0 bit-identity (1 shard; 8-device leg in
# sharded_parity_worker.py) and API validation
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def graph():
    return dc_sbm(256, 2048, n_comm=4, mixing=0.25, degree_exponent=0.5,
                  seed=5)


@pytest.fixture(scope="module")
def sdg(graph):
    return prepare_sharded_device_graph(graph, make_blocks_mesh(),
                                        n_blocks=8, halo=True)


@pytest.mark.parametrize("algo", [a for a in superstep_algorithms()
                                  if get_algorithm(a).kind == "chunk"])
def test_s0_bit_identical_to_halo(graph, sdg, algo):
    algorithm = get_algorithm(algo)
    cfg = algorithm.config_cls(k=5, chunk_schedule="halo")
    key = jax.random.PRNGKey(3)
    st_h = engine.place_state(algorithm, algorithm.init(sdg.dg, cfg, key),
                              sdg)
    st_a = engine.place_state(algorithm, algorithm.init(sdg.dg, cfg, key),
                              sdg)
    for _ in range(5):
        st_h = engine.superstep(algorithm, sdg, cfg, st_h)
        st_a, cache = engine.async_superstep(algorithm, sdg, cfg, st_a)
    for f in set(("labels", "loads") + algorithm.vertex_fields) \
            & set(st_h._fields):
        np.testing.assert_array_equal(
            np.asarray(getattr(st_h, f)), np.asarray(getattr(st_a, f)),
            err_msg=f"{algo}.{f} diverged at staleness_bound=0")
    if algorithm.supports_probs:
        np.testing.assert_array_equal(np.asarray(st_h.probs),
                                      np.asarray(st_a.probs))


def test_stale_cache_reuse_runs_and_differs_only_when_stale(graph, sdg):
    """Reusing the returned cache must be accepted and reproducible: two
    identically-driven stale sequences agree bit-for-bit."""
    algorithm = get_algorithm("revolver")
    cfg = algorithm.config_cls(k=5, chunk_schedule="halo")
    key = jax.random.PRNGKey(0)

    def run():
        s = engine.place_state(algorithm, algorithm.init(sdg.dg, cfg, key),
                               sdg)
        cache = None
        for g in range(6):
            if g % 3 == 0:      # refresh every 3rd superstep (s=2 policy)
                cache = None
            s, cache = engine.async_superstep(algorithm, sdg, cfg, s,
                                              cache=cache)
        return s

    a, b = run(), run()
    np.testing.assert_array_equal(np.asarray(a.labels), np.asarray(b.labels))
    np.testing.assert_array_equal(np.asarray(a.loads), np.asarray(b.loads))


def test_async_rejects_bad_inputs(graph, sdg):
    spinner = get_algorithm("spinner")
    with pytest.raises(ValueError, match="chunk_schedule"):
        spinner.config_cls(k=4, chunk_schedule="async")
    with pytest.raises(ValueError, match="no block scan"):
        engine.async_superstep(spinner, sdg, None, None)
    revolver = get_algorithm("revolver")
    cfg = revolver.config_cls(k=4, chunk_schedule="async")
    with pytest.raises(TypeError, match="ShardedDeviceGraph"):
        engine.async_superstep(revolver, sdg.dg, cfg, None)
    with pytest.raises(ValueError, match="staleness_bound"):
        revolver.config_cls(k=4, chunk_schedule="halo", staleness_bound=1)
    no_halo = prepare_sharded_device_graph(graph, make_blocks_mesh(),
                                           n_blocks=8, halo=False)
    with pytest.raises(ValueError, match="halo-enabled"):
        engine.async_superstep(revolver, no_halo, cfg, None)


# --------------------------------------------------------------------------
# staleness conformance: never read a halo older than the bound (pinned via
# the halo_staleness obs counter, not engine internals)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bound", [0, 1, 3])
def test_staleness_never_exceeds_bound(graph, bound):
    t = obs.Tracer()
    res = run_partitioner("revolver", graph, 4, seed=1, max_steps=9,
                          patience=10_000, chunk_schedule="async",
                          staleness_bound=bound, trace=t)
    pts = t.series["halo_staleness"]
    assert [s for s, _ in pts] == list(range(res.steps))
    ages = [v for _, v in pts]
    assert max(ages) <= bound
    if bound:
        assert max(ages) == bound    # the bound is actually exercised
    else:
        assert ages == [0.0] * res.steps


def test_s0_run_partitioner_matches_halo_on_shared_layout(graph, sdg):
    """End-to-end s=0 parity: same layout, same seed — the async run's
    labels/probs are bit-identical to the halo schedule's."""
    kw = dict(seed=2, max_steps=8, patience=10_000, keep_probs=True, dg=sdg)
    r_h = run_partitioner("revolver", graph, 5, chunk_schedule="halo", **kw)
    r_a = run_partitioner("revolver", graph, 5, chunk_schedule="async", **kw)
    np.testing.assert_array_equal(r_h.labels, r_a.labels)
    np.testing.assert_array_equal(r_h.probs, r_a.probs)
    assert r_h.local_edges == r_a.local_edges
    assert r_h.max_norm_load == r_a.max_norm_load
