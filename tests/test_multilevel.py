"""Tests for the multilevel V-cycle: contraction primitives (heavy-edge
matching, graph contraction), exact conservation invariants, label-projection
monotonicity, budget schedule, and the vcycle mode / assignment plumbing."""
import numpy as np
import pytest

from repro.core import run_partitioner
from repro.core.multilevel import (
    DEFAULT_COARSE_N,
    build_level_stack,
    level_budgets,
)
from repro.core.registry import warm_startable_algorithms
from repro.graphs.blocking import (
    block_adjacency,
    locality_block_order,
    vcycle_block_order,
    _cross_weight,
    _worst_boundary,
)
from repro.graphs.csr import build_graph, contract_graph, heavy_edge_matching
from repro.graphs.generators import ring_of_cliques, rmat


def star(n_leaves):
    """Vertex 0 is the hub; every leaf has one reciprocal edge to it."""
    leaves = np.arange(1, n_leaves + 1)
    src = np.concatenate([np.zeros(n_leaves, dtype=np.int64), leaves])
    dst = np.concatenate([leaves, np.zeros(n_leaves, dtype=np.int64)])
    return build_graph(src, dst, n_leaves + 1)


def fine_local_fraction(g, labels):
    """local_edges of `labels` over g's directed edge list, in numpy."""
    src = np.repeat(np.arange(g.n), np.diff(g.row_ptr))
    return float(np.mean(labels[src] == labels[g.col_idx]))


class TestHeavyEdgeMatching:
    def test_valid_matching(self):
        g = rmat(512, 4096, seed=0)
        cmap, nc = heavy_edge_matching(g)
        assert cmap.shape == (g.n,)
        # dense ids, groups of size <= 2
        sizes = np.bincount(cmap, minlength=nc)
        assert sizes.min() >= 1 and sizes.max() <= 2
        assert nc < g.n  # rmat has plenty of edges to match along
        # every merged pair is an actual edge of the symmetrized adjacency
        for c in np.where(sizes == 2)[0]:
            u, v = np.where(cmap == c)[0]
            assert v in g.neighbors(u)

    def test_deterministic(self):
        g = rmat(256, 2048, seed=3)
        c1, n1 = heavy_edge_matching(g)
        c2, n2 = heavy_edge_matching(g)
        assert n1 == n2
        np.testing.assert_array_equal(c1, c2)

    def test_prefers_heavy_edge(self):
        # 0-1 reciprocal (eq.-4 weight 2), 1-2 one-way (weight 1): vertex 2
        # is visited first (lowest degree) and must take 1 before 0 can,
        # unless the heavy edge wins — it does, because 1 pairs with 0 only
        # if still free. Build the unambiguous case: 0-1 heavy, 2 pendant
        # on 0 via a one-way edge; 1 has degree 1, visited early, grabs 0
        # through the heavy edge; 2 is left a singleton.
        g = build_graph(np.array([0, 1, 0]), np.array([1, 0, 2]), 3)
        cmap, nc = heavy_edge_matching(g)
        assert nc == 2
        assert cmap[0] == cmap[1] != cmap[2]

    def test_isolated_vertices_become_singletons(self):
        # vertices 3, 4 have no edges at all
        g = build_graph(np.array([0, 1]), np.array([1, 0]), 5)
        cmap, nc = heavy_edge_matching(g)
        assert nc == 4  # {0,1} merged, 2, 3, 4 singletons
        sizes = np.bincount(cmap, minlength=nc)
        assert sorted(sizes) == [1, 1, 1, 2]
        assert cmap[0] == cmap[1]
        assert len({int(cmap[2]), int(cmap[3]), int(cmap[4])}) == 3


class TestContractGraph:
    def test_internal_weight_folds_into_self_w(self):
        # contract the matched pair {0,1}: its reciprocal edge (weight 2,
        # both CSR directions) must land in self_w, not the coarse adjacency
        g = build_graph(np.array([0, 1, 1]), np.array([1, 0, 2]), 3)
        cmap, nc = heavy_edge_matching(g)
        assert cmap[0] == cmap[1]
        coarse, self_w = contract_graph(g, cmap, nc)
        pair = cmap[0]
        assert self_w[pair] == pytest.approx(4.0)  # w=2 in both directions
        assert float(self_w.sum() + coarse.adj_w.sum()) == pytest.approx(
            float(g.adj_w.sum()))
        # aggregated vertex weight keeps the internal directed edges counted
        assert int(coarse.deg_out[pair]) == int(g.deg_out[0] + g.deg_out[1])

    def test_exact_conservation_roundtrip(self):
        g = rmat(1024, 8192, seed=1)
        cmap, nc = heavy_edge_matching(g)
        coarse, self_w = contract_graph(g, cmap, nc)
        # edge-weight conservation is exact, not approximate: the weights
        # are integer-valued and aggregated in float64
        assert float(coarse.adj_w.sum()) + float(self_w.sum()) \
            == float(g.adj_w.sum())
        # aggregated vertex weights conserve the fine load exactly
        assert int(coarse.deg_out.sum()) == int(g.deg_out.sum()) == g.m
        assert coarse.m == g.m
        # directed coarse edges are exactly the fine cross edges
        src = np.repeat(np.arange(g.n), np.diff(g.row_ptr))
        n_cross = int((cmap[src] != cmap[g.col_idx]).sum())
        assert int(coarse.row_ptr[-1]) == n_cross
        # expand back: every coarse adjacency weight equals the sum of the
        # fine weights between the two coarse sets
        members = [np.where(cmap == c)[0] for c in range(nc)]
        a_src = np.repeat(np.arange(g.n), np.diff(g.adj_ptr))
        fine_w = {}
        for s, d, w in zip(cmap[a_src], cmap[g.adj_idx],
                           g.adj_w.astype(np.float64)):
            if s != d:
                fine_w[(int(s), int(d))] = fine_w.get((int(s), int(d)), 0.0) + w
        for c in range(nc):
            for i in range(coarse.adj_ptr[c], coarse.adj_ptr[c + 1]):
                d = int(coarse.adj_idx[i])
                assert float(coarse.adj_w[i]) == pytest.approx(fine_w[(c, d)])
        assert len(members) == nc

    def test_rejects_bad_cmap(self):
        g = build_graph(np.array([0]), np.array([1]), 2)
        with pytest.raises(ValueError):
            contract_graph(g, np.array([0]), 1)           # wrong shape
        with pytest.raises(ValueError):
            contract_graph(g, np.array([0, 3]), 2)        # out of range


class TestLevelStack:
    def test_reaches_coarse_n_or_stalls(self):
        g = rmat(2048, 16384, seed=0)
        graphs, cmaps = build_level_stack(g, 128)
        assert graphs[0] is g
        assert len(cmaps) == len(graphs) - 1
        assert len(graphs) >= 3
        ns = [x.n for x in graphs]
        # every kept level shrank by at least the stall threshold; the
        # stack ends at coarse_n or where matching stalled (hub-dominated
        # contractions stop shrinking — the guard keeps the stack finite)
        for a, b in zip(ns, ns[1:]):
            assert b <= 0.95 * a
        for lvl in range(len(cmaps)):
            assert cmaps[lvl].shape == (graphs[lvl].n,)
            assert int(cmaps[lvl].max()) == graphs[lvl + 1].n - 1

    def test_degenerate_small_graph_is_one_level(self):
        g = rmat(256, 1024, seed=0)
        graphs, cmaps = build_level_stack(g, 512)
        assert len(graphs) == 1 and cmaps == []

    def test_matching_stall_stops_the_stack(self):
        # a star only ever loses one vertex per matching pass (the hub pairs
        # with a single leaf; every other leaf is a singleton), so the
        # reduction stalls immediately and the stack stays flat
        g = star(64)
        graphs, cmaps = build_level_stack(g, 8)
        assert len(graphs) == 1 and cmaps == []

    def test_budget_schedule(self):
        budgets = level_budgets(290, 3, 0.12, patience=5)
        assert budgets[-1] == 290                     # coarsest: full budget
        assert budgets[0] == round(290 * 0.12)        # finest: decay * full
        assert budgets[0] <= budgets[1] <= budgets[2]
        assert all(b >= 8 for b in budgets)           # patience + 3 floor
        # fine cap is depth-independent: a deeper stack must not inflate it
        assert level_budgets(290, 6, 0.12, patience=5)[0] == budgets[0]
        assert level_budgets(290, 1, 0.12, patience=5) == [290]


class TestProjectionMonotonicity:
    def test_projected_quality_identity_every_level(self):
        """At each uncoarsen level the projected labels' fine-level quality
        relates to the coarse-level quality by an exact identity: internal
        edges are local by construction, cross edges keep their coarse
        locality. Hence projection never loses quality."""
        g = rmat(2048, 16384, seed=2)
        graphs, cmaps = build_level_stack(g, 128)
        assert len(graphs) >= 3
        rng = np.random.default_rng(0)
        labels = rng.integers(0, 8, graphs[-1].n)
        for lvl in range(len(graphs) - 2, -1, -1):
            fine, coarse = graphs[lvl], graphs[lvl + 1]
            le_coarse = fine_local_fraction(coarse, labels)
            labels = labels[cmaps[lvl]]
            le_fine = fine_local_fraction(fine, labels)
            m_cross = int(coarse.row_ptr[-1])
            m_fine = int(fine.row_ptr[-1])
            expected = (m_fine - m_cross + le_coarse * m_cross) / m_fine
            assert le_fine == pytest.approx(expected, abs=1e-9)
            assert le_fine >= le_coarse - 1e-9


class TestVcycleMode:
    def test_end_to_end_matches_flat_quality_shape(self):
        g = rmat(2048, 16384, seed=0)
        res = run_partitioner("revolver", g, 4, seed=0, mode="vcycle",
                              coarse_n=256, track_history=False)
        assert res.labels.shape == (g.n,)
        assert set(np.unique(res.labels)) <= set(range(4))
        assert 0.0 < res.local_edges <= 1.0
        assert res.steps >= 1

    def test_warm_startable_rules_all_run(self):
        g = ring_of_cliques(16, 8, seed=0)
        assert set(warm_startable_algorithms()) \
            == {"revolver", "spinner", "restream"}
        for algo in warm_startable_algorithms():
            res = run_partitioner(algo, g, 4, seed=0, mode="vcycle",
                                  coarse_n=32, track_history=False)
            assert res.labels.shape == (g.n,)

    def test_degenerate_graph_falls_back_to_flat(self):
        g = rmat(128, 1024, seed=0)
        res = run_partitioner("revolver", g, 4, seed=0, mode="vcycle",
                              max_steps=20, track_history=False)
        assert res.labels.shape == (g.n,)
        assert g.n <= DEFAULT_COARSE_N  # the stack is one level

    def test_rejects_incompatible_args(self):
        g = rmat(128, 1024, seed=0)
        with pytest.raises(ValueError):
            run_partitioner("revolver", g, 4, mode="between")
        with pytest.raises(ValueError):
            run_partitioner("revolver", g, 4, coarse_n=64)  # flat mode
        with pytest.raises(TypeError):
            run_partitioner("hash", g, 4, mode="vcycle")
        with pytest.raises(ValueError):
            run_partitioner("revolver", g, 4, mode="vcycle", guard="raise")
        with pytest.raises(ValueError):
            run_partitioner("revolver", g, 4, mode="vcycle",
                            init_labels=np.zeros(g.n, dtype=np.int32))
        with pytest.raises(ValueError):
            run_partitioner("revolver", g, 4, mode="vcycle", coarse_n=2)

    def test_trace_spans_and_counters(self):
        from repro import obs

        g = rmat(2048, 16384, seed=0)
        tracer = obs.Tracer()
        res = run_partitioner("revolver", g, 4, seed=0, mode="vcycle",
                              coarse_n=256, max_steps=40,
                              track_history=False, trace=tracer)
        names = [e["name"] for e in tracer.events]
        assert "coarsen" in names and "coarse-solve" in names
        assert any(n.startswith("uncoarsen-level-") for n in names)
        assert "uncoarsen-level-0" in names
        levels = tracer.series["level_n_vertices"]
        assert levels[0][1] == g.n  # finest first, step = level index
        # one runs-manifest entry per per-level run_partitioner call — the
        # trace_report --validate superstep accounting
        n_steps = sum(r["steps"] for r in tracer.meta["runs"])
        assert n_steps == len([n for n in names if n == "superstep"])
        assert tracer.meta["vcycle"][0]["steps_per_level"][0] == res.steps


class TestVcycleAssignment:
    def test_never_worse_than_locality(self):
        from repro.graphs.blocking import block_edges

        for seed in range(3):
            g = rmat(4096, 32768, seed=seed)
            be = block_edges(g, block_v=128)    # 32 blocks
            adj = block_adjacency(be.edge_dst, be.edge_w, be.block_v)
            bps = be.n_blocks // 8
            loc = np.asarray(locality_block_order(adj, 8))
            vc = np.asarray(vcycle_block_order(adj, 8))
            key_loc = (_worst_boundary(adj, loc, bps),
                       _cross_weight(adj, loc, bps))
            key_vc = (_worst_boundary(adj, vc, bps),
                      _cross_weight(adj, vc, bps))
            assert key_vc <= key_loc
            assert sorted(vc) == list(range(be.n_blocks))  # a permutation

    def test_vcycle_assignment_runs_end_to_end(self):
        g = rmat(2048, 16384, seed=0)
        res = run_partitioner("revolver", g, 4, seed=0, max_steps=6,
                              n_blocks=16, chunk_schedule="halo",
                              assignment="vcycle", track_history=False)
        assert res.labels.shape == (g.n,)


class TestBlockAdjCache:
    def test_cached_on_sharded_layout(self):
        from repro.core.device_graph import prepare_sharded_device_graph
        from repro.launch.mesh import make_blocks_mesh

        g = rmat(2048, 16384, seed=0)
        dg = prepare_sharded_device_graph(
            g, make_blocks_mesh(), n_blocks=16, assignment="locality")
        assert dg.block_adj is not None        # seeded by the layout build
        a1 = dg.block_adj_matrix()
        assert a1 is dg.block_adj              # no recompute
        assert a1.shape == (dg.n_blocks, dg.n_blocks)
        # the cached matrix is in storage order: recomputing from the
        # storage-order edge arrays matches it exactly
        fresh = block_adjacency(np.asarray(dg.blk_dst),
                                np.asarray(dg.blk_w), dg.block_v)
        np.testing.assert_allclose(a1, fresh)

    def test_lazy_on_contiguous_layout(self):
        from repro.core.device_graph import prepare_sharded_device_graph
        from repro.launch.mesh import make_blocks_mesh

        g = rmat(1024, 8192, seed=0)
        dg = prepare_sharded_device_graph(
            g, make_blocks_mesh(), n_blocks=16, assignment="contiguous")
        assert dg.block_adj is None
        a = dg.block_adj_matrix()
        assert dg.block_adj is a               # memoized after first call
