"""Deterministic stand-in for `hypothesis` when the real library is absent.

The test image does not ship hypothesis and nothing may be pip-installed, so
`conftest.py` registers this module under the `hypothesis` name as a
fallback. Property tests degrade into seeded fuzz tests: `@given` draws
`max_examples` pseudo-random examples per strategy from a fixed-seed RNG
(no shrinking, no example database). When the real hypothesis is
installed it always wins — see conftest.
"""
from __future__ import annotations

import functools
import random
import types

DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def _integers(min_value=0, max_value=1 << 30) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(int(min_value), int(max_value)))


def _floats(min_value=0.0, max_value=1.0, **_unused) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(float(min_value), float(max_value)))


def _booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from


def given(*args, **strategy_kwargs):
    if args:
        raise TypeError("the hypothesis stub only supports keyword strategies")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                drawn = {name: s.sample(rng) for name, s in strategy_kwargs.items()}
                fn(*call_args, **drawn, **call_kwargs)

        # pytest must see the wrapper's (*args, **kwargs) signature — not the
        # wrapped function's — or it would treat the strategy parameters as
        # missing fixtures.
        del wrapper.__wrapped__
        wrapper._stub_max_examples = DEFAULT_MAX_EXAMPLES
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_unused):
    def decorate(fn):
        fn._stub_max_examples = max_examples
        return fn

    return decorate
