"""Tests for the host-side convergence loop in repro.core.runner:
patience/theta halting, warm-start vs cold-start behavior, the hash/range
fast paths, strict config kwargs, and the windowed score-fetch knob."""
import numpy as np
import pytest

from repro.core.runner import run_partitioner
from repro.graphs.generators import dc_sbm, ring_of_cliques


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(768, 6144, n_comm=12, mixing=0.25, degree_exponent=0.5, seed=5)


@pytest.fixture(scope="module")
def clique_graph():
    return ring_of_cliques(6, 12)


class TestHalting:
    def test_huge_theta_halts_at_patience(self, sbm_graph):
        """theta=inf makes every step a stall -> halt after patience+1
        supersteps (the first step always improves on prev_score=-inf)."""
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, theta=np.inf,
                            patience=3, track_history=False)
        assert r.converged
        assert r.steps == 4

    def test_impossible_patience_runs_to_max_steps(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, max_steps=12,
                            patience=10_000, track_history=False)
        assert not r.converged
        assert r.steps == 12

    def test_spinner_halting_same_contract(self, sbm_graph):
        r = run_partitioner("spinner", sbm_graph, 4, seed=0, theta=np.inf,
                            patience=4, track_history=False)
        assert r.converged and r.steps == 5

    def test_history_lengths_match_steps(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, max_steps=8,
                            patience=10_000, track_history=True)
        assert len(r.history["score"]) == r.steps
        assert len(r.history["local_edges"]) == r.steps
        assert len(r.history["max_norm_load"]) == r.steps


class TestSyncEvery:
    def test_sync_every_equivalent_labels(self, sbm_graph):
        """The windowed fetch only batches host syncs; with a fixed step
        budget (no early halt) the trajectory is identical."""
        r1 = run_partitioner("revolver", sbm_graph, 4, seed=3, max_steps=10,
                             patience=10_000, track_history=False, sync_every=1)
        r4 = run_partitioner("revolver", sbm_graph, 4, seed=3, max_steps=10,
                             patience=10_000, track_history=False, sync_every=4)
        np.testing.assert_array_equal(r1.labels, r4.labels)
        assert r1.steps == r4.steps == 10

    def test_sync_every_overshoot_bounded(self, sbm_graph):
        """Convergence is detected at most sync_every-1 steps late."""
        r1 = run_partitioner("revolver", sbm_graph, 4, seed=0, theta=np.inf,
                             patience=3, track_history=False, sync_every=1)
        r4 = run_partitioner("revolver", sbm_graph, 4, seed=0, theta=np.inf,
                             patience=3, track_history=False, sync_every=4)
        assert r1.converged and r4.converged
        assert r1.steps <= r4.steps <= r1.steps + 3

    def test_sync_every_validation(self, sbm_graph):
        with pytest.raises(ValueError):
            run_partitioner("revolver", sbm_graph, 4, sync_every=0)

    def test_history_windowed_fetch_matches_per_step(self, sbm_graph):
        """track_history now buffers the per-step metric arrays and drains
        them through the sync_every window; the recorded values must be
        identical to the fully synchronous per-step fetch."""
        r1 = run_partitioner("revolver", sbm_graph, 4, seed=3, max_steps=10,
                             patience=10_000, track_history=True, sync_every=1)
        r4 = run_partitioner("revolver", sbm_graph, 4, seed=3, max_steps=10,
                             patience=10_000, track_history=True, sync_every=4)
        assert r1.history == r4.history
        for key in ("score", "local_edges", "max_norm_load"):
            assert len(r4.history[key]) == r4.steps

    def test_history_full_on_windowed_early_halt(self, sbm_graph):
        """Convergence inside a fetch window: every *executed* step still
        lands in all three history lists (they stay aligned with steps)."""
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, theta=np.inf,
                            patience=3, track_history=True, sync_every=4)
        assert r.converged
        for key in ("score", "local_edges", "max_norm_load"):
            assert len(r.history[key]) == r.steps


class TestWarmStart:
    def test_warm_start_converges_faster(self, sbm_graph):
        """10 warm supersteps reach the quality 120 cold supersteps buy;
        a cold run is nowhere near it after its own first 10."""
        cold = run_partitioner("revolver", sbm_graph, 8, seed=0, max_steps=120,
                               track_history=True, keep_probs=True)
        warm = run_partitioner("revolver", sbm_graph, 8, seed=1, max_steps=10,
                               init_labels=cold.labels, init_probs=cold.probs,
                               init_sharpen=0.9, track_history=False)
        assert warm.local_edges >= cold.local_edges - 0.03
        assert cold.history["local_edges"][9] < warm.local_edges - 0.15

    def test_warm_start_labels_only(self, sbm_graph):
        """Without carried LA probs, sharpening toward the carried labels
        keeps the assignment from being wrecked by uniform exploration."""
        cold = run_partitioner("revolver", sbm_graph, 8, seed=0, max_steps=120,
                               track_history=False)
        warm = run_partitioner("revolver", sbm_graph, 8, seed=1, max_steps=20,
                               init_labels=cold.labels, init_sharpen=0.9,
                               track_history=False)
        assert warm.local_edges >= cold.local_edges - 0.1

    def test_spinner_warm_start(self, sbm_graph):
        cold = run_partitioner("spinner", sbm_graph, 8, seed=0,
                               track_history=False)
        warm = run_partitioner("spinner", sbm_graph, 8, seed=1,
                               init_labels=cold.labels, track_history=False)
        assert warm.steps <= cold.steps

    def test_revolver_result_carries_probs_on_request(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, max_steps=5,
                            patience=10_000, track_history=False,
                            keep_probs=True)
        assert r.probs is not None and r.probs.shape[-1] == 4
        np.testing.assert_allclose(r.probs.sum(-1), 1.0, atol=1e-4)
        # default: no LA-state fetch (the tensor is big at production scale)
        r2 = run_partitioner("revolver", sbm_graph, 4, seed=0, max_steps=2,
                             patience=10_000, track_history=False)
        assert r2.probs is None

    def test_spinner_rejects_probs(self, sbm_graph):
        with pytest.raises(TypeError):
            run_partitioner("spinner", sbm_graph, 4,
                            init_labels=np.zeros(sbm_graph.n, np.int32),
                            init_probs=np.ones((1, 1, 4)))

    def test_probs_without_labels_rejected(self, sbm_graph):
        with pytest.raises(TypeError):
            run_partitioner("revolver", sbm_graph, 4, init_probs=np.ones((1, 1, 4)))
        with pytest.raises(TypeError):
            run_partitioner("revolver", sbm_graph, 4, init_sharpen=0.5)


class TestFastPaths:
    @pytest.mark.parametrize("algo", ["hash", "range"])
    def test_static_partitioners_skip_the_loop(self, clique_graph, algo):
        r = run_partitioner(algo, clique_graph, 4)
        assert r.steps == 0 and r.converged
        assert r.labels.shape == (clique_graph.n,)
        assert 0.0 <= r.local_edges <= 1.0
        assert len(r.history["local_edges"]) == 1

    @pytest.mark.parametrize("algo", ["hash", "range"])
    def test_static_partitioners_reject_warm_start(self, clique_graph, algo):
        with pytest.raises(TypeError):
            run_partitioner(algo, clique_graph, 4,
                            init_labels=np.zeros(clique_graph.n, np.int32))


class TestStrictKwargs:
    def test_revolver_unknown_kwarg_raises(self, sbm_graph):
        with pytest.raises(TypeError, match="capacty_mode"):
            run_partitioner("revolver", sbm_graph, 4, capacty_mode="spinner")

    def test_spinner_unknown_kwarg_raises(self, sbm_graph):
        """The spinner branch used to silently drop unknown keys."""
        with pytest.raises(TypeError, match="weight_mode"):
            run_partitioner("spinner", sbm_graph, 4, weight_mode="self_lambda")

    def test_known_kwargs_still_flow(self, sbm_graph):
        r = run_partitioner("spinner", sbm_graph, 4, epsilon=0.10, max_steps=3,
                            patience=10_000, track_history=False)
        assert r.steps == 3

    def test_unknown_algo(self, sbm_graph):
        with pytest.raises(ValueError):
            run_partitioner("metis", sbm_graph, 4)
