"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs — plus
prefill/decode vs full-forward consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, input_specs
from repro.models import (init_cache, init_lm, lm_decode_step, lm_loss,
                          lm_prefill)

B, S = 2, 32


def _reduced(arch):
    return get_config(arch).reduced()


def _batch(cfg, key):
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab, dtype=jnp.int32)
    batch = {"tokens": toks,
             "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-100)}
    if cfg.family in ("vlm", "encdec"):
        n = cfg.n_patches or cfg.enc_seq
        batch["frontend"] = jax.random.normal(k2, (B, n, cfg.d_model), cfg.cdt)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = _reduced(arch)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(lambda p, b: lm_loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # one grad step moves the loss
    g = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_prefill_decode_consistency(arch):
    """prefill(S-1) + decode(1 token) logits == teacher-forced forward."""
    cfg = _reduced(arch)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    toks = batch["tokens"]

    cache = init_cache(cfg, B, S + 16)
    pre_batch = dict(batch, tokens=toks[:, :S - 1])
    pre_batch.pop("labels")
    lg_pre, cache = lm_prefill(params, cfg, cache, pre_batch)
    lg_dec, cache = lm_decode_step(params, cfg, cache, toks[:, S - 1])

    # teacher-forced logits from the hidden pass
    from repro.models import api, transformer, rwkv_model, whisper, zamba
    if cfg.family in ("dense", "moe", "vlm"):
        h = transformer.decoder_hidden(params, cfg, toks,
                                       batch.get("frontend"))
        emb = transformer._out_emb(cfg, params)
        full = (h @ emb.T).astype(jnp.float32) * cfg.logit_scale
        off = cfg.n_patches if cfg.family == "vlm" else 0
        want_pre, want_dec = full[:, off + S - 2], full[:, off + S - 1]
    elif cfg.family == "ssm":
        h = rwkv_model.rwkv_hidden(params, cfg, toks)
        full = (h @ params["unembed"]["emb"].T).astype(jnp.float32)
        want_pre, want_dec = full[:, S - 2], full[:, S - 1]
    elif cfg.family == "hybrid":
        h = zamba.zamba_hidden(params, cfg, toks)
        full = (h @ params["unembed"]["emb"].T).astype(jnp.float32)
        want_pre, want_dec = full[:, S - 2], full[:, S - 1]
    else:
        h = whisper.whisper_hidden(params, cfg, toks, batch["frontend"])
        full = (h @ params["embed"]["emb"].T).astype(jnp.float32)
        want_pre, want_dec = full[:, S - 2], full[:, S - 1]

    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(want_pre),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(want_dec),
                               atol=2e-3, rtol=2e-2)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    spec = {
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "rwkv6-3b": (32, 2560, 32, 0, 8960, 65536),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == l and cfg.d_model == d, arch
        assert cfg.n_heads == h and cfg.n_kv == kv, arch
        assert cfg.d_ff == ff and cfg.vocab == v, arch
    # family-specific assignments
    ds = get_config("deepseek-v2-236b")
    assert ds.kv_lora_rank == 512 and ds.n_experts == 160 and ds.top_k == 6
    dsl = get_config("deepseek-v2-lite-16b")
    assert dsl.n_experts == 64 and dsl.top_k == 6 and dsl.q_lora_rank == 0
    assert get_config("h2o-danube-3-4b").window == 4096
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("whisper-base").enc_seq == 1500
    assert get_config("internvl2-1b").n_patches == 256


def test_moe_dispatch_matches_dense_ref():
    from repro.models.moe import MoESpec, _apply_moe_local, init_moe, moe_ref
    spec = MoESpec(d_model=16, n_experts=8, top_k=2, d_ff_expert=32,
                   n_shared=1, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 16))
    np.testing.assert_allclose(np.asarray(_apply_moe_local(p, x, spec)),
                               np.asarray(moe_ref(p, x, spec)),
                               atol=1e-5, rtol=1e-5)


def test_mamba2_chunked_equals_scan():
    from repro.models.ssm import Mamba2Spec, apply_mamba2, init_mamba2
    spec = Mamba2Spec(d_model=32, d_state=16, d_head=16, chunk=8)
    p = init_mamba2(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    np.testing.assert_allclose(
        np.asarray(apply_mamba2(p, spec, x, impl="chunked")),
        np.asarray(apply_mamba2(p, spec, x, impl="scan")),
        atol=1e-4, rtol=1e-4)


def test_rwkv6_chunked_equals_scan():
    from repro.models.rwkv6 import RWKV6Spec, apply_rwkv6_time, init_rwkv6_time
    spec = RWKV6Spec(d_model=64, n_heads=4, d_ffn=128, chunk=8)
    p = init_rwkv6_time(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64)) * 0.5
    yc, (_, sc) = apply_rwkv6_time(p, spec, x, impl="chunked")
    ys, (_, ss) = apply_rwkv6_time(p, spec, x, impl="scan")
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(ss),
                               atol=2e-5, rtol=2e-5)


def test_attention_impls_agree():
    import repro.models.attention as A
    spec = A.AttnSpec(d_model=64, n_q=8, n_kv=2, d_head=16,
                      block_q=16, block_k=16)
    p = A.init_attention(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 64))
    pos = jnp.arange(48)
    y_naive = A.apply_attention(p, dataclasses.replace(spec, impl="naive"),
                                x, pos)
    for impl in ("xla", "pallas"):
        y = A.apply_attention(p, dataclasses.replace(spec, impl=impl), x, pos)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_naive),
                                   atol=3e-5, rtol=3e-5)


def test_chunked_ce_matches_naive():
    from repro.models.common import chunked_cross_entropy
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (2, 24, 16))
    emb = jax.random.normal(jax.random.fold_in(key, 1), (50, 16))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (2, 24), 0, 50)
    labels = labels.at[0, :3].set(-100)
    got = chunked_cross_entropy(h, emb, labels, chunk=8)
    logits = (h @ emb.T).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    tgt = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                              -1)[..., 0]
    mask = labels >= 0
    want = jnp.sum(jnp.where(mask, lse - tgt, 0)) / jnp.sum(mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
