"""System-level tests: fault-tolerant training, elastic checkpointing,
data determinism, HLO roofline analyzer, gradient compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, make_batch
from repro.models.config import ModelConfig
from repro.optim import OptConfig

_SMOKE = ModelConfig(
    name="sys-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, d_ff=128, vocab=128, impl="naive", param_dtype="float32",
    compute_dtype="float32", remat=False, logits_chunk=16)


def test_trainer_failure_resume_is_deterministic():
    from repro.train import SimulatedFailure, Trainer
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    data = DataConfig(vocab=128, seq_len=32, batch_per_host=4, v_eff=64)
    with tempfile.TemporaryDirectory() as td:
        t_ref = Trainer(_SMOKE, opt, data, ckpt_dir=td + "/a",
                        ckpt_every=2).init_or_resume(jax.random.PRNGKey(0))
        h_ref = t_ref.run(6)
        t_f = Trainer(_SMOKE, opt, data, ckpt_dir=td + "/b", ckpt_every=2,
                      inject_failure_at=4).init_or_resume(jax.random.PRNGKey(0))
        with pytest.raises(SimulatedFailure):
            t_f.run(6)
        t_r = Trainer(_SMOKE, opt, data, ckpt_dir=td + "/b",
                      ckpt_every=2).init_or_resume(jax.random.PRNGKey(0))
        # resumes from the latest COMPLETED checkpoint (async saves may
        # legitimately race a crash; atomic rename guarantees integrity)
        assert t_r.step in (2, 4)
        h_res = t_r.run(6)
        np.testing.assert_allclose(h_ref[-2:], h_res[-2:], rtol=1e-5)


def test_checkpoint_atomic_and_elastic_restore():
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 3, tree)
        save_checkpoint(td, 7, jax.tree.map(lambda x: x * 2, tree))
        assert latest_step(td) == 7
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        # restore with explicit shardings = the elastic re-shard path
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((1,), ("model",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), like)
        out = restore_checkpoint(td, 7, like, shardings=sh)
        np.testing.assert_allclose(np.asarray(out["a"]),
                                   np.asarray(tree["a"]) * 2)
        # shape mismatch is rejected
        bad = dict(like, a=jax.ShapeDtypeStruct((4, 3), jnp.float32))
        with pytest.raises(ValueError):
            restore_checkpoint(td, 7, bad)


def test_data_pipeline_determinism_and_structure():
    cfg = DataConfig(vocab=1000, seq_len=64, batch_per_host=4, v_eff=256,
                     noise_k=8)
    b1 = make_batch(cfg, step=5)
    b2 = make_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # the bigram structure bounds the label entropy: given prev token,
    # next is one of noise_k values
    nxt = (31 * b1["tokens"].astype(np.int64) + 7) % 256
    gap = (b1["labels"] - nxt) % 256
    assert gap.max() < cfg.noise_k


def test_hlo_analyzer_exact_on_nested_scans():
    from repro.parallel import analyze_compiled

    def inner(c, w):
        return c @ w, None

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, None

    def nested(x, ws):
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    costs = analyze_compiled(jax.jit(nested).lower(x, ws).compile())
    true_flops = 2 * 15 * 64 ** 3
    assert abs(costs.flops - true_flops) / true_flops < 1e-6
    assert not costs.unknown_trips
    # bytes must reflect per-iteration slab reads, not LxW overcounts
    assert costs.bytes < 30 * ws.size * 4


def test_ef_int8_quantization_properties():
    from repro.parallel.collectives import _quantize_int8
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, scale = _quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(x - deq))) <= float(scale) * 0.5 + 1e-6
    # error feedback keeps the time-averaged signal unbiased
    err = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    for _ in range(50):
        xe = x + err
        q, scale = _quantize_int8(xe)
        deq = q.astype(jnp.float32) * scale
        err = xe - deq
        acc = acc + deq
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(x),
                               atol=float(scale))


@pytest.mark.skipif(not hasattr(jax, "set_mesh"),
                    reason="activation sharding needs jax.set_mesh (newer JAX)")
def test_moe_shardmap_matches_ref_on_4_devices():
    """The expert-parallel shard_map dispatch (separate process: needs
    xla_force_host_platform_device_count, which must NOT leak into this
    test process)."""
    import subprocess
    import sys
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
import repro.models.moe as M
from repro.parallel.act_sharding import use_activation_sharding
mesh = jax.make_mesh((2, 2), ('data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
spec = M.MoESpec(d_model=32, n_experts=8, top_k=2, d_ff_expert=64,
                 n_shared=1, capacity_factor=8.0)
p = M.init_moe(jax.random.PRNGKey(0), spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
y_ref = M.moe_ref(p, x, spec)
with use_activation_sharding(mesh, sp=False):
    y = jax.jit(lambda p, x: M.apply_moe(p, x, spec))(p, x)
    g = jax.jit(jax.grad(lambda p, x: M.apply_moe(p, x, spec).sum()))(p, x)
np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)
g2 = jax.grad(lambda p, x: M._apply_moe_local(p, x, spec).sum())(p, x)
err = max(float(jnp.abs(a-b).max())
          for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g2)))
assert err < 1e-4, err
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_expert_placement_improves_locality_and_preserves_semantics():
    from repro.core.placement import (_cross_fraction, apply_placement,
                                      place_experts)
    from repro.models.moe import MoESpec, init_moe, moe_ref
    rng = np.random.default_rng(0)
    e, dev, t, k = 32, 4, 1500, 2
    hidden = rng.permutation(e).reshape(dev, e // dev)
    grp = rng.integers(0, dev, t)
    top = hidden[grp[:, None], rng.integers(0, e // dev, (t, k))]
    naive = np.arange(e) // (e // dev)
    pl = place_experts(top, e, dev, max_steps=80)
    assert pl.cross_coactivation < _cross_fraction(top, naive) - 0.3
    counts = np.bincount(pl.expert_to_device, minlength=dev)
    assert counts.max() == counts.min() == e // dev   # exact balance
    spec = MoESpec(d_model=8, n_experts=e, top_k=2, d_ff_expert=16)
    p = init_moe(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    np.testing.assert_allclose(
        np.asarray(moe_ref(p, x, spec)),
        np.asarray(moe_ref(apply_placement(p, pl), x, spec)),
        atol=1e-5, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(steps=st.integers(1, 30))
def test_lr_schedule_properties(steps):
    from repro.optim import schedule
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s = jnp.asarray(float(steps))
    lr = float(schedule(cfg, s))
    # f32 rounding at the warmup->cosine boundary can exceed lr by 1 ulp
    assert 0.0 <= lr <= cfg.lr * (1 + 1e-5)
    if steps < 10:   # warmup is monotone
        assert lr <= float(schedule(cfg, s + 1.0)) + 1e-12


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_clip_by_global_norm_property(seed):
    from repro.optim import clip_by_global_norm
    key = jax.random.PRNGKey(seed)
    g = {"a": jax.random.normal(key, (17,)) * 10,
         "b": jax.random.normal(jax.random.fold_in(key, 1), (3, 5))}
    clipped, norm = clip_by_global_norm(g, 1.0)
    new_norm = float(jnp.sqrt(sum(jnp.sum(x * x)
                                  for x in jax.tree.leaves(clipped))))
    assert new_norm <= 1.0 + 1e-5


@pytest.mark.skipif(not hasattr(jax, "set_mesh"),
                    reason="EP2D path needs jax.set_mesh (newer JAX)")
def test_moe_ep2d_matches_ref_on_8_devices():
    """Cross-pod EP (experts over pod x model) — §Perf C3 path."""
    import subprocess
    import sys
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
import repro.models.moe as M
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
spec = M.MoESpec(d_model=32, n_experts=8, top_k=2, d_ff_expert=64,
                 n_shared=1, capacity_factor=8.0)
p = M.init_moe(jax.random.PRNGKey(0), spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
y_ref = M.moe_ref(p, x, spec)
with jax.set_mesh(mesh):
    y = jax.jit(lambda p, x: M._apply_moe_ep2d(p, x, spec, mesh))(p, x)
    g = jax.jit(jax.grad(
        lambda p, x: M._apply_moe_ep2d(p, x, spec, mesh).sum()))(p, x)
np.testing.assert_allclose(y, y_ref, atol=1e-5, rtol=1e-5)
g2 = jax.grad(lambda p, x: M._apply_moe_local(p, x, spec).sum())(p, x)
err = max(float(jnp.abs(a-b).max())
          for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g2)))
assert err < 1e-4, err
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OK" in r.stdout, r.stderr[-2000:]
