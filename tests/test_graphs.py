"""Tests for the graph substrate: CSR build, eq.-(4) weights, generators,
Table-I stats, block padding."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs.blocking import block_edges
from repro.graphs.csr import build_graph, graph_stats
from repro.graphs.datasets import DATASETS, load_dataset
from repro.graphs.generators import dc_sbm, erdos_renyi, grid_road, ring_of_cliques, rmat


class TestBuildGraph:
    def test_simple_triangle(self):
        # edges: 0->1, 1->0 (reciprocal), 1->2 (one-way)
        g = build_graph(np.array([0, 1, 1]), np.array([1, 0, 2]), 3)
        assert g.n == 3 and g.m == 3
        np.testing.assert_array_equal(g.deg_out, [1, 2, 0])
        # symmetrized: (0,1) w2, (1,0) w2, (1,2) w1, (2,1) w1
        assert g.num_sym_edges == 4
        w_by_pair = {}
        for v in range(3):
            for i in range(g.adj_ptr[v], g.adj_ptr[v + 1]):
                w_by_pair[(v, int(g.adj_idx[i]))] = float(g.adj_w[i])
        assert w_by_pair == {(0, 1): 2.0, (1, 0): 2.0, (1, 2): 1.0, (2, 1): 1.0}

    def test_self_loops_and_dups_removed(self):
        g = build_graph(np.array([0, 0, 0, 1]), np.array([0, 1, 1, 1]), 2)
        assert g.m == 1  # only 0->1 survives
        np.testing.assert_array_equal(g.deg_out, [1, 0])

    def test_load_conservation(self):
        g = rmat(256, 2048, seed=0)
        assert int(g.deg_out.sum()) == g.m

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(4, 64), seed=st.integers(0, 1000))
    def test_property_symmetrized_adjacency_is_symmetric(self, n, seed):
        rng = np.random.default_rng(seed)
        m = 4 * n
        g = build_graph(rng.integers(0, n, m), rng.integers(0, n, m), n)
        pairs = set()
        for v in range(g.n):
            for i in range(g.adj_ptr[v], g.adj_ptr[v + 1]):
                pairs.add((v, int(g.adj_idx[i])))
        for (u, v) in pairs:
            assert (v, u) in pairs  # N(v) relation is symmetric


class TestGenerators:
    def test_rmat_right_skewed(self):
        s = graph_stats(rmat(2048, 16384, seed=0))
        assert s["skewness"] > 0

    def test_grid_left_skewed_sparse(self):
        s = graph_stats(grid_road(4096, seed=0))
        assert s["skewness"] < 0
        assert s["mean_deg"] < 6

    def test_dcsbm_skew_knob(self):
        flat = graph_stats(dc_sbm(2048, 16384, degree_exponent=0.0, seed=0))
        skew = graph_stats(dc_sbm(2048, 16384, degree_exponent=0.8, seed=0))
        assert abs(flat["skewness"]) < 0.4
        assert skew["skewness"] > flat["skewness"]

    def test_ring_of_cliques_structure(self):
        g = ring_of_cliques(4, 8)
        assert g.n == 32
        # each clique vertex has 7 intra out-edges; one ring edge per clique
        assert g.m == 4 * 8 * 7 + 4

    def test_erdos_density(self):
        g = erdos_renyi(512, 4096, seed=0)
        assert abs(g.m - 4096) / 4096 < 0.1


class TestDatasets:
    def test_all_datasets_load_small(self):
        for name in DATASETS:
            g = load_dataset(name, scale=0.0005)
            assert g.n > 0 and g.m > 0

    def test_skew_signs_match_table1(self):
        # Table I: USA negative; WIKI/UK/LJ/EN/OK/HLWD positive
        assert graph_stats(load_dataset("USA", scale=0.002))["skewness"] < 0
        assert graph_stats(load_dataset("WIKI", scale=0.002))["skewness"] > 0
        assert graph_stats(load_dataset("UK", scale=0.002))["skewness"] > 0


class TestBlocking:
    def test_roundtrip_all_edges_present(self):
        g = rmat(300, 2400, seed=1)
        be = block_edges(g, block_v=64)
        # sum of nonzero weights must equal total symmetrized weight
        assert np.isclose(be.edge_w.sum(), g.adj_w.sum())
        # every real edge appears exactly once with the right local row
        cnt = int((be.edge_w > 0).sum())
        assert cnt == g.num_sym_edges

    def test_rows_within_block(self):
        g = rmat(300, 2400, seed=1)
        be = block_edges(g, block_v=64)
        assert be.edge_row.max() < be.block_v
        assert be.edge_dst.max() < g.n

    def test_histogram_equivalence_flat_vs_blocked(self):
        """Blocked-layout histogram == flat scatter histogram."""
        import jax.numpy as jnp
        from repro.core.lp import edge_histogram_jnp

        g = dc_sbm(256, 2048, n_comm=8, seed=2)
        be = block_edges(g, block_v=64)
        k = 4
        rng = np.random.default_rng(0)
        labels = rng.integers(0, k, size=g.n).astype(np.int32)

        # flat
        src = np.repeat(np.arange(g.n), np.diff(g.adj_ptr).astype(np.int64))
        flat = np.asarray(edge_histogram_jnp(
            jnp.asarray(src), jnp.asarray(labels[g.adj_idx]),
            jnp.asarray(g.adj_w), g.n, k))

        # blocked
        labels_pad = np.zeros(be.n_pad, dtype=np.int32)
        labels_pad[: g.n] = labels
        out = np.zeros((be.n_pad, k), dtype=np.float32)
        for b in range(be.n_blocks):
            h = np.asarray(edge_histogram_jnp(
                jnp.asarray(be.edge_row[b]),
                jnp.asarray(labels_pad[be.edge_dst[b]]),
                jnp.asarray(be.edge_w[b]), be.block_v, k))
            out[b * be.block_v : (b + 1) * be.block_v] = h
        np.testing.assert_allclose(out[: g.n], flat, rtol=1e-5)
