"""Bit-identity regression against pre-refactor golden fixtures.

`tests/golden/*.npz` pin the fixed-seed revolver and spinner trajectories
(labels / loads / score after 6 supersteps) as computed by the pre-engine
implementations (PR 3 HEAD), for both execution schedules. The
schedule-agnostic engine must reproduce them bit-for-bit — this is the
refactor's non-negotiable gate, and it keeps holding for every future
change to `core/engine.py` or the rule modules.

The sequential check runs in-process (any device count); the sharded check
runs `golden_worker.py` in a subprocess pinned to 4 forced host devices
(2 blocks per shard) so the Jacobi machinery — all-gather, psum load-delta
merge, per-shard PRNG chains — is genuinely multi-shard.

Regenerating fixtures is a deliberate act (see golden_worker.py's docstring
for the commands); a mismatch here means the superstep semantics changed.
"""
import importlib.util
import os
import subprocess
import sys

import numpy as np
import pytest

_HERE = os.path.dirname(os.path.abspath(__file__))
_WORKER = os.path.join(_HERE, "golden_worker.py")
_FIXTURES = os.path.join(_HERE, "golden")


def _load_worker():
    spec = importlib.util.spec_from_file_location("golden_worker", _WORKER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_sequential_bit_identity():
    worker = _load_worker()
    got = worker.compute("sequential")
    want = np.load(os.path.join(_FIXTURES, "sequential.npz"))
    for key in ("revolver_labels", "revolver_loads",
                "spinner_labels", "spinner_loads"):
        np.testing.assert_array_equal(got[key], want[key], err_msg=key)
    for key in ("revolver_score", "spinner_score"):
        assert abs(float(got[key]) - float(want[key])) <= 1e-6, key


def test_sharded_bit_identity():
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(
        f"--xla_force_host_platform_device_count={_load_worker().SHARDED_DEVICES}")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.abspath(os.path.join(_HERE, os.pardir, "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, _WORKER, "--schedule", "sharded",
         "--check", os.path.join(_FIXTURES, "sharded4.npz")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
