"""Tests for the normalized-LP scoring (eqs. 10-12) and Spinner scoring (eq. 3)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lp import (
    edge_histogram_jnp,
    normalized_penalty,
    revolver_scores,
    spinner_scores,
    tau_term,
)


def _hist_oracle(rows, slots, vals, n_rows, k):
    h = np.zeros((n_rows, k), dtype=np.float64)
    for r, s, v in zip(rows, slots, vals):
        h[r, s] += v
    return h


class TestEdgeHistogram:
    def test_matches_oracle(self):
        rng = np.random.default_rng(0)
        E, n, k = 500, 32, 8
        rows = rng.integers(0, n, size=E)
        slots = rng.integers(0, k, size=E)
        vals = rng.uniform(0, 2, size=E).astype(np.float32)
        out = edge_histogram_jnp(jnp.asarray(rows), jnp.asarray(slots),
                                 jnp.asarray(vals), n, k)
        np.testing.assert_allclose(np.asarray(out), _hist_oracle(rows, slots, vals, n, k),
                                   rtol=1e-5)

    def test_padding_zero_vals_ignored(self):
        rows = jnp.array([0, 0, 1])
        slots = jnp.array([1, 1, 0])
        vals = jnp.array([1.0, 0.0, 2.0])
        out = edge_histogram_jnp(rows, slots, vals, 2, 2)
        np.testing.assert_allclose(np.asarray(out), [[0, 1], [2, 0]])


class TestNormalizedPenalty:
    def test_sums_to_one(self):
        loads = jnp.array([10.0, 20.0, 5.0, 1.0])
        pi = normalized_penalty(loads, capacity=30.0)
        np.testing.assert_allclose(float(jnp.sum(pi)), 1.0, rtol=1e-6)

    def test_less_loaded_gets_higher_penalty_score(self):
        loads = jnp.array([10.0, 20.0])
        pi = normalized_penalty(loads, capacity=30.0)
        assert float(pi[0]) > float(pi[1])

    def test_negative_shift_footnote(self):
        """Over-capacity partitions make (1 - b/C) negative; footnote 1 shifts."""
        loads = jnp.array([40.0, 10.0])  # first partition over capacity 30
        pi = normalized_penalty(loads, capacity=30.0)
        assert float(jnp.min(pi)) >= 0.0
        np.testing.assert_allclose(float(jnp.sum(pi)), 1.0, rtol=1e-6)

    def test_paper_capacity_mode_all_negative(self):
        """With C = eps|E|/k every term is negative; still a distribution."""
        loads = jnp.array([100.0, 120.0, 90.0])
        pi = normalized_penalty(loads, capacity=5.0)
        assert float(jnp.min(pi)) >= 0.0
        np.testing.assert_allclose(float(jnp.sum(pi)), 1.0, rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(k=st.integers(2, 64), seed=st.integers(0, 2**16),
           cap=st.floats(1.0, 1e4))
    def test_property_distribution(self, k, seed, cap):
        rng = np.random.default_rng(seed)
        loads = jnp.asarray(rng.uniform(0, 2 * cap, size=k).astype(np.float32))
        pi = np.asarray(normalized_penalty(loads, cap))
        assert np.all(pi >= 0)
        np.testing.assert_allclose(pi.sum(), 1.0, atol=1e-4)


class TestScores:
    def test_revolver_score_bounds(self):
        """tau in [0,1], pi in [0,1] => score in [0,1]."""
        rng = np.random.default_rng(1)
        n, k = 16, 4
        hist = rng.uniform(0, 3, size=(n, k)).astype(np.float32)
        wsum = hist.sum(-1) + 1e-6
        inv = (1.0 / wsum).astype(np.float32)
        loads = jnp.asarray(rng.uniform(0, 50, size=k).astype(np.float32))
        s = np.asarray(revolver_scores(jnp.asarray(hist), jnp.asarray(inv), loads, 40.0))
        assert np.all(s >= 0) and np.all(s <= 1.0 + 1e-5)

    def test_spinner_score_matches_eq3(self):
        hist = jnp.array([[2.0, 1.0]])
        inv = jnp.array([1.0 / 3.0])
        loads = jnp.array([30.0, 60.0])
        s = np.asarray(spinner_scores(hist, inv, loads, capacity=60.0))
        np.testing.assert_allclose(s, [[2 / 3 - 0.5, 1 / 3 - 1.0]], rtol=1e-5)

    def test_tau_prefers_majority_label(self):
        hist = jnp.array([[5.0, 1.0, 0.0]])
        inv = jnp.array([1.0 / 6.0])
        tau = np.asarray(tau_term(hist, inv))
        assert tau[0, 0] > tau[0, 1] > tau[0, 2]
        np.testing.assert_allclose(tau.sum(), 1.0, rtol=1e-5)
