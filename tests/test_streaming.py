"""Tests for the streaming subsystem: delta batching, incremental CSR /
DeviceGraph maintenance, warm-start state carry, and the end-to-end
streaming-vs-batch acceptance criterion."""
import jax
import numpy as np
import pytest

from repro.core import run_partitioner
from repro.core.device_graph import prepare_device_graph
from repro.core.metrics import partition_loads
from repro.core.revolver import RevolverConfig, revolver_init_from_labels
from repro.graphs.csr import build_graph
from repro.graphs.datasets import load_dataset
from repro.graphs.generators import dc_sbm, edge_split
from repro.streaming import (
    EdgeDelta,
    IncrementalDeviceGraph,
    IncrementalGraph,
    StreamBuffer,
    StreamConfig,
    StreamRunner,
    stream_from_graph,
)


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(512, 4096, n_comm=8, mixing=0.3, degree_exponent=0.5, seed=1)


class TestStreamBuffer:
    def test_emits_fixed_size_deltas(self):
        buf = StreamBuffer(delta_size=10)
        buf.push(np.arange(7), np.arange(7) + 1)
        assert buf.pop_delta() is None
        buf.push(np.arange(7), np.arange(7) + 2)
        d = buf.pop_delta()
        assert d is not None and d.n_add == 10
        assert buf.pop_delta() is None          # 4 left, below threshold
        tail = buf.flush()
        assert tail.n_add == 4
        assert buf.flush() is None

    def test_deletions_ride_along(self):
        buf = StreamBuffer(delta_size=4)
        buf.push(3, 4, delete=True)
        assert buf.pop_delta() is None          # deletions alone never emit
        buf.push(np.arange(4), np.arange(4) + 1)
        d = buf.pop_delta()
        assert d.n_add == 4 and d.n_del == 1
        assert int(d.del_src[0]) == 3 and int(d.del_dst[0]) == 4

    def test_validation(self):
        with pytest.raises(ValueError):
            StreamBuffer(delta_size=0)
        buf = StreamBuffer(delta_size=4)
        with pytest.raises(ValueError):
            buf.push(np.arange(3), np.arange(4))

    def test_delete_never_overtakes_insert(self):
        """Regression: insert(e) then delete(e) in the stream must leave e
        absent regardless of how the events land in deltas — a deletion may
        neither travel ahead into an earlier delta nor share a delta with
        an earlier insertion of the same edge (deltas apply dels first)."""
        buf = StreamBuffer(delta_size=4)
        buf.push(np.arange(5), np.arange(5) + 1)       # inserts e1..e5
        buf.push(4, 5, delete=True)                    # deletes e5 (still buffered)
        inc = IncrementalGraph(8)
        inc.apply(buf.pop_delta())                     # e1..e4, no deletion yet
        while (d := buf.flush()) is not None:
            info = inc.apply(d)
            assert info.missing_dropped == 0
        g = inc.to_graph()
        assert g.m == 4
        assert g.row_ptr[4] == g.row_ptr[5]            # vertex 4 has no out-edge

    def test_insert_delete_reinsert_stays_present(self):
        buf = StreamBuffer(delta_size=100)
        buf.push(0, 1)
        buf.push(0, 1, delete=True)
        buf.push(0, 1)
        inc = IncrementalGraph(4)
        while (d := buf.flush()) is not None:
            inc.apply(d)
        assert inc.m == 1

    def test_preserves_arrival_order(self):
        buf = StreamBuffer(delta_size=3)
        for i in range(5):
            buf.push(i, i + 1)
        d = buf.pop_delta()
        np.testing.assert_array_equal(d.add_src, [0, 1, 2])
        np.testing.assert_array_equal(buf.flush().add_src, [3, 4])


class TestStreamFromGraph:
    def test_covers_every_edge_exactly_once(self, sbm_graph):
        g = sbm_graph
        seen = []
        for d in stream_from_graph(g, 7, seed=3):
            assert d.n_del == 0
            seen.append(d.add_src.astype(np.int64) * g.n + d.add_dst)
        keys = np.concatenate(seen)
        assert keys.size == g.m
        assert np.unique(keys).size == g.m

    def test_orders(self, sbm_graph):
        n_arr = sum(d.n_add for d in stream_from_graph(sbm_graph, 4, order="arrival"))
        assert n_arr == sbm_graph.m
        with pytest.raises(ValueError):
            list(stream_from_graph(sbm_graph, 4, order="bogus"))


class TestIncrementalGraph:
    def test_insert_merge_equals_batch_build(self, sbm_graph):
        g = sbm_graph
        inc = IncrementalGraph(g.n)
        for d in stream_from_graph(g, 6, seed=2):
            inc.apply(d)
        g2 = inc.to_graph()
        np.testing.assert_array_equal(g2.row_ptr, g.row_ptr)
        np.testing.assert_array_equal(g2.col_idx, g.col_idx)
        np.testing.assert_array_equal(g2.adj_ptr, g.adj_ptr)
        np.testing.assert_array_equal(g2.adj_idx, g.adj_idx)
        np.testing.assert_array_equal(g2.adj_w, g.adj_w)
        np.testing.assert_array_equal(g2.deg_out, g.deg_out)

    def test_deletions_match_rebuilt_graph(self, sbm_graph):
        g = sbm_graph
        inc = IncrementalGraph(g.n)
        inc.apply(next(stream_from_graph(g, 1)))
        src, dst = edge_split(g)
        sel = np.random.default_rng(0).choice(g.m, 64, replace=False)
        empty = np.empty(0, np.int32)
        inc.apply(EdgeDelta(empty, empty, src[sel].astype(np.int32),
                            dst[sel].astype(np.int32)))
        keep = np.ones(g.m, bool)
        keep[sel] = False
        ref = build_graph(src[keep], dst[keep], g.n)
        g2 = inc.to_graph()
        assert g2.m == ref.m
        np.testing.assert_array_equal(g2.adj_idx, ref.adj_idx)
        np.testing.assert_array_equal(g2.adj_w, ref.adj_w)
        np.testing.assert_array_equal(g2.deg_out, ref.deg_out)

    def test_eq4_weight_transitions(self):
        """1 direction -> w=1; both -> w=2; back to 1 -> w=1; none -> gone."""
        inc = IncrementalGraph(4)
        empty = np.empty(0, np.int32)

        inc.apply(EdgeDelta.inserts(np.array([0]), np.array([1])))
        g = inc.to_graph()
        assert g.adj_w.tolist() == [1.0, 1.0]          # (0,1) and (1,0) slots

        inc.apply(EdgeDelta.inserts(np.array([1]), np.array([0])))
        g = inc.to_graph()
        assert g.adj_w.tolist() == [2.0, 2.0]

        inc.apply(EdgeDelta(empty, empty, np.array([0], np.int32),
                            np.array([1], np.int32)))
        g = inc.to_graph()
        assert g.m == 1 and g.adj_w.tolist() == [1.0, 1.0]

        inc.apply(EdgeDelta(empty, empty, np.array([1], np.int32),
                            np.array([0], np.int32)))
        g = inc.to_graph()
        assert g.m == 0 and g.num_sym_edges == 0

    def test_dup_and_missing_accounting(self):
        inc = IncrementalGraph(8)
        info = inc.apply(EdgeDelta.inserts(np.array([0, 0, 1, 2]),
                                           np.array([1, 1, 2, 2])))
        # one in-delta duplicate + one self loop dropped
        assert info.added == 2 and info.dup_dropped == 2
        info = inc.apply(EdgeDelta.inserts(np.array([0]), np.array([1])))
        assert info.added == 0 and info.dup_dropped == 1
        empty = np.empty(0, np.int32)
        info = inc.apply(EdgeDelta(empty, empty, np.array([5], np.int32),
                                   np.array([6], np.int32)))
        assert info.deleted == 0 and info.missing_dropped == 1

    def test_delete_then_readd_same_delta_survives(self):
        inc = IncrementalGraph(4)
        inc.apply(EdgeDelta.inserts(np.array([0]), np.array([1])))
        info = inc.apply(EdgeDelta(np.array([0], np.int32), np.array([1], np.int32),
                                   np.array([0], np.int32), np.array([1], np.int32)))
        assert info.deleted == 1 and info.added == 1
        assert inc.m == 1


class TestIncrementalDeviceGraph:
    def test_layout_stable_and_slabs_match_batch(self, sbm_graph):
        g = sbm_graph
        idg = IncrementalDeviceGraph(g.n, n_blocks=4)
        layouts = set()
        for d in stream_from_graph(g, 5, seed=1):
            dg, info = idg.apply(d)
            layouts.add((dg.n_pad, dg.block_v, dg.n_blocks))
        assert len(layouts) == 1                       # vertex layout never moves
        ref = prepare_device_graph(g, n_blocks=4)
        assert dg.n_pad == ref.n_pad and dg.block_v == ref.block_v
        # final slabs hold the same edge multiset per block as a cold build
        for b in range(dg.n_blocks):
            got = sorted(
                (int(r), int(c), float(w))
                for r, c, w in zip(np.asarray(dg.blk_row[b]),
                                   np.asarray(dg.blk_dst[b]),
                                   np.asarray(dg.blk_w[b]))
                if w > 0)
            want = sorted(
                (int(r), int(c), float(w))
                for r, c, w in zip(np.asarray(ref.blk_row[b]),
                                   np.asarray(ref.blk_dst[b]),
                                   np.asarray(ref.blk_w[b]))
                if w > 0)
            assert got == want

    def test_local_delta_dirties_few_blocks(self, sbm_graph):
        g = sbm_graph
        idg = IncrementalDeviceGraph(g.n, n_blocks=8, e_headroom=4.0)
        idg.apply(next(stream_from_graph(g, 1, seed=0)))
        # a delta touching only vertices 0..3 must not rewrite other blocks
        _, info = idg.apply(EdgeDelta.inserts(np.array([0, 1]), np.array([2, 3])))
        assert not info.repadded
        assert info.dirty_blocks == 1

    def test_overflow_triggers_repad(self):
        g0 = dc_sbm(256, 512, n_comm=4, seed=0)
        idg = IncrementalDeviceGraph(256, n_blocks=4, e_headroom=1.0)
        _, info0 = idg.apply(next(stream_from_graph(g0, 1, seed=0)))
        assert info0.repadded
        e0 = idg.e_max
        # dense burst into one block overflows its slab
        rng = np.random.default_rng(1)
        _, info1 = idg.apply(EdgeDelta.inserts(
            rng.integers(0, 64, 3000).astype(np.int32),
            rng.integers(0, 256, 3000).astype(np.int32)))
        assert info1.repadded and idg.e_max > e0


class TestWarmStartInit:
    def test_loads_invariant_and_label_carry(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4)
        labels = np.random.default_rng(0).integers(0, 4, sbm_graph.n).astype(np.int32)
        st = revolver_init_from_labels(dg, cfg, jax.random.PRNGKey(0), labels)
        np.testing.assert_array_equal(np.asarray(st.labels[: sbm_graph.n]), labels)
        expect = partition_loads(st.labels, dg.deg_out, 4)
        np.testing.assert_allclose(np.asarray(st.loads), np.asarray(expect), rtol=1e-5)

    def test_probs_carried_and_uniform_for_new(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4)
        labels = np.zeros(100, np.int32)     # only 100 surviving vertices
        probs = np.full((100, 4), 0.0, np.float32)
        probs[:, 2] = 1.0
        st = revolver_init_from_labels(dg, cfg, jax.random.PRNGKey(0), labels,
                                       probs=probs)
        flat = np.asarray(st.probs).reshape(dg.n_pad, 4)
        np.testing.assert_allclose(flat[:100, 2], 1.0)
        np.testing.assert_allclose(flat[100:], 0.25)

    def test_k_mismatch_rejected(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        with pytest.raises(ValueError):
            revolver_init_from_labels(dg, RevolverConfig(k=4),
                                      jax.random.PRNGKey(0),
                                      np.zeros(8, np.int32),
                                      probs=np.ones((8, 5), np.float32))


class TestStreamRunner:
    def test_reports_and_carry(self, sbm_graph):
        cfg = StreamConfig(k=4, n_blocks=4, refine_max_steps=6,
                           refine_patience=2, sync_every=2)
        runner = StreamRunner(sbm_graph.n, cfg, seed=0)
        reports = runner.run(stream_from_graph(sbm_graph, 4, seed=0))
        assert len(reports) == 4
        assert reports[-1].m == sbm_graph.m
        assert runner.labels.shape == (sbm_graph.n,)
        assert runner.probs.shape[-1] == 4
        for r in reports:
            assert 0.0 <= r.local_edges <= 1.0
            assert r.steps <= 6

    def test_restream_mode_runs(self, sbm_graph):
        cfg = StreamConfig(k=4, n_blocks=4, refine_max_steps=4,
                           refine_patience=2, restream=True,
                           restream_frac=0.2, restream_chunks=2,
                           restream_steps_per_chunk=1)
        runner = StreamRunner(sbm_graph.n, cfg, seed=0)
        reports = runner.run(stream_from_graph(sbm_graph, 3, seed=0))
        # replay passes only fire from the second delta on
        assert reports[0].steps <= 4
        assert reports[1].steps > reports[0].steps or reports[1].converged
        assert 0.0 <= reports[-1].local_edges <= 1.0

    def test_fused_kernel_knob_plumbs_through(self, sbm_graph):
        """hist_impl/la_impl flow through StreamRunner into the shared
        RevolverConfig: the fused pallas edge phase must reproduce the jnp
        refinement trajectory at fixed seed (same deltas, same stream)."""
        finals = {}
        for impl in ("jnp", "pallas"):
            cfg = StreamConfig(k=4, n_blocks=4, refine_max_steps=4,
                               refine_patience=2)
            runner = StreamRunner(sbm_graph.n, cfg, seed=0, hist_impl=impl)
            assert runner.rcfg.hist_impl == impl
            runner.run(stream_from_graph(sbm_graph, 2, seed=0))
            finals[impl] = runner.labels
        # bit-exact only where both paths accumulate f32 identically (CPU
        # interpret mode); see the parity tests in test_revolver.py
        if jax.default_backend() == "cpu":
            np.testing.assert_array_equal(finals["jnp"], finals["pallas"])

    def test_bad_impl_knob_rejected_at_construction(self, sbm_graph):
        with pytest.raises(ValueError, match="hist_impl"):
            StreamRunner(sbm_graph.n, StreamConfig(k=4), hist_impl="palas")

    def test_deletion_delta_keeps_partition_sane(self, sbm_graph):
        cfg = StreamConfig(k=4, n_blocks=4, refine_max_steps=4, refine_patience=2)
        runner = StreamRunner(sbm_graph.n, cfg, seed=0)
        runner.ingest(next(stream_from_graph(sbm_graph, 1, seed=0)))
        src, dst = edge_split(sbm_graph)
        sel = np.random.default_rng(3).choice(sbm_graph.m, 128, replace=False)
        empty = np.empty(0, np.int32)
        rep = runner.ingest(EdgeDelta(empty, empty, src[sel].astype(np.int32),
                                      dst[sel].astype(np.int32)))
        assert rep.deleted == 128
        assert rep.m == sbm_graph.m - 128
        assert 0.0 <= rep.local_edges <= 1.0


class TestStreamingEndToEnd:
    def test_quality_within_10pct_at_half_the_steps(self):
        """ISSUE 1 acceptance: >= 4 deltas, warm-start refinement, final
        local-edges within 10% of the one-shot batch run, total supersteps
        < 50% of the batch steps-to-convergence (seed 0, scale=0.002, k=8)."""
        g = load_dataset("WIKI", scale=0.002, seed=0)
        batch = run_partitioner("revolver", g, 8, seed=0, track_history=False)

        cfg = StreamConfig(k=8, refine_max_steps=15, refine_patience=3,
                           sync_every=2, warm_sharpen=0.5)
        runner = StreamRunner(g.n, cfg, seed=0)
        reports = runner.run(stream_from_graph(g, 5, seed=0))

        assert len(reports) >= 4
        assert reports[-1].m == g.m
        total = runner.total_steps
        assert reports[-1].local_edges >= 0.9 * batch.local_edges, (
            f"stream le {reports[-1].local_edges:.4f} vs batch {batch.local_edges:.4f}")
        assert total < 0.5 * batch.steps, (
            f"stream used {total} supersteps vs batch {batch.steps}")
