"""Shared test configuration.

Registers a deterministic stub under the `hypothesis` module name when the
real library is not installed (the pinned test image ships without it and
the suite must not depend on network installs). The real hypothesis, when
present, always takes precedence.
"""
import importlib.util
import pathlib
import sys


def _ensure_hypothesis() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass
    stub_path = pathlib.Path(__file__).with_name("_hypothesis_stub.py")
    spec = importlib.util.spec_from_file_location("hypothesis", stub_path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    sys.modules["hypothesis"] = module
    sys.modules["hypothesis.strategies"] = module.strategies


_ensure_hypothesis()
