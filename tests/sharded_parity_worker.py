"""Subprocess worker for tests/test_sharded.py (NOT a pytest module).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
parent test sets it; device count must be pinned before the backend
initializes, which is why this is a subprocess) and prints one JSON document
with:

  * ``jacobi_parity`` — the 8-shard shard_map superstep vs a single-device
    pure-jnp emulation of the same Jacobi schedule (per-shard scans from the
    start-of-superstep state, fold_in(key, shard) chains, delta-summed
    loads). With one block per shard this is the fully-synchronous corner of
    the schedule; labels/probs must match bit-exactly over several
    supersteps, scores to float tolerance (psum association).
  * ``halo_parity`` — ``chunk_schedule="halo"`` vs ``"sharded"`` at 8
    shards on WIKI/LJ/USA (contiguous + locality assignments, coverage
    fallback disabled, block and per-vertex granularities): the halo
    exchange — boundary block slabs, or per-vertex need lists moving labels
    on the int8 wire — must reproduce the full-gather trajectory
    bit-for-bit on labels/loads/probs.
  * ``async_parity`` — ``chunk_schedule="async"`` at ``staleness_bound=0``
    (refresh every superstep) vs ``"halo"`` at 8 shards on WIKI/LJ/USA, on
    the *same* interior-first layout: the two-phase scan overlaps the
    exchange with the interior blocks but consumes the identical
    start-of-superstep tail, so labels/loads/probs must match bit-for-bit.
  * ``quality`` — sharded-vs-sequential local-edges ratio on WIKI and LJ at
    k=8 after a fixed step budget (the Jacobi merge's quality cost).
  * ``hub_quality`` — 8-shard hub replication vs the full-gather reference:
    hubs change the trajectory (frozen scan + vote reconcile), so the gate
    is a quality ratio + balance bound rather than bit-identity.
"""
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_graph import (
    capacity_device,
    prepare_device_graph,
    prepare_sharded_device_graph,
)
from repro.core.engine import ChunkContext
from repro.core.revolver import (
    REVOLVER,
    RevolverConfig,
    RevolverState,
    place_revolver_state,
    revolver_init,
    revolver_superstep,
)
from repro.core.runner import run_partitioner
from repro.graphs import load_dataset
from repro.launch.mesh import make_blocks_mesh


def jacobi_reference_superstep(dg, cfg, state, n_shards):
    """Single-device emulation of the engine's sharded chunk schedule: every
    shard drives the revolver chunk rule over its blocks against the
    start-of-superstep labels/lam/loads, then label slices are concatenated,
    the per-shard load deltas (loads_end - loads_start) summed, and shard
    0's key chain carried forward — exactly what `engine._chunk_superstep`
    does under shard_map, written out by hand."""
    nb, bv = dg.n_blocks, dg.block_v
    bps = nb // n_shards
    local_n = bps * bv
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    deg_b = dg.deg_out.reshape(nb, bv)
    inv_b = dg.inv_wsum.reshape(nb, bv)
    msk_b = dg.vmask.reshape(nb, bv)

    labels_out, lam_out, probs_out = [], [], []
    delta_sum = jnp.zeros_like(state.loads)
    score_sum = jnp.zeros((), jnp.float32)
    key_new = None
    for s in range(n_shards):
        key_s = state.key if s == 0 else jax.random.fold_in(state.key, s)
        vert = {"labels": state.labels, "lam": state.lam}
        loads = state.loads
        probs_s = []
        for b in range(s * bps, (s + 1) * bps):
            ctx = ChunkContext(
                blk_idx=jnp.int32(b), v0=jnp.int32(b * bv),
                gv0=jnp.int32(b * bv),
                e_dst=dg.blk_dst[b], e_row=dg.blk_row[b], e_w=dg.blk_w[b],
                deg=deg_b[b], inv_wsum=inv_b[b], vmask=msk_b[b],
                step=state.step, n_shards=n_shards, loads0=state.loads,
                repl={})
            upd = REVOLVER.chunk_rule(cfg, ctx, vert, {"probs": state.probs[b]},
                                      loads, cap, key_s)
            vert = {f: jax.lax.dynamic_update_slice(vert[f], upd.vert[f],
                                                    (ctx.v0,))
                    for f in vert}
            loads, key_s = upd.loads, upd.key
            score_sum = score_sum + upd.score
            probs_s.append(upd.block["probs"])
        v = slice(s * local_n, (s + 1) * local_n)
        labels_out.append(vert["labels"][v])
        lam_out.append(vert["lam"][v])
        probs_out.append(jnp.stack(probs_s))
        delta_sum = delta_sum + (loads - state.loads)
        if s == 0:
            key_new = key_s
    return RevolverState(
        labels=jnp.concatenate(labels_out),
        lam=jnp.concatenate(lam_out),
        probs=jnp.concatenate(probs_out, axis=0),
        loads=state.loads + delta_sum,
        key=key_new,
        step=state.step + 1,
        score=score_sum / dg.n,
    )


def jacobi_parity(n_shards: int, n_blocks: int, steps: int = 5) -> dict:
    from repro.graphs.generators import dc_sbm

    g = dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5, seed=3)
    mesh = make_blocks_mesh(n_shards)
    sdg = prepare_sharded_device_graph(g, mesh, n_blocks=n_blocks)
    dg = prepare_device_graph(g, n_blocks=n_blocks)
    assert sdg.n_blocks == dg.n_blocks == n_blocks
    cfg = RevolverConfig(k=8, chunk_schedule="sharded")
    cfg_ref = RevolverConfig(k=8)   # reference runs the emulation by hand

    key = jax.random.PRNGKey(0)
    st_sh = place_revolver_state(revolver_init(sdg, cfg, key), sdg)
    st_ref = revolver_init(dg, cfg_ref, key)
    for _ in range(steps):
        st_sh = revolver_superstep(sdg, cfg, st_sh)
        st_ref = jacobi_reference_superstep(dg, cfg_ref, st_ref, n_shards)
    lab_sh, lab_ref = np.asarray(st_sh.labels), np.asarray(st_ref.labels)
    probs_sh, probs_ref = np.asarray(st_sh.probs), np.asarray(st_ref.probs)
    return {
        "n_shards": n_shards,
        "blocks_per_shard": n_blocks // n_shards,
        "steps": steps,
        "labels_equal": bool((lab_sh == lab_ref).all()),
        "max_probs_diff": float(np.abs(probs_sh - probs_ref).max()),
        "score_diff": abs(float(st_sh.score) - float(st_ref.score)),
        "loads_equal": bool(
            (np.asarray(st_sh.loads) == np.asarray(st_ref.loads)).all()),
    }


def halo_parity(dataset: str, *, scale: float, n_shards: int = 8,
                n_blocks: int = 64, steps: int = 5, k: int = 8,
                assignment="contiguous", granularity="auto") -> dict:
    """chunk_schedule="halo" vs "sharded" on the same fixed assignment:
    the exchange (boundary blocks, or per-vertex need lists with the int8
    label wire) is an exact optimization of the full-gather sync, so
    labels/loads/probs must match bit-for-bit over the trajectory.
    threshold=2.0 disables the coverage fallback so the real halo path runs
    even on power-law graphs whose halo covers every block."""
    g = load_dataset(dataset, scale=scale, seed=0)
    mesh = make_blocks_mesh(n_shards)
    kwargs = dict(n_blocks=n_blocks, assignment=assignment)
    sdg = prepare_sharded_device_graph(g, mesh, **kwargs)
    sdg_halo = prepare_sharded_device_graph(g, mesh, halo=True,
                                            halo_threshold=2.0,
                                            halo_granularity=granularity,
                                            **kwargs)
    cfg_sh = RevolverConfig(k=k, chunk_schedule="sharded")
    cfg_halo = RevolverConfig(k=k, chunk_schedule="halo")
    key = jax.random.PRNGKey(0)
    st_sh = place_revolver_state(revolver_init(sdg, cfg_sh, key), sdg)
    st_halo = place_revolver_state(revolver_init(sdg_halo, cfg_halo, key),
                                   sdg_halo)
    for _ in range(steps):
        st_sh = revolver_superstep(sdg, cfg_sh, st_sh)
        st_halo = revolver_superstep(sdg_halo, cfg_halo, st_halo)
    spec = sdg_halo.halo
    return {
        "dataset": dataset, "n_shards": n_shards, "n_blocks": n_blocks,
        "steps": steps,
        "assignment": assignment if isinstance(assignment, str) else "explicit",
        "b_max": spec.b_max, "blocks_per_shard": spec.blocks_per_shard,
        "coverage": spec.coverage,
        "granularity": spec.granularity, "h_max": spec.h_max,
        "labels_equal": bool((np.asarray(st_sh.labels)
                              == np.asarray(st_halo.labels)).all()),
        "loads_equal": bool((np.asarray(st_sh.loads)
                             == np.asarray(st_halo.loads)).all()),
        "max_probs_diff": float(np.abs(np.asarray(st_sh.probs)
                                       - np.asarray(st_halo.probs)).max()),
        "score_diff": abs(float(st_sh.score) - float(st_halo.score)),
    }


def async_parity(dataset: str, *, scale: float, n_shards: int = 8,
                 n_blocks: int = 64, steps: int = 6, k: int = 8,
                 assignment="contiguous", granularity="auto") -> dict:
    """chunk_schedule="async" (staleness_bound=0: refresh every superstep)
    vs "halo" on the same interior-first layout: phase 1 scans the interior
    blocks against the shard's own slice while the exchange is in flight,
    phase 2 consumes the same start-of-superstep tail the halo schedule
    gathers — bit-identity on labels/loads/probs is the s=0 contract."""
    from repro.core import engine
    from repro.core.halo import interior_first_order

    g = load_dataset(dataset, scale=scale, seed=0)
    mesh = make_blocks_mesh(n_shards)
    kwargs = dict(n_blocks=n_blocks, halo=True, halo_threshold=2.0,
                  halo_granularity=granularity)
    sdg = prepare_sharded_device_graph(g, mesh, assignment=assignment,
                                       **kwargs)
    order = interior_first_order(sdg.halo)
    if order is not None:
        perm = (np.asarray(sdg.block_perm)[order]
                if sdg.block_perm is not None else order)
        sdg = prepare_sharded_device_graph(g, mesh, assignment=perm, **kwargs)
    cfg_h = RevolverConfig(k=k, chunk_schedule="halo")
    cfg_a = RevolverConfig(k=k, chunk_schedule="async")
    key = jax.random.PRNGKey(0)
    st_h = place_revolver_state(revolver_init(sdg, cfg_h, key), sdg)
    st_a = place_revolver_state(revolver_init(sdg, cfg_a, key), sdg)
    for _ in range(steps):
        st_h = revolver_superstep(sdg, cfg_h, st_h)
        st_a, _ = engine.async_superstep(REVOLVER, sdg, cfg_a, st_a)
    spec = sdg.halo
    return {
        "dataset": dataset, "n_shards": n_shards, "n_blocks": n_blocks,
        "steps": steps, "granularity": spec.granularity,
        "assignment": assignment if isinstance(assignment, str) else "explicit",
        "fallback": spec.fallback,
        "interior_split": spec.interior_split,
        "interior_counts": list(spec.interior_counts),
        "labels_equal": bool((np.asarray(st_h.labels)
                              == np.asarray(st_a.labels)).all()),
        "loads_equal": bool((np.asarray(st_h.loads)
                             == np.asarray(st_a.loads)).all()),
        "max_probs_diff": float(np.abs(np.asarray(st_h.probs)
                                       - np.asarray(st_a.probs)).max()),
        "score_diff": abs(float(st_h.score) - float(st_a.score)),
    }


def quality(dataset: str, *, scale: float, steps: int, k: int = 8) -> dict:
    g = load_dataset(dataset, scale=scale, seed=0)
    mesh = make_blocks_mesh(8)
    common = dict(seed=0, max_steps=steps, patience=10_000, track_history=False)
    seq = run_partitioner("revolver", g, k, **common)
    sh = run_partitioner("revolver", g, k, mesh=mesh,
                         chunk_schedule="sharded", **common)
    return {
        "dataset": dataset, "n": g.n, "m": g.m, "steps": steps,
        "sequential_local_edges": seq.local_edges,
        "sharded_local_edges": sh.local_edges,
        "quality_ratio": sh.local_edges / max(seq.local_edges, 1e-9),
    }


def hub_quality(dataset: str, *, scale: float, steps: int, k: int = 8,
                n_blocks: int = 64, quantile: float = 0.95) -> dict:
    """8-shard hub mode vs the 8-shard full-gather reference: hub freezing
    + vote reconciliation change the trajectory (not an exact optimization
    like the hubs-off exchanges), so the gate is quality + balance, not
    bit-identity — documented in core/README.md."""
    g = load_dataset(dataset, scale=scale, seed=0)
    mesh = make_blocks_mesh(8)
    common = dict(seed=0, max_steps=steps, patience=10_000,
                  track_history=False, n_blocks=n_blocks, mesh=mesh)
    sh = run_partitioner("revolver", g, k, chunk_schedule="sharded", **common)
    hub = run_partitioner("revolver", g, k, chunk_schedule="halo",
                          halo_threshold=2.0, hub_replication=True,
                          hub_quantile=quantile, **common)
    return {
        "dataset": dataset, "n": g.n, "m": g.m, "steps": steps,
        "quantile": quantile,
        "sharded_local_edges": sh.local_edges,
        "hub_local_edges": hub.local_edges,
        "quality_ratio": hub.local_edges / max(sh.local_edges, 1e-9),
        "hub_max_norm_load": hub.max_norm_load,
    }


def main() -> int:
    assert jax.device_count() >= 8, (
        f"worker needs 8 host devices, has {jax.device_count()}")
    result = {
        "device_count": jax.device_count(),
        "jacobi_parity": [
            jacobi_parity(8, 8),    # one block per shard: pure Jacobi corner
            jacobi_parity(4, 8),    # two blocks per shard: async-within mix
        ],
        "halo_parity": [
            # the acceptance gate: halo == sharded bit-for-bit at 8 host
            # devices on WIKI/LJ, contiguous and locality assignments,
            # block and per-vertex (int8 label wire) granularities
            halo_parity("WIKI", scale=5e-4, granularity="block"),
            halo_parity("LJ", scale=3e-4, granularity="block"),
            halo_parity("USA", scale=5e-4, granularity="block"),
            halo_parity("WIKI", scale=5e-4, granularity="block",
                        assignment="locality"),
            halo_parity("WIKI", scale=5e-4, granularity="vertex"),
            halo_parity("LJ", scale=3e-4, granularity="vertex"),
            halo_parity("USA", scale=5e-4, granularity="vertex",
                        assignment="locality"),
        ],
        "async_parity": [
            # staleness_bound=0 bit-identity gate at 8 host devices on the
            # acceptance trio, both exchange granularities + locality
            async_parity("WIKI", scale=5e-4, granularity="vertex"),
            async_parity("LJ", scale=3e-4, granularity="vertex"),
            async_parity("USA", scale=5e-4, granularity="block"),
            async_parity("USA", scale=5e-4, granularity="vertex",
                         assignment="locality"),
        ],
        "quality": [
            quality("WIKI", scale=5e-4, steps=40),
            quality("LJ", scale=3e-4, steps=40),
        ],
        "hub_quality": [
            hub_quality("WIKI", scale=5e-4, steps=40),
        ],
    }
    print("SHARDED_PARITY_JSON:" + json.dumps(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
