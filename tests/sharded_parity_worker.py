"""Subprocess worker for tests/test_sharded.py (NOT a pytest module).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
parent test sets it; device count must be pinned before the backend
initializes, which is why this is a subprocess) and prints one JSON document
with:

  * ``jacobi_parity`` — the 8-shard shard_map superstep vs a single-device
    pure-jnp emulation of the same Jacobi schedule (per-shard scans from the
    start-of-superstep state, fold_in(key, shard) chains, delta-summed
    loads). With one block per shard this is the fully-synchronous corner of
    the schedule; labels/probs must match bit-exactly over several
    supersteps, scores to float tolerance (psum association).
  * ``quality`` — sharded-vs-sequential local-edges ratio on WIKI and LJ at
    k=8 after a fixed step budget (the Jacobi merge's quality cost).
"""
import json
import sys
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_graph import (
    capacity_device,
    prepare_device_graph,
    prepare_sharded_device_graph,
)
from repro.core.revolver import (
    RevolverConfig,
    RevolverState,
    _chunk_step,
    place_revolver_state,
    revolver_init,
    revolver_superstep,
)
from repro.core.runner import run_partitioner
from repro.graphs import load_dataset
from repro.launch.mesh import make_blocks_mesh


def jacobi_reference_superstep(dg, cfg, state, n_shards):
    """Single-device emulation of `_sharded_shard_body`'s schedule: every
    shard scans its blocks against the start-of-superstep labels/lam/loads,
    then label slices are concatenated, load deltas summed, and shard 0's
    key chain carried forward."""
    nb, bv = dg.n_blocks, dg.block_v
    bps = nb // n_shards
    local_n = bps * bv
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    deg_b = dg.deg_out.reshape(nb, bv)
    inv_b = dg.inv_wsum.reshape(nb, bv)
    msk_b = dg.vmask.reshape(nb, bv)
    step_fn = partial(_chunk_step, cfg, bv)

    labels_out, lam_out, probs_out = [], [], []
    delta_sum = jnp.zeros_like(state.loads)
    score_sum = jnp.zeros((), jnp.float32)
    key_new = None
    for s in range(n_shards):
        key_s = state.key if s == 0 else jax.random.fold_in(state.key, s)
        sl = slice(s * bps, (s + 1) * bps)
        xs = (
            jnp.arange(s * bps, (s + 1) * bps, dtype=jnp.int32),
            dg.blk_dst[sl], dg.blk_row[sl], dg.blk_w[sl],
            state.probs[sl], deg_b[sl], inv_b[sl], msk_b[sl],
        )
        carry = (state.labels, state.lam, state.loads,
                 jnp.zeros_like(state.loads), cap, key_s,
                 jnp.zeros((), jnp.float32))
        (lab_g, lam_g, _, delta, _, key_f, ssum), probs_s = \
            jax.lax.scan(step_fn, carry, xs)
        v = slice(s * local_n, (s + 1) * local_n)
        labels_out.append(lab_g[v])
        lam_out.append(lam_g[v])
        probs_out.append(probs_s)
        delta_sum = delta_sum + delta
        score_sum = score_sum + ssum
        if s == 0:
            key_new = key_f
    return RevolverState(
        labels=jnp.concatenate(labels_out),
        lam=jnp.concatenate(lam_out),
        probs=jnp.concatenate(probs_out, axis=0),
        loads=state.loads + delta_sum,
        key=key_new,
        step=state.step + 1,
        score=score_sum / dg.n,
    )


def jacobi_parity(n_shards: int, n_blocks: int, steps: int = 5) -> dict:
    from repro.graphs.generators import dc_sbm

    g = dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5, seed=3)
    mesh = make_blocks_mesh(n_shards)
    sdg = prepare_sharded_device_graph(g, mesh, n_blocks=n_blocks)
    dg = prepare_device_graph(g, n_blocks=n_blocks)
    assert sdg.n_blocks == dg.n_blocks == n_blocks
    cfg = RevolverConfig(k=8, chunk_schedule="sharded")
    cfg_ref = RevolverConfig(k=8)   # reference runs the emulation by hand

    key = jax.random.PRNGKey(0)
    st_sh = place_revolver_state(revolver_init(sdg, cfg, key), sdg)
    st_ref = revolver_init(dg, cfg_ref, key)
    for _ in range(steps):
        st_sh = revolver_superstep(sdg, cfg, st_sh)
        st_ref = jacobi_reference_superstep(dg, cfg_ref, st_ref, n_shards)
    lab_sh, lab_ref = np.asarray(st_sh.labels), np.asarray(st_ref.labels)
    probs_sh, probs_ref = np.asarray(st_sh.probs), np.asarray(st_ref.probs)
    return {
        "n_shards": n_shards,
        "blocks_per_shard": n_blocks // n_shards,
        "steps": steps,
        "labels_equal": bool((lab_sh == lab_ref).all()),
        "max_probs_diff": float(np.abs(probs_sh - probs_ref).max()),
        "score_diff": abs(float(st_sh.score) - float(st_ref.score)),
        "loads_equal": bool(
            (np.asarray(st_sh.loads) == np.asarray(st_ref.loads)).all()),
    }


def quality(dataset: str, *, scale: float, steps: int, k: int = 8) -> dict:
    g = load_dataset(dataset, scale=scale, seed=0)
    mesh = make_blocks_mesh(8)
    common = dict(seed=0, max_steps=steps, patience=10_000, track_history=False)
    seq = run_partitioner("revolver", g, k, **common)
    sh = run_partitioner("revolver", g, k, mesh=mesh,
                         chunk_schedule="sharded", **common)
    return {
        "dataset": dataset, "n": g.n, "m": g.m, "steps": steps,
        "sequential_local_edges": seq.local_edges,
        "sharded_local_edges": sh.local_edges,
        "quality_ratio": sh.local_edges / max(seq.local_edges, 1e-9),
    }


def main() -> int:
    assert jax.device_count() >= 8, (
        f"worker needs 8 host devices, has {jax.device_count()}")
    result = {
        "device_count": jax.device_count(),
        "jacobi_parity": [
            jacobi_parity(8, 8),    # one block per shard: pure Jacobi corner
            jacobi_parity(4, 8),    # two blocks per shard: async-within mix
        ],
        "quality": [
            quality("WIKI", scale=5e-4, steps=40),
            quality("LJ", scale=3e-4, steps=40),
        ],
    }
    print("SHARDED_PARITY_JSON:" + json.dumps(result))
    return 0


if __name__ == "__main__":
    sys.exit(main())
