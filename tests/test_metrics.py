"""Edge cases of the Section V-E quality metrics (`repro.core.metrics`).

These functions run inside the engine's windowed metric drain on padded,
possibly permuted label arrays, so the edge cases are not hypothetical:
padded vertices carry zero degree and arbitrary (zeroed) labels, early
supersteps can leave partitions empty, and synthetic smoke graphs can be
degenerate (no edges at all).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.metrics import (
    edge_cuts,
    local_edges,
    max_normalized_load,
    partition_loads,
)


def test_partition_loads_sums_to_total_degree():
    labels = np.array([0, 1, 1, 2, 0], dtype=np.int32)
    deg = np.array([3, 1, 4, 1, 5], dtype=np.int32)
    loads = np.asarray(partition_loads(labels, deg, 3))
    assert loads.tolist() == [8.0, 5.0, 1.0]
    assert loads.sum() == deg.sum()


def test_empty_partition_gets_zero_load():
    labels = np.array([0, 0, 2, 2], dtype=np.int32)
    deg = np.ones(4, dtype=np.int32)
    loads = np.asarray(partition_loads(labels, deg, 4))
    assert loads.tolist() == [2.0, 0.0, 2.0, 0.0]
    # balance metric still finite and reflects the imbalance: max load 2
    # over expected 4/4 = 1
    assert float(max_normalized_load(labels, deg, 4)) == pytest.approx(2.0)


def test_padded_vertices_do_not_count():
    """Padding rides the [n_pad] arrays with label 0 and degree 0 — it must
    not tilt partition 0's load or the balance metric."""
    labels = np.array([1, 2, 3], dtype=np.int32)
    deg = np.array([2, 2, 2], dtype=np.int32)
    base = np.asarray(partition_loads(labels, deg, 4))
    padded_labels = np.concatenate([labels, np.zeros(5, np.int32)])
    padded_deg = np.concatenate([deg, np.zeros(5, np.int32)])
    padded = np.asarray(partition_loads(padded_labels, padded_deg, 4))
    np.testing.assert_array_equal(base, padded)
    assert float(max_normalized_load(padded_labels, padded_deg, 4)) == \
        pytest.approx(float(max_normalized_load(labels, deg, 4)))


def test_k_larger_than_used_labels():
    """All vertices in one partition: max load == |E|, expected == |E|/k,
    so the metric saturates at exactly k."""
    labels = np.zeros(6, dtype=np.int32)
    deg = np.ones(6, dtype=np.int32)
    assert float(max_normalized_load(labels, deg, 8)) == pytest.approx(8.0)


def test_zero_total_degree_guard_returns_zero():
    """A graph with no edges has expected load 0; the epsilon guard must
    yield 0, not inf/nan."""
    labels = np.array([0, 1, 2], dtype=np.int32)
    deg = np.zeros(3, dtype=np.int32)
    val = float(max_normalized_load(labels, deg, 3))
    assert val == 0.0
    assert np.isfinite(val)


def test_local_edges_and_cuts_complement():
    labels = np.array([0, 0, 1, 1], dtype=np.int32)
    src = np.array([0, 0, 2, 1], dtype=np.int32)
    dst = np.array([1, 2, 3, 3], dtype=np.int32)
    le = float(local_edges(labels, src, dst))
    assert le == pytest.approx(0.5)   # 0-1 and 2-3 internal; 0-2, 1-3 cut
    assert float(edge_cuts(labels, src, dst)) == pytest.approx(1.0 - le)
