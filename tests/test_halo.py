"""Halo-exchange schedule + locality-aware shard assignment.

Three layers:

  * **plan-level** (pure numpy, no devices needed): `build_halo_spec` on
    crafted block layouts — empty halo, full halo, asymmetric reference
    patterns — plus the buffer-space slab rewrite checked against a
    simulated exchange, and `locality_block_order` determinism/recovery;
  * **schedule-level** (in-process, 1 shard): `chunk_schedule="halo"` must
    be bit-identical to `"sharded"` (and hence to `"sequential"`) for every
    registered rule, under both the contiguous and a permuted assignment;
  * **boundary conversions**: labels/probs cross `run_partitioner` /
    `StreamRunner` in original vertex order whatever the assignment.

The true multi-shard halo (8 forced host devices) is pinned by
`tests/sharded_parity_worker.py`, driven from `tests/test_sharded.py`.
"""
import numpy as np
import jax
import pytest

from repro.core.device_graph import (
    block_vertex_perms,
    permute_blocks,
    prepare_device_graph,
    prepare_sharded_device_graph,
    vertices_to_original,
)
from repro.core.halo import build_halo_spec
from repro.core.metrics import local_edges
from repro.core.registry import get_algorithm, superstep_algorithms
from repro.core.runner import run_partitioner
from repro.graphs.blocking import block_adjacency, locality_block_order
from repro.graphs.csr import build_graph
from repro.graphs.generators import dc_sbm, ring_of_cliques
from repro.launch.mesh import make_blocks_mesh


# --------------------------------------------------------------------------
# crafted block layouts (slab arrays built by hand; block_v = 4)
# --------------------------------------------------------------------------
BV = 4


def slabs(n_blocks, e_max, refs):
    """Build (blk_dst, blk_w) where block b references the blocks listed in
    refs[b] (one unit-weight edge each, row 0)."""
    dst = np.zeros((n_blocks, e_max), dtype=np.int32)
    w = np.zeros((n_blocks, e_max), dtype=np.float32)
    for b, targets in refs.items():
        for j, t in enumerate(targets):
            dst[b, j] = t * BV          # row 0 of the target block
            w[b, j] = 1.0
    return dst, w


class TestHaloSpec:
    def test_empty_halo(self):
        """Two shards whose slabs only reference their own blocks: nothing
        to exchange, zero-width boundary, never a fallback."""
        dst, w = slabs(4, 4, {0: [0, 1], 1: [0], 2: [3], 3: [2, 3]})
        spec = build_halo_spec(dst, w, 2, BV)
        assert spec.b_max == 0 and spec.coverage == 0.0
        assert not spec.fallback
        assert spec.halo_blocks == (0, 0) and spec.boundary_blocks == (0, 0)
        assert spec.gathered_elems_per_device() == 0
        # all-local rewrite: dst ids become shard-local offsets
        local = np.asarray(spec.blk_dst_halo)
        assert local[0, 0] == 0 * BV and local[0, 1] == 1 * BV
        assert local[2, 0] == 1 * BV      # block 3 is shard 1's local block 1

    def test_full_halo_falls_back(self):
        """Every block referencing every remote block: block coverage 1.0 —
        pinned to block granularity the exchange cannot beat the all-gather,
        so the plan falls back."""
        refs = {b: list(range(4)) for b in range(4)}
        dst, w = slabs(4, 4, refs)
        spec = build_halo_spec(dst, w, 2, BV, granularity="block")
        assert spec.b_max == 2 and spec.coverage == 1.0
        assert spec.fallback and spec.blk_dst_halo is None
        assert spec.gathered_elems_per_device() == \
            spec.full_gather_elems_per_device()

    def test_pervertex_rescues_full_block_halo(self):
        """The same all-blocks-referenced layout under "auto": only row 0 of
        each block is actually read, so the per-vertex plan moves 2 vertices
        where the block plan would move every slot — auto picks it and the
        fallback is avoided."""
        refs = {b: list(range(4)) for b in range(4)}
        dst, w = slabs(4, 4, refs)
        spec = build_halo_spec(dst, w, 2, BV)
        assert spec.granularity == "vertex" and not spec.fallback
        assert spec.h_max == 2
        assert spec.gathered_elems_per_device() == 2
        assert spec.coverage < 1.0

    def test_genuinely_dense_references_fall_back(self):
        """When every *vertex* of every remote block is read, neither
        granularity can beat the all-gather — the plan must fall back even
        under "auto"."""
        nb, S = 4, 2
        dst = np.tile(np.arange(nb * BV, dtype=np.int32), (nb, 1))
        w = np.ones((nb, nb * BV), dtype=np.float32)
        spec = build_halo_spec(dst, w, S, BV)
        assert spec.fallback and spec.blk_dst_halo is None
        assert spec.coverage >= 1.0

    def test_coverage_exactly_at_threshold_falls_back(self):
        """The fallback comparison is `coverage >= threshold`, not `>` — a
        plan that moves exactly the threshold fraction is not worth its
        complexity."""
        refs = {b: list(range(4)) for b in range(4)}
        dst, w = slabs(4, 4, refs)
        # per-vertex coverage is exactly 2/8 = 0.25 here
        spec = build_halo_spec(dst, w, 2, BV, threshold=0.25)
        assert spec.coverage == 0.25 and spec.fallback

    def test_asymmetric_references(self):
        """Shard 0 reads one of shard 1's blocks; shard 1 reads nothing
        remote — need/send sets are per-direction."""
        dst, w = slabs(4, 4, {0: [0, 2], 1: [1], 2: [2], 3: [3]})
        spec = build_halo_spec(dst, w, 2, BV, threshold=2.0,
                               granularity="block")
        assert spec.halo_blocks == (1, 0)       # shard 0 needs block 2
        assert spec.boundary_blocks == (0, 1)   # shard 1 sends block 2
        assert spec.b_max == 1 and not spec.fallback
        # block 2 sits at boundary position 0 of owner shard 1
        rows = np.asarray(spec.boundary_rows)
        assert rows[1, 0] == 0
        # shard 0's slab ref to block 2 -> buffer slot local_n + (1*b_max+0)*BV
        assert np.asarray(spec.blk_dst_halo)[0, 1] == spec.local_n + 1 * BV

    def test_rewrite_matches_simulated_exchange(self):
        """For every shard, gathering labels through the rewritten slab ids
        out of the assembled local+halo buffer must read the same values the
        full [n_pad] gather would."""
        rng = np.random.default_rng(0)
        nb, e_max, S = 8, 6, 4
        refs = {b: sorted(rng.choice(nb, size=3, replace=False).tolist())
                for b in range(nb)}
        dst, w = slabs(nb, e_max, refs)
        # also reference arbitrary rows, not just row 0
        dst[w > 0] += rng.integers(0, BV, size=int((w > 0).sum()))
        spec = build_halo_spec(dst, w, S, BV, threshold=2.0,
                               granularity="block")
        assert not spec.fallback
        bps = nb // S
        labels = rng.integers(0, 100, size=nb * BV)
        rows = np.asarray(spec.boundary_rows)
        halo_dst = np.asarray(spec.blk_dst_halo)
        gathered = np.stack([
            labels[(t * bps + rows[t])[:, None] * BV + np.arange(BV)]
            for t in range(S)
        ]) if spec.b_max else np.zeros((S, 0, BV), labels.dtype)
        for s in range(S):
            local = labels[s * spec.local_n:(s + 1) * spec.local_n]
            buf = np.concatenate([local, gathered.reshape(-1)])
            for b in range(s * bps, (s + 1) * bps):
                real = w[b] > 0
                np.testing.assert_array_equal(
                    buf[halo_dst[b][real]], labels[dst[b][real]])

    def test_b_max_floor_keeps_shape(self):
        dst, w = slabs(4, 4, {0: [0, 2], 1: [1], 2: [2], 3: [3]})
        spec = build_halo_spec(dst, w, 2, BV, threshold=2.0, b_max_floor=3)
        assert spec.b_max == 3
        assert np.asarray(spec.boundary_rows).shape == (2, 3)


class TestPerVertexSpec:
    def test_empty_boundary(self):
        """All-local references under forced vertex granularity: zero-width
        need lists, nothing exchanged, no fallback."""
        dst, w = slabs(4, 4, {0: [0, 1], 1: [0], 2: [3], 3: [2, 3]})
        spec = build_halo_spec(dst, w, 2, BV, granularity="vertex")
        assert spec.granularity == "vertex"
        assert spec.h_max == 0 and not spec.fallback
        assert spec.gathered_elems_per_device() == 0
        assert np.asarray(spec.send_ids).shape == (2, 2, 0)

    def test_h_max_floor_keeps_shape(self):
        dst, w = slabs(4, 4, {0: [0, 2], 1: [1], 2: [2], 3: [3]})
        spec = build_halo_spec(dst, w, 2, BV, threshold=2.0,
                               granularity="vertex", h_max_floor=5)
        assert spec.h_max == 5
        assert np.asarray(spec.send_ids).shape == (2, 2, 5)

    def test_rewrite_matches_simulated_all_to_all(self):
        """Assembling each shard's buffer the way the engine does — local
        slice, then the all-to-all tail laid out [t, h_max] — and reading
        through the rewritten slab ids must reproduce the full gather."""
        rng = np.random.default_rng(1)
        nb, e_max, S = 8, 6, 4
        refs = {b: sorted(rng.choice(nb, size=3, replace=False).tolist())
                for b in range(nb)}
        dst, w = slabs(nb, e_max, refs)
        dst[w > 0] += rng.integers(0, BV, size=int((w > 0).sum()))
        spec = build_halo_spec(dst, w, S, BV, threshold=2.0,
                               granularity="vertex")
        assert not spec.fallback and spec.h_max > 0
        bps = nb // S
        local_n = spec.local_n
        labels = rng.integers(0, 100, size=nb * BV)
        send = np.asarray(spec.send_ids)           # [S, S, h_max] local ids
        halo_dst = np.asarray(spec.blk_dst_halo)
        for s in range(S):
            # tail: for each owner t, the values of the vertices t sends to s
            tail = np.concatenate([
                labels[t * local_n + send[t, s]] for t in range(S)])
            buf = np.concatenate([labels[s * local_n:(s + 1) * local_n], tail])
            for b in range(s * bps, (s + 1) * bps):
                real = w[b] > 0
                np.testing.assert_array_equal(
                    buf[halo_dst[b][real]], labels[dst[b][real]])

    def test_auto_prefers_the_cheaper_granularity(self):
        """Sparse scattered references -> vertex; whole-block-dense
        references -> block (the tie also resolves to block)."""
        sparse, w1 = slabs(4, 4, {0: [2], 1: [3], 2: [0], 3: [1]})
        spec = build_halo_spec(sparse, w1, 2, BV, threshold=2.0)
        assert spec.granularity == "vertex"
        # every row of the remote block referenced: block exchange moves the
        # same elements with simpler indexing
        nb = 4
        dense = np.zeros((nb, BV), dtype=np.int32)
        wd = np.ones((nb, BV), dtype=np.float32)
        for b, t in ((0, 2), (1, 3), (2, 0), (3, 1)):
            dense[b] = t * BV + np.arange(BV)
        spec = build_halo_spec(dense, wd, 2, BV, threshold=2.0)
        assert spec.granularity == "block"


class TestHubSpec:
    def hub_layout(self):
        """Every block reads vertex 0 (shard 0, block 0, row 0) plus one
        local vertex — vertex 0 is the obvious hub."""
        nb = 4
        dst = np.zeros((nb, 2), dtype=np.int32)
        w = np.ones((nb, 2), dtype=np.float32)
        for b in range(nb):
            dst[b, 0] = 0             # the hub
            dst[b, 1] = b * BV + 1    # something local
        deg = np.zeros(nb * BV, dtype=np.float32)
        deg[0] = 100.0
        deg[1::BV] = 1.0
        vmask = np.ones(nb * BV, dtype=bool)
        blk_row = np.tile(np.array([0, 1], dtype=np.int32), (nb, 1))
        return dst, w, deg, vmask, blk_row

    def test_hub_absorbs_remote_references(self):
        from repro.core.halo import HubConfig

        dst, w, deg, vmask, blk_row = self.hub_layout()
        bare = build_halo_spec(dst, w, 2, BV, threshold=2.0,
                               granularity="vertex")
        assert bare.h_max == 1          # shard 1 needs vertex 0
        spec = build_halo_spec(dst, w, 2, BV, threshold=2.0,
                               granularity="vertex",
                               hubs=HubConfig(quantile=0.9),
                               deg=deg, vmask=vmask, blk_row=blk_row)
        assert 0 in np.asarray(spec.hub_ids)
        assert spec.h_max == 0          # the hub ref left the need lists
        # hub refs rewritten into the replicated region past the tail
        hub_base = spec.local_n + spec.exchange_len
        halo_dst = np.asarray(spec.blk_dst_halo)
        assert halo_dst[0, 0] == hub_base and halo_dst[2, 0] == hub_base

    def test_hub_needs_degree_arrays(self):
        from repro.core.halo import HubConfig

        dst, w, *_ = self.hub_layout()
        with pytest.raises(ValueError, match="deg"):
            build_halo_spec(dst, w, 2, BV, hubs=HubConfig(quantile=0.9))

    def test_hub_floors_carry(self):
        """hub_ids_floor pins earlier hubs; hub_pad_floor keeps the
        replicated-region shape when the set hasn't grown to it yet."""
        from repro.core.halo import HubConfig

        dst, w, deg, vmask, blk_row = self.hub_layout()
        spec = build_halo_spec(dst, w, 2, BV, threshold=2.0,
                               hubs=HubConfig(quantile=0.9),
                               deg=deg, vmask=vmask, blk_row=blk_row,
                               hub_ids_floor=(5,), hub_pad_floor=7)
        ids = np.asarray(spec.hub_ids)
        assert 5 in ids and 0 in ids
        assert spec.hub_pad == 7
        assert np.asarray(spec.hub_owner).shape == (7,)

    def test_quantile_selection_shard_count_independent(self):
        """The quantile rule reads only deg/vmask — the same graph split
        1-way and 2-way replicates the same hub set (what makes the 1-shard
        oracle comparable to the multi-shard run)."""
        from repro.core.halo import HubConfig

        dst, w, deg, vmask, blk_row = self.hub_layout()
        ids = []
        for S in (1, 2):
            spec = build_halo_spec(dst, w, S, BV, threshold=2.0,
                                   hubs=HubConfig(quantile=0.9),
                                   deg=deg, vmask=vmask, blk_row=blk_row)
            ids.append(tuple(int(h) for h in np.asarray(spec.hub_ids)))
        assert ids[0] == ids[1]


class TestLocalityAssignment:
    def scrambled_cliques(self):
        """32 cliques of 16 vertices on a ring, vertex ids permuted at block
        granularity with a stride so contiguous striping splits every
        neighborhood while a locality pass can fully recover it."""
        g = ring_of_cliques(32, 16)
        nb = 32
        scram = np.arange(nb).reshape(-1, 8).T.reshape(-1)  # stride-8 order
        o2s, _ = block_vertex_perms(scram, 16)
        src = np.repeat(np.arange(g.n, dtype=np.int64),
                        np.diff(g.row_ptr).astype(np.int64))
        return build_graph(o2s[src], o2s[g.col_idx], g.n)

    def test_deterministic(self):
        g = self.scrambled_cliques()
        dg = prepare_device_graph(g, n_blocks=32, block_multiple=16)
        adj = block_adjacency(np.asarray(dg.blk_dst), np.asarray(dg.blk_w),
                              dg.block_v)
        p1 = locality_block_order(adj, 8)
        p2 = locality_block_order(adj, 8)
        np.testing.assert_array_equal(p1, p2)
        np.testing.assert_array_equal(np.sort(p1), np.arange(dg.n_blocks))

    def test_recovers_scrambled_structure(self):
        """Locality assignment must beat contiguous striping on the
        scrambled layout: fewer boundary blocks, active (non-fallback)
        halo."""
        g = self.scrambled_cliques()
        mesh = make_blocks_mesh(1)
        contig = prepare_sharded_device_graph(
            g, mesh, n_blocks=32, block_multiple=16, halo=True)
        # measure the halo of both assignments for an 8-shard split without
        # needing 8 devices: plan-level only
        spec_c = build_halo_spec(np.asarray(contig.blk_dst),
                                 np.asarray(contig.blk_w), 8,
                                 contig.block_v, threshold=2.0)
        adj = block_adjacency(np.asarray(contig.blk_dst),
                              np.asarray(contig.blk_w), contig.block_v)
        perm = locality_block_order(adj, 8)
        assert not np.array_equal(perm, np.arange(32))
        loc = permute_blocks(contig.dg, perm)
        spec_l = build_halo_spec(np.asarray(loc.blk_dst),
                                 np.asarray(loc.blk_w), 8, loc.block_v,
                                 threshold=2.0)
        assert spec_l.b_max < spec_c.b_max
        assert spec_l.coverage < 0.75      # active halo at default threshold

    def test_never_worse_than_contiguous(self):
        """On a vertex order that is already locality-friendly (road
        lattice), the pass keeps the identity assignment."""
        from repro.graphs.generators import grid_road
        g = grid_road(4096, seed=0)
        dg = prepare_device_graph(g, n_blocks=32)
        adj = block_adjacency(np.asarray(dg.blk_dst), np.asarray(dg.blk_w),
                              dg.block_v)
        perm = locality_block_order(adj, 8)
        np.testing.assert_array_equal(perm, np.arange(dg.n_blocks))


class TestPermutedLayout:
    def test_permute_blocks_preserves_graph(self):
        """A permuted layout is the same graph under a vertex relabeling:
        any labeling scores the same local_edges through the remapped
        metric arrays."""
        g = dc_sbm(512, 4096, n_comm=8, mixing=0.3, seed=1)
        dg = prepare_device_graph(g, n_blocks=8)
        rng = np.random.default_rng(0)
        perm = rng.permutation(8)
        pdg = permute_blocks(dg, perm)
        o2s, s2o = block_vertex_perms(perm, dg.block_v)
        labels = rng.integers(0, 4, size=dg.n_pad).astype(np.int32)
        le = float(local_edges(jax.numpy.asarray(labels),
                               dg.dir_src, dg.dir_dst))
        le_p = float(local_edges(jax.numpy.asarray(labels[s2o]),
                                 pdg.dir_src, pdg.dir_dst))
        assert le == pytest.approx(le_p, abs=1e-7)
        # degree mass follows the blocks
        np.testing.assert_array_equal(
            np.asarray(pdg.deg_out)[o2s], np.asarray(dg.deg_out))


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5,
                  seed=3)


class TestHaloSchedule:
    """1-shard in-process checks; the 8-shard legs live in the parity
    worker (device count is pinned at backend init)."""

    @pytest.mark.parametrize("name", superstep_algorithms())
    def test_halo_bit_identical_to_sharded(self, sbm_graph, name):
        mesh = make_blocks_mesh(1)
        common = dict(seed=3, max_steps=4, patience=10_000,
                      track_history=False, n_blocks=8, mesh=mesh)
        r_sh = run_partitioner(name, sbm_graph, 4, chunk_schedule="sharded",
                               **common)
        r_halo = run_partitioner(name, sbm_graph, 4, chunk_schedule="halo",
                                 **common)
        np.testing.assert_array_equal(r_sh.labels, r_halo.labels)
        assert r_halo.local_edges == pytest.approx(r_sh.local_edges, abs=1e-7)

    def test_halo_with_permuted_assignment_bit_identical(self, sbm_graph):
        """For any fixed assignment, halo is an exact optimization of the
        full-gather sync: same trajectory bit-for-bit."""
        mesh = make_blocks_mesh(1)
        perm = np.arange(8)[::-1].copy()
        common = dict(seed=3, max_steps=4, patience=10_000,
                      track_history=False, n_blocks=8, mesh=mesh,
                      assignment=perm)
        r_sh = run_partitioner("revolver", sbm_graph, 4,
                               chunk_schedule="sharded", **common)
        r_halo = run_partitioner("revolver", sbm_graph, 4,
                                 chunk_schedule="halo", **common)
        np.testing.assert_array_equal(r_sh.labels, r_halo.labels)

    def test_permuted_labels_returned_in_original_order(self, sbm_graph):
        """The reported metric must match a host-side recompute from the
        returned labels on the *original* graph — the permutation cannot
        leak through the API."""
        g = sbm_graph
        r = run_partitioner("revolver", g, 4, seed=0, max_steps=4,
                            patience=10_000, track_history=False,
                            chunk_schedule="sharded", mesh=make_blocks_mesh(1),
                            assignment=np.arange(8)[::-1].copy())
        src = np.repeat(np.arange(g.n), np.diff(g.row_ptr).astype(np.int64))
        le = float((r.labels[src] == r.labels[g.col_idx]).mean())
        assert le == pytest.approx(r.local_edges, abs=1e-6)

    def test_warm_start_round_trip_under_permutation(self, sbm_graph):
        """Carried labels (and probs) are original-order on both sides of a
        permuted run: warm-starting from a permuted run's output preserves
        the assignment."""
        mesh = make_blocks_mesh(1)
        perm = np.roll(np.arange(8), 3)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8,
                                           assignment=perm)
        algo = get_algorithm("revolver")
        cfg = algo.config_cls(k=4, chunk_schedule="sharded")
        carried = (np.arange(sbm_graph.n) % 4).astype(np.int32)
        state = algo.init_from_labels(sdg, cfg, jax.random.PRNGKey(0), carried)
        back = np.asarray(
            vertices_to_original(sdg, state.labels)[: sbm_graph.n])
        np.testing.assert_array_equal(back, carried)

    def test_keep_probs_original_order(self, sbm_graph):
        """probs returned by a permuted run are original-order and chain
        into a warm restart losslessly (same check as labels: vertex v's
        automaton row is row v)."""
        mesh = make_blocks_mesh(1)
        perm = np.roll(np.arange(8), 2)
        common = dict(seed=0, max_steps=3, patience=10_000,
                      track_history=False, chunk_schedule="sharded",
                      mesh=mesh, n_blocks=8)
        r_id = run_partitioner("revolver", sbm_graph, 4, keep_probs=True,
                               **common)
        r_pm = run_partitioner("revolver", sbm_graph, 4, keep_probs=True,
                               assignment=perm, **common)
        # same graph, same seed, different layout -> different trajectories,
        # but both probs tensors must describe real vertices in rows [0, n)
        assert r_id.probs.shape == r_pm.probs.shape
        n = sbm_graph.n
        flat = r_pm.probs.reshape(-1, 4)
        assert np.all(np.abs(flat[:n].sum(axis=1) - 1.0) < 1e-5)

    def test_halo_errors_without_plan(self, sbm_graph):
        from repro.core import engine
        mesh = make_blocks_mesh(1)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        algo = get_algorithm("revolver")
        cfg = algo.config_cls(k=4, chunk_schedule="halo")
        st = algo.init(sdg, algo.config_cls(k=4), jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="halo"):
            engine.superstep(algo, sdg, cfg, st)

    def test_assignment_requires_sharded_schedule(self, sbm_graph):
        with pytest.raises(ValueError, match="assignment"):
            run_partitioner("revolver", sbm_graph, 4, assignment="locality")

    @pytest.mark.parametrize("granularity", ["block", "vertex"])
    def test_forced_granularity_bit_identical(self, sbm_graph, granularity):
        """Either exchange unit is an exact optimization of the full
        gather — same trajectory bit-for-bit (hubs off)."""
        mesh = make_blocks_mesh(1)
        common = dict(seed=3, max_steps=4, patience=10_000,
                      track_history=False, n_blocks=8, mesh=mesh)
        r_sh = run_partitioner("revolver", sbm_graph, 4,
                               chunk_schedule="sharded", **common)
        r_halo = run_partitioner("revolver", sbm_graph, 4,
                                 chunk_schedule="halo", halo_threshold=2.0,
                                 halo_granularity=granularity, **common)
        np.testing.assert_array_equal(r_sh.labels, r_halo.labels)

    def test_hub_oracle_one_shard_matches_sequential(self, sbm_graph):
        """The sequential hub schedule and the 1-shard mesh hub schedule run
        the same plan through different code paths (identity collectives vs
        shard_map psums) — they must agree bit-for-bit."""
        common = dict(seed=3, max_steps=4, patience=10_000,
                      track_history=False, n_blocks=8,
                      hub_replication=True, hub_quantile=0.9)
        r_seq = run_partitioner("revolver", sbm_graph, 4, **common)
        r_mesh = run_partitioner("revolver", sbm_graph, 4,
                                 chunk_schedule="halo", halo_threshold=2.0,
                                 mesh=make_blocks_mesh(1), **common)
        np.testing.assert_array_equal(r_seq.labels, r_mesh.labels)

    def test_hub_replication_engages(self, sbm_graph):
        """With hubs on, the frozen-scan + vote-reconcile trajectory differs
        from the plain sequential one (the machinery is not a no-op), and
        the result still covers every vertex with in-range labels."""
        common = dict(seed=3, max_steps=6, patience=10_000,
                      track_history=False, n_blocks=8)
        r_plain = run_partitioner("revolver", sbm_graph, 4, **common)
        r_hub = run_partitioner("revolver", sbm_graph, 4,
                                hub_replication=True, hub_quantile=0.9,
                                **common)
        assert not np.array_equal(r_plain.labels, r_hub.labels)
        assert r_hub.labels.shape == (sbm_graph.n,)
        assert ((r_hub.labels >= 0) & (r_hub.labels < 4)).all()

    def test_hub_rejects_sharded_schedule(self, sbm_graph):
        with pytest.raises(ValueError, match="hub_replication"):
            run_partitioner("revolver", sbm_graph, 4, hub_replication=True,
                            chunk_schedule="sharded",
                            mesh=make_blocks_mesh(1), max_steps=2)

    def test_hub_knobs_require_hub_replication(self, sbm_graph):
        with pytest.raises(ValueError, match="hub_quantile"):
            run_partitioner("revolver", sbm_graph, 4, hub_quantile=0.9,
                            max_steps=2)

    def test_assignment_rejected_on_prebuilt_layout(self, sbm_graph):
        """A placed layout's assignment is baked into its storage order —
        asking for a different one must raise, not silently run the
        pre-built layout (that would fake locality measurements)."""
        mesh = make_blocks_mesh(1)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        with pytest.raises(ValueError, match="pre-built"):
            run_partitioner("revolver", sbm_graph, 4, dg=sdg,
                            chunk_schedule="sharded", assignment="locality",
                            max_steps=2)


class TestStreamingHalo:
    def test_stream_halo_matches_sequential_one_shard(self, sbm_graph):
        from repro.streaming.runner import StreamConfig, StreamRunner
        from repro.streaming.stream import stream_from_graph

        cfg = StreamConfig(k=4, n_blocks=8, refine_max_steps=4,
                           refine_patience=10_000, sync_every=2)
        r_seq = StreamRunner(sbm_graph.n, cfg, seed=0)
        r_halo = StreamRunner(sbm_graph.n, cfg, seed=0,
                              chunk_schedule="halo",
                              mesh=make_blocks_mesh(1))
        for d_seq, d_halo in zip(stream_from_graph(sbm_graph, 3, seed=0),
                                 stream_from_graph(sbm_graph, 3, seed=0)):
            rep_seq = r_seq.ingest(d_seq)
            rep_halo = r_halo.ingest(d_halo)
            assert rep_halo.steps == rep_seq.steps
            assert rep_halo.local_edges == pytest.approx(
                rep_seq.local_edges, abs=1e-7)
        np.testing.assert_array_equal(r_seq.labels, r_halo.labels)

    def test_stream_permuted_assignment_carries_state(self, sbm_graph):
        """Dirty slabs keep landing on their owning shard under an explicit
        permutation, and quality tracks the unpermuted stream (same rule,
        different but equivalent layout)."""
        from repro.streaming.runner import StreamConfig, StreamRunner
        from repro.streaming.stream import stream_from_graph

        cfg = StreamConfig(k=4, n_blocks=8, refine_max_steps=4,
                           refine_patience=10_000, sync_every=2)
        perm = np.arange(8)[::-1].copy()
        r_ref = StreamRunner(sbm_graph.n, cfg, seed=0)
        r_perm = StreamRunner(sbm_graph.n, cfg, seed=0,
                              chunk_schedule="halo", mesh=make_blocks_mesh(1),
                              assignment=perm)
        last_ref = last_perm = None
        for d_ref, d_perm in zip(stream_from_graph(sbm_graph, 3, seed=0),
                                 stream_from_graph(sbm_graph, 3, seed=0)):
            last_ref = r_ref.ingest(d_ref)
            last_perm = r_perm.ingest(d_perm)
        assert last_perm.local_edges == pytest.approx(
            last_ref.local_edges, abs=0.08)
        assert r_perm.labels.shape == (sbm_graph.n,)

    def test_stream_locality_requires_mesh(self, sbm_graph):
        from repro.streaming.delta_graph import IncrementalDeviceGraph

        with pytest.raises(ValueError, match="mesh"):
            IncrementalDeviceGraph(64, assignment="locality")

    def test_stream_locality_decided_once(self, sbm_graph):
        """The locality decision runs exactly once (first non-empty merge)
        even when it settles on the identity assignment — later deltas must
        not re-litigate (and potentially flip) the layout."""
        from unittest import mock

        from repro.streaming import delta_graph as dg_mod
        from repro.streaming.stream import stream_from_graph

        idg = dg_mod.IncrementalDeviceGraph(
            sbm_graph.n, n_blocks=8, mesh=make_blocks_mesh(1),
            assignment="locality")
        with mock.patch.object(dg_mod, "locality_block_order",
                               wraps=dg_mod.locality_block_order) as spy:
            for delta in stream_from_graph(sbm_graph, 3, seed=0):
                idg.apply(delta)
        assert spy.call_count == 1

    def test_stream_floors_are_monotonic(self, sbm_graph):
        """b_max / h_max floors only ever grow across deltas (the jitted
        superstep's shapes must not shrink mid-stream)."""
        from repro.streaming.delta_graph import IncrementalDeviceGraph
        from repro.streaming.stream import stream_from_graph

        idg = IncrementalDeviceGraph(sbm_graph.n, n_blocks=8,
                                     mesh=make_blocks_mesh(1))
        prev_b = prev_h = 0
        for delta in stream_from_graph(sbm_graph, 4, seed=0):
            idg.apply(delta)
            sdg = idg.as_sharded(halo=True, halo_threshold=2.0,
                                 halo_granularity="vertex")
            assert sdg.halo.b_max >= prev_b
            assert sdg.halo.h_max >= prev_h
            assert sdg.halo.h_max == idg.h_max_floor
            prev_b, prev_h = sdg.halo.b_max, sdg.halo.h_max

    def test_stream_hub_set_grows_monotonically(self, sbm_graph):
        """Hub promotion on a delta only ever adds hubs: each delta's hub
        set contains the previous one, and hub_pad floors at its maximum."""
        from repro.core.halo import HubConfig
        from repro.streaming.delta_graph import IncrementalDeviceGraph
        from repro.streaming.stream import stream_from_graph

        idg = IncrementalDeviceGraph(sbm_graph.n, n_blocks=8,
                                     mesh=make_blocks_mesh(1))
        hubs = HubConfig(quantile=0.95)
        prev_ids = set()
        prev_pad = 0
        for delta in stream_from_graph(sbm_graph, 4, seed=0):
            idg.apply(delta)
            sdg = idg.as_sharded(halo=True, halo_threshold=2.0, hubs=hubs)
            ids = set(int(h) for h in np.asarray(sdg.halo.hub_ids))
            assert prev_ids <= ids          # promotion only, no demotion
            assert sdg.halo.hub_pad >= prev_pad
            prev_ids, prev_pad = ids, sdg.halo.hub_pad
        assert prev_ids                      # something was promoted

    def test_streaming_permuted_layout_matches_static(self, sbm_graph):
        """The incremental permuted layout and `permute_blocks` implement
        the same rewrite field-for-field: streaming a whole graph as one
        delta under an explicit permutation must reproduce the statically
        permuted layout (up to slab padding width)."""
        from repro.core.device_graph import shard_device_graph
        from repro.streaming.delta_graph import IncrementalDeviceGraph
        from repro.streaming.stream import stream_from_graph

        g = sbm_graph
        perm = np.roll(np.arange(8), 3)
        mesh = make_blocks_mesh(1)
        idg = IncrementalDeviceGraph(g.n, n_blocks=8, mesh=mesh,
                                     assignment=perm)
        (delta,) = stream_from_graph(g, 1, seed=0)
        dg_stream, _ = idg.apply(delta)
        dg_static = shard_device_graph(
            prepare_device_graph(g, n_blocks=8), mesh, assignment=perm).dg
        assert dg_stream.block_v == dg_static.block_v
        assert dg_stream.n_blocks == dg_static.n_blocks
        for field in ("deg_out", "inv_wsum", "vmask", "edge_src", "edge_dst",
                      "dir_src", "dir_dst"):
            np.testing.assert_array_equal(
                np.asarray(getattr(dg_stream, field)),
                np.asarray(getattr(dg_static, field)), err_msg=field)
        # slab e_max differs (streaming pads with headroom): compare the
        # real (dst, row, w) triples per storage row
        for b in range(dg_static.n_blocks):
            def triples(dg_, blk):
                d = np.asarray(dg_.blk_dst[blk])
                r = np.asarray(dg_.blk_row[blk])
                w = np.asarray(dg_.blk_w[blk])
                m = w > 0
                return sorted(zip(d[m], r[m], w[m]))
            assert triples(dg_stream, b) == triples(dg_static, b), b
