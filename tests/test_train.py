"""Training-substrate tests: microbatching equivalence, launch CLIs."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, make_batch
from repro.models.config import ModelConfig
from repro.optim import OptConfig
from repro.train import init_train_state, make_train_step

_CFG = ModelConfig(
    name="mb-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv=2, d_ff=128, vocab=128, impl="naive", param_dtype="float32",
    compute_dtype="float32", remat=False, logits_chunk=16)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_microbatch_accumulation_matches_full_batch():
    """grad-accumulated step (microbatch=4) == single-shot step."""
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    data = DataConfig(vocab=128, seq_len=32, batch_per_host=8, v_eff=64)
    batch = {k: jnp.asarray(v) for k, v in make_batch(data, 0).items()}

    s1 = init_train_state(_CFG, opt, jax.random.PRNGKey(0))
    s4 = jax.tree.map(jnp.copy, s1)
    step1 = jax.jit(make_train_step(_CFG, opt, microbatch=1))
    step4 = jax.jit(make_train_step(_CFG, opt, microbatch=4))
    s1, m1 = step1(s1, batch)
    s4, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def _run_cli(args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    return subprocess.run([sys.executable, "-m"] + args, cwd=_ROOT, env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_partition_cli():
    r = _run_cli(["repro.launch.partition", "--dataset", "SO",
                  "--scale", "0.0005", "--k", "4", "--algo", "revolver",
                  "--algo", "hash", "--max-steps", "20", "--json"])
    assert r.returncode == 0, r.stderr[-1500:]
    assert "revolver" in r.stdout and "local_edges" in r.stdout


def test_train_cli_reduced_and_resume():
    with tempfile.TemporaryDirectory() as td:
        r = _run_cli(["repro.launch.train", "--arch", "tinyllama-1.1b",
                      "--reduced", "--steps", "4", "--batch", "2",
                      "--seq", "32", "--ckpt-dir", td,
                      "--inject-failure-at", "2"])
        assert r.returncode == 42, (r.returncode, r.stderr[-1500:])
        r2 = _run_cli(["repro.launch.train", "--arch", "tinyllama-1.1b",
                       "--reduced", "--steps", "4", "--batch", "2",
                       "--seq", "32", "--ckpt-dir", td])
        assert r2.returncode == 0, r2.stderr[-1500:]
        assert "done:" in r2.stdout


def test_serve_cli_reduced():
    r = _run_cli(["repro.launch.serve", "--arch", "whisper-base",
                  "--reduced", "--batch", "2", "--prompt-len", "8",
                  "--max-new", "4"])
    assert r.returncode == 0, r.stderr[-1500:]
    assert "tok/s" in r.stdout
