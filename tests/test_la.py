"""Unit + property tests for the learning-automata update rules (eqs. 6-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.la import (
    classic_la_update,
    split_weights_and_signals,
    weighted_la_update,
)


def _random_simplex(rng, shape):
    x = rng.uniform(0.1, 1.0, size=shape)
    return x / x.sum(axis=-1, keepdims=True)


class TestClassicLA:
    def test_reward_increases_chosen(self):
        p = jnp.array([[0.25, 0.25, 0.25, 0.25]])
        out = classic_la_update(p, jnp.array([1]), jnp.array([0]), 0.1, 0.1)
        assert out[0, 1] > 0.25
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-6)

    def test_penalty_decreases_chosen(self):
        p = jnp.array([[0.25, 0.25, 0.25, 0.25]])
        out = classic_la_update(p, jnp.array([1]), jnp.array([1]), 0.1, 0.1)
        assert out[0, 1] < 0.25
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-6)

    def test_simplex_preserved_exactly(self):
        rng = np.random.default_rng(0)
        p = jnp.asarray(_random_simplex(rng, (32, 8)).astype(np.float32))
        a = jnp.asarray(rng.integers(0, 8, size=32))
        r = jnp.asarray(rng.integers(0, 2, size=32))
        out = classic_la_update(p, a, r, 0.3, 0.15)
        np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 1.0, rtol=1e-5)


def _split_oracle(w_row):
    """Pure-python oracle for the mean-split normalization."""
    m = len(w_row)
    mean = sum(w_row) / m
    r = [1.0 if wi <= mean else 0.0 for wi in w_row]
    rew = sum(wi for wi, ri in zip(w_row, r) if ri == 0)
    pen = sum(wi for wi, ri in zip(w_row, r) if ri == 1)
    out = []
    for wi, ri in zip(w_row, r):
        if ri == 0:
            out.append(wi / rew if rew > 0 else 0.0)
        else:
            out.append(wi / pen if pen > 0 else 0.0)
    return out, r


class TestSplitWeights:
    def test_halves_sum_to_one(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.uniform(0, 5, size=(64, 16)).astype(np.float32))
        wn, r = split_weights_and_signals(w)
        rew_sum = np.asarray(jnp.sum(wn * (1 - r), -1))
        pen_sum = np.asarray(jnp.sum(wn * r, -1))
        np.testing.assert_allclose(rew_sum, 1.0, atol=1e-5)
        np.testing.assert_allclose(pen_sum, 1.0, atol=1e-5)
        # so sum(W) == 2 as the paper requires
        np.testing.assert_allclose(np.asarray(jnp.sum(wn, -1)), 2.0, atol=1e-5)

    def test_matches_oracle(self):
        rng = np.random.default_rng(2)
        w = rng.uniform(0, 3, size=(8,)).astype(np.float32)
        wn, r = split_weights_and_signals(jnp.asarray(w[None]))
        expect_w, expect_r = _split_oracle(list(w))
        np.testing.assert_allclose(np.asarray(wn[0]), expect_w, atol=1e-5)
        np.testing.assert_allclose(np.asarray(r[0]), expect_r)

    def test_all_zero_row_is_silent(self):
        wn, r = split_weights_and_signals(jnp.zeros((1, 8)))
        np.testing.assert_allclose(np.asarray(wn), 0.0)
        # zero-signal weights => weighted_la_update must be a no-op
        p = jnp.full((1, 8), 1.0 / 8)
        out = weighted_la_update(p, wn, r, 1.0, 0.1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(p), atol=1e-6)


class TestWeightedLA:
    def test_single_reward_slot_converges(self):
        """Consistent reinforcement of one slot must drive its prob -> ~1."""
        k = 8
        p = jnp.full((1, k), 1.0 / k)
        w = jnp.zeros((1, k)).at[0, 3].set(1.0)
        r = jnp.ones((1, k)).at[0, 3].set(0.0)
        for _ in range(30):
            p = weighted_la_update(p, w, r, 1.0, 0.1)
        assert float(p[0, 3]) > 0.95

    def test_ascending_order_cannot_become_decisive(self):
        """DESIGN.md §10.6 ablation: the literal ascending pass order caps
        max(p) well below 1 when penalty slots carry weight."""
        k = 8
        p = jnp.full((1, k), 1.0 / k)
        # reward slot 0; weighted penalty slots 5,6 run AFTER it in
        # ascending order, crushing the rewarded probability every step
        w = jnp.zeros((1, k)).at[0, 0].set(1.0)
        w = w.at[0, 5].set(0.5).at[0, 6].set(0.5)
        r = jnp.ones((1, k)).at[0, 0].set(0.0)
        p_asc = p
        for _ in range(60):
            p_asc = weighted_la_update(p_asc, w, r, 1.0, 0.1, pass_order="ascending")
        p_pf = p
        for _ in range(60):
            p_pf = weighted_la_update(p_pf, w, r, 1.0, 0.1, pass_order="penalty_first")
        # the reward pass runs LAST under penalty_first -> decisive
        assert float(p_pf[0, 0]) > 0.9
        assert float(p_asc[0, 0]) < 0.9

    def test_simplex_after_renorm(self):
        rng = np.random.default_rng(3)
        p = jnp.asarray(_random_simplex(rng, (128, 16)).astype(np.float32))
        w_raw = jnp.asarray(rng.uniform(0, 4, size=(128, 16)).astype(np.float32))
        wn, r = split_weights_and_signals(w_raw)
        out = weighted_la_update(p, wn, r, 1.0, 0.1, renorm=True)
        np.testing.assert_allclose(np.asarray(jnp.sum(out, -1)), 1.0, atol=1e-5)
        assert float(jnp.min(out)) >= 0.0

    def test_simplex_drift_without_renorm_is_small(self):
        """The paper claims eqs. (8)/(9) preserve sum(p)=1; measure the drift."""
        rng = np.random.default_rng(4)
        p = jnp.asarray(_random_simplex(rng, (256, 8)).astype(np.float32))
        w_raw = jnp.asarray(rng.uniform(0, 4, size=(256, 8)).astype(np.float32))
        wn, r = split_weights_and_signals(w_raw)
        out = weighted_la_update(p, wn, r, 0.1, 0.1, renorm=False)
        drift = np.abs(np.asarray(jnp.sum(out, -1)) - 1.0)
        assert drift.max() < 0.2  # bounded, but not exact -> renorm needed

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=2, max_value=32),
        rows=st.integers(min_value=1, max_value=16),
        seed=st.integers(min_value=0, max_value=2**16),
        alpha=st.floats(min_value=0.01, max_value=1.0),
        beta=st.floats(min_value=0.01, max_value=1.0),
    )
    def test_property_valid_distribution(self, m, rows, seed, alpha, beta):
        """For any inputs, the renormalized update is a valid distribution."""
        rng = np.random.default_rng(seed)
        p = jnp.asarray(_random_simplex(rng, (rows, m)).astype(np.float32))
        w_raw = jnp.asarray(rng.uniform(0, 4, size=(rows, m)).astype(np.float32))
        wn, r = split_weights_and_signals(w_raw)
        out = np.asarray(weighted_la_update(p, wn, r, alpha, beta, renorm=True))
        assert np.all(out >= 0)
        assert np.all(out <= 1.0 + 1e-6)
        np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
