"""Registry round-trip: every registered algorithm must run through the
shared engine — a few supersteps on a tiny graph, under both execution
schedules, from both cold and warm init — plus registry lookup/extension
semantics. This is the contract a new rule module buys into: pass this
sweep and `run_partitioner` / the streaming runner / the benches all work.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.core.device_graph import (
    prepare_device_graph,
    prepare_sharded_device_graph,
)
from repro.core.metrics import partition_loads
from repro.core import registry as registry_module
from repro.core.registry import (
    StaticAlgorithm,
    available_algorithms,
    get_algorithm,
    register,
    superstep_algorithms,
)
from repro.core.runner import run_partitioner
from repro.graphs.generators import ring_of_cliques
from repro.launch.mesh import make_blocks_mesh

K = 4
STEPS = 3


@pytest.fixture(scope="module")
def graph():
    return ring_of_cliques(8, 12)


class TestLookup:
    def test_builtins_registered(self):
        assert {"revolver", "spinner", "restream", "hash",
                "range"} <= set(available_algorithms())
        assert set(superstep_algorithms()) == {"revolver", "spinner",
                                               "restream"}

    def test_unknown_name_lists_available(self):
        with pytest.raises(ValueError, match="restream"):
            get_algorithm("metis")

    def test_static_entries_are_static(self):
        assert isinstance(get_algorithm("hash"), StaticAlgorithm)
        assert isinstance(get_algorithm("range"), StaticAlgorithm)


class TestRoundTrip:
    """Every engine algorithm x {sequential, sharded, halo} x {cold, warm}."""

    @pytest.mark.parametrize("name", superstep_algorithms())
    @pytest.mark.parametrize("schedule", ["sequential", "sharded", "halo"])
    @pytest.mark.parametrize("warm", [False, True])
    def test_supersteps_preserve_invariants(self, graph, name, schedule, warm):
        algo = get_algorithm(name)
        cfg = algo.config_cls(k=K, chunk_schedule=schedule)
        if schedule in ("sharded", "halo"):
            dg = prepare_sharded_device_graph(graph, make_blocks_mesh(1),
                                              n_blocks=4,
                                              halo=schedule == "halo")
        else:
            dg = prepare_device_graph(graph, n_blocks=4)
        key = jax.random.PRNGKey(0)
        if warm:
            carried = np.arange(graph.n, dtype=np.int32) % K
            state = algo.init_from_labels(dg, cfg, key, carried)
            # surviving vertices keep their carried assignment
            np.testing.assert_array_equal(
                np.asarray(state.labels[: graph.n]), carried)
        else:
            state = algo.init(dg, cfg, key)
        if schedule in ("sharded", "halo"):
            state = engine.place_state(algo, state, dg)
        for step in range(STEPS):
            state = engine.superstep(algo, dg, cfg, state)
            lab = np.asarray(state.labels)
            assert lab.min() >= 0 and lab.max() < K
            # the engine's load accounting must stay exact under both
            # schedules (psum-delta merge == recomputed b(l))
            expect = partition_loads(state.labels, dg.deg_out, K)
            np.testing.assert_array_equal(np.asarray(state.loads),
                                          np.asarray(expect))
        assert int(state.step) == STEPS
        assert np.isfinite(float(state.score))

    @pytest.mark.parametrize("name", superstep_algorithms())
    def test_run_partitioner_by_name(self, graph, name):
        r = run_partitioner(name, graph, K, max_steps=STEPS, patience=10_000,
                            track_history=True)
        assert r.steps == STEPS
        assert 0.0 <= r.local_edges <= 1.0
        assert len(r.history["score"]) == STEPS

    @pytest.mark.parametrize("name", ["hash", "range"])
    def test_run_partitioner_static_by_name(self, graph, name):
        r = run_partitioner(name, graph, K)
        assert r.steps == 0 and r.converged
        assert r.labels.shape == (graph.n,)

    def test_static_rejects_superstep_kwargs(self, graph):
        with pytest.raises(TypeError, match="no supersteps"):
            run_partitioner("hash", graph, K, chunk_schedule="sharded")
        with pytest.raises(TypeError, match="no supersteps"):
            run_partitioner("range", graph, K, epsilon=0.1)


class TestRestreamRule:
    """The third partitioner exercises the engine paths revolver/spinner
    don't: a chunk rule with no block tensors and a replicated state field."""

    def test_degree_priority_gates_early_steps(self, graph):
        """With a long ramp, the first superstep may only move the top
        degree quantile; the frozen tail keeps its initial labels."""
        dg = prepare_device_graph(graph, n_blocks=4)
        algo = get_algorithm("restream")
        cfg = algo.config_cls(k=K, priority_ramp=1000)
        state = algo.init(dg, cfg, jax.random.PRNGKey(0))
        before = np.asarray(state.labels)
        rank = np.asarray(state.rank)
        state = engine.superstep(algo, dg, cfg, state)
        after = np.asarray(state.labels)
        locked = rank < 1.0 - 1.0 / 1000
        np.testing.assert_array_equal(before[locked], after[locked])

    def test_ramp_one_is_unprioritized(self, graph):
        r = run_partitioner("restream", graph, K, max_steps=10,
                            patience=10_000, priority_ramp=1,
                            track_history=False)
        assert 0.0 <= r.local_edges <= 1.0

    def test_beats_hash_on_cliques(self, graph):
        rh = run_partitioner("hash", graph, K)
        rr = run_partitioner("restream", graph, K, max_steps=60, seed=0,
                             track_history=False)
        assert rr.local_edges > rh.local_edges + 0.1

    def test_config_validation(self):
        algo = get_algorithm("restream")
        with pytest.raises(ValueError, match="priority_ramp"):
            algo.config_cls(k=4, priority_ramp=0)
        with pytest.raises(ValueError, match="chunk_schedule"):
            algo.config_cls(k=4, chunk_schedule="bsp")

    def test_streaming_runner_accepts_restream(self, graph):
        from repro.streaming.runner import StreamConfig, StreamRunner
        from repro.streaming.stream import stream_from_graph

        cfg = StreamConfig(k=K, n_blocks=4, refine_max_steps=4,
                           refine_patience=10_000)
        runner = StreamRunner(graph.n, cfg, algo="restream", seed=0)
        reports = runner.run(stream_from_graph(graph, 2, seed=0))
        assert len(reports) == 2
        assert all(0.0 <= rep.local_edges <= 1.0 for rep in reports)
        # restream carries no LA state between deltas
        assert runner.probs is None

    def test_streaming_replay_needs_probs(self, graph):
        from repro.streaming.runner import StreamConfig, StreamRunner

        cfg = StreamConfig(k=K, restream=True)
        with pytest.raises(ValueError, match="probs|probabilities"):
            StreamRunner(graph.n, cfg, algo="spinner")


class TestExtension:
    def test_register_out_of_tree_algorithm(self, graph):
        """A rule module's whole integration surface: register an Algorithm
        and it is immediately runnable by name with schedules, warm starts,
        and the convergence loop inherited from the engine."""
        spinner = get_algorithm("spinner")

        @dataclasses.dataclass(frozen=True)
        class LazyConfig:
            k: int
            epsilon: float = 0.05
            max_steps: int = 10
            patience: int = 5
            theta: float = 0.001
            capacity_mode: str = "spinner"
            chunk_schedule: str = "sequential"

        def lazy_rule(cfg, ctx, local, loads, cap, key):
            # never migrates; scores zero — the minimal legal shard rule
            return engine.ShardUpdate(
                vert={"labels": local["labels"]},
                loads_delta=jnp.zeros_like(loads),
                key=key,
                score=jnp.zeros((), jnp.float32),
            )

        algo = register(engine.Algorithm(
            name="_test_lazy",
            config_cls=LazyConfig,
            state_cls=spinner.state_cls,
            kind="shard",
            init=spinner.init,
            shard_rule=lazy_rule,
        ))
        try:
            assert get_algorithm("_test_lazy") is algo
            r = run_partitioner("_test_lazy", graph, K, max_steps=3,
                                patience=10_000, track_history=False)
            assert r.steps == 3
        finally:
            # the registry is process-global; leaking the entry would break
            # exact-set assertions in tests that run after this one
            registry_module._REGISTRY.pop("_test_lazy", None)

    def test_algorithm_declaration_validated(self):
        spinner = get_algorithm("spinner")
        with pytest.raises(ValueError, match="kind"):
            engine.Algorithm(name="x", config_cls=spinner.config_cls,
                             state_cls=spinner.state_cls, kind="bsp",
                             init=spinner.init, shard_rule=lambda *a: None)
        with pytest.raises(ValueError, match="rule"):
            engine.Algorithm(name="x", config_cls=spinner.config_cls,
                             state_cls=spinner.state_cls, kind="shard",
                             init=spinner.init,
                             chunk_rule=lambda *a: None)
