"""Per-kernel shape/dtype sweeps against the ref.py oracles
(interpret=True on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.la import split_weights_and_signals
from repro.kernels import ops, ref
# test-oracle import only: the production superstep dispatches the fused
# edge phase; the single-histogram kernel has no ops.py wrapper anymore
from repro.kernels.edge_histogram import edge_histogram_pallas


# --------------------------------------------------------------------------
# edge_histogram
# --------------------------------------------------------------------------
@pytest.mark.parametrize("nb,e_max,block_v,k,chunk", [
    (1, 256, 64, 8, 256),
    (3, 512, 128, 16, 256),
    (2, 1024, 256, 32, 512),
])
def test_edge_histogram_sweep(nb, e_max, block_v, k, chunk):
    rng = np.random.default_rng(nb * 1000 + k)
    slots = rng.integers(0, k, (nb, e_max)).astype(np.int32)
    rows = rng.integers(0, block_v, (nb, e_max)).astype(np.int32)
    vals = rng.uniform(0, 2, (nb, e_max)).astype(np.float32)
    vals[:, e_max // 2:] *= (rng.random((nb, e_max - e_max // 2)) > 0.3)
    out = edge_histogram_pallas(jnp.asarray(slots), jnp.asarray(rows),
                                jnp.asarray(vals), block_v=block_v, k=k,
                                edge_chunk=chunk)
    want = ref.edge_histogram_ref(slots, rows, vals, block_v=block_v, k=k)
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# fused_edge_phase (dual-histogram edge phase; both weight_modes, padded
# slabs, k not a multiple of 128)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("weight_mode", ["self_lambda", "neighbor_lambda"])
@pytest.mark.parametrize("nb,e_max,block_v,k,chunk", [
    (1, 256, 64, 8, 256),
    (3, 512, 128, 10, 256),     # k=10: not a lane multiple
    (2, 1024, 256, 32, 512),
    (2, 768, 32, 5, 256),       # odd k, short rows
])
def test_fused_edge_phase_sweep(nb, e_max, block_v, k, chunk, weight_mode):
    rng = np.random.default_rng(nb * 1000 + k)
    n_pad = nb * block_v
    dst = rng.integers(0, n_pad, (nb, e_max)).astype(np.int32)
    rows = rng.integers(0, block_v, (nb, e_max)).astype(np.int32)
    vals = rng.uniform(0.1, 2, (nb, e_max)).astype(np.float32)
    # padded tail: ~40% of the back half are padding slots (val 0, row/dst 0)
    pad = rng.random((nb, e_max)) > 0.6
    pad[:, : e_max // 2] = False
    vals[pad] = 0.0
    dst[pad] = 0
    rows[pad] = 0
    labels = rng.integers(0, k, n_pad).astype(np.int32)
    lam = rng.integers(0, k, n_pad).astype(np.int32)
    actions = rng.integers(0, k, (nb, block_v)).astype(np.int32)
    feasible = (rng.random((nb, k)) > 0.3).astype(np.float32)

    hist, wacc = ops.fused_edge_phase(
        jnp.asarray(dst), jnp.asarray(rows), jnp.asarray(vals),
        jnp.asarray(labels), jnp.asarray(lam), jnp.asarray(actions),
        jnp.asarray(feasible), block_v=block_v, k=k,
        weight_mode=weight_mode, edge_chunk=chunk)
    hist_want, wacc_want = ref.fused_edge_phase_ref(
        dst, rows, vals, labels, lam, actions, feasible,
        block_v=block_v, k=k, weight_mode=weight_mode)
    np.testing.assert_allclose(np.asarray(hist), hist_want,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(wacc), wacc_want,
                               atol=1e-4, rtol=1e-4)


def test_fused_edge_phase_score_hist_matches_edge_histogram():
    """The fused kernel's first output == the standalone histogram kernel
    fed the externally gathered neighbor labels (the two-call path)."""
    rng = np.random.default_rng(7)
    nb, e_max, block_v, k = 2, 512, 64, 8
    n_pad = nb * block_v
    dst = rng.integers(0, n_pad, (nb, e_max)).astype(np.int32)
    rows = rng.integers(0, block_v, (nb, e_max)).astype(np.int32)
    vals = (rng.uniform(0.1, 2, (nb, e_max))
            * (rng.random((nb, e_max)) > 0.3)).astype(np.float32)
    labels = rng.integers(0, k, n_pad).astype(np.int32)
    lam = rng.integers(0, k, n_pad).astype(np.int32)
    actions = rng.integers(0, k, (nb, block_v)).astype(np.int32)
    feasible = np.ones((nb, k), np.float32)

    hist, _ = ops.fused_edge_phase(
        jnp.asarray(dst), jnp.asarray(rows), jnp.asarray(vals),
        jnp.asarray(labels), jnp.asarray(lam), jnp.asarray(actions),
        jnp.asarray(feasible), block_v=block_v, k=k)
    want = edge_histogram_pallas(
        jnp.asarray(labels)[jnp.asarray(dst)], jnp.asarray(rows),
        jnp.asarray(vals), block_v=block_v, k=k)
    np.testing.assert_allclose(np.asarray(hist), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_fused_edge_phase_rejects_bad_mode():
    z2 = jnp.zeros((1, 256), jnp.int32)
    zf = jnp.zeros((1, 256), jnp.float32)
    zl = jnp.zeros((64,), jnp.int32)
    with pytest.raises(ValueError, match="weight_mode"):
        ops.fused_edge_phase(z2, z2, zf, zl, zl,
                             jnp.zeros((1, 64), jnp.int32),
                             jnp.zeros((1, 4), jnp.float32),
                             block_v=64, k=4, weight_mode="bogus")


# --------------------------------------------------------------------------
# la_update
# --------------------------------------------------------------------------
@pytest.mark.parametrize("v,k,alpha,beta", [
    (16, 4, 1.0, 0.1),
    (300, 8, 0.5, 0.05),
    (64, 32, 1.0, 0.1),
])
def test_la_update_sweep(v, k, alpha, beta):
    key = jax.random.PRNGKey(v + k)
    p = jax.random.dirichlet(key, jnp.ones(k), (v,))
    w_raw = jax.random.uniform(jax.random.fold_in(key, 1), (v, k))
    w, r = split_weights_and_signals(w_raw)
    out = ops.la_update(p, w, r, alpha, beta, renorm=True)
    want = ref.la_update_ref(np.asarray(p), np.asarray(w), np.asarray(r),
                             alpha=alpha, beta=beta, renorm=True)
    np.testing.assert_allclose(np.asarray(out), want, atol=5e-6, rtol=5e-5)


@settings(max_examples=20, deadline=None)
@given(v=st.integers(2, 40), k=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
def test_la_update_stays_on_simplex(v, k, seed):
    key = jax.random.PRNGKey(seed)
    p = jax.random.dirichlet(key, jnp.ones(k), (v,))
    w_raw = jax.random.uniform(jax.random.fold_in(key, 1), (v, k))
    w, r = split_weights_and_signals(w_raw)
    out = np.asarray(ops.la_update(p, w, r, 1.0, 0.1, renorm=True))
    assert (out >= 0).all()
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-5)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window,dtype", [
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 8, 1, 256, 256, 32, True, 64, jnp.float32),
    (2, 4, 4, 128, 256, 64, True, None, jnp.bfloat16),
    (1, 2, 2, 128, 128, 128, False, None, jnp.float32),
])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# --------------------------------------------------------------------------
# decode_attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,s,d,block_k", [
    (2, 8, 2, 512, 64, 128),
    (1, 4, 4, 1024, 32, 256),
    (3, 6, 2, 256, 128, 256),
])
def test_decode_attention_sweep(b, hq, hkv, s, d, block_k):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, hkv, s, d))
    vc = jax.random.normal(ks[2], (b, hkv, s, d))
    kv_len = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = ops.decode_attention(q, kc, vc, kv_len, block_k=block_k)
    want = ref.decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_lse_combine_matches_unsharded():
    """Seq-split shards + lse-combine == full-cache decode (the long_500k
    sharded-decode math, validated without a multi-device mesh)."""
    from repro.parallel.collectives import lse_combine_psum  # noqa: F401
    b, hq, hkv, s, d = 2, 4, 2, 512, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (b, hq, d))
    kc = jax.random.normal(ks[1], (b, hkv, s, d))
    vc = jax.random.normal(ks[2], (b, hkv, s, d))
    kv_len = jnp.array([s, s // 2], jnp.int32)
    want = ref.decode_attention_ref(q, kc, vc, kv_len)

    # two shards along seq; emulate the psum combine locally
    outs, ms, ls = [], [], []
    for sh in range(2):
        sl = slice(sh * s // 2, (sh + 1) * s // 2)
        len_loc = jnp.clip(kv_len - sh * s // 2, 0, s // 2)
        o, m, l = ops.decode_attention(q, kc[:, :, sl], vc[:, :, sl],
                                       len_loc, return_lse=True)
        outs.append(o.astype(jnp.float32)); ms.append(m); ls.append(l)
    m_g = jnp.maximum(ms[0], ms[1])
    scale = [jnp.exp(m - m_g) * l for m, l in zip(ms, ls)]
    denom = scale[0] + scale[1]
    got = (outs[0] * scale[0][..., None] + outs[1] * scale[1][..., None]) \
        / denom[..., None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# wkv6
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,n,block_s", [
    (2, 64, 2, 16, 32),
    (1, 128, 4, 32, 64),
    (3, 32, 1, 8, 32),
])
def test_wkv6_kernel_sweep(b, s, h, n, block_s):
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 2.0)
    u = jax.random.normal(ks[4], (h, n)) * 0.3
    s0 = jax.random.normal(ks[5], (b, h, n, n)) * 0.1
    y, st = ops.wkv6(r, k, v, logw, u, s0, block_s=block_s)
    y_ref, st_ref = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, atol=2e-4, rtol=2e-4)


def test_wkv6_kernel_matches_model_scan():
    """The kernel implements exactly models.rwkv6._wkv_scan semantics."""
    from repro.models.rwkv6 import _wkv_scan
    b, s, h, n = 2, 48, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    r = jax.random.normal(ks[0], (b, s, h, n))
    k = jax.random.normal(ks[1], (b, s, h, n))
    v = jax.random.normal(ks[2], (b, s, h, n))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) - 2.0)
    u = jax.random.normal(ks[4], (h, n)) * 0.3
    s0 = jnp.zeros((b, h, n, n))
    y_k, st_k = ops.wkv6(r, k, v, logw, u, s0, block_s=16)
    y_m, st_m = _wkv_scan(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m),
                               atol=2e-5, rtol=2e-5)
