"""Sharding-rule unit tests (specs only; no multi-device execution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.launch.mesh import make_production_mesh  # noqa: F401 (import-only)
from repro.models import init_cache, init_lm
from repro.parallel import (batch_specs, cache_specs, param_specs,
                            validate_specs, zero_dp_specs)


class FakeMesh:
    """Spec-validation stand-in (no devices needed)."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _pshape(cfg):
    return jax.eval_shape(lambda k: init_lm(cfg, k),
                          jax.ShapeDtypeStruct((2,), np.uint32))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divide_for_all_archs(arch):
    cfg = get_config(arch)
    shapes = _pshape(cfg)
    for mesh in (MESH, MESH_MP):
        specs = param_specs(shapes, cfg=cfg, mesh=mesh)
        assert validate_specs(specs, shapes, mesh) == []


def test_kv_replication_rule():
    cfg = get_config("tinyllama-1.1b")          # n_kv=4 < model=16
    shapes = _pshape(cfg)
    specs = param_specs(shapes, cfg=cfg, mesh=MESH)
    wk = specs["blocks"]["attn"]["wk"]["w"]
    wq = specs["blocks"]["attn"]["wq"]["w"]
    assert wk == P(None, None, None)            # replicated (stacked axis +2d)
    assert wq == P(None, None, "model")         # q heads still sharded


def test_vocab_indivisible_is_repaired():
    cfg = get_config("whisper-base")            # vocab 51865 % 16 != 0
    shapes = _pshape(cfg)
    specs = param_specs(shapes, cfg=cfg, mesh=MESH)
    assert specs["embed"]["emb"] == P(None, None)


def test_batch_specs_shard_only_divisible():
    b_ok = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    b_small = {"token": jax.ShapeDtypeStruct((1,), jnp.int32)}
    s1 = batch_specs(b_ok, MESH)
    assert s1["tokens"] == P(("data",), None)
    s2 = batch_specs(b_small, MESH)
    assert s2["token"] == P(None)
    s3 = batch_specs(b_ok, MESH_MP)
    assert s3["tokens"] == P(("pod", "data"), None)


def test_cache_specs_gqa_heads_vs_seq():
    # zamba kv cache: 32 kv heads -> heads on model
    cfg = get_config("zamba2-7b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_specs(cfg, cache, MESH)
    assert specs["kv"][0] == P(None, ("data",), "model", None, None)
    # danube (kv=8): heads cannot shard on 16 -> seq axis takes "model"
    cfg2 = get_config("h2o-danube-3-4b")
    cache2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 32768))
    specs2 = cache_specs(cfg2, cache2, MESH)
    ck = specs2["main"][0]
    assert ck[2] is None and ck[3] == "model"
    assert validate_specs(specs2, cache2, MESH) == []


def test_cache_specs_long_context_batch1():
    cfg = get_config("zamba2-7b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    specs = cache_specs(cfg, cache, MESH)
    ck = specs["kv"][0]
    assert ck[1] is None            # batch=1: unsharded
    assert ck[2] == "model"         # kv heads on the model axis
    assert ck[3] == "data"          # 524k cache seq sharded over data
    assert validate_specs(specs, cache, MESH) == []


def test_zero_dp_extends_large_leaves_only():
    shapes = {"big": jax.ShapeDtypeStruct((64, 4096, 512), jnp.float32),
              "small": jax.ShapeDtypeStruct((7,), jnp.float32)}
    specs = {"big": P(None, None, "model"), "small": P(None)}
    out = zero_dp_specs(specs, shapes, MESH)
    assert out["big"] == P("data", None, "model")
    assert out["small"] == P(None)


def test_mla_cache_is_latent_sized():
    """The MLA decode cache must store [S, kv_lora+rope] per token, not
    per-head K/V — the paper-faithful memory win."""
    cfg = get_config("deepseek-v2-236b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 2, 64))
    c, pe = cache["main"]
    per_tok = (c.shape[-1] + pe.shape[-1])
    assert per_tok == cfg.kv_lora_rank + cfg.mla_d_rope == 576
    gqa_equiv = cfg.n_heads * (cfg.mla_d_nope + cfg.mla_d_v)
    assert per_tok * 18 < gqa_equiv          # >18x smaller than full KV
