"""Telemetry subsystem tests: tracer mechanics + the overhead contract.

The contract pinned here (see docs/observability.md):
  * tracing off (the default NULL_TRACER) leaves results bit-identical;
  * tracing on adds no blocking device fetches beyond the existing
    windowed syncs (counted via a device_get stub);
  * a traced run exports well-formed perfetto JSON with one superstep
    span per executed step, counter series riding the drain windows, and
    attributed recompile events;
  * streaming shape-change recompiles warn when untraced and are
    attributed ("e_max-repad") when traced.
"""
from __future__ import annotations

import importlib.util
import json
import logging
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.runner import run_partitioner
from repro.graphs.generators import dc_sbm
from repro.streaming import StreamConfig, StreamRunner, stream_from_graph

_TOOLS = os.path.join(os.path.dirname(__file__), os.pardir, "tools")


def _load_trace_report():
    spec = importlib.util.spec_from_file_location(
        "trace_report", os.path.join(_TOOLS, "trace_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def graph():
    return dc_sbm(256, 2048, n_comm=4, mixing=0.25, degree_exponent=0.5,
                  seed=5)


# --------------------------------------------------------------------------
# tracer unit mechanics
# --------------------------------------------------------------------------

def test_null_tracer_is_default_and_noop():
    assert obs.current() is obs.NULL_TRACER
    assert not obs.NULL_TRACER.enabled
    with obs.NULL_TRACER.span("x", a=1):
        pass
    obs.NULL_TRACER.counter("c", 1.0)
    obs.NULL_TRACER.compile_event("r")
    assert obs.NULL_TRACER.now_us() == 0.0


def test_use_installs_and_restores():
    t = obs.Tracer()
    with obs.use(t):
        assert obs.current() is t
        with obs.use(None):
            assert obs.current() is obs.NULL_TRACER
        assert obs.current() is t
    assert obs.current() is obs.NULL_TRACER


def test_span_nesting_and_export(tmp_path):
    t = obs.Tracer()
    with t.span("outer", run=1):
        with t.span("inner"):
            pass
    t.instant("marker", note="hi")
    t.counter("gauge", 3.0, step=0)
    path = t.save(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    assert set(doc) >= {"traceEvents", "displayTimeUnit", "otherData"}
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    # inner closes before outer; both are complete events with durations
    assert by_name["inner"]["ph"] == "X" and by_name["outer"]["ph"] == "X"
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert by_name["marker"]["ph"] == "i"
    assert by_name["gauge"]["ph"] == "C"
    assert by_name["gauge"]["args"]["value"] == 3.0
    assert t.series["gauge"] == [(0, 3.0)]


def test_recompile_cause_priority():
    t = obs.Tracer()
    t.compile_event("superstep", e_max=128, algo="revolver")
    assert t.recompiles[-1]["cause"] == "first-compile"
    # inferred diff of static args against the previous compile
    t.compile_event("superstep", e_max=256, algo="revolver")
    assert t.recompiles[-1]["cause"] == "shape-change(e_max)"
    # a pre-registered semantic cause wins over inference
    t.note_recompile_cause("e_max-repad")
    t.compile_event("superstep", e_max=512, algo="revolver")
    assert t.recompiles[-1]["cause"] == "e_max-repad"
    # cleared causes must not leak onto the next event
    t.note_recompile_cause("halo-widen")
    t.clear_recompile_cause()
    t.compile_event("superstep", e_max=512, algo="spinner")
    assert t.recompiles[-1]["cause"] == "shape-change(algo)"
    assert t.series["recompiles"][-1][1] == 4.0


def test_annotate_tags_trace_time():
    t = obs.Tracer()
    with obs.use(t):
        with obs.annotate("edge-phase", impl="jnp"):
            pass
    ev = [e for e in t.events if e["name"] == "edge-phase"]
    assert len(ev) == 1 and ev[0]["args"]["during"] == "trace"


# --------------------------------------------------------------------------
# traced batch runs
# --------------------------------------------------------------------------

def test_traced_run_records_spans_and_counters(graph):
    t = obs.Tracer()
    res = run_partitioner("revolver", graph, 5, seed=1, max_steps=5,
                          patience=10_000, trace=t)
    assert res.steps == 5
    sup = [e for e in t.events if e["name"] == "superstep" and e["ph"] == "X"]
    assert len(sup) == res.steps
    assert [e["args"]["step"] for e in sup] == list(range(res.steps))
    for name in ("local_edges", "max_norm_load", "migrations"):
        assert len(t.series[name]) == res.steps, name
        assert [s for s, _ in t.series[name]] == list(range(res.steps))
    # counter series mirror the history the untraced path reports
    assert [v for _, v in t.series["local_edges"]] == \
        pytest.approx(res.history["local_edges"])
    # migrations are bounded by the vertex count and someone moved at step 0
    migs = [v for _, v in t.series["migrations"]]
    assert all(0 <= v <= graph.n for v in migs) and migs[0] > 0
    # run manifest for trace_report --validate
    assert t.meta["runs"] == [{"algo": "revolver", "k": 5,
                               "schedule": "sequential", "steps": 5}]
    # jit-trace-time phase spans nested under the compiling superstep
    phases = {e["name"] for e in t.events
              if e.get("args", {}).get("during") == "trace"}
    assert "edge-phase" in phases and "la-update" in phases
    assert any(r["cause"] == "first-compile" for r in t.recompiles)
    summary = t.summary()
    assert summary["spans"]["superstep"]["count"] == res.steps
    json.dumps(summary)   # artifact-embeddable


def test_tracing_off_is_bit_identical(graph):
    kw = dict(seed=3, max_steps=4, patience=10_000)
    base = run_partitioner("revolver", graph, 4, **kw)
    traced = run_partitioner("revolver", graph, 4, trace=obs.Tracer(), **kw)
    again = run_partitioner("revolver", graph, 4, trace=None, **kw)
    np.testing.assert_array_equal(base.labels, traced.labels)
    np.testing.assert_array_equal(base.labels, again.labels)
    assert base.history == traced.history == again.history
    assert base.local_edges == traced.local_edges
    assert base.max_norm_load == traced.max_norm_load


def test_tracer_adds_no_device_syncs(graph, monkeypatch):
    """The traced loop must issue exactly as many blocking device fetches
    as the untraced one — counters ride the existing drain windows."""
    counts = []
    real = jax.device_get

    def counting(x):
        counts[-1] += 1
        return real(x)

    kw = dict(seed=2, max_steps=6, patience=10_000, sync_every=3,
              track_history=True)
    monkeypatch.setattr(jax, "device_get", counting)
    counts.append(0)
    run_partitioner("revolver", graph, 4, **kw)
    untraced = counts[-1]
    counts.append(0)
    run_partitioner("revolver", graph, 4, trace=obs.Tracer(), **kw)
    traced = counts[-1]
    assert untraced > 0
    assert traced == untraced


def test_async_traced_run_overlap_and_staleness(graph):
    """Traced async runs show the overlap pair (a halo-exchange span inside
    the interior-scan span's time range) and a halo_staleness counter series
    that never exceeds the bound — the schedule's observable contract,
    pinned without reading engine internals."""
    t = obs.Tracer()
    res = run_partitioner("revolver", graph, 4, seed=1, max_steps=6,
                          patience=10_000, chunk_schedule="async",
                          staleness_bound=1, trace=t)
    assert t.meta["runs"][0]["schedule"] == "async"
    interior = [e for e in t.events
                if e["name"] == "interior-scan" and e["ph"] == "X"]
    exchange = [e for e in t.events
                if e["name"] == "halo-exchange" and e["ph"] == "X"]
    assert interior and exchange
    assert any(h["ts"] >= i["ts"] and
               h["ts"] + h["dur"] <= i["ts"] + i["dur"]
               for i in interior for h in exchange), \
        "no halo-exchange span nested inside an interior-scan span"
    # the overlapped exchange is tagged so profiles can tell it apart from
    # the halo schedule's barrier exchange
    assert all(e["args"].get("overlap") == 1 for e in exchange)
    # staleness series: one point per superstep, bounded by staleness_bound,
    # and at least one genuinely stale superstep actually happened
    pts = t.series["halo_staleness"]
    assert [s for s, _ in pts] == list(range(res.steps))
    assert max(v for _, v in pts) <= 1
    assert any(v == 1 for _, v in pts)
    assert pts[0][1] == 0         # first superstep is always fresh
    # trace_report --validate knows the contract
    tr = _load_trace_report()
    doc = t.to_dict()
    assert tr.validate(doc) == []
    # ... and flags traces that claim async but lack the evidence
    no_stale = dict(doc)
    no_stale["traceEvents"] = [e for e in doc["traceEvents"]
                               if e["name"] != "halo_staleness"]
    assert any("halo_staleness" in p for p in tr.validate(no_stale))
    no_pair = dict(doc)
    no_pair["traceEvents"] = [e for e in doc["traceEvents"]
                              if e["name"] != "halo-exchange"]
    assert any("overlap" in p for p in tr.validate(no_pair))


def test_async_tracer_adds_no_device_syncs(graph, monkeypatch):
    """halo_staleness is emitted from the host-side refresh policy — the
    traced async loop must not fetch anything beyond the drain windows."""
    counts = []
    real = jax.device_get

    def counting(x):
        counts[-1] += 1
        return real(x)

    kw = dict(seed=2, max_steps=6, patience=10_000, sync_every=3,
              track_history=True, chunk_schedule="async", staleness_bound=2)
    monkeypatch.setattr(jax, "device_get", counting)
    counts.append(0)
    run_partitioner("revolver", graph, 4, **kw)
    untraced = counts[-1]
    counts.append(0)
    run_partitioner("revolver", graph, 4, trace=obs.Tracer(), **kw)
    assert untraced > 0
    assert counts[-1] == untraced


def test_trace_kwarg_smoke_other_schedules(graph):
    # sequential restream/spinner run traced end to end; schedule recorded
    for algo in ("spinner", "restream"):
        t = obs.Tracer()
        res = run_partitioner(algo, graph, 4, seed=0, max_steps=3,
                              patience=10_000, trace=t)
        assert t.meta["runs"][0]["algo"] == algo
        assert t.summary()["spans"]["superstep"]["count"] == res.steps


# --------------------------------------------------------------------------
# streaming
# --------------------------------------------------------------------------

def _stream_parts(graph, trace=None, deltas=4):
    cfg = StreamConfig(k=4, n_blocks=8, refine_max_steps=5,
                       refine_patience=10_000)
    runner = StreamRunner(graph.n, cfg, seed=7, trace=trace)
    runner.run(stream_from_graph(graph, deltas, seed=0))
    return runner


def test_streaming_traced_bit_identical_and_attributed(graph):
    # traced stream first: its e_max re-pads hit a cold jit cache, so the
    # recompile events actually fire (a warm cache would swallow them)
    t = obs.Tracer()
    traced = _stream_parts(graph, trace=t)
    base = _stream_parts(graph)
    np.testing.assert_array_equal(base.labels, traced.labels)
    assert [r.local_edges for r in base.reports] == \
        [r.local_edges for r in traced.reports]
    # one delta span per ingest, superstep spans numbered globally
    assert t.summary()["spans"]["delta"]["count"] == 4
    sup_steps = [e["args"]["step"] for e in t.events
                 if e["name"] == "superstep" and e["ph"] == "X"]
    assert sup_steps == list(range(traced.total_steps))
    # per-delta counters
    assert len(t.series["delta_dirty_blocks"]) == 4
    assert len(t.series["delta_m"]) == 4
    assert t.series["delta_m"][-1][1] == traced.reports[-1].m
    # this stream re-pads e_max after the first delta; the traced run's
    # recompiles must carry the pre-registered semantic cause
    repads = [r for r in traced.reports[1:] if r.repadded]
    assert repads, "fixture stream no longer re-pads; enlarge the deltas"
    causes = {r["cause"] for r in t.recompiles}
    assert "e_max-repad" in causes
    # run manifest covers every delta
    assert sum(r["steps"] for r in t.meta["runs"]) == traced.total_steps


def test_streaming_untraced_repad_warns(graph, caplog):
    with caplog.at_level(logging.WARNING, logger="repro.streaming"):
        runner = _stream_parts(graph)
    assert any(r.repadded for r in runner.reports[1:])
    warnings = [r for r in caplog.records
                if "recompiles the refine superstep" in r.getMessage()]
    assert warnings, "silent recompile: expected a one-line warning"


# --------------------------------------------------------------------------
# trace_report tool
# --------------------------------------------------------------------------

def test_trace_report_validates_real_trace(graph, tmp_path):
    tr = _load_trace_report()
    t = obs.Tracer()
    run_partitioner("revolver", graph, 4, seed=0, max_steps=3,
                    patience=10_000, trace=t)
    path = str(tmp_path / "trace.json")
    t.save(path)
    doc = tr.load(path)
    assert tr.validate(doc) == []
    assert "superstep" in tr.report(doc)
    assert tr.main([path, "--validate"]) == 0


def test_trace_report_rejects_corrupted(graph, tmp_path):
    tr = _load_trace_report()
    t = obs.Tracer()
    run_partitioner("revolver", graph, 4, seed=0, max_steps=3,
                    patience=10_000, trace=t)
    doc = t.to_dict()

    # dropped superstep span -> count mismatch against otherData.runs
    pruned = dict(doc)
    pruned["traceEvents"] = [e for e in doc["traceEvents"]
                             if e["name"] != "superstep"][:]
    problems = tr.validate(pruned)
    assert any("superstep" in p for p in problems)

    # malformed event (missing ts)
    broken = dict(doc)
    broken["traceEvents"] = doc["traceEvents"] + [{"name": "x", "ph": "X"}]
    assert any("missing" in p for p in tr.validate(broken))

    # not trace-event JSON at all
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ValueError):
        tr.load(str(bad))
    assert tr.main([str(bad), "--validate"]) == 2
