"""Sharded-vs-sequential parity suite for chunk_schedule="sharded".

Two layers:

  * in-process tests on however many devices this process has (tier-1 runs
    them at 1 device): the 1-shard sharded schedule must be **bit-identical**
    to the sequential scan (same key chain, same update order, exact integer
    load arithmetic), plus layout/validation invariants;
  * a subprocess worker (`sharded_parity_worker.py`) pinned to 8 forced host
    devices — device count is fixed at backend init, hence the subprocess —
    checking the true multi-shard schedule: shard_map output vs a
    single-device Jacobi emulation (bit-exact labels), halo-exchange
    bit-identity at both granularities (block rows and the per-vertex
    int8-wire all-to-all), the Jacobi merge's quality ratio vs sequential
    on WIKI/LJ at k=8, and hub replication's quality/balance gate.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device_graph import (
    align_blocks,
    prepare_device_graph,
    prepare_sharded_device_graph,
    shard_device_graph,
)
from repro.core.metrics import partition_loads
from repro.core.revolver import (
    RevolverConfig,
    place_revolver_state,
    revolver_init,
    revolver_superstep,
)
from repro.core.runner import run_partitioner
from repro.core.spinner import (
    SpinnerConfig,
    place_spinner_state,
    spinner_init,
    spinner_superstep,
)
from repro.graphs.generators import dc_sbm, ring_of_cliques
from repro.launch.mesh import make_blocks_mesh


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5, seed=3)


class TestOneDeviceBitIdentity:
    """n_shards=1 "sharded" must reproduce "sequential" bit-for-bit: shard 0
    keeps the sequential key chain, the scan is the same scan, and the
    psum-delta load merge is exact integer arithmetic."""

    def test_superstep_trajectory_bit_identical(self, sbm_graph):
        mesh = make_blocks_mesh(1)
        dg = prepare_device_graph(sbm_graph, n_blocks=8)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        key = jax.random.PRNGKey(7)
        cfg_seq = RevolverConfig(k=4)
        cfg_sh = RevolverConfig(k=4, chunk_schedule="sharded")
        st_seq = revolver_init(dg, cfg_seq, key)
        st_sh = place_revolver_state(revolver_init(sdg, cfg_sh, key), sdg)
        for _ in range(6):
            st_seq = revolver_superstep(dg, cfg_seq, st_seq)
            st_sh = revolver_superstep(sdg, cfg_sh, st_sh)
        np.testing.assert_array_equal(np.asarray(st_seq.labels),
                                      np.asarray(st_sh.labels))
        np.testing.assert_array_equal(np.asarray(st_seq.probs),
                                      np.asarray(st_sh.probs))
        np.testing.assert_array_equal(np.asarray(st_seq.loads),
                                      np.asarray(st_sh.loads))
        assert float(st_seq.score) == float(st_sh.score)

    def test_spinner_one_shard_bit_identical(self, sbm_graph):
        """Spinner's sharded histogram sums the same integer-valued eq.-4
        weights as the flat path and the migration uniforms are drawn from
        the same full-[n_pad] stream, so one shard reproduces the sequential
        BSP step bit-for-bit too."""
        mesh = make_blocks_mesh(1)
        dg = prepare_device_graph(sbm_graph, n_blocks=8)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        key = jax.random.PRNGKey(5)
        cfg_seq = SpinnerConfig(k=4)
        cfg_sh = SpinnerConfig(k=4, chunk_schedule="sharded")
        st_seq = spinner_init(dg, cfg_seq, key)
        st_sh = place_spinner_state(spinner_init(sdg, cfg_sh, key), sdg)
        for _ in range(6):
            st_seq = spinner_superstep(dg, cfg_seq, st_seq)
            st_sh = spinner_superstep(sdg, cfg_sh, st_sh)
        np.testing.assert_array_equal(np.asarray(st_seq.labels),
                                      np.asarray(st_sh.labels))
        assert float(st_sh.score) == pytest.approx(float(st_seq.score),
                                                   abs=1e-7)

    def test_run_partitioner_bit_identical(self, sbm_graph):
        common = dict(seed=3, max_steps=10, patience=10_000,
                      track_history=False)
        r_seq = run_partitioner("revolver", sbm_graph, 4, **common)
        r_sh = run_partitioner("revolver", sbm_graph, 4,
                               chunk_schedule="sharded",
                               mesh=make_blocks_mesh(1), **common)
        np.testing.assert_array_equal(r_seq.labels, r_sh.labels)
        assert r_seq.steps == r_sh.steps
        assert r_sh.local_edges == pytest.approx(r_seq.local_edges, abs=1e-7)


class TestShardedInvariants:
    def test_spinner_sharded_loads_consistent(self, sbm_graph):
        mesh = make_blocks_mesh(1)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        cfg = SpinnerConfig(k=4, chunk_schedule="sharded")
        st = place_spinner_state(
            spinner_init(sdg, cfg, jax.random.PRNGKey(0)), sdg)
        for _ in range(5):
            st = spinner_superstep(sdg, cfg, st)
            expect = partition_loads(st.labels, sdg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads),
                                       np.asarray(expect), rtol=1e-5)

    def test_revolver_sharded_loads_consistent(self, sbm_graph):
        mesh = make_blocks_mesh(1)
        sdg = prepare_sharded_device_graph(sbm_graph, mesh, n_blocks=8)
        cfg = RevolverConfig(k=4, chunk_schedule="sharded")
        st = place_revolver_state(
            revolver_init(sdg, cfg, jax.random.PRNGKey(0)), sdg)
        for _ in range(5):
            st = revolver_superstep(sdg, cfg, st)
            expect = partition_loads(st.labels, sdg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads),
                                       np.asarray(expect), rtol=1e-5)

    def test_keep_probs_and_history_on_sharded(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, seed=0, max_steps=5,
                            patience=10_000, chunk_schedule="sharded",
                            keep_probs=True, track_history=True)
        assert r.probs is not None and r.probs.shape[-1] == 4
        assert len(r.history["score"]) == r.steps == 5
        assert len(r.history["local_edges"]) == 5


class TestLayout:
    def test_align_blocks_pads_empty_blocks(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=8)
        aligned = align_blocks(dg, 3)
        assert aligned.n_blocks == 9
        assert aligned.n_pad == 9 * dg.block_v
        assert aligned.blk_dst.shape == (9, dg.e_max)
        pad_v = np.asarray(aligned.vmask[dg.n_pad:])
        assert not pad_v.any()
        assert float(jnp.sum(aligned.blk_w[8:])) == 0.0
        assert float(jnp.sum(aligned.deg_out)) == float(jnp.sum(dg.deg_out))

    def test_align_blocks_noop_when_divisible(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=8)
        assert align_blocks(dg, 4) is dg

    def test_aligned_layout_same_partition(self, sbm_graph):
        """Empty alignment blocks change nothing: a sharded run on a layout
        padded with an extra empty block reaches the same quality as on the
        exact layout (same mesh, 1 shard)."""
        mesh = make_blocks_mesh(1)
        dg = prepare_device_graph(sbm_graph, n_blocks=8)
        sdg_exact = shard_device_graph(dg, mesh)
        common = dict(seed=0, max_steps=8, patience=10_000,
                      track_history=False, chunk_schedule="sharded")
        r_a = run_partitioner("revolver", sbm_graph, 4, dg=sdg_exact, **common)
        r_b = run_partitioner("revolver", sbm_graph, 4,
                              dg=align_blocks(dg, 9), mesh=mesh, **common)
        # layouts differ (8 vs 9 blocks -> different per-chunk RNG framing),
        # so compare quality, not bits
        assert r_b.local_edges == pytest.approx(r_a.local_edges, abs=0.05)


class TestValidation:
    def test_bad_chunk_schedule_raises(self):
        with pytest.raises(ValueError, match="chunk_schedule"):
            RevolverConfig(k=4, chunk_schedule="sharded_jacobi")
        with pytest.raises(ValueError, match="chunk_schedule"):
            SpinnerConfig(k=4, chunk_schedule="bsp")

    def test_sharded_superstep_needs_sharded_graph(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4, chunk_schedule="sharded")
        st = revolver_init(dg, RevolverConfig(k=4), jax.random.PRNGKey(0))
        with pytest.raises(TypeError, match="ShardedDeviceGraph"):
            revolver_superstep(dg, cfg, st)

    def test_mesh_without_sharded_raises(self, sbm_graph):
        with pytest.raises(ValueError, match="mesh"):
            run_partitioner("revolver", sbm_graph, 4,
                            mesh=make_blocks_mesh(1))

    def test_sequential_schedule_accepts_sharded_graph(self):
        """A ShardedDeviceGraph's arrays are usable by the sequential path
        (the scaling bench's 1-device reference leg does this)."""
        g = ring_of_cliques(6, 12)
        mesh = make_blocks_mesh(1)
        sdg = prepare_sharded_device_graph(g, mesh, n_blocks=4)
        cfg = RevolverConfig(k=4)
        st = revolver_init(sdg, cfg, jax.random.PRNGKey(0))
        st = revolver_superstep(sdg, cfg, st)
        assert int(st.step) == 1


class TestStreamingSharded:
    def test_stream_runner_one_shard_matches_sequential(self, sbm_graph):
        """The sharded refine path through StreamRunner (mesh-aligned
        incremental layout + placed warm starts) reproduces the sequential
        stream bit-for-bit on one shard."""
        from repro.streaming.runner import StreamConfig, StreamRunner
        from repro.streaming.stream import stream_from_graph

        cfg = StreamConfig(k=4, n_blocks=8, refine_max_steps=5,
                           refine_patience=10_000, sync_every=2)
        r_seq = StreamRunner(sbm_graph.n, cfg, seed=0)
        r_sh = StreamRunner(sbm_graph.n, cfg, seed=0,
                            chunk_schedule="sharded",
                            mesh=make_blocks_mesh(1))
        for d_seq, d_sh in zip(stream_from_graph(sbm_graph, 3, seed=0),
                               stream_from_graph(sbm_graph, 3, seed=0)):
            rep_seq = r_seq.ingest(d_seq)
            rep_sh = r_sh.ingest(d_sh)
            assert rep_sh.steps == rep_seq.steps
            assert rep_sh.local_edges == pytest.approx(
                rep_seq.local_edges, abs=1e-7)
        np.testing.assert_array_equal(r_seq.labels, r_sh.labels)


# --------------------------------------------------------------------------
# true multi-shard checks: subprocess pinned to 8 forced host devices
# --------------------------------------------------------------------------
_MARK = "SHARDED_PARITY_JSON:"


@pytest.fixture(scope="module")
def parity_report():
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append("--xla_force_host_platform_device_count=8")
    env["XLA_FLAGS"] = " ".join(flags)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    worker = os.path.join(os.path.dirname(__file__), "sharded_parity_worker.py")
    proc = subprocess.run([sys.executable, worker], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for line in proc.stdout.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    pytest.fail("worker printed no parity report:\n" + proc.stdout + proc.stderr)


class TestMultiShard:
    def test_jacobi_schedule_matches_reference(self, parity_report):
        """8 shards (one block per shard: the pure-Jacobi corner) and 4
        shards (async-within mix) must match the single-device emulation of
        the schedule bit-exactly on labels/loads."""
        for par in parity_report["jacobi_parity"]:
            assert par["labels_equal"], par
            assert par["loads_equal"], par
            assert par["max_probs_diff"] <= 1e-6, par
            assert par["score_diff"] <= 1e-5, par

    def test_halo_schedule_bit_identical_to_sharded(self, parity_report):
        """The boundary-only halo exchange is an exact optimization of the
        full-gather Jacobi sync: labels/loads bit-equal at 8 shards on
        WIKI/LJ/USA, under contiguous and locality assignments alike — and
        at BOTH granularities: whole-block rows and the per-vertex int8-wire
        all-to-all (hubs off, per the exactness contract)."""
        seen = set()
        for par in parity_report["halo_parity"]:
            seen.add((par["dataset"], par["assignment"], par["granularity"]))
            assert par["labels_equal"], par
            assert par["loads_equal"], par
            assert par["max_probs_diff"] == 0.0, par
            assert par["score_diff"] <= 1e-6, par
        assert {("WIKI", "contiguous", "block"),
                ("LJ", "contiguous", "block"),
                ("WIKI", "locality", "block"),
                ("WIKI", "contiguous", "vertex"),
                ("LJ", "contiguous", "vertex"),
                ("USA", "locality", "vertex")} <= seen

    def test_async_s0_bit_identical_to_halo(self, parity_report):
        """staleness_bound=0 async (refresh every superstep) runs the halo
        schedule's exchange concurrently with the interior scan but consumes
        the same start-of-superstep tail — bit-identity on labels/loads/
        probs at 8 shards on WIKI/LJ/USA, both granularities, with a real
        (non-fallback) plan and a non-degenerate split somewhere."""
        seen = set()
        for par in parity_report["async_parity"]:
            seen.add((par["dataset"], par["assignment"], par["granularity"]))
            assert not par["fallback"], par
            assert par["labels_equal"], par
            assert par["loads_equal"], par
            assert par["max_probs_diff"] == 0.0, par
            assert par["score_diff"] <= 1e-6, par
            assert par["interior_split"] == min(par["interior_counts"]), par
        assert {("WIKI", "contiguous", "vertex"),
                ("LJ", "contiguous", "vertex"),
                ("USA", "contiguous", "block"),
                ("USA", "locality", "vertex")} <= seen
        # at least one leg genuinely overlaps (USA's road structure gives
        # interior blocks even at 8 shards)
        assert any(par["interior_split"] > 0
                   for par in parity_report["async_parity"])

    def test_quality_ratio_vs_sequential(self, parity_report):
        """The Jacobi merge trades per-superstep freshness for parallelism;
        the satellite's acceptance bar is >= 0.97 of sequential quality on
        WIKI/LJ at k=8."""
        for q in parity_report["quality"]:
            assert q["quality_ratio"] >= 0.97, q

    def test_hub_replication_quality_and_balance(self, parity_report):
        """Multi-shard hub replication changes the trajectory (hubs are
        frozen in the scan and reconciled by global vote), so its gate is
        quality + balance, not bit-identity: hub-mode local edges must stay
        within 0.90 of the plain sharded run and the load balance must hold
        (measured on WIKI: ratio ~1.01, max_norm_load ~1.09)."""
        hub = parity_report["hub_quality"]
        assert hub, "worker produced no hub_quality rows"
        for q in hub:
            assert q["quality_ratio"] >= 0.90, q
            assert q["hub_max_norm_load"] <= 1.30, q
