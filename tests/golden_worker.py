"""Golden-fixture worker: pin revolver/spinner partitions at a fixed seed.

The schedule-agnostic engine refactor's non-negotiable gate is that the
post-refactor revolver and spinner supersteps are **bit-identical** to the
pre-refactor implementations at a fixed seed, for both
``chunk_schedule="sequential"`` and ``"sharded"``. This worker computes the
fixed-seed trajectories through the *public* partitioner API only (configs,
inits, supersteps, state placement — everything the refactor must preserve)
so the exact same script runs against any revision:

  # write fixtures (run once, at the pre-refactor HEAD)
  PYTHONPATH=src python tests/golden_worker.py --schedule sequential \
      --write tests/golden/sequential.npz
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python tests/golden_worker.py --schedule sharded \
      --write tests/golden/sharded4.npz

  # check fixtures (tests/test_golden.py spawns this; exit 1 on drift)
  ... --schedule sequential --check tests/golden/sequential.npz

Sharded fixtures are generated/checked at 4 forced host devices with 8
blocks (2 blocks per shard) so the Jacobi machinery — label all-gather,
psum load-delta merge, per-shard PRNG chains — is genuinely multi-shard,
not the 1-shard degenerate case that equals the sequential scan.
"""
from __future__ import annotations

import argparse
import sys

GRAPH = dict(n=1024, m=8192, n_comm=16, mixing=0.25, degree_exponent=0.5,
             seed=3)
K = 4
N_BLOCKS = 8
STEPS = 6
SEED = 7
SHARDED_DEVICES = 4


def compute(schedule: str) -> dict:
    import jax
    import numpy as np

    from repro.core.device_graph import (
        prepare_device_graph,
        prepare_sharded_device_graph,
    )
    from repro.core.revolver import (
        RevolverConfig,
        place_revolver_state,
        revolver_init,
        revolver_superstep,
    )
    from repro.core.spinner import (
        SpinnerConfig,
        place_spinner_state,
        spinner_init,
        spinner_superstep,
    )
    from repro.graphs.generators import dc_sbm
    from repro.launch.mesh import make_blocks_mesh

    g = dc_sbm(GRAPH["n"], GRAPH["m"], n_comm=GRAPH["n_comm"],
               mixing=GRAPH["mixing"],
               degree_exponent=GRAPH["degree_exponent"], seed=GRAPH["seed"])
    algos = {
        "revolver": (RevolverConfig, revolver_init, revolver_superstep,
                     place_revolver_state),
        "spinner": (SpinnerConfig, spinner_init, spinner_superstep,
                    place_spinner_state),
    }
    if schedule == "sharded":
        assert jax.device_count() >= SHARDED_DEVICES, (
            f"sharded fixtures need {SHARDED_DEVICES} devices, have "
            f"{jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={SHARDED_DEVICES})")
        mesh = make_blocks_mesh(SHARDED_DEVICES)
        dg = prepare_sharded_device_graph(g, mesh, n_blocks=N_BLOCKS)
    else:
        dg = prepare_device_graph(g, n_blocks=N_BLOCKS)

    out = {}
    for name, (cfg_cls, init, superstep, place) in algos.items():
        cfg = cfg_cls(k=K, chunk_schedule=schedule)
        st = init(dg, cfg, jax.random.PRNGKey(SEED))
        if schedule == "sharded":
            st = place(st, dg)
        for _ in range(STEPS):
            st = superstep(dg, cfg, st)
        out[f"{name}_labels"] = np.asarray(st.labels)
        out[f"{name}_loads"] = np.asarray(st.loads)
        out[f"{name}_score"] = np.asarray(st.score)
    return out


def main(argv=None) -> int:
    import numpy as np

    ap = argparse.ArgumentParser()
    ap.add_argument("--schedule", choices=["sequential", "sharded"],
                    required=True)
    ap.add_argument("--write", default=None, help="write fixtures to this npz")
    ap.add_argument("--check", default=None, help="compare against this npz")
    args = ap.parse_args(argv)
    if (args.write is None) == (args.check is None):
        raise SystemExit("exactly one of --write / --check is required")

    got = compute(args.schedule)
    if args.write:
        np.savez(args.write, **got)
        print(f"wrote {args.write}: {sorted(got)}")
        return 0

    want = np.load(args.check)
    failures = []
    for key in sorted(got):
        if key not in want.files:
            failures.append(f"{key}: missing from fixture")
            continue
        g, w = got[key], want[key]
        if key.endswith("_score"):
            # score is a float reduction; everything integer-exact
            # (labels, loads) must match bit-for-bit, the score to ~ulp
            if abs(float(g) - float(w)) > 1e-6:
                failures.append(f"{key}: got {float(g)!r}, want {float(w)!r}")
        elif not np.array_equal(g, w):
            n_bad = int((np.asarray(g) != np.asarray(w)).sum())
            failures.append(f"{key}: {n_bad}/{np.asarray(w).size} entries differ")
    if failures:
        print(f"GOLDEN MISMATCH ({args.schedule}):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"golden fixtures match ({args.schedule}: {sorted(got)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
