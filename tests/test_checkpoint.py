"""Checkpoint store robustness: async-failure surfacing, corrupt/truncated
payload handling, partial-write artifacts, dtype/shape validation, and the
elastic re-shard round-trip across device counts (subprocess workers pin
``XLA_FLAGS`` before the backend initializes)."""
import json
import os
import shutil
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    all_steps,
    latest_step,
    load_checkpoint_arrays,
    load_manifest,
    restore_checkpoint,
    save_checkpoint,
)

TREE = {"a": np.arange(12.0, dtype=np.float32).reshape(3, 4),
        "b": {"c": np.ones(5, np.int32)}}


def _step_dir(td, step):
    return os.path.join(td, f"step_{step:08d}")


def test_async_wait_reraises_writer_failure():
    # point the writer at a path whose parent is a *file* — makedirs fails
    # inside the thread; wait() must surface it, not swallow it
    with tempfile.TemporaryDirectory() as td:
        blocker = os.path.join(td, "blocker")
        with open(blocker, "w") as f:
            f.write("x")
        handle = save_checkpoint(os.path.join(blocker, "nested"), 1, TREE,
                                 async_save=True)
        with pytest.raises(OSError):
            handle.wait()
        # wait() after the failure was consumed is a clean no-op
        handle.wait()


def test_async_save_completes_and_loads():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 5, TREE, async_save=True, meta={"tag": 7}).wait()
        arrays, manifest = load_checkpoint_arrays(td, 5)
        assert manifest["meta"] == {"tag": 7}
        np.testing.assert_array_equal(arrays["a"], TREE["a"])
        np.testing.assert_array_equal(arrays["b/c"], TREE["b"]["c"])


def test_corrupt_npz_is_checkpoint_error():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, TREE)
        path = os.path.join(_step_dir(td, 1), "arrays.npz")
        with open(path, "wb") as f:
            f.write(b"not a zip archive")
        with pytest.raises(CheckpointError):
            load_checkpoint_arrays(td, 1)
        with pytest.raises(CheckpointError):
            restore_checkpoint(td, 1, TREE)


def test_truncated_npz_is_checkpoint_error():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, TREE)
        path = os.path.join(_step_dir(td, 1), "arrays.npz")
        data = open(path, "rb").read()
        with open(path, "wb") as f:
            f.write(data[: len(data) // 2])
        with pytest.raises(CheckpointError):
            load_checkpoint_arrays(td, 1)


def test_payload_missing_manifest_key_is_checkpoint_error():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, TREE)
        # payload lists fewer arrays than the manifest promises
        path = os.path.join(_step_dir(td, 1), "arrays.npz")
        with np.load(path) as z:
            partial = {k: z[k] for k in z.files if k != "a"}
        np.savez(path, **partial)
        with pytest.raises(CheckpointError):
            load_checkpoint_arrays(td, 1)


def test_missing_or_invalid_manifest_skipped_by_latest_step():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, TREE)
        save_checkpoint(td, 2, TREE)
        save_checkpoint(td, 3, TREE)
        # step 3: manifest deleted (crash between payload and manifest —
        # impossible with the tmp-dir protocol, but belt and braces)
        os.remove(os.path.join(_step_dir(td, 3), "manifest.json"))
        # step 2: manifest truncated mid-json
        mpath = os.path.join(_step_dir(td, 2), "manifest.json")
        with open(mpath, "w") as f:
            f.write('{"step": 2, "ke')
        assert all_steps(td) == [1]
        assert latest_step(td) == 1
        # manifest lacking required keys is equally unusable
        with open(mpath, "w") as f:
            json.dump({"something": "else"}, f)
        assert latest_step(td) == 1
        with pytest.raises(CheckpointError):
            load_manifest(td, 2)


def test_leftover_tmp_dirs_ignored():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 4, TREE)
        # a crash mid-save leaves step_<n>.tmp; it must never be a resume
        # candidate and must not break enumeration
        shutil.copytree(_step_dir(td, 4), _step_dir(td, 9) + ".tmp")
        os.makedirs(os.path.join(td, "step_junk"))
        os.makedirs(os.path.join(td, "unrelated"))
        assert all_steps(td) == [4]
        assert latest_step(td) == 4


def test_restore_dtype_cast_and_shape_mismatch():
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 1, TREE)
        like = {"a": jax.ShapeDtypeStruct((3, 4), jnp.float16),
                "b": {"c": jax.ShapeDtypeStruct((5,), jnp.float32)}}
        out = restore_checkpoint(td, 1, like)
        assert np.asarray(out["a"]).dtype == np.float16
        assert np.asarray(out["b"]["c"]).dtype == np.float32
        bad = {"a": jax.ShapeDtypeStruct((4, 3), jnp.float32),
               "b": {"c": jax.ShapeDtypeStruct((5,), jnp.int32)}}
        with pytest.raises(ValueError):
            restore_checkpoint(td, 1, bad)
        with pytest.raises(KeyError):
            restore_checkpoint(td, 1, dict(like, extra=like["a"]))


def test_keep_prunes_only_oldest():
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4):
            save_checkpoint(td, s, TREE, keep=2)
        assert all_steps(td) == [3, 4]


_ELASTIC_WORKER = r"""
import json, os, sys
import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.launch.mesh import make_blocks_mesh

mode, td = sys.argv[1], sys.argv[2]
n_dev = len(jax.devices())
mesh = make_blocks_mesh()
arr = np.arange(32.0, dtype=np.float32).reshape(8, 4)
like = {"x": jax.ShapeDtypeStruct(arr.shape, arr.dtype)}
sh = {"x": NamedSharding(mesh, P("blocks", None))}
if mode == "seed":
    placed = jax.device_put(arr, sh["x"])
    save_checkpoint(td, 1, {"x": placed})
else:
    out = restore_checkpoint(td, 1, like, shardings=sh)["x"]
    assert len(out.sharding.device_set) == n_dev, out.sharding
    np.save(os.path.join(td, f"rt_{n_dev}.npy"), np.asarray(out))
    if mode == "roundtrip":
        save_checkpoint(td, 1, {"x": out})
print(json.dumps({"devices": n_dev}))
"""


def _elastic(mode, td, devices):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", _ELASTIC_WORKER, mode, td],
                       env=env, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert json.loads(r.stdout.splitlines()[-1])["devices"] == devices


def test_elastic_restore_1_4_1_roundtrips_bit_exact():
    """A checkpoint written on 1 device restores onto a 4-device mesh, is
    re-saved from there, and restores back onto 1 device — every hop
    bit-exact (the store's re-shard path is pure data movement)."""
    arr = np.arange(32.0, dtype=np.float32).reshape(8, 4)
    with tempfile.TemporaryDirectory() as td:
        _elastic("seed", td, 1)
        _elastic("roundtrip", td, 4)      # restore on 4, re-save
        np.testing.assert_array_equal(
            np.load(os.path.join(td, "rt_4.npy")), arr)
        _elastic("restore", td, 1)        # restore the 4-device save on 1
        np.testing.assert_array_equal(
            np.load(os.path.join(td, "rt_1.npy")), arr)
