"""Integration tests for the Revolver partitioner and its baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device_graph import capacity, prepare_device_graph
from repro.core.metrics import local_edges, max_normalized_load, partition_loads
from repro.core.revolver import RevolverConfig, revolver_init, revolver_superstep
from repro.core.runner import run_partitioner
from repro.core.spinner import SpinnerConfig, spinner_init, spinner_superstep
from repro.graphs.generators import dc_sbm, ring_of_cliques


@pytest.fixture(scope="module")
def clique_graph():
    return ring_of_cliques(8, 16)


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5, seed=3)


class TestRevolverInvariants:
    def test_loads_match_labels_every_step(self, sbm_graph):
        """Invariant: state.loads == recomputed b(l) after async chunk updates."""
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4, max_steps=10)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(0))
        for _ in range(5):
            st = revolver_superstep(dg, cfg, st)
            expect = partition_loads(st.labels, dg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads), np.asarray(expect), rtol=1e-5)

    def test_labels_in_range(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=6)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(1))
        for _ in range(3):
            st = revolver_superstep(dg, cfg, st)
        lab = np.asarray(st.labels)
        assert lab.min() >= 0 and lab.max() < 6

    def test_probs_remain_simplex(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(2))
        for _ in range(5):
            st = revolver_superstep(dg, cfg, st)
        sums = np.asarray(jnp.sum(st.probs, axis=-1))
        np.testing.assert_allclose(sums, 1.0, atol=1e-4)

    def test_deterministic_given_seed(self, clique_graph):
        r1 = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False)
        r2 = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False)
        np.testing.assert_array_equal(r1.labels, r2.labels)

    def test_sync_mode_single_block(self, sbm_graph):
        """n_blocks=1 (synchronous degenerate case) still works."""
        r = run_partitioner("revolver", sbm_graph, 4, max_steps=20, seed=0,
                            n_blocks=1, track_history=False)
        assert r.local_edges > 0


class TestRevolverQuality:
    def test_recovers_planted_cliques(self, clique_graph):
        r = run_partitioner("revolver", clique_graph, 8, max_steps=290, seed=0,
                            track_history=False)
        assert r.local_edges > 0.9          # near-perfect: one clique per part
        assert r.max_norm_load < 1.10

    def test_beats_hash_on_communities(self, sbm_graph):
        rh = run_partitioner("hash", sbm_graph, 8)
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=150, seed=0,
                             track_history=False)
        assert rr.local_edges > rh.local_edges + 0.1

    def test_balance_within_epsilon_slack(self, sbm_graph):
        """Paper claim: Revolver stays within the 5% imbalance budget."""
        r = run_partitioner("revolver", sbm_graph, 8, max_steps=150, seed=0,
                            track_history=False)
        assert r.max_norm_load <= 1.10  # 1+eps (+ small sampling noise)

    def test_paper_capacity_mode_runs(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, max_steps=20, seed=0,
                            capacity_mode="paper", track_history=False)
        assert 0.0 <= r.local_edges <= 1.0


class TestSpinner:
    def test_spinner_improves_over_random(self, sbm_graph):
        rh = run_partitioner("hash", sbm_graph, 8)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=150, seed=0,
                             track_history=False)
        assert rs.local_edges > rh.local_edges + 0.1

    def test_spinner_loads_consistent(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=1)
        cfg = SpinnerConfig(k=4)
        st = spinner_init(dg, cfg, jax.random.PRNGKey(0))
        for _ in range(5):
            st = spinner_superstep(dg, cfg, st)
            expect = partition_loads(st.labels, dg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads), np.asarray(expect), rtol=1e-5)


class TestStaticPartitioners:
    def test_hash_balanced_on_uniform_ids(self):
        g = dc_sbm(1024, 4096, n_comm=8, seed=0)
        r = run_partitioner("hash", g, 8)
        assert r.max_norm_load < 1.5

    def test_range_contiguous(self):
        g = ring_of_cliques(4, 8)
        r = run_partitioner("range", g, 4)
        # range partitioning on community-sorted ids == planted partition
        assert r.local_edges > 0.9


class TestCapacity:
    def test_capacity_modes(self):
        assert capacity(1000, 10, 0.05, "spinner") == pytest.approx(105.0)
        assert capacity(1000, 10, 0.05, "paper") == pytest.approx(5.0)
        with pytest.raises(ValueError):
            capacity(1000, 10, 0.05, "bogus")


class TestPaperClaims:
    """The paper's two headline claims, validated on the DC-SBM suite
    (EXPERIMENTS.md §Reproduction reports the full sweep)."""

    def test_revolver_balance_beats_spinner(self, sbm_graph):
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        assert rr.max_norm_load <= rs.max_norm_load + 0.02

    def test_revolver_local_edges_comparable_to_spinner(self, sbm_graph):
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        assert rr.local_edges >= rs.local_edges - 0.05
