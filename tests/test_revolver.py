"""Integration tests for the Revolver partitioner and its baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.device_graph import capacity, capacity_device, prepare_device_graph
from repro.core.metrics import local_edges, max_normalized_load, partition_loads
from repro.core.revolver import RevolverConfig, revolver_init, revolver_superstep
from repro.core.runner import run_partitioner
from repro.core.spinner import SpinnerConfig, spinner_init, spinner_superstep
from repro.graphs.generators import dc_sbm, ring_of_cliques


@pytest.fixture(scope="module")
def clique_graph():
    return ring_of_cliques(8, 16)


@pytest.fixture(scope="module")
def sbm_graph():
    return dc_sbm(1024, 8192, n_comm=16, mixing=0.25, degree_exponent=0.5, seed=3)


class TestRevolverInvariants:
    def test_loads_match_labels_every_step(self, sbm_graph):
        """Invariant: state.loads == recomputed b(l) after async chunk updates."""
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4, max_steps=10)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(0))
        for _ in range(5):
            st = revolver_superstep(dg, cfg, st)
            expect = partition_loads(st.labels, dg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads), np.asarray(expect), rtol=1e-5)

    def test_labels_in_range(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=6)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(1))
        for _ in range(3):
            st = revolver_superstep(dg, cfg, st)
        lab = np.asarray(st.labels)
        assert lab.min() >= 0 and lab.max() < 6

    def test_probs_remain_simplex(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        cfg = RevolverConfig(k=4)
        st = revolver_init(dg, cfg, jax.random.PRNGKey(2))
        for _ in range(5):
            st = revolver_superstep(dg, cfg, st)
        sums = np.asarray(jnp.sum(st.probs, axis=-1))
        np.testing.assert_allclose(sums, 1.0, atol=1e-4)

    def test_deterministic_given_seed(self, clique_graph):
        r1 = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False)
        r2 = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False)
        np.testing.assert_array_equal(r1.labels, r2.labels)

    def test_sync_mode_single_block(self, sbm_graph):
        """n_blocks=1 (synchronous degenerate case) still works."""
        r = run_partitioner("revolver", sbm_graph, 4, max_steps=20, seed=0,
                            n_blocks=1, track_history=False)
        assert r.local_edges > 0


class TestRevolverQuality:
    def test_recovers_planted_cliques(self, clique_graph):
        r = run_partitioner("revolver", clique_graph, 8, max_steps=290, seed=0,
                            track_history=False)
        assert r.local_edges > 0.9          # near-perfect: one clique per part
        assert r.max_norm_load < 1.10

    def test_beats_hash_on_communities(self, sbm_graph):
        rh = run_partitioner("hash", sbm_graph, 8)
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=150, seed=0,
                             track_history=False)
        assert rr.local_edges > rh.local_edges + 0.1

    def test_balance_within_epsilon_slack(self, sbm_graph):
        """Paper claim: Revolver stays within the 5% imbalance budget."""
        r = run_partitioner("revolver", sbm_graph, 8, max_steps=150, seed=0,
                            track_history=False)
        assert r.max_norm_load <= 1.10  # 1+eps (+ small sampling noise)

    def test_paper_capacity_mode_runs(self, sbm_graph):
        r = run_partitioner("revolver", sbm_graph, 4, max_steps=20, seed=0,
                            capacity_mode="paper", track_history=False)
        assert 0.0 <= r.local_edges <= 1.0


class TestSpinner:
    def test_spinner_improves_over_random(self, sbm_graph):
        rh = run_partitioner("hash", sbm_graph, 8)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=150, seed=0,
                             track_history=False)
        assert rs.local_edges > rh.local_edges + 0.1

    def test_spinner_loads_consistent(self, sbm_graph):
        dg = prepare_device_graph(sbm_graph, n_blocks=1)
        cfg = SpinnerConfig(k=4)
        st = spinner_init(dg, cfg, jax.random.PRNGKey(0))
        for _ in range(5):
            st = spinner_superstep(dg, cfg, st)
            expect = partition_loads(st.labels, dg.deg_out, 4)
            np.testing.assert_allclose(np.asarray(st.loads), np.asarray(expect), rtol=1e-5)


class TestStaticPartitioners:
    def test_hash_balanced_on_uniform_ids(self):
        g = dc_sbm(1024, 4096, n_comm=8, seed=0)
        r = run_partitioner("hash", g, 8)
        assert r.max_norm_load < 1.5

    def test_range_contiguous(self):
        g = ring_of_cliques(4, 8)
        r = run_partitioner("range", g, 4)
        # range partitioning on community-sorted ids == planted partition
        assert r.local_edges > 0.9


class TestCapacity:
    def test_capacity_modes(self):
        assert capacity(1000, 10, 0.05, "spinner") == pytest.approx(105.0)
        assert capacity(1000, 10, 0.05, "paper") == pytest.approx(5.0)
        with pytest.raises(ValueError):
            capacity(1000, 10, 0.05, "bogus")

    def test_capacity_device_cached(self):
        """The superstep-side capacity is hoisted: same (m, cfg) inputs hit
        one committed device buffer instead of a per-step recompute."""
        a = capacity_device(1000, 10, 0.05, "spinner")
        b = capacity_device(1000, 10, 0.05, "spinner")
        assert a is b
        assert float(a) == pytest.approx(105.0)
        assert capacity_device(1000, 10, 0.05, "paper") is not a


class TestConfigValidation:
    """Impl/mode knobs reject typos at construction instead of silently
    falling back to the jnp path."""

    @pytest.mark.parametrize("field,bad", [
        ("la_impl", "palas"),
        ("hist_impl", "cuda"),
        ("weight_mode", "self_lamda"),
        ("capacity_mode", "bogus"),
    ])
    def test_revolver_bad_choice_raises(self, field, bad):
        with pytest.raises(ValueError, match=field):
            RevolverConfig(k=4, **{field: bad})

    def test_revolver_valid_choices_accepted(self):
        cfg = RevolverConfig(k=4, la_impl="pallas", hist_impl="pallas",
                             weight_mode="neighbor_lambda",
                             capacity_mode="paper")
        assert cfg.hist_impl == "pallas"

    def test_spinner_bad_capacity_mode_raises(self):
        with pytest.raises(ValueError, match="capacity_mode"):
            SpinnerConfig(k=4, capacity_mode="bogus")


class TestFusedHistParity:
    """hist_impl="pallas" routes the superstep through the fused
    dual-histogram edge-phase kernel; at fixed seed it must reproduce the
    jnp scatter-add partition (acceptance: 1e-5 score tolerance)."""

    @pytest.mark.parametrize("weight_mode", ["self_lambda", "neighbor_lambda"])
    def test_superstep_trajectory_matches_jnp(self, sbm_graph, weight_mode):
        dg = prepare_device_graph(sbm_graph, n_blocks=4)
        finals = {}
        for impl in ("jnp", "pallas"):
            cfg = RevolverConfig(k=4, hist_impl=impl, weight_mode=weight_mode)
            st = revolver_init(dg, cfg, jax.random.PRNGKey(0))
            for _ in range(6):
                st = revolver_superstep(dg, cfg, st)
            finals[impl] = st
        assert abs(float(finals["jnp"].score)
                   - float(finals["pallas"].score)) <= 1e-5
        np.testing.assert_allclose(np.asarray(finals["jnp"].probs),
                                   np.asarray(finals["pallas"].probs),
                                   atol=1e-5, rtol=1e-5)
        # bit-exact labels only hold where both paths accumulate f32 the
        # same way (CPU interpret mode); a compiled MXU reduction may flip
        # ULP-level argmax ties, which the score tolerance above absorbs
        if jax.default_backend() == "cpu":
            np.testing.assert_array_equal(np.asarray(finals["jnp"].labels),
                                          np.asarray(finals["pallas"].labels))

    def test_end_to_end_partition_matches_jnp(self, clique_graph):
        rj = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False, hist_impl="jnp")
        rp = run_partitioner("revolver", clique_graph, 4, max_steps=15, seed=7,
                             track_history=False, hist_impl="pallas")
        assert rp.local_edges == pytest.approx(rj.local_edges, abs=1e-5)
        assert rp.max_norm_load == pytest.approx(rj.max_norm_load, abs=1e-5)
        if jax.default_backend() == "cpu":  # see trajectory test above
            assert rp.steps == rj.steps
            np.testing.assert_array_equal(rj.labels, rp.labels)

    def test_pallas_hist_with_pallas_la(self, clique_graph):
        """Both kernel knobs on at once (the full-TPU configuration)."""
        r = run_partitioner("revolver", clique_graph, 4, max_steps=10, seed=0,
                            track_history=False, hist_impl="pallas",
                            la_impl="pallas")
        assert 0.0 <= r.local_edges <= 1.0
        assert r.max_norm_load > 0.0


class TestPaperClaims:
    """The paper's two headline claims, validated on the DC-SBM suite
    (EXPERIMENTS.md §Reproduction reports the full sweep)."""

    def test_revolver_balance_beats_spinner(self, sbm_graph):
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        assert rr.max_norm_load <= rs.max_norm_load + 0.02

    def test_revolver_local_edges_comparable_to_spinner(self, sbm_graph):
        rr = run_partitioner("revolver", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        rs = run_partitioner("spinner", sbm_graph, 8, max_steps=200, seed=0,
                             track_history=False)
        assert rr.local_edges >= rs.local_edges - 0.05
