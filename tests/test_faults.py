"""Crash-safety behavior: fault-plan parsing, kill-and-resume bit-identity
(batch and streaming, in-process via step-budget cuts plus one real
subprocess SIGKILL), and the drain-window state guard policies."""
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro import faults
from repro.core.runner import PartitionStateError, run_partitioner
from repro.graphs import load_dataset
from repro.streaming.runner import StreamConfig, StreamRunner
from repro.streaming.stream import stream_from_graph

G = load_dataset("WIKI", scale=0.005, seed=0)
K = 4


# --------------------------------------------------------------------------
# fault-plan grammar
# --------------------------------------------------------------------------
def test_parse_faults_grammar():
    plan = faults.parse_faults(
        "kill@superstep=12,kill@save,nan@superstep=8,kill@delta=2,"
        "badlabel@superstep=3,kill@save-payload,kill@save=1")
    assert len(plan.actions) == 7
    a = plan.actions[0]
    assert (a.action, a.point, a.index) == ("kill", "superstep", 12)
    assert plan.actions[1].index is None     # first hit of the point
    assert plan.actions[6].index == 1        # second save, counted per point


@pytest.mark.parametrize("bad", [
    "explode@superstep=1",       # unknown action
    "kill@lunch",                # unknown point
    "nan@save",                  # poisons only apply at supersteps
    "kill@superstep=x",          # non-integer index
    "kill",                      # no point
])
def test_parse_faults_rejects(bad):
    with pytest.raises(ValueError):
        faults.parse_faults(bad)


def test_fire_consumes_actions_once():
    with faults.use_plan("nan@superstep=2"):
        assert faults.fire("superstep", 1) is None
        assert faults.fire("superstep", 2) == "nan"
        assert faults.fire("superstep", 2) is None   # consumed
    assert faults.fire("superstep", 2) is None       # plan scoped


# --------------------------------------------------------------------------
# batch kill-and-resume (in-process: the "kill" is a step-budget cut at an
# arbitrary — including mid-window — superstep; resume must still land on
# the last drain-aligned checkpoint and reproduce the reference exactly)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("algo", ["revolver", "restream"])
@pytest.mark.parametrize("cut", [9, 12])   # mid-window and on-window
def test_resume_bit_identical(algo, cut):
    common = dict(seed=3, max_steps=20, sync_every=4, track_history=False)
    ref = run_partitioner(algo, G, K, **common)
    with tempfile.TemporaryDirectory() as td:
        run_partitioner(algo, G, K, checkpoint_dir=td, checkpoint_every=4,
                        **dict(common, max_steps=cut))
        res = run_partitioner(algo, G, K, checkpoint_dir=td,
                              checkpoint_every=4, resume=True, **common)
        assert res.resumed_from > 0
        assert res.steps == ref.steps
        np.testing.assert_array_equal(ref.labels, res.labels)


def test_resume_with_checkpointing_changes_nothing():
    common = dict(seed=3, max_steps=16, sync_every=4, track_history=False)
    ref = run_partitioner("revolver", G, K, **common)
    with tempfile.TemporaryDirectory() as td:
        on = run_partitioner("revolver", G, K, checkpoint_dir=td,
                             checkpoint_every=4, **common)
        np.testing.assert_array_equal(ref.labels, on.labels)
        # resume=True with no checkpoint on disk is a fresh run
        fresh = run_partitioner("revolver", G, K,
                                checkpoint_dir=td + "/empty", resume=True,
                                **common)
        assert fresh.resumed_from == 0
        np.testing.assert_array_equal(ref.labels, fresh.labels)


def test_resume_skips_corrupt_newest_checkpoint():
    common = dict(seed=3, max_steps=16, sync_every=4, track_history=False)
    ref = run_partitioner("revolver", G, K, **common)
    with tempfile.TemporaryDirectory() as td:
        run_partitioner("revolver", G, K, checkpoint_dir=td,
                        checkpoint_every=4, keep_checkpoints=4, **common)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(td))
        assert len(steps) >= 2
        newest = os.path.join(td, f"step_{steps[-1]:08d}", "arrays.npz")
        with open(newest, "wb") as f:
            f.write(b"garbage")
        res = run_partitioner("revolver", G, K, checkpoint_dir=td,
                              checkpoint_every=4, resume=True, **common)
        # fell back to the previous checkpoint and still finished exactly
        assert res.resumed_from == steps[-2]
        np.testing.assert_array_equal(ref.labels, res.labels)


def test_checkpoint_validation_errors():
    with pytest.raises(ValueError):
        run_partitioner("revolver", G, K, checkpoint_every=4)  # no dir
    with pytest.raises(ValueError):
        run_partitioner("revolver", G, K, resume=True)
    with pytest.raises(ValueError):
        run_partitioner("revolver", G, K, guard="rollback")
    with pytest.raises(ValueError):
        run_partitioner("revolver", G, K, guard="nonsense")
    with pytest.raises(TypeError):
        run_partitioner("hash", G, K, guard="raise")
    with tempfile.TemporaryDirectory() as td:
        # a checkpoint from different run parameters is rejected, not
        # silently resumed into the wrong trajectory
        run_partitioner("revolver", G, K, seed=3, max_steps=8, sync_every=4,
                        checkpoint_dir=td, checkpoint_every=4,
                        track_history=False)
        res = run_partitioner("revolver", G, K + 1, seed=3, max_steps=8,
                              sync_every=4, checkpoint_dir=td, resume=True,
                              track_history=False)
        assert res.resumed_from == 0   # incompatible -> fresh run


# --------------------------------------------------------------------------
# streaming kill-and-resume
# --------------------------------------------------------------------------
def _deltas():
    return list(stream_from_graph(G, n_deltas=4, seed=7))


def test_stream_resume_bit_identical():
    cfg = StreamConfig(k=K, n_blocks=8, refine_max_steps=8, sync_every=2)
    ref = StreamRunner(G.n, cfg, algo="revolver", seed=5)
    ref.run(_deltas())
    with tempfile.TemporaryDirectory() as td:
        r1 = StreamRunner(G.n, cfg, algo="revolver", seed=5,
                          checkpoint_dir=td)
        for d in _deltas()[:2]:
            r1.ingest(d)
        r1.finish()
        r2 = StreamRunner(G.n, cfg, algo="revolver", seed=5,
                          checkpoint_dir=td, resume=True)
        assert r2.delta_base == 2
        reports = r2.run(_deltas())          # full stream: skips 2, runs 2
        r2.finish()
        assert [r.delta_idx for r in reports] == [2, 3]
        np.testing.assert_array_equal(ref.labels, r2.labels)
        np.testing.assert_array_equal(ref.probs, r2.probs)
        assert ref.total_steps == r2.total_steps


def test_stream_resume_rejects_other_stream():
    cfg = StreamConfig(k=K, n_blocks=8, refine_max_steps=4, sync_every=2)
    with tempfile.TemporaryDirectory() as td:
        r1 = StreamRunner(G.n, cfg, algo="revolver", seed=5,
                          checkpoint_dir=td)
        r1.ingest(_deltas()[0])
        r1.finish()
        other = StreamRunner(
            G.n, StreamConfig(k=K + 1, n_blocks=8, refine_max_steps=4,
                              sync_every=2),
            algo="revolver", seed=5, checkpoint_dir=td, resume=True)
        assert other.delta_base == 0   # k mismatch -> fresh stream


def test_stream_kill_at_delta_point():
    cfg = StreamConfig(k=K, n_blocks=8, refine_max_steps=4, sync_every=2)
    with faults.use_plan(faults.parse_faults("nan@superstep=999")):
        # unrelated plan: the delta point fires but matches nothing
        r = StreamRunner(G.n, cfg, algo="revolver", seed=5)
        r.ingest(_deltas()[0])
        assert len(r.reports) == 1


# --------------------------------------------------------------------------
# guard policies (poison injection via use_plan)
# --------------------------------------------------------------------------
def test_guard_raise_on_nan_probs():
    with faults.use_plan("nan@superstep=5"):
        with pytest.raises(PartitionStateError):
            run_partitioner("revolver", G, K, seed=3, max_steps=16,
                            sync_every=4, track_history=False, guard="raise")


def test_guard_raise_on_bad_labels():
    # spinner recomputes every label per superstep, so the poison must land
    # on the last step of a window (0-based step 7 -> drain at steps=8) to
    # still be visible at the guard check — which is exactly when real
    # corruption would be caught, too
    with faults.use_plan("badlabel@superstep=7"):
        with pytest.raises(PartitionStateError):
            run_partitioner("spinner", G, K, seed=3, max_steps=16,
                            sync_every=4, track_history=False, guard="raise")


def test_guard_off_lets_corruption_through():
    with faults.use_plan("badlabel@superstep=7"):
        res = run_partitioner("spinner", G, K, seed=3, max_steps=8,
                              sync_every=4, track_history=False)
        assert (res.labels >= K).any()   # the poison survived: guard off


def test_guard_reinit_recovers():
    with faults.use_plan("nan@superstep=5"):
        res = run_partitioner("revolver", G, K, seed=3, max_steps=16,
                              sync_every=4, track_history=False,
                              guard="reinit-affected-vertices",
                              keep_probs=True)
    assert res.steps == 16
    assert ((res.labels >= 0) & (res.labels < K)).all()
    assert np.isfinite(res.probs).all()


def test_guard_rollback_recovers_and_rollback_without_ckpt_escalates():
    with tempfile.TemporaryDirectory() as td:
        with faults.use_plan("nan@superstep=9"):
            res = run_partitioner("revolver", G, K, seed=3, max_steps=20,
                                  sync_every=4, track_history=False,
                                  checkpoint_dir=td, checkpoint_every=4,
                                  guard="rollback-to-last-checkpoint")
        assert ((res.labels >= 0) & (res.labels < K)).all()
    with tempfile.TemporaryDirectory() as td:
        with faults.use_plan("nan@superstep=2"):
            with pytest.raises(PartitionStateError):
                run_partitioner("revolver", G, K, seed=3, max_steps=20,
                                sync_every=4, track_history=False,
                                checkpoint_dir=td, checkpoint_every=100,
                                guard="rollback")


# --------------------------------------------------------------------------
# one real SIGKILL: the env-var plan kills a subprocess run mid-way; the
# resumed process must finish bit-identically (the CI smoke leg runs the
# same flow via tools/kill_resume_check.py at larger scale)
# --------------------------------------------------------------------------
def test_subprocess_sigkill_and_resume_exact():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("REPRO_FAULTS", None)
    with tempfile.TemporaryDirectory() as td:
        base = [sys.executable, "-m", "repro.launch.partition",
                "--dataset", "WIKI", "--scale", "0.005", "--k", "4",
                "--algo", "revolver", "--seed", "3", "--max-steps", "16",
                "--sync-every", "4", "--json"]
        ref_out = os.path.join(td, "ref.npz")
        r = subprocess.run(base + ["--labels-out", ref_out], env=env,
                           capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        ckpt = base + ["--checkpoint-dir", os.path.join(td, "ckpt"),
                       "--checkpoint-every", "4"]
        victim = subprocess.run(
            ckpt, env=dict(env, REPRO_FAULTS="kill@superstep=9"),
            capture_output=True, text=True)
        assert victim.returncode == -signal.SIGKILL, (
            victim.returncode, victim.stdout + victim.stderr)
        res_out = os.path.join(td, "res.npz")
        r = subprocess.run(ckpt + ["--resume", "--labels-out", res_out],
                           env=env, capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        with np.load(ref_out) as a, np.load(res_out) as b:
            np.testing.assert_array_equal(a["revolver"], b["revolver"])


def test_subprocess_sigkill_and_resume_exact_async():
    # the async schedule at staleness_bound=1 keeps a stale halo cache that
    # never hits disk; checkpoint windows force a halo refresh before the
    # snapshot, so a SIGKILL + resume must still reproduce the uninterrupted
    # run bit-for-bit (the resumed process restarts with an empty cache at a
    # refresh-aligned superstep — the same forced refresh the reference took)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    env.pop("REPRO_FAULTS", None)
    with tempfile.TemporaryDirectory() as td:
        base = [sys.executable, "-m", "repro.launch.partition",
                "--dataset", "WIKI", "--scale", "0.005", "--k", "4",
                "--algo", "revolver", "--seed", "3", "--max-steps", "16",
                "--sync-every", "4", "--n-blocks", "8",
                "--chunk-schedule", "async", "--staleness-bound", "1",
                "--json"]
        ref_out = os.path.join(td, "ref.npz")
        r = subprocess.run(base + ["--labels-out", ref_out], env=env,
                           capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        ckpt = base + ["--checkpoint-dir", os.path.join(td, "ckpt"),
                       "--checkpoint-every", "4"]
        victim = subprocess.run(
            ckpt, env=dict(env, REPRO_FAULTS="kill@superstep=9"),
            capture_output=True, text=True)
        assert victim.returncode == -signal.SIGKILL, (
            victim.returncode, victim.stdout + victim.stderr)
        res_out = os.path.join(td, "res.npz")
        r = subprocess.run(ckpt + ["--resume", "--labels-out", res_out],
                           env=env, capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        with np.load(ref_out) as a, np.load(res_out) as b:
            np.testing.assert_array_equal(a["revolver"], b["revolver"])
