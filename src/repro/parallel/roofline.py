"""Three-term roofline from a compiled dry-run artifact (§Roofline).

  compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
  memory_s     = HLO_bytes_per_device / HBM_bw
  collective_s = collective_bytes_per_device / link_bw

HLO terms come from ``parallel.hlo_analysis`` (post-SPMD per-device
module, while-loop trip counts folded in — XLA's own cost_analysis
counts scan bodies once and is unusable here; see hlo_analysis docs).

MODEL_FLOPS uses the assignment's convention: 6·N·D for training
(N = active params, D = global tokens per step), 2·N·D for prefill,
2·N·B for decode (one token per sequence). The useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs · chips) exposes remat/duplication waste.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link
HBM_BYTES = 16 * 1024**3     # 16 GiB


def param_counts(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from abstract init."""
    from repro.models import init_lm
    shapes = jax.eval_shape(lambda k: init_lm(cfg, k),
                            jax.ShapeDtypeStruct((2,), np.uint32))
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))
    active = total
    if cfg.moe:
        moe_layers = cfg.n_layers - cfg.first_dense
        per_expert = 3 * cfg.d_model * cfg.d_ff_expert
        active -= moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total, active


def model_flops(cfg, shape) -> float:
    _, n_active = param_counts(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch       # decode: 1 tok/seq


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device
    hlo_bytes: float             # per device
    collective_bytes: float      # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # global
    useful_ratio: float
    device_mem_bytes: int | None = None
    fits_hbm: bool | None = None
    collectives: dict | None = None
    unknown_trips: int = 0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def roofline_from_costs(costs, *, cfg, shape, mesh_name: str, chips: int,
                        mem_stats=None) -> Roofline:
    compute_s = costs.flops / PEAK_FLOPS
    memory_s = costs.bytes / HBM_BW
    coll_s = costs.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    mf = model_flops(cfg, shape)
    dev_mem = None
    fits = None
    if mem_stats is not None:
        dev_mem = int(mem_stats.argument_size_in_bytes
                      + mem_stats.temp_size_in_bytes
                      + mem_stats.output_size_in_bytes
                      - mem_stats.alias_size_in_bytes)
        fits = dev_mem <= HBM_BYTES
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=costs.flops, hlo_bytes=costs.bytes,
        collective_bytes=costs.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=max(terms, key=terms.get),
        model_flops=mf,
        useful_ratio=mf / (costs.flops * chips) if costs.flops else 0.0,
        device_mem_bytes=dev_mem, fits_hbm=fits,
        collectives={k: dict(v) for k, v in costs.collectives.items()},
        unknown_trips=len(costs.unknown_trips),
    )
