"""HLO-text cost analyzer: FLOPs / HBM bytes / collective bytes with
correct while-loop (lax.scan) accounting.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body
ONCE regardless of trip count (measured in this repo: an 8-step scanned
matmul reports 1/8 of the true FLOPs). Every model here scans over
layers and over attention/sequence chunks, so XLA's own numbers are off
by orders of magnitude. This module parses ``compiled.as_text()`` (the
post-SPMD, per-device module), builds the computation call graph, reads
each while loop's trip count from its condition's compare-against
constant, and scales op costs by the product of enclosing trip counts.

Accounting rules:
  FLOPs       2 * prod(result_shape) * prod(contracting dims) for dot;
              convolutions: 2 * prod(result) * prod(kernel spatial) * Cin
              (models here have no hot convs); elementwise not counted
              (dots dominate by >100x at these shapes).
  HBM bytes   sum(operand bytes) + result bytes per kernel-level op
              (fusion internals excluded — a fusion's own operands and
              result ARE its HBM traffic under perfect fusion locality).
  collective  operand bytes of all-reduce / all-gather / reduce-scatter /
              all-to-all / collective-permute, also x trip multipliers.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_PLUMBING = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "call", "conditional", "after-all", "custom-call",
             "partition-id", "replica-id", "iota"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_ATTR_COMP_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=\%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_REF_RE = re.compile(r"%([\w.\-]+)")


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operand_str: str
    attr_str: str
    is_root: bool = False

    def result_bytes(self) -> int:
        return _shape_bytes(self.result_type)

    def operand_refs(self):
        return _REF_RE.findall(self.operand_str)

    def operand_bytes(self, symtab) -> int:
        """Operands are printed as bare %refs; resolve via the symbol table."""
        inline = _shape_bytes(self.operand_str)
        if inline:
            return inline
        return sum(_shape_bytes(symtab.get(r, "")) for r in self.operand_refs())

    def operand_shapes(self, symtab):
        shapes = _SHAPE_RE.findall(self.operand_str)
        if shapes:
            return shapes
        out = []
        for r in self.operand_refs():
            out.extend(_SHAPE_RE.findall(symtab.get(r, "")))
        return out


def _split_rhs(rhs: str):
    """'f32[2]{0} dot(f32[..] %a, ...), attrs' -> (type, opcode, operands, attrs)."""
    rhs = rhs.strip()
    # result type: tuple or single
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                break
        rtype, rest = rhs[:i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        rtype, rest = rhs[:sp], rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return rtype, rest.split("(")[0], "", ""
    opcode = m.group(1)
    # operand section: matching paren
    start = rest.find("(")
    depth = 0
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    operands = rest[start + 1:i]
    attrs = rest[i + 1:]
    return rtype, opcode, operands, attrs


def parse_computations(text: str):
    """-> {comp_name: [Op, ...]}, entry_name."""
    comps: dict[str, list[Op]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.rstrip().endswith("{") and "->" in line:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m or "=" not in line:
            continue
        name, rhs = m.group(1), m.group(2)
        if " " not in rhs:
            continue
        rtype, opcode, operands, attrs = _split_rhs(rhs)
        comps[cur].append(Op(name, opcode, rtype, operands, attrs,
                             is_root="ROOT" in line.split("=")[0]))
    return comps, entry


def _trip_count(comps, cond_name: str) -> int | None:
    """Max integer constant in the while-condition computation (the scan
    bound in the `i < N` compare; other constants are smaller)."""
    best = None
    for op in comps.get(cond_name, ()):
        if op.opcode == "constant":
            m = re.match(r"^\s*(-?\d+)\s*$", op.operand_str)
            if m:
                v = int(m.group(1))
                if best is None or v > best:
                    best = v
    return best


def _dot_flops(op: Op, symtab) -> float:
    out = 1
    for _, dims in _SHAPE_RE.findall(op.result_type):
        for d in dims.split(","):
            if d:
                out *= int(d)
    shapes = op.operand_shapes(symtab)
    if not shapes:
        return 0.0
    lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attr_str)
    contract = 1
    if m and m.group(1):
        for ax in m.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_dims):
                contract *= lhs_dims[ax]
    return 2.0 * out * contract


def _conv_flops(op: Op, symtab) -> float:
    out = 1
    for _, dims in _SHAPE_RE.findall(op.result_type):
        for d in dims.split(","):
            if d:
                out *= int(d)
    shapes = op.operand_shapes(symtab)
    if len(shapes) < 2:
        return 0.0
    k_dims = [int(d) for d in shapes[1][1].split(",") if d]
    import numpy as _np
    return 2.0 * out * float(_np.prod(k_dims[:-1])) if k_dims else 0.0


def _fusion_bytes(op: Op, comps, symtab) -> float:
    """HBM traffic of a fusion op, correcting for dynamic-slice / gather
    reads (only the slice leaves HBM) and dynamic-update-slice writes
    (in-place: only the update window is written). This is what makes a
    scan-over-layers step report one layer's params per iteration rather
    than the whole stack."""
    m = re.search(r"calls=\%?([\w.\-]+)", op.attr_str)
    if not m or m.group(1) not in comps:
        return op.operand_bytes(symtab) + op.result_bytes()
    inner_ops = comps[m.group(1)]
    inner_tab = {o.name: o.result_type for o in inner_ops}
    params = {}
    for o in inner_ops:
        if o.opcode == "parameter":
            pm = re.match(r"^\s*(\d+)\s*$", o.operand_str)
            if pm:
                params[int(pm.group(1))] = o.name

    read = 0.0
    for i, _ in enumerate(op.operand_refs()):
        pname = params.get(i)
        full = _shape_bytes(symtab.get(op.operand_refs()[i], ""))
        if pname is None:
            read += full
            continue
        consumers = [o for o in inner_ops if pname in o.operand_refs()]
        if consumers and all(
                o.opcode in ("dynamic-slice", "gather")
                and o.operand_refs()[0] == pname for o in consumers):
            read += sum(o.result_bytes() for o in consumers)
        elif consumers and all(
                o.opcode == "dynamic-update-slice"
                and o.operand_refs()[0] == pname for o in consumers):
            read += 0.0          # aliased in-place target: no read
        else:
            read += full

    roots = [o for o in inner_ops if o.is_root] or inner_ops[-1:]
    write = 0.0
    for r in roots:
        if r.opcode == "dynamic-update-slice" and len(r.operand_refs()) > 1:
            write += _shape_bytes(inner_tab.get(r.operand_refs()[1], ""))
        else:
            write += op.result_bytes()
    return read + write


def _plain_op_bytes(op: Op, symtab) -> float:
    if op.opcode in ("dynamic-slice", "gather"):
        idx = sum(_shape_bytes(symtab.get(r, ""))
                  for r in op.operand_refs()[1:])
        return 2.0 * op.result_bytes() + idx
    if op.opcode == "dynamic-update-slice" and len(op.operand_refs()) > 1:
        upd = _shape_bytes(symtab.get(op.operand_refs()[1], ""))
        return 2.0 * upd
    return op.operand_bytes(symtab) + op.result_bytes()


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "bytes": 0.0}))
    while_trips: dict = dataclasses.field(default_factory=dict)
    unknown_trips: list = dataclasses.field(default_factory=list)
    bytes_by_shape: dict = dataclasses.field(default_factory=dict)
    coll_by_shape: dict = dataclasses.field(default_factory=dict)

    def top_shapes(self, n=12):
        return sorted(self.bytes_by_shape.items(), key=lambda kv: -kv[1])[:n]

    def top_coll(self, n=12):
        return sorted(self.coll_by_shape.items(), key=lambda kv: -kv[1])[:n]

    def summary(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": {k: dict(v) for k, v in self.collectives.items()},
        }


def analyze_hlo_text(text: str) -> HloCosts:
    comps, entry = parse_computations(text)
    costs = HloCosts()
    if entry is None:
        return costs

    # ---- build multipliers over the call graph -----------------------------
    mult: dict[str, float] = defaultdict(float)
    fusion_body: set[str] = set()
    mult[entry] = 1.0
    work = [entry]
    seen_edges = set()
    while work:
        comp = work.pop()
        m = mult[comp]
        for op in comps.get(comp, ()):
            refs = _ATTR_COMP_RE.findall(op.attr_str)
            if op.opcode == "while":
                cond = re.search(r"condition=\%?([\w.\-]+)", op.attr_str)
                body = re.search(r"body=\%?([\w.\-]+)", op.attr_str)
                trip = _trip_count(comps, cond.group(1)) if cond else None
                if trip is None:
                    trip = 1
                    costs.unknown_trips.append(op.name)
                costs.while_trips[op.name] = trip
                targets = [(body.group(1), m * trip)] if body else []
                if cond:
                    targets.append((cond.group(1), m * trip))
            elif op.opcode == "fusion":
                targets = [(r, m) for r in refs]
                for r in refs:
                    fusion_body.add(r)
            else:
                targets = [(r, m) for r in refs]
            for tgt, tm in targets:
                key = (comp, tgt, tm)
                if key in seen_edges:
                    continue
                seen_edges.add(key)
                mult[tgt] += tm
                work.append(tgt)

    # ---- accumulate costs ----------------------------------------------------
    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        symtab = {op.name: op.result_type for op in ops}
        in_fusion = comp in fusion_body
        for op in ops:
            if op.opcode == "dot":
                costs.flops += m * _dot_flops(op, symtab)
            elif op.opcode == "convolution":
                costs.flops += m * _conv_flops(op, symtab)
            if in_fusion:
                continue        # bytes: fusion internals are VMEM-local
            if op.opcode in _COLLECTIVES:
                b = op.operand_bytes(symtab)
                costs.collective_bytes += m * b
                costs.collectives[op.opcode]["count"] += m
                costs.collectives[op.opcode]["bytes"] += m * b
                key = f"{op.opcode} {op.result_type.split('{')[0]}"
                costs.coll_by_shape[key] = costs.coll_by_shape.get(key, 0) + m * b
            if op.opcode == "fusion":
                b = m * _fusion_bytes(op, comps, symtab)
                costs.bytes += b
                key = f"fusion->{op.result_type.split('{')[0][:48]}"
                costs.bytes_by_shape[key] = costs.bytes_by_shape.get(key, 0) + b
                continue
            if op.opcode in _PLUMBING:
                continue
            b = m * _plain_op_bytes(op, symtab)
            costs.bytes += b
            key = f"{op.opcode}->{op.result_type.split('{')[0][:48]}"
            costs.bytes_by_shape[key] = costs.bytes_by_shape.get(key, 0) + b
    return costs


def analyze_compiled(compiled) -> HloCosts:
    return analyze_hlo_text(compiled.as_text())
