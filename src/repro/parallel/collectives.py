"""Collective helpers built on shard_map primitives.

  lse_combine / sharded_decode_attention — flash-decode over a
      seq-sharded KV cache: each shard attends to its slice, partial
      outputs are merged with the log-sum-exp combine so the cross-
      device traffic is O(B·H·D) instead of an all-gather of the cache.
  ef_int8_psum — error-feedback int8 gradient all-reduce (the DP
      gradient-compression feature; 4x wire-format reduction).
  gather_shards / psum_delta_merge / shard_chain_key / replicated_chain_key
      — the graph-partitioner's sharded-superstep primitives (Jacobi merge
      across a 1-D "blocks" mesh; see core/revolver.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lse_combine_psum(o, m, l, axis: str):
    """Merge per-shard partial attention (o, running max m, running sum l)
    across a mesh axis inside shard_map. Shapes: o [..., D]; m, l [...]."""
    m_g = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_g) * l                     # [...] corrected mass
    denom = jax.lax.psum(scale, axis)
    num = jax.lax.psum(o * scale[..., None], axis)
    return num / jnp.maximum(denom, 1e-30)[..., None]


def sharded_decode_attention(q, k_shard, v_shard, kv_len_local, axis: str,
                             *, interpret=None):
    """Flash-decode where the cache seq axis is sharded over ``axis``.

    Call inside shard_map. q [B,Hq,D] (replicated over ``axis``);
    k/v_shard [B,Hkv,S_local,D]; kv_len_local [B] valid length within
    this shard. Returns [B,Hq,D].
    """
    from repro.kernels import ops
    o, m, l = ops.decode_attention(q, k_shard, v_shard, kv_len_local,
                                   interpret=interpret, return_lse=True)
    return lse_combine_psum(o.astype(jnp.float32), m, l, axis).astype(q.dtype)


# --------------------------------------------------------------------------
# error-feedback int8 compressed all-reduce (gradient compression)
# --------------------------------------------------------------------------
def _quantize_int8(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_psum(g, err, axis: str):
    """psum(int8-quantized g+err) with error feedback.

    Returns (g_hat mean-reduced f32, new_err). Wire format is int8 (4x
    smaller than f32); the quantization residual is carried to the next
    step so the compression is unbiased in the long run.
    """
    x = g.astype(jnp.float32) + err
    q, scale = _quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    new_err = x - deq
    n = jax.lax.psum(1, axis)
    g_hat = jax.lax.psum(deq, axis) / n
    return g_hat, new_err


# --------------------------------------------------------------------------
# sharded partitioner superstep (Jacobi merge across a "blocks" mesh)
# --------------------------------------------------------------------------
def gather_shards(x, axis: str):
    """All-gather a sharded per-vertex vector back to its global shape.

    The sharded superstep's edge phase gathers neighbor labels by global
    vertex id, so each shard needs the full label vector once per superstep
    (the Jacobi sync point); everything after the gather is shard-local.
    """
    return jax.lax.all_gather(x, axis, tiled=True)


def psum_delta_merge(base, delta, axis: str):
    """``base + psum(delta)`` — merge shard-local counter deltas.

    The per-partition load vector b(l) stays exact under this merge: each
    shard accumulates only the degree deltas of its own migrations, and the
    deltas are integer-valued f32 (vertex outdegrees), so the psum neither
    loses precision (below 2^24 edges) nor double-counts. On one shard this
    degenerates to ``base + delta`` bit-exactly.
    """
    return base + jax.lax.psum(delta, axis)


def vertex_halo_exchange(x, send_ids, axis: str, wire_dtype=None):
    """Per-vertex (sub-block) halo sync: one ragged all-to-all.

    ``x`` is the shard's local per-vertex slice ``[local_n]``;
    ``send_ids[s, t]`` (replicated ``[S, S, h_max]`` int32, see
    `repro.core.halo.build_halo_spec`) lists the local rows shard ``s``
    sends to shard ``t``, 0-padded. Each shard gathers the rows it owes
    every peer and a single ``all_to_all`` routes them: the returned
    ``[S * h_max]`` tail holds, at ``t * h_max + p``, the p-th vertex this
    shard needs from shard ``t`` — exactly the positions the host-side slab
    rewrite points at. Cross-device traffic is ``(S-1) * h_max`` elements
    per field (the self-chunk never leaves the device), vs
    ``(S-1) * b_max * block_v`` for the block-granularity exchange.

    ``wire_dtype`` (e.g. ``jnp.int8`` for label-valued fields when
    ``k <= 127``) narrows the wire format of the exchange: values are cast
    before the all_to_all and restored after, an *exact* round trip for
    in-range values — the same wire-compression move as `ef_int8_psum`,
    worth another 4x in bytes on top of the need-list reduction.

    The rows delivered are the same start-of-superstep snapshots the full
    gather would deliver, so the per-vertex plan is an exact optimization
    of the Jacobi sync (bit-identity gated by tests and the scaling bench).

    Because the gathered rows are start-of-superstep values with no data
    dependency on the current scan, the ``"async"`` schedule issues this
    exchange concurrently with the interior block scan (the tail is only
    consumed by the boundary blocks) — see `repro.core.engine` and
    docs/async-superstep.md.
    """
    n_shards, _, h_max = send_ids.shape
    if h_max == 0:                    # no cross-shard references at all
        return jnp.zeros((0,), x.dtype)
    idx = jax.lax.axis_index(axis)
    mine = jnp.take(send_ids, idx, axis=0)                    # [S, h_max]
    contrib = jnp.take(x, mine.reshape(-1), axis=0).reshape(mine.shape)
    if wire_dtype is not None:
        contrib = contrib.astype(wire_dtype)
    recv = jax.lax.all_to_all(contrib, axis, split_axis=0, concat_axis=0)
    return recv.reshape(-1).astype(x.dtype)


def hub_gather(x, hub_owner, hub_local, axis):
    """Assemble the replicated hub region from the owners' local slices.

    Exactly one shard owns each hub slot (`hub_owner`; pad slots carry -1
    and assemble to 0), so masking non-owners to zero and psum-ing is an
    exact broadcast — O(hub_pad) traffic per field, no carried replica
    state. With ``axis=None`` (the sequential schedule) the psum is the
    identity and owner 0 contributes directly.
    """
    vals = jnp.take(x, jnp.maximum(hub_local, 0), axis=0)
    if axis is None:
        return jnp.where(hub_owner == 0, vals, jnp.zeros_like(vals))
    idx = jax.lax.axis_index(axis)
    vals = jnp.where(hub_owner == idx, vals, jnp.zeros_like(vals))
    return jax.lax.psum(vals, axis)


def shard_chain_key(key, axis: str):
    """Per-shard PRNG chain root: shard 0 keeps ``key``, shard s folds in s.

    Keeping shard 0's chain untouched makes the 1-shard sharded schedule
    bit-identical to the sequential scan (same key chain, same draws).
    """
    idx = jax.lax.axis_index(axis)
    return jnp.where(idx == 0, key, jax.random.fold_in(key, idx))


def replicated_chain_key(key, axis: str):
    """Carry shard 0's final chained key forward as the replicated state key
    (the next superstep re-derives per-shard chains from it)."""
    return jax.lax.all_gather(key, axis)[0]
