"""Collective helpers built on shard_map primitives.

  lse_combine / sharded_decode_attention — flash-decode over a
      seq-sharded KV cache: each shard attends to its slice, partial
      outputs are merged with the log-sum-exp combine so the cross-
      device traffic is O(B·H·D) instead of an all-gather of the cache.
  ef_int8_psum — error-feedback int8 gradient all-reduce (the DP
      gradient-compression feature; 4x wire-format reduction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lse_combine_psum(o, m, l, axis: str):
    """Merge per-shard partial attention (o, running max m, running sum l)
    across a mesh axis inside shard_map. Shapes: o [..., D]; m, l [...]."""
    m_g = jax.lax.pmax(m, axis)
    scale = jnp.exp(m - m_g) * l                     # [...] corrected mass
    denom = jax.lax.psum(scale, axis)
    num = jax.lax.psum(o * scale[..., None], axis)
    return num / jnp.maximum(denom, 1e-30)[..., None]


def sharded_decode_attention(q, k_shard, v_shard, kv_len_local, axis: str,
                             *, interpret=None):
    """Flash-decode where the cache seq axis is sharded over ``axis``.

    Call inside shard_map. q [B,Hq,D] (replicated over ``axis``);
    k/v_shard [B,Hkv,S_local,D]; kv_len_local [B] valid length within
    this shard. Returns [B,Hq,D].
    """
    from repro.kernels import ops
    o, m, l = ops.decode_attention(q, k_shard, v_shard, kv_len_local,
                                   interpret=interpret, return_lse=True)
    return lse_combine_psum(o.astype(jnp.float32), m, l, axis).astype(q.dtype)


# --------------------------------------------------------------------------
# error-feedback int8 compressed all-reduce (gradient compression)
# --------------------------------------------------------------------------
def _quantize_int8(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_psum(g, err, axis: str):
    """psum(int8-quantized g+err) with error feedback.

    Returns (g_hat mean-reduced f32, new_err). Wire format is int8 (4x
    smaller than f32); the quantization residual is carried to the next
    step so the compression is unbiased in the long run.
    """
    x = g.astype(jnp.float32) + err
    q, scale = _quantize_int8(x)
    deq = q.astype(jnp.float32) * scale
    new_err = x - deq
    n = jax.lax.psum(1, axis)
    g_hat = jax.lax.psum(deq, axis) / n
    return g_hat, new_err
