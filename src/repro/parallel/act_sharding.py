"""Mesh context + opt-in activation-sharding hooks.

``use_activation_sharding(mesh, sp=..., moe_shardmap=...)`` makes the
mesh visible to model code without threading it through every call:

  * ``maybe_shard_hidden(h)`` (sp=True) constrains [B,S,d] hiddens to
    the sequence-parallel layout P(dp, "model", None).
  * ``current_mesh()`` lets the MoE layer pick its shard_map dispatch
    path (explicit local-expert compute + one psum over "model" instead
    of XLA's scatter-resharding fallback).

With no context active every hook is a no-op and models stay
mesh-agnostic (single-device tests).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: object
    sp: bool = False             # sequence-parallel hidden constraints
    moe_shardmap: bool = True    # shard_map MoE dispatch
    bf16_silu: bool = False      # activation-dtype silu/swiglu (perf knob)
    moe_ep2d: bool = False       # cross-pod EP (experts over pod x model)


def get_ctx() -> MeshCtx | None:
    return getattr(_STATE, "ctx", None)


def current_mesh():
    ctx = get_ctx()
    return ctx.mesh if ctx else None


@contextlib.contextmanager
def use_activation_sharding(mesh, *, enabled: bool = True, sp: bool | None = None,
                            moe_shardmap: bool = True, bf16_silu: bool = False,
                            moe_ep2d: bool = False):
    """enabled=False -> no context at all. sp defaults to ``enabled``'s
    legacy meaning only when explicitly passed."""
    prev = getattr(_STATE, "ctx", None)
    if mesh is None:
        _STATE.ctx = None
        try:
            yield
        finally:
            _STATE.ctx = prev
        return
    _STATE.ctx = MeshCtx(mesh=mesh, sp=bool(enabled if sp is None else sp),
                         moe_shardmap=moe_shardmap, bf16_silu=bf16_silu,
                         moe_ep2d=moe_ep2d)
    with jax.set_mesh(mesh):
        try:
            yield
        finally:
            _STATE.ctx = prev


def dp_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def maybe_shard_hidden(x):
    """Constrain [B, S, d] (or [B, S]) activations to the SP layout.

    Megatron-SP discipline: ONLY the residual stream (the per-layer remat
    residual) lives seq-sharded; compute consumers must re-gather via
    ``maybe_gather_hidden`` first — constraining the stream alone and
    letting XLA propagate seq-sharding into the attention scans causes a
    resharding storm (measured: 33k all-gathers on command-r; §Perf A1).
    """
    ctx = get_ctx()
    if ctx is None or not ctx.sp:
        return x
    mesh = ctx.mesh
    dp = dp_axes_of(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in dp]))
    msz = int(mesh.shape.get("model", 1))
    spec = [None] * x.ndim
    if x.shape[0] % dsz == 0:
        spec[0] = dp
    if x.ndim >= 2 and x.shape[1] % msz == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def maybe_gather_hidden(x):
    """SP counterpart: bring [B, S, d] back to the replicated-seq layout
    before attention/FFN (the Megatron-SP `g` all-gather point)."""
    ctx = get_ctx()
    if ctx is None or not ctx.sp:
        return x
    mesh = ctx.mesh
    dp = dp_axes_of(mesh)
    dsz = int(np.prod([mesh.shape[a] for a in dp]))
    spec = [None] * x.ndim
    if x.shape[0] % dsz == 0:
        spec[0] = dp
    return jax.lax.with_sharding_constraint(x, P(*spec))
