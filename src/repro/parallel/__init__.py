"""Distribution substrate: sharding rules, HLO cost analysis, roofline,
collective helpers."""
from repro.parallel.sharding import (batch_specs, cache_specs, dp_axes,
                                     param_specs, validate_specs,
                                     zero_dp_specs)
from repro.parallel.hlo_analysis import HloCosts, analyze_compiled, analyze_hlo_text
from repro.parallel.roofline import (Roofline, model_flops, param_counts,
                                     roofline_from_costs)

__all__ = [
    "param_specs", "batch_specs", "cache_specs", "zero_dp_specs",
    "validate_specs", "dp_axes",
    "HloCosts", "analyze_compiled", "analyze_hlo_text",
    "Roofline", "model_flops", "param_counts", "roofline_from_costs",
]
