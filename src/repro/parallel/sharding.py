"""Sharding rules: param/batch/cache pytrees -> PartitionSpec trees.

Logical plan (DESIGN.md §7), mesh axes ("pod",)+"data"+"model":
  * batch            -> ("pod","data") = the DP axes (when divisible)
  * vocab / heads / FFN hidden / experts / SSM channels -> "model"
  * megatron pairs: column-parallel in-projections (None,"model"),
    row-parallel out-projections ("model",None) — one all-reduce per block
  * decode caches: KV heads -> "model" when divisible, else cache seq ->
    "model" (SPMD flash-decode: XLA turns the softmax reductions over the
    sharded seq axis into small all-reduces instead of gathering the cache)
  * long_500k (batch=1): cache seq -> "data" as well

Stacked layer params ([L, ...] from scan-over-layers) get leading None
axes by stack depth of their top-level collection.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# stack depth of each top-level param collection (leading scan axes)
_STACK_DEPTH = {
    "blocks": 1, "dense_blocks": 1, "enc_blocks": 1, "dec_blocks": 1,
    "trailing": 1, "mamba": 2, "lora": 1,
}

# ordered (regex on "a/b/c" path, base spec for the unstacked param)
_RULES = [
    (r"(embed|unembed)/emb$", ("model", None)),
    (r"dec_pos$", (None, None)),
    # attention projections (megatron column/row)
    (r"(wq|wk|wv|wq_b|wk_b|wv_b)/w$", (None, "model")),
    (r"(wq|wk|wv|wq_b|wk_b|wv_b)/b$", ("model",)),
    (r"wo/w$", ("model", None)),
    (r"wo/b$", (None,)),
    (r"(wq_a|wkv_a)/w$", (None, None)),          # low-rank stems: replicated
    # dense mlp
    (r"(w_gate|w_up)/w$", (None, "model")),
    (r"(w_gate|w_up)/b$", ("model",)),
    (r"w_down/w$", ("model", None)),
    (r"w_down/b$", (None,)),
    # moe (expert-parallel over "model"; raw [E, ...] arrays)
    (r"moe/(w_gate|w_up|w_down)$", ("model", None, None)),
    (r"router/w$", (None, None)),
    # mamba2 (split projections; B/C/dt replicated per SSD TP)
    (r"(in_z|in_x)/w$", (None, "model")),
    (r"(in_bc|in_dt)/w$", (None, None)),
    (r"conv_w_x$", (None, "model")),
    (r"conv_b_x$", ("model",)),
    (r"conv_w_bc$", (None, None)),
    (r"conv_b_bc$", (None,)),
    (r"(A_log|D|dt_bias)$", ("model",)),
    (r"mix/norm/g$", ("model",)),                # gated-rmsnorm over d_inner
    (r"out_proj/w$", ("model", None)),
    # rwkv6 time mix
    (r"time/(wr|wk|wv|wg)/w$", (None, "model")),
    (r"time/wo/w$", ("model", None)),
    (r"time/w0$", ("model",)),
    (r"decay_w2$", (None, "model")),
    (r"time/u$", ("model", None)),
    (r"ln_x/(g|b)$", ("model",)),
    # rwkv6 channel mix
    (r"chan/wk/w$", (None, "model")),
    (r"chan/wv/w$", ("model", None)),
    (r"chan/wr/w$", (None, None)),
    # zamba2 shared block extras
    (r"shared/out/w$", ("model", None)),
    (r"lora/(q|k|v)/a$", (None, None)),
    (r"lora/(q|k|v)/b$", (None, "model")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _base_spec(path_str: str):
    for rx, spec in _RULES:
        if re.search(rx, path_str):
            return spec
    return ()


def param_specs(params_shape, *, cfg=None, mesh=None, moe_ep2d=False) -> object:
    """Pytree of PartitionSpec matching a params pytree (or eval_shape of it).

    When cfg/mesh are given, attention projections whose HEAD COUNT does
    not divide the model-axis size are replicated instead of column-
    sharded (Megatron GQA rule: a fractional head per device forces XLA
    to re-gather K/V each layer — replicating small-n_kv projections is
    strictly cheaper). Applies to q as well (internvl's 14 heads,
    whisper's 8, vs model=16).
    """
    msz = int(mesh.shape.get("model", 1)) if mesh is not None else 1

    def heads_ok(ps: str) -> bool:
        if cfg is None or msz == 1:
            return True
        if re.search(r"(wq|wq_b)/[wb]$", ps):
            return cfg.n_heads % msz == 0
        if re.search(r"(wk|wv|wk_b|wv_b)/[wb]$", ps):
            n_kv = cfg.n_kv or cfg.n_heads
            return n_kv % msz == 0
        if re.search(r"wo/w$", ps):
            return cfg.n_heads % msz == 0
        return True

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        top = ps.split("/", 1)[0]
        depth = _STACK_DEPTH.get(top, 0)
        base = _base_spec(ps)
        if not heads_ok(ps):
            base = ()
        if moe_ep2d and re.search(r"moe/(w_gate|w_up|w_down)$", ps):
            base = (("pod", "model"), None, None)   # cross-pod EP storage
        spec = (None,) * depth + tuple(base)
        nd = len(leaf.shape)
        spec = list((spec + (None,) * nd)[:nd])
        if mesh is not None:    # auto-repair: drop non-dividing axes
            for ax, part in enumerate(spec):
                if part is None:
                    continue
                names = part if isinstance(part, tuple) else (part,)
                size = int(np.prod([mesh.shape[n] for n in names]))
                if leaf.shape[ax] % size:
                    spec[ax] = None   # e.g. whisper's vocab 51865 vs 16
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _squeeze_axis(ax):
    """A 1-tuple PartitionSpec entry shards identically to its scalar;
    normalize so spec entries compare stably against axis names."""
    if isinstance(ax, tuple) and len(ax) == 1:
        return ax[0]
    return ax


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def batch_specs(batch_shape, mesh) -> object:
    """Shard the leading batch axis over the DP axes when divisible."""
    dp = dp_axes(mesh)
    dsz = _dp_size(mesh)

    def leaf_spec(path, leaf):
        nd = len(leaf.shape)
        if leaf.shape and leaf.shape[0] % dsz == 0:
            return P(*((dp,) + (None,) * (nd - 1)))
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_shape)


def _model_size(mesh) -> int:
    return int(mesh.shape.get("model", 1))


def cache_specs(cfg, cache_shape, mesh) -> object:
    """Decode-cache sharding (see module docstring)."""
    dp = dp_axes(mesh)
    dsz = _dp_size(mesh)
    msz = _model_size(mesh)

    def leaf_spec(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        top = ps.split("/", 1)[0]
        if top == "pos":
            return P(dp) if shape and shape[0] % dsz == 0 else P(None)
        spec = [None] * nd

        if top in ("main", "dense", "self", "cross", "kv"):
            if nd == 5:          # gqa KV: [L, B, H, S, D]
                b_ax, h_ax, s_ax = 1, 2, 3
            elif nd == 4:        # mla latent: [L, B, S, R]
                b_ax, h_ax, s_ax = 1, None, 2
            else:
                return P(*spec)
            batch_ok = shape[b_ax] % dsz == 0
            if batch_ok:
                spec[b_ax] = dp
            if h_ax is not None and shape[h_ax] % msz == 0:
                spec[h_ax] = "model"
            elif shape[s_ax] % msz == 0:
                spec[s_ax] = "model"           # SPMD flash-decode
            if not batch_ok and spec[s_ax] is None and \
                    shape[s_ax] % (dsz * 1) == 0:
                spec[s_ax] = _squeeze_axis(dp)  # long-context: seq over data
            elif not batch_ok and spec[s_ax] == "model" and \
                    shape[s_ax] % (dsz * msz) == 0:
                spec[s_ax] = ("model",) + dp   # seq over both
            return P(*spec)

        if top in ("ssm", "trail_ssm"):
            # [*stack, B, ...states]; stack depth 2 for grouped, 1 trailing
            depth = 2 if top == "ssm" else 1
            b_ax = depth
            if shape[b_ax] % dsz == 0:
                spec[b_ax] = dp
            # shard head/channel axis (first axis after batch) over model
            if nd > b_ax + 1 and shape[b_ax + 1] % msz == 0:
                spec[b_ax + 1] = "model"
            return P(*spec)

        if top == "wkv":                        # [L, B, H, N, N]
            if shape[1] % dsz == 0:
                spec[1] = dp
            if shape[2] % msz == 0:
                spec[2] = "model"
            return P(*spec)

        if top in ("x_time", "x_chan"):         # [L, B, 1, d]
            if shape[1] % dsz == 0:
                spec[1] = dp
            return P(*spec)

        if top == "h0":                         # [B, 1, d]
            if shape[0] % dsz == 0:
                spec[0] = dp
            return P(*spec)

        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def zero_dp_specs(specs, shapes, mesh) -> object:
    """ZeRO-style extension: additionally shard large leaves over "data"
    on the first free, divisible axis (used for optimizer moments and the
    fp32 master copy)."""
    dsz = int(mesh.shape.get("data", 1))

    def extend(spec, leaf):
        shape = leaf.shape
        if int(np.prod(shape or (1,))) < (1 << 20):
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        for ax, dim in enumerate(shape):
            if parts[ax] is None and dim % dsz == 0:
                parts[ax] = "data"
                return P(*parts)
        return spec

    return jax.tree.map(extend, specs, shapes)


def validate_specs(specs, shapes, mesh) -> list[str]:
    """Return a list of leaves whose spec doesn't divide the shape."""
    bad = []

    def check(path, spec, leaf):
        for ax, part in enumerate(spec):
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            size = int(np.prod([mesh.shape[n] for n in names]))
            if leaf.shape[ax] % size:
                bad.append(f"{_path_str(path)}: {leaf.shape} vs {spec}")

    jax.tree_util.tree_map_with_path(check, specs, shapes)
    return bad
