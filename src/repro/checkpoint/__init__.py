"""Sharded checkpointing (npz + manifest, async, elastic re-shard)."""
from repro.checkpoint.store import (
    CheckpointError,
    all_steps,
    latest_step,
    load_checkpoint_arrays,
    load_manifest,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointError",
    "all_steps",
    "latest_step",
    "load_checkpoint_arrays",
    "load_manifest",
    "restore_checkpoint",
    "save_checkpoint",
]
