"""Sharded pytree checkpointing: npz payload + JSON manifest, async save,
elastic restore (re-shard onto a different mesh).

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/manifest.json
Writes go to a tmp dir renamed into place, so a checkpoint directory is
either absent or complete — a crash mid-save can't corrupt resume.
Restore loads host arrays and ``jax.device_put``s them with the target
sharding, which is exactly the elastic mesh-to-mesh re-shard path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *, async_save=False):
    """Returns a handle with .wait() (no-op handle when synchronous)."""
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "::"): v for k, v in host.items()})
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        t = threading.Thread(target=_write)
        t.start()

        class Handle:
            def wait(self):
                t.join()
        return Handle()
    _write()

    class Done:
        def wait(self):
            pass
    return Done()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding for
    elastic placement onto the current mesh; None = default device."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        host = {k.replace("::", "/"): z[k] for k in z.files}
    flat_like, treedef = _flatten(like)
    missing = set(flat_like) - set(host)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    flat_sh = _flatten(shardings)[0] if shardings is not None else {}
    leaves = []
    for key, leaf in flat_like.items():
        arr = host[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} vs expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        sh = flat_sh.get(key)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    # rebuild in treedef order (flatten order is deterministic)
    return jax.tree_util.tree_unflatten(treedef, leaves)
