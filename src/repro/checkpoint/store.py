"""Sharded pytree checkpointing: npz payload + JSON manifest, async save,
elastic restore (re-shard onto a different mesh).

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/manifest.json
Writes go to a tmp dir renamed into place after the payload and manifest
are fsynced, so a checkpoint directory is either absent or complete and
durable — a crash mid-save leaves only a ``.tmp`` dir that `latest_step`
ignores. Restore loads host arrays and ``jax.device_put``s them with the
target sharding, which is exactly the elastic mesh-to-mesh re-shard path.

The manifest carries an opaque ``meta`` dict (JSON) alongside the array
inventory; runners use it for host-side loop state (score-stall counters,
step counts) that must survive a crash with the device state.

Fault-injection points (`repro.faults`, no-ops unless a plan is active):
``save-payload`` after the npz write, ``save`` right before the atomic
rename — the two torn-write shapes a resume must tolerate.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import List, Optional

import jax
import numpy as np

from repro import faults

_FORMAT = 1


class CheckpointError(ValueError):
    """An on-disk checkpoint exists but cannot be read back (corrupt or
    truncated payload, unreadable manifest). Subclasses ValueError so
    callers catching the store's shape/dtype errors catch this too."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out, treedef


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Handle:
    """Async-save handle. `wait()` joins the writer thread and re-raises
    anything it raised — a swallowed ENOSPC is a checkpoint that does not
    exist when the resume needs it."""

    def __init__(self, thread: Optional[threading.Thread] = None):
        self._thread = thread
        self._exc: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc


def save_checkpoint(ckpt_dir: str, step: int, tree, *, async_save=False,
                    meta: Optional[dict] = None,
                    keep: Optional[int] = None) -> Handle:
    """Write one checkpoint; returns a `Handle` (`wait()` is a no-op when
    synchronous, and re-raises writer-thread failures when async).

    The device->host snapshot happens *before* this returns (one bundled
    ``jax.device_get``; leaves that are already host numpy arrays are
    taken as-is), so async saves are safe against donated buffers being
    overwritten by the next superstep. ``meta`` is stored in the manifest;
    ``keep`` prunes all but the newest N complete checkpoints after the
    rename (crash-safe: pruning only ever removes older, complete steps).
    """
    flat, _ = _flatten(tree)
    keys = list(flat)
    vals = [flat[k] for k in keys]
    if any(isinstance(v, jax.Array) for v in vals):
        vals = jax.device_get(vals)
    host = {k: np.asarray(v) for k, v in zip(keys, vals)}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **{k.replace("/", "::"): v for k, v in host.items()})
            f.flush()
            os.fsync(f.fileno())
        faults.fire("save-payload")
        manifest = {
            "format": _FORMAT,
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        faults.fire("save")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(ckpt_dir)
        if keep is not None and keep > 0:
            for old in all_steps(ckpt_dir)[:-keep]:
                shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:08d}"),
                              ignore_errors=True)

    handle = Handle()
    if async_save:
        def _guarded():
            try:
                _write()
            except BaseException as e:   # re-raised by Handle.wait
                handle._exc = e

        handle._thread = threading.Thread(target=_guarded, daemon=True)
        handle._thread.start()
        return handle
    _write()
    return handle


def _manifest_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """Read one checkpoint's manifest; `CheckpointError` if unreadable."""
    try:
        with open(_manifest_path(ckpt_dir, step)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(
            f"unreadable manifest for step {step} in {ckpt_dir}: {e}") from e
    if "step" not in manifest or "keys" not in manifest:
        raise CheckpointError(
            f"manifest for step {step} in {ckpt_dir} lacks required keys")
    return manifest


def _valid(ckpt_dir: str, step: int) -> bool:
    try:
        load_manifest(ckpt_dir, step)
        return True
    except CheckpointError:
        return False


def all_steps(ckpt_dir: str) -> List[int]:
    """Sorted steps of *complete* checkpoints: a ``step_<n>`` dir counts
    only if its manifest exists and parses — half-written ``.tmp`` dirs and
    directories with a missing/corrupt manifest are skipped, never
    returned as a resume candidate."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        try:
            step = int(d.split("_")[1])
        except (IndexError, ValueError):
            continue
        if _valid(ckpt_dir, step):
            steps.append(step)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint_arrays(ckpt_dir: str, step: int):
    """Raw host-side load: ``(arrays, manifest)`` with arrays keyed by the
    flattened tree path. No ``like`` structure needed — the entry point for
    callers whose array shapes are data-dependent (the streaming CSR state)
    and for tools inspecting a checkpoint directly."""
    manifest = load_manifest(ckpt_dir, step)
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    try:
        with np.load(path) as z:
            arrays = {k.replace("::", "/"): z[k] for k in z.files}
    except Exception as e:
        raise CheckpointError(
            f"corrupt checkpoint payload for step {step} in {ckpt_dir}: "
            f"{e}") from e
    missing = set(manifest["keys"]) - set(arrays)
    if missing:
        raise CheckpointError(
            f"checkpoint payload for step {step} lacks arrays listed in its "
            f"manifest: {sorted(missing)[:5]} ...")
    return arrays, manifest


def restore_checkpoint(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding for
    elastic placement onto the current mesh; None = default device."""
    host, _ = load_checkpoint_arrays(ckpt_dir, step)
    flat_like, treedef = _flatten(like)
    missing = set(flat_like) - set(host)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")

    flat_sh = _flatten(shardings)[0] if shardings is not None else {}
    leaves = []
    for key, leaf in flat_like.items():
        arr = host[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt {arr.shape} vs expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        sh = flat_sh.get(key)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.device_put(arr))
    # rebuild in treedef order (flatten order is deterministic)
    return jax.tree_util.tree_unflatten(treedef, leaves)
