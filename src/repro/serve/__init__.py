"""Serving engine (batched prefill + decode)."""
from repro.serve.engine import Engine, GenerationResult
__all__ = ["Engine", "GenerationResult"]
