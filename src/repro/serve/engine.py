"""Batched serving engine: prefill + step-wise decode with sampling.

Minimal continuous-batching shape: a fixed pool of B slots, each with
its own cache position; finished sequences are masked. jit-compiled
prefill and decode steps are shared across requests of the same padded
length bucket.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import init_cache, lm_decode_step, lm_prefill


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # [B, max_new]
    logprobs: jnp.ndarray        # [B, max_new]


def _sample(key, logits, temperature: float):
    if temperature == 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        tok = jax.random.categorical(key, logits / temperature, axis=-1)
    lp = jax.nn.log_softmax(logits, axis=-1)
    return tok.astype(jnp.int32), jnp.take_along_axis(
        lp, tok[:, None].astype(jnp.int32), axis=-1)[:, 0]


class Engine:
    def __init__(self, cfg, params, *, s_max: int, eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.s_max = s_max
        self.eos_id = eos_id
        self._prefill = jax.jit(partial(lm_prefill, cfg=cfg))
        self._decode = jax.jit(partial(lm_decode_step, cfg=cfg))

    def generate(self, prompts, *, max_new: int, temperature: float = 0.0,
                 key=None, frontend=None) -> GenerationResult:
        """prompts [B, Sp] int32 (left-aligned, equal length bucket)."""
        b = prompts.shape[0]
        key = key if key is not None else jax.random.PRNGKey(0)
        cache = init_cache(self.cfg, b, self.s_max)
        batch = {"tokens": prompts}
        if frontend is not None:
            batch["frontend"] = frontend
        logits, cache = self._prefill(self.params, cache=cache, batch=batch)

        toks, lps = [], []
        done = jnp.zeros((b,), bool)
        for i in range(max_new):
            key, sub = jax.random.split(key)
            tok, lp = _sample(sub, logits, temperature)
            if self.eos_id is not None:
                done = done | (tok == self.eos_id)
                tok = jnp.where(done, self.eos_id or 0, tok)
            toks.append(tok)
            lps.append(lp)
            if i + 1 < max_new:
                logits, cache = self._decode(self.params, cache=cache,
                                             token=tok)
        return GenerationResult(jnp.stack(toks, 1), jnp.stack(lps, 1))
