"""Observability subsystem: superstep tracing, engine counters, perfetto
export. See docs/observability.md for the span taxonomy, counter glossary,
and the overhead contract."""
from repro.obs.tracer import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    annotate,
    current,
    record_compile,
    use,
)

__all__ = [
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "annotate",
    "current",
    "record_compile",
    "use",
]
