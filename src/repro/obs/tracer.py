"""Host-side tracing + metrics for the partitioner engine.

The engine is asynchronous by design: supersteps are dispatched without
blocking and scores come back in `sync_every`-sized windows, so the only
honest places to *measure* are the host-visible boundaries — superstep
dispatch, the windowed device sync, layout builds, jit (re)compiles — plus
whatever per-superstep scalars can ride the existing drain windows without
adding host syncs. This module records exactly those:

  * **Spans** — nested wall-clock regions (`Tracer.span`) emitted as
    Chrome/perfetto trace-event JSON (`Tracer.save` -> load the file at
    https://ui.perfetto.dev). Spans opened *inside* jitted code via
    `annotate` fire once per trace (XLA compiles the region; Python runs it
    only at trace time) — they are tagged ``during="trace"`` and nest under
    the superstep span that triggered the compile, giving the phase
    structure (edge-phase / la-update / halo-exchange) of every compiled
    superstep variant. `annotate` also opens a `jax.named_scope` (and a
    `jax.profiler.TraceAnnotation` when available) so the same names line
    up inside an XLA device profile captured with `jax.profiler.trace`.
  * **Counters** — per-superstep series (`Tracer.counter`) emitted as
    trace-event counter tracks and retained in `Tracer.series` for reports
    and bench artifacts.
  * **Recompile events** — the engine's jitted superstep bodies call
    `obs.record_compile(...)` as their first statement, which fires exactly
    once per jit-cache miss. The tracer attributes a cause: the first event
    per region is ``first-compile``; a caller that knows *why* shapes
    changed (streaming's `e_max` re-pad / halo widen) pre-registers the
    cause with `note_recompile_cause`; otherwise the cause is inferred by
    diffing the static shape args against the region's previous compile.

Overhead contract (pinned by tests/test_obs.py): the default `NULL_TRACER`
leaves every instrumented path bit-identical and adds no work — `span` /
`annotate` return a shared no-op context manager and every recording method
is a pass. An enabled tracer adds per-superstep host timestamps, one O(n)
device comparison for the migration counter, and counter drains that ride
the *existing* `sync_every` windows — never an additional device sync.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

_NULL_CTX = contextlib.nullcontext()


class NullTracer:
    """Default tracer: records nothing, costs (almost) nothing.

    Kept API-compatible with `Tracer` so instrumented code never branches
    on the tracer kind — it just calls the method.
    """

    enabled = False

    def span(self, name: str, **args):
        return _NULL_CTX

    def annotate(self, name: str, **args):
        return _NULL_CTX

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, value, step: Optional[int] = None,
                ts: Optional[float] = None) -> None:
        pass

    def compile_event(self, region: str, **args) -> None:
        pass

    def note_recompile_cause(self, cause: str) -> None:
        pass

    def clear_recompile_cause(self) -> None:
        pass

    def now_us(self) -> float:
        return 0.0


NULL_TRACER = NullTracer()


class Tracer:
    """Collects spans/counters/events and exports perfetto-loadable JSON.

    One `Tracer` spans one logical run (a `run_partitioner` call, a whole
    stream, a CLI invocation with several algorithms); pass it via
    ``run_partitioner(trace=...)`` / ``StreamRunner(trace=...)`` /
    ``launch partition --trace PATH`` and call `save(path)` at the end.

    `xprof=True` (default) additionally opens `jax.named_scope` +
    `jax.profiler.TraceAnnotation` inside `annotate`, so span names appear
    in XLA metadata and in device profiles captured with
    `jax.profiler.trace` — alignment is free when you are not profiling.
    """

    enabled = True

    def __init__(self, *, xprof: bool = True):
        self.events: List[Dict[str, Any]] = []
        # counter name -> [(step, value)]; step is None for run-level gauges
        self.series: Dict[str, List[Tuple[Optional[int], float]]] = {}
        self.recompiles: List[Dict[str, Any]] = []
        self.meta: Dict[str, Any] = {}
        self._pid = os.getpid()
        self._t0 = time.perf_counter_ns()
        self._pending_causes: List[str] = []
        self._last_compile_args: Dict[str, Dict[str, Any]] = {}
        self._xprof = xprof
        if xprof:
            try:
                import jax
                from jax.profiler import TraceAnnotation

                self._named_scope = jax.named_scope
                self._trace_annotation = TraceAnnotation
            except Exception:   # pragma: no cover - jax always present here
                self._xprof = False

    # ------------------------------------------------------------------ #
    # clocks / event plumbing
    # ------------------------------------------------------------------ #
    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _emit(self, ev: Dict[str, Any]) -> None:
        ev.setdefault("pid", self._pid)
        ev.setdefault("tid", threading.get_ident() & 0xFFFF)
        self.events.append(ev)

    # ------------------------------------------------------------------ #
    # spans
    # ------------------------------------------------------------------ #
    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Record a complete ("X") span around the enclosed block."""
        ts = self.now_us()
        try:
            yield self
        finally:
            self._emit({"ph": "X", "name": name, "ts": ts,
                        "dur": self.now_us() - ts,
                        "args": args or {}})

    @contextlib.contextmanager
    def annotate(self, name: str, **args):
        """Span for code that may run under `jax.jit`.

        Inside jitted code the Python block executes only while XLA traces
        it, so the recorded wall-clock is *trace* time (tagged
        ``during="trace"``) — one span per compiled variant, nested under
        the superstep that triggered the compile. The `named_scope` /
        `TraceAnnotation` side makes the same name show up inside XLA
        profiles, where the *device* time of the region lives.
        """
        args = dict(args, during="trace")
        if not self._xprof:
            with self.span(name, **args):
                yield self
            return
        with self._named_scope(name), self._trace_annotation(name), \
                self.span(name, **args):
            yield self

    def instant(self, name: str, **args) -> None:
        self._emit({"ph": "i", "s": "t", "name": name, "ts": self.now_us(),
                    "args": args or {}})

    # ------------------------------------------------------------------ #
    # counters
    # ------------------------------------------------------------------ #
    def counter(self, name: str, value, step: Optional[int] = None,
                ts: Optional[float] = None) -> None:
        """Record one point of a counter track.

        `step` indexes the superstep (or delta) the value belongs to and is
        retained in `series`; `ts` back-dates the trace event to when the
        value was *produced* (the superstep's dispatch), not when it was
        drained — counters ride the windowed sync, so the two differ by up
        to `sync_every` supersteps.
        """
        value = float(value)
        self.series.setdefault(name, []).append((step, value))
        ev: Dict[str, Any] = {"ph": "C", "name": name,
                              "ts": self.now_us() if ts is None else ts,
                              "args": {"value": value}}
        self._emit(ev)

    # ------------------------------------------------------------------ #
    # recompile events
    # ------------------------------------------------------------------ #
    def note_recompile_cause(self, cause: str) -> None:
        """Pre-register the semantic cause of the *next* compile event —
        callers that change shapes knowingly (streaming `e_max` re-pad,
        halo widen) call this right before dispatching the rebuilt
        function. Consumed by the next `compile_event`; cleared by
        `clear_recompile_cause` if no compile fired (a stale cause must not
        mis-attribute a later, unrelated recompile)."""
        if cause not in self._pending_causes:
            self._pending_causes.append(cause)

    def clear_recompile_cause(self) -> None:
        self._pending_causes = []

    def compile_event(self, region: str, **args) -> None:
        """Called (via `obs.record_compile`) from inside a jitted body —
        i.e. exactly once per jit-cache miss. Attributes a cause:
        pre-registered > first-compile > inferred static-shape diff."""
        prev = self._last_compile_args.get(region)
        if self._pending_causes:
            cause = "+".join(self._pending_causes)
            self._pending_causes = []
        elif prev is None:
            cause = "first-compile"
        else:
            changed = sorted(k for k in set(prev) | set(args)
                             if prev.get(k) != args.get(k))
            cause = ("shape-change(" + ",".join(changed) + ")"
                     if changed else "unattributed")
        self._last_compile_args[region] = dict(args)
        rec = {"region": region, "cause": cause, **args}
        self.recompiles.append(rec)
        self.instant("recompile", **rec)
        self.counter("recompiles", len(self.recompiles))

    # ------------------------------------------------------------------ #
    # export / summaries
    # ------------------------------------------------------------------ #
    def to_dict(self) -> Dict[str, Any]:
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": dict(self.meta),
        }

    def save(self, path: str) -> str:
        """Write perfetto/chrome trace-event JSON (open at ui.perfetto.dev
        or chrome://tracing)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path

    def summary(self) -> Dict[str, Any]:
        """Aggregates for bench artifacts: per-span totals, counter
        min/max/last, recompile causes. No raw series (those stay in
        `series` / the saved trace)."""
        spans: Dict[str, Dict[str, float]] = {}
        for ev in self.events:
            if ev.get("ph") != "X":
                continue
            agg = spans.setdefault(ev["name"], {"count": 0, "total_ms": 0.0})
            agg["count"] += 1
            agg["total_ms"] += ev.get("dur", 0.0) / 1e3
        counters = {
            name: {
                "points": len(pts),
                "last": pts[-1][1],
                "min": min(v for _, v in pts),
                "max": max(v for _, v in pts),
            }
            for name, pts in self.series.items() if pts
        }
        causes: Dict[str, int] = {}
        for rec in self.recompiles:
            causes[rec["cause"]] = causes.get(rec["cause"], 0) + 1
        return {
            "spans": {k: {"count": v["count"],
                          "total_ms": round(v["total_ms"], 3)}
                      for k, v in sorted(spans.items())},
            "counters": counters,
            "recompiles": len(self.recompiles),
            "recompile_causes": causes,
        }


# ---------------------------------------------------------------------------
# current-tracer plumbing (module-global; the jitted engine bodies and rule
# modules cannot take a tracer argument — it would be a jit static arg)
# ---------------------------------------------------------------------------
_current: Any = NULL_TRACER


def current():
    """The active tracer (`NULL_TRACER` unless inside a `use` block)."""
    return _current


@contextlib.contextmanager
def use(tracer):
    """Install `tracer` as the current tracer for the enclosed block (pass
    None for the no-op tracer). Entry points (`run_partitioner`,
    `StreamRunner.ingest`) wrap their whole body in this so engine- and
    rule-level instrumentation sees the caller's tracer."""
    global _current
    prev = _current
    _current = tracer if tracer is not None else NULL_TRACER
    try:
        yield _current
    finally:
        _current = prev


def annotate(name: str, **args):
    """`current().annotate(...)` — the form instrumented jit-side code uses."""
    return _current.annotate(name, **args)


def record_compile(region: str = "superstep", **args) -> None:
    """First statement of every jitted superstep body: fires once per
    jit-cache miss (the body only runs while XLA traces it), recording a
    recompile event with attributed cause. No-op when tracing is off."""
    if _current.enabled:
        _current.compile_event(region, **args)
