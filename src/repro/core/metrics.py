"""Partition-quality metrics from Section V-E of the paper."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partition_loads(labels: jax.Array, deg_out: jax.Array, k: int) -> jax.Array:
    """b(l) = sum of outdegrees of vertices assigned to l (eq. 5); sums to |E|."""
    return jnp.zeros((k,), dtype=jnp.float32).at[labels].add(deg_out.astype(jnp.float32))


def local_edges(labels: jax.Array, edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """Fraction of directed edges with both endpoints in the same partition."""
    same = (labels[edge_src] == labels[edge_dst]).astype(jnp.float32)
    return jnp.mean(same)


def edge_cuts(labels: jax.Array, edge_src: jax.Array, edge_dst: jax.Array) -> jax.Array:
    """1 - local_edges (Section V-E)."""
    return 1.0 - local_edges(labels, edge_src, edge_dst)


def max_normalized_load(labels: jax.Array, deg_out: jax.Array, k: int) -> jax.Array:
    """Max Load / Expected Load, Expected Load = |E|/k."""
    loads = partition_loads(labels, deg_out, k)
    expected = jnp.sum(loads) / k
    return jnp.max(loads) / jnp.maximum(expected, 1e-9)
