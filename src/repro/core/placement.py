"""Revolver -> MoE expert placement (the paper's technique as a
first-class feature of the LM stack; DESIGN.md §5).

The token->expert routing of a trained (or profiled) MoE layer induces a
weighted EXPERT CO-ACTIVATION GRAPH: vertices = experts, edge (i, j)
weighted by how often experts i and j fire on the same token (top-k
routing activates k experts per token). Placing co-activating experts
on the same device makes the combine step local — the cross-device
share of co-activation weight is a direct proxy for the EP dispatch/
combine traffic that is NOT intra-device.

Revolver's balanced k-way partitioning is exactly this problem:
  * vertices = experts, k = number of EP devices,
  * balance constraint = per-device expert-load balance (the biggest
    partition bounds step time — same argument as the paper §II),
  * local edges = co-activation locality (maximizing it minimizes
    cross-device combine traffic).

``place_experts`` runs Revolver on the co-activation graph and returns
a permutation mapping experts to devices; ``apply_placement`` permutes
the expert dimension of the MoE params so device d's shard holds the
experts Revolver assigned to it.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.runner import PartitionResult, run_partitioner
from repro.graphs.csr import build_graph


def coactivation_graph(top_idx: np.ndarray, n_experts: int):
    """top_idx [T, K] routed expert ids -> directed co-activation graph.

    Edge weights are sample counts collapsed to multi-edges (the CSR
    dedups parallel edges; we replicate by quantized weight so the
    partitioner's edge-balance view matches activation frequency).
    """
    top_idx = np.asarray(top_idx)
    t, k = top_idx.shape
    pairs = {}
    for a in range(k):
        for b in range(k):
            if a == b:
                continue
            src = top_idx[:, a]
            dst = top_idx[:, b]
            for s, d in zip(src, dst):
                if s != d:
                    pairs[(int(s), int(d))] = pairs.get((int(s), int(d)), 0) + 1
    if not pairs:
        # degenerate: no co-activation (top-1 routing) — ring fallback
        src = np.arange(n_experts)
        dst = (src + 1) % n_experts
        return build_graph(src, dst, n_experts), np.ones(len(src))
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    w = np.array(list(pairs.values()), dtype=np.float64)
    return build_graph(src, dst, n_experts), w


@dataclasses.dataclass
class Placement:
    expert_to_device: np.ndarray     # [E] device id per expert
    permutation: np.ndarray          # [E] new order (device-major)
    result: PartitionResult
    cross_coactivation: float        # fraction of co-activation weight cut


def place_experts(top_idx: np.ndarray, n_experts: int, n_devices: int, *,
                  seed: int = 0, max_steps: int = 120,
                  algo: str = "revolver") -> Placement:
    """Partition experts across n_devices from routing statistics."""
    g, _ = coactivation_graph(top_idx, n_experts)
    res = run_partitioner(algo, g, n_devices, seed=seed,
                          max_steps=max_steps, n_blocks=1)
    labels = np.asarray(res.labels[:n_experts])
    # balance repair: Revolver balances by out-degree; the EP shard needs
    # exactly E/n_devices experts per device -> pack greedily by label
    cap = n_experts // n_devices
    counts = np.zeros(n_devices, np.int64)
    assign = np.full(n_experts, -1, np.int64)
    order = np.argsort(-np.bincount(labels, minlength=n_devices)[labels],
                       kind="stable")
    for e in order:
        d = labels[e]
        if counts[d] < cap:
            assign[e] = d
            counts[d] += 1
    for e in np.where(assign < 0)[0]:          # overflow -> least loaded
        d = int(np.argmin(counts))
        assign[e] = d
        counts[d] += 1
    perm = np.argsort(assign, kind="stable")   # device-major expert order
    cross = _cross_fraction(top_idx, assign)
    return Placement(expert_to_device=assign, permutation=perm,
                     result=res, cross_coactivation=cross)


def _cross_fraction(top_idx: np.ndarray, assign: np.ndarray) -> float:
    """Fraction of same-token expert pairs that span two devices."""
    top_idx = np.asarray(top_idx)
    t, k = top_idx.shape
    dev = assign[top_idx]                      # [T, K]
    same = 0
    total = 0
    for a in range(k):
        for b in range(a + 1, k):
            total += t
            same += int(np.sum(dev[:, a] == dev[:, b]))
    return 1.0 - same / max(total, 1)


def apply_placement(moe_params: dict, placement: Placement) -> dict:
    """Permute the expert axis so the EP shard layout follows Revolver."""
    perm = placement.permutation
    out = dict(moe_params)
    for k in ("w_gate", "w_up", "w_down"):
        out[k] = moe_params[k][perm]
    router = dict(moe_params["router"])
    router["w"] = moe_params["router"]["w"][:, perm]
    out["router"] = router
    return out
