"""Device-resident graph layout consumed by the partitioning supersteps.

Two layouts are kept:

  * **flat** edge arrays `[M]` (src row, dst, eq.-4 weight) — used by the
    synchronous Spinner baseline and by the quality metrics;
  * **blocked** per-chunk slabs `[n_blocks, e_max]` — used by Revolver's
    chunked semi-asynchronous superstep (the TPU adaptation of the paper's
    per-thread asynchrony; see DESIGN.md §3) and by the Pallas kernels.

All per-vertex arrays are padded to `n_pad = n_blocks * block_v`; `vmask`
marks real vertices. Padding vertices carry zero degree and no edges so they
never influence loads or scores.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.graphs.blocking import block_edges
from repro.graphs.csr import Graph


class DeviceGraph(NamedTuple):
    """Static-shape device arrays for one graph. Ints are python (static)."""

    n: int
    n_pad: int
    m: int               # |E| directed edges
    n_blocks: int
    block_v: int
    e_max: int
    # flat symmetrized adjacency (for sync algorithms / metrics)
    edge_src: jnp.ndarray     # [Ms] int32 owning vertex
    edge_dst: jnp.ndarray     # [Ms] int32 neighbor
    edge_w: jnp.ndarray       # [Ms] f32 eq.(4) weight
    # flat *directed* edges (for the local-edges metric)
    dir_src: jnp.ndarray      # [M] int32
    dir_dst: jnp.ndarray      # [M] int32
    # blocked symmetrized adjacency (for async chunks / Pallas kernels)
    blk_dst: jnp.ndarray      # [n_blocks, e_max] int32 (0 pad)
    blk_row: jnp.ndarray      # [n_blocks, e_max] int32 local row (0 pad)
    blk_w: jnp.ndarray        # [n_blocks, e_max] f32 (0.0 pad)
    # per-vertex
    deg_out: jnp.ndarray      # [n_pad] f32 outdegree (load contribution)
    inv_wsum: jnp.ndarray     # [n_pad] f32 1/sum_u w_hat(u,v) (0 if isolated)
    vmask: jnp.ndarray        # [n_pad] bool real-vertex mask


def prepare_device_graph(g: Graph, n_blocks: int = 8, block_multiple: int = 8) -> DeviceGraph:
    """Build the DeviceGraph with `n_blocks` asynchronous chunks."""
    n_blocks = max(1, min(n_blocks, g.n))
    block_v = -(-g.n // n_blocks)
    block_v = -(-block_v // block_multiple) * block_multiple
    blocked = block_edges(g, block_v=block_v)
    n_blocks = blocked.n_blocks
    n_pad = blocked.n_pad

    deg_out = np.zeros(n_pad, dtype=np.float32)
    deg_out[: g.n] = g.deg_out.astype(np.float32)

    wsum = np.zeros(n_pad, dtype=np.float32)
    np.add.at(wsum, np.repeat(np.arange(g.n), np.diff(g.adj_ptr).astype(np.int64)), g.adj_w)
    inv_wsum = np.where(wsum > 0, 1.0 / np.maximum(wsum, 1e-30), 0.0).astype(np.float32)

    vmask = np.zeros(n_pad, dtype=bool)
    vmask[: g.n] = True

    src_flat = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.adj_ptr).astype(np.int64))
    dir_src = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.row_ptr).astype(np.int64))

    return DeviceGraph(
        n=g.n,
        n_pad=n_pad,
        m=g.m,
        n_blocks=n_blocks,
        block_v=blocked.block_v,
        e_max=blocked.e_max,
        edge_src=jnp.asarray(src_flat),
        edge_dst=jnp.asarray(g.adj_idx),
        edge_w=jnp.asarray(g.adj_w),
        dir_src=jnp.asarray(dir_src),
        dir_dst=jnp.asarray(g.col_idx),
        blk_dst=jnp.asarray(blocked.edge_dst),
        blk_row=jnp.asarray(blocked.edge_row),
        blk_w=jnp.asarray(blocked.edge_w),
        deg_out=jnp.asarray(deg_out),
        inv_wsum=jnp.asarray(inv_wsum),
        vmask=jnp.asarray(vmask),
    )


CAPACITY_MODES = ("spinner", "paper")


def capacity(m: int, k: int, epsilon: float, mode: str) -> float:
    """Partition capacity C.

    mode="spinner": C = (1+eps)|E|/k — Spinner's definition, the default.
    mode="paper":   C = eps|E|/k     — the literal Section III-A text (makes
                    every partition over-capacity; kept for faithfulness,
                    the footnote-1 shift in eq. (12) keeps it well-defined).
    """
    if mode == "spinner":
        return (1.0 + epsilon) * m / k
    if mode == "paper":
        return epsilon * m / k
    raise ValueError(f"unknown capacity mode {mode!r}")


@functools.lru_cache(maxsize=512)
def capacity_device(m: int, k: int, epsilon: float, mode: str) -> jnp.ndarray:
    """`capacity(...)` as a device-resident f32 scalar, cached on its inputs.

    Capacity depends only on (|E|, cfg); the supersteps call this instead of
    recomputing + re-`asarray`-ing it every step, so the same graph/config
    pair reuses one committed device buffer across the whole convergence
    loop (and across warm restarts in the streaming runner).
    """
    return jnp.asarray(capacity(m, k, epsilon, mode), jnp.float32)
