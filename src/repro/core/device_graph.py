"""Device-resident graph layout consumed by the partitioning supersteps.

Two layouts are kept:

  * **flat** edge arrays `[M]` (src row, dst, eq.-4 weight) — used by the
    synchronous Spinner baseline and by the quality metrics;
  * **blocked** per-chunk slabs `[n_blocks, e_max]` — used by Revolver's
    chunked semi-asynchronous superstep (the TPU adaptation of the paper's
    per-thread asynchrony; see DESIGN.md §3) and by the Pallas kernels.

All per-vertex arrays are padded to `n_pad = n_blocks * block_v`; `vmask`
marks real vertices. Padding vertices carry zero degree and no edges so they
never influence loads or scores.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.halo import (
    DEFAULT_HALO_THRESHOLD,
    HaloSpec,
    HubConfig,
    build_halo_spec,
)
from repro.graphs.blocking import (
    block_adjacency,
    block_edges,
    locality_block_order,
    vcycle_block_order,
)
from repro.graphs.csr import Graph


class DeviceGraph(NamedTuple):
    """Static-shape device arrays for one graph. Ints are python (static)."""

    n: int
    n_pad: int
    m: int               # |E| directed edges
    n_blocks: int
    block_v: int
    e_max: int
    # flat symmetrized adjacency (for sync algorithms / metrics)
    edge_src: jnp.ndarray     # [Ms] int32 owning vertex
    edge_dst: jnp.ndarray     # [Ms] int32 neighbor
    edge_w: jnp.ndarray       # [Ms] f32 eq.(4) weight
    # flat *directed* edges (for the local-edges metric)
    dir_src: jnp.ndarray      # [M] int32
    dir_dst: jnp.ndarray      # [M] int32
    # blocked symmetrized adjacency (for async chunks / Pallas kernels)
    blk_dst: jnp.ndarray      # [n_blocks, e_max] int32 (0 pad)
    blk_row: jnp.ndarray      # [n_blocks, e_max] int32 local row (0 pad)
    blk_w: jnp.ndarray        # [n_blocks, e_max] f32 (0.0 pad)
    # per-vertex
    deg_out: jnp.ndarray      # [n_pad] f32 outdegree (load contribution)
    inv_wsum: jnp.ndarray     # [n_pad] f32 1/sum_u w_hat(u,v) (0 if isolated)
    vmask: jnp.ndarray        # [n_pad] bool real-vertex mask


def prepare_device_graph(g: Graph, n_blocks: int = 8, block_multiple: int = 8) -> DeviceGraph:
    """Build the DeviceGraph with `n_blocks` asynchronous chunks."""
    n_blocks = max(1, min(n_blocks, g.n))
    block_v = -(-g.n // n_blocks)
    block_v = -(-block_v // block_multiple) * block_multiple
    blocked = block_edges(g, block_v=block_v)
    n_blocks = blocked.n_blocks
    n_pad = blocked.n_pad

    deg_out = np.zeros(n_pad, dtype=np.float32)
    deg_out[: g.n] = g.deg_out.astype(np.float32)

    wsum = np.zeros(n_pad, dtype=np.float32)
    np.add.at(wsum, np.repeat(np.arange(g.n), np.diff(g.adj_ptr).astype(np.int64)), g.adj_w)
    inv_wsum = np.where(wsum > 0, 1.0 / np.maximum(wsum, 1e-30), 0.0).astype(np.float32)

    vmask = np.zeros(n_pad, dtype=bool)
    vmask[: g.n] = True

    src_flat = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.adj_ptr).astype(np.int64))
    dir_src = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.row_ptr).astype(np.int64))

    return DeviceGraph(
        n=g.n,
        n_pad=n_pad,
        m=g.m,
        n_blocks=n_blocks,
        block_v=blocked.block_v,
        e_max=blocked.e_max,
        edge_src=jnp.asarray(src_flat),
        edge_dst=jnp.asarray(g.adj_idx),
        edge_w=jnp.asarray(g.adj_w),
        dir_src=jnp.asarray(dir_src),
        dir_dst=jnp.asarray(g.col_idx),
        blk_dst=jnp.asarray(blocked.edge_dst),
        blk_row=jnp.asarray(blocked.edge_row),
        blk_w=jnp.asarray(blocked.edge_w),
        deg_out=jnp.asarray(deg_out),
        inv_wsum=jnp.asarray(inv_wsum),
        vmask=jnp.asarray(vmask),
    )


# ---------------------------------------------------------------------------
# sharded (multi-device) layout: chunk_schedule="sharded"
# ---------------------------------------------------------------------------
# arrays indexed by block (or by block-major vertex) shard over "blocks";
# the flat metric arrays are replicated so eager metrics stay SPMD-legal
_BLOCKED_FIELDS = ("blk_dst", "blk_row", "blk_w")
_VERTEX_FIELDS = ("deg_out", "inv_wsum", "vmask")


@dataclasses.dataclass(frozen=True)
class ShardedDeviceGraph:
    """A `DeviceGraph` placed on a 1-D ``("blocks",)`` mesh.

    The block axis (and the block-major padded vertex axis) is sharded:
    device d owns `blocks_per_shard` contiguous blocks and the matching
    `[n_pad / n_shards]` slices of every per-vertex array, so the sharded
    superstep's edge phase reads only device-local slabs. Flat metric
    arrays are replicated. Attribute access falls through to the wrapped
    `DeviceGraph`, so metric/runner code consumes either layout unchanged.

    `n_blocks` is always a multiple of `n_shards` (see `align_blocks`):
    alignment pads with empty blocks (zero slabs, masked vertices) rather
    than resizing `block_v`, keeping per-shard shapes static and identical.

    **Locality-aware assignment** (`assignment="locality"`, or an explicit
    permutation): the stored block order is permuted so each shard's
    contiguous slice is a cluster of densely connected blocks
    (`locality_block_order`), and every vertex id in the wrapped arrays is
    rewritten into the permuted space (`permute_blocks`). `block_perm` /
    `o2s` / `s2o` record the mapping; labels and probabilities cross the
    public API boundary in *original* vertex order (`vertices_to_original`
    and the warm-start helpers convert).

    **Halo exchange** (`halo=True`): `halo` carries the precomputed
    boundary-exchange plan for `chunk_schedule="halo"`
    (see `repro.core.halo`); `None` means only the full-gather schedules
    are runnable.
    """

    dg: DeviceGraph
    mesh: jax.sharding.Mesh
    n_shards: int
    blocks_per_shard: int
    block_perm: Optional[Tuple[int, ...]] = None  # storage slot -> orig block
    o2s: Optional[np.ndarray] = None   # [n_pad] original vertex -> storage id
    s2o: Optional[np.ndarray] = None   # [n_pad] storage id -> original vertex
    halo: Optional[HaloSpec] = None
    # [n_blocks, n_blocks] block edge-cut matrix in *storage* order, filled
    # once (by the layout prep that already needed it, or lazily by
    # `block_adj_matrix`) and reused by every later consumer — locality
    # re-preps, the V-cycle, the scaling bench's traffic model
    block_adj: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __getattr__(self, name):
        return getattr(self.dg, name)

    def block_adj_matrix(self) -> np.ndarray:
        """The block-level edge-cut matrix of the stored layout, computed at
        most once per layout (cached on the instance — an `np.add.at` over
        every slab edge is an O(E) host pass worth not repeating)."""
        if self.block_adj is None:
            adj = block_adjacency(np.asarray(self.blk_dst),
                                  np.asarray(self.blk_w), self.block_v)
            object.__setattr__(self, "block_adj", adj)
        return self.block_adj


def align_blocks(dg: DeviceGraph, multiple: int) -> DeviceGraph:
    """Pad `dg` with empty blocks until `n_blocks % multiple == 0`.

    Padding blocks carry all-zero slabs (dst=0, row=0, w=0.0) and masked-out
    vertices with zero degree, exactly like the in-block padding the kernels
    already ignore, so they change no score, load, or migration.
    """
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    pad_blocks = (-dg.n_blocks) % multiple
    if pad_blocks == 0:
        return dg
    nb = dg.n_blocks + pad_blocks
    n_pad = nb * dg.block_v
    pad_v = n_pad - dg.n_pad

    def pad_rows(a, fill):
        return jnp.concatenate(
            [a, jnp.full((pad_blocks, a.shape[1]), fill, a.dtype)], axis=0)

    return dg._replace(
        n_pad=n_pad,
        n_blocks=nb,
        blk_dst=pad_rows(dg.blk_dst, 0),
        blk_row=pad_rows(dg.blk_row, 0),
        blk_w=pad_rows(dg.blk_w, 0.0),
        deg_out=jnp.pad(dg.deg_out, (0, pad_v)),
        inv_wsum=jnp.pad(dg.inv_wsum, (0, pad_v)),
        vmask=jnp.pad(dg.vmask, (0, pad_v)),
    )


def block_vertex_perms(perm: np.ndarray, block_v: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vertex-id maps induced by a block permutation.

    Returns `(o2s, s2o)` int32 `[n_blocks * block_v]` arrays: `o2s[v]` is
    the storage position of original vertex `v` (its block moved, its row
    within the block did not), `s2o` the inverse.
    """
    perm = np.asarray(perm, dtype=np.int64)
    nb = perm.size
    pos = np.empty(nb, dtype=np.int64)
    pos[perm] = np.arange(nb)
    v = np.arange(nb * block_v, dtype=np.int64)
    o2s = pos[v // block_v] * block_v + v % block_v
    s2o = np.empty_like(o2s)
    s2o[o2s] = v
    return o2s.astype(np.int32), s2o.astype(np.int32)


def permute_blocks(dg: DeviceGraph, perm: np.ndarray) -> DeviceGraph:
    """Reorder the blocked layout so storage slot i holds block `perm[i]`.

    Every vertex id in the returned graph — slab neighbor ids and the flat
    metric arrays included — is rewritten into the permuted space, so the
    result is a self-consistent `DeviceGraph`: the engine, the kernels, and
    the metrics consume it exactly like an unpermuted one. Only the *meaning*
    of index v changes (storage slot, not original vertex id); callers that
    cross the boundary convert with `block_vertex_perms` /
    `vertices_to_original`.

    The streaming layer maintains the same permuted layout incrementally
    (`repro.streaming.delta_graph.IncrementalDeviceGraph._to_device`); a
    field added to one rewrite must be added to the other —
    `tests/test_halo.py` pins the two layouts equal.
    """
    perm = np.asarray(perm, dtype=np.int64)
    if perm.shape != (dg.n_blocks,) or not np.array_equal(
            np.sort(perm), np.arange(dg.n_blocks)):
        raise ValueError(
            f"perm must be a permutation of range({dg.n_blocks})")
    if np.array_equal(perm, np.arange(dg.n_blocks)):
        return dg
    o2s, _ = block_vertex_perms(perm, dg.block_v)

    def per_vertex(a):
        return jnp.asarray(
            np.asarray(a).reshape(dg.n_blocks, dg.block_v)[perm].reshape(-1))

    def ids(a):
        return jnp.asarray(o2s[np.asarray(a)])

    return dg._replace(
        edge_src=ids(dg.edge_src),
        edge_dst=ids(dg.edge_dst),
        dir_src=ids(dg.dir_src),
        dir_dst=ids(dg.dir_dst),
        blk_dst=jnp.asarray(o2s[np.asarray(dg.blk_dst)[perm]]),
        blk_row=jnp.asarray(np.asarray(dg.blk_row)[perm]),
        blk_w=jnp.asarray(np.asarray(dg.blk_w)[perm]),
        deg_out=per_vertex(dg.deg_out),
        inv_wsum=per_vertex(dg.inv_wsum),
        vmask=per_vertex(dg.vmask),
    )


def vertices_to_original(sdg, x: jax.Array) -> jax.Array:
    """Reindex a storage-order per-vertex array into original vertex order
    (identity for unpermuted layouts and plain `DeviceGraph`s); the first
    `n` entries then correspond to real vertices 0..n-1 again."""
    o2s = getattr(sdg, "o2s", None)
    if o2s is None:
        return x
    return jnp.take(x, jnp.asarray(o2s), axis=0)


def resolve_assignment(
    dg: DeviceGraph,
    n_shards: int,
    assignment: Union[str, np.ndarray, None],
    adj: Optional[np.ndarray] = None,
) -> Optional[np.ndarray]:
    """Turn an `assignment=` argument into a block permutation (or None).

    "contiguous" / None keep the natural block striping; "locality" runs
    the greedy co-location pass over the block-level edge-cut matrix;
    "vcycle" runs the one-level-up multilevel solve of the same problem
    (`vcycle_block_order` — never worse than "locality" by construction);
    an explicit array is validated and used as-is. Identity permutations
    collapse to None so the unpermuted fast paths stay in force. `adj`
    hands in a precomputed edge-cut matrix so callers that already hold
    one (`ShardedDeviceGraph.block_adj_matrix`) skip the O(E) rebuild.
    """
    if assignment is None or (isinstance(assignment, str)
                              and assignment == "contiguous"):
        return None
    if isinstance(assignment, str):
        if assignment not in ("locality", "vcycle"):
            raise ValueError(
                f"unknown assignment {assignment!r}; expected 'contiguous', "
                "'locality', 'vcycle', or an explicit block permutation")
        if adj is None:
            adj = block_adjacency(np.asarray(dg.blk_dst),
                                  np.asarray(dg.blk_w), dg.block_v)
        order_fn = (locality_block_order if assignment == "locality"
                    else vcycle_block_order)
        perm = order_fn(adj, n_shards)
    else:
        perm = np.asarray(assignment, dtype=np.int64)
    if np.array_equal(perm, np.arange(dg.n_blocks)):
        return None
    return perm


def shard_device_graph(
    dg: DeviceGraph,
    mesh: jax.sharding.Mesh,
    *,
    assignment: Union[str, np.ndarray, None] = "contiguous",
    halo: bool = False,
    halo_threshold: float = DEFAULT_HALO_THRESHOLD,
    halo_granularity: str = "auto",
    hubs: Optional[HubConfig] = None,
) -> ShardedDeviceGraph:
    """Align `dg` to the mesh and place every array with a `NamedSharding`.

    Blocked slabs and per-vertex arrays land sliced on their owning device
    (`P("blocks", ...)`), flat metric arrays replicated (`P()`), so the
    sharded superstep starts from committed, correctly-placed buffers and
    donation can reuse them in place.

    `assignment` selects the block->shard mapping: "contiguous" (default)
    keeps the natural striping, "locality" greedily co-locates densely
    connected blocks (`locality_block_order`), an explicit `[n_blocks]`
    permutation is used verbatim. `halo=True` additionally precomputes the
    halo-exchange plan consumed by `chunk_schedule="halo"`; see
    `repro.core.halo` for the traffic model, the `halo_threshold`
    full-gather fallback, the `halo_granularity` knob ("auto" | "block" |
    "vertex" exchange plan), and `hubs` (a `HubConfig` enabling Spinner-
    style hub replication with per-superstep vote reconciliation).
    """
    if "blocks" not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no 'blocks' axis")
    n_shards = int(mesh.shape["blocks"])
    dg = align_blocks(dg, n_shards)
    adj = None
    if isinstance(assignment, str) and assignment in ("locality", "vcycle"):
        # computed once here, seeded onto the returned layout's cache so
        # the V-cycle / traffic model never rebuild it for this layout
        adj = block_adjacency(np.asarray(dg.blk_dst), np.asarray(dg.blk_w),
                              dg.block_v)
    perm = resolve_assignment(dg, n_shards, assignment, adj=adj)
    o2s = s2o = None
    if perm is not None:
        dg = permute_blocks(dg, perm)
        o2s, s2o = block_vertex_perms(perm, dg.block_v)
        if adj is not None:
            # re-expressed in storage order: slot i holds original block
            # perm[i], so the cached matrix matches the stored layout
            adj = np.ascontiguousarray(adj[np.ix_(perm, perm)])
    placed = {}
    for name in dg._fields:
        value = getattr(dg, name)
        if not isinstance(value, jnp.ndarray):
            placed[name] = value
            continue
        if name in _BLOCKED_FIELDS:
            spec = P("blocks", None)
        elif name in _VERTEX_FIELDS:
            spec = P("blocks")
        else:
            spec = P()
        placed[name] = jax.device_put(value, NamedSharding(mesh, spec))
    spec = None
    if halo:
        spec = build_halo_spec(
            np.asarray(dg.blk_dst), np.asarray(dg.blk_w), n_shards,
            dg.block_v, threshold=halo_threshold,
            granularity=halo_granularity, hubs=hubs,
            deg=np.asarray(dg.deg_out), vmask=np.asarray(dg.vmask),
            blk_row=np.asarray(dg.blk_row), mesh=mesh)
    return ShardedDeviceGraph(
        dg=DeviceGraph(**placed),
        mesh=mesh,
        n_shards=n_shards,
        blocks_per_shard=dg.n_blocks // n_shards,
        block_perm=tuple(int(b) for b in perm) if perm is not None else None,
        o2s=o2s,
        s2o=s2o,
        halo=spec,
        block_adj=adj,
    )


def attach_halo(
    sdg: ShardedDeviceGraph,
    halo_threshold: float = DEFAULT_HALO_THRESHOLD,
    *,
    halo_granularity: str = "auto",
    hubs: Optional[HubConfig] = None,
) -> ShardedDeviceGraph:
    """Build (or rebuild) the halo-exchange plan for an already-placed
    sharded layout — the path `run_partitioner(chunk_schedule="halo")`
    takes when handed a pre-built `ShardedDeviceGraph` without one (or
    with one built under different granularity/hub knobs)."""
    spec = build_halo_spec(
        np.asarray(sdg.blk_dst), np.asarray(sdg.blk_w), sdg.n_shards,
        sdg.block_v, threshold=halo_threshold,
        granularity=halo_granularity, hubs=hubs,
        deg=np.asarray(sdg.deg_out), vmask=np.asarray(sdg.vmask),
        blk_row=np.asarray(sdg.blk_row), mesh=sdg.mesh)
    return dataclasses.replace(sdg, halo=spec)


def prepare_sharded_device_graph(
    g: Graph,
    mesh: jax.sharding.Mesh,
    n_blocks: int = 8,
    block_multiple: int = 8,
    *,
    assignment: Union[str, np.ndarray, None] = "contiguous",
    halo: bool = False,
    halo_threshold: float = DEFAULT_HALO_THRESHOLD,
    halo_granularity: str = "auto",
    hubs: Optional[HubConfig] = None,
) -> ShardedDeviceGraph:
    """`prepare_device_graph` + device-aligned blocking + NamedSharding placement.

    Requests at least one block per shard; whatever block count the blocking
    pass settles on is then padded up to a multiple of the mesh size. See
    `shard_device_graph` for `assignment` / `halo` / `halo_granularity` /
    `hubs`.
    """
    n_shards = int(mesh.shape["blocks"])
    dg = prepare_device_graph(
        g, n_blocks=max(n_blocks, n_shards), block_multiple=block_multiple)
    return shard_device_graph(dg, mesh, assignment=assignment, halo=halo,
                              halo_threshold=halo_threshold,
                              halo_granularity=halo_granularity, hubs=hubs)


CAPACITY_MODES = ("spinner", "paper")


def capacity(m: int, k: int, epsilon: float, mode: str) -> float:
    """Partition capacity C.

    mode="spinner": C = (1+eps)|E|/k — Spinner's definition, the default.
    mode="paper":   C = eps|E|/k     — the literal Section III-A text (makes
                    every partition over-capacity; kept for faithfulness,
                    the footnote-1 shift in eq. (12) keeps it well-defined).
    """
    if mode == "spinner":
        return (1.0 + epsilon) * m / k
    if mode == "paper":
        return epsilon * m / k
    raise ValueError(f"unknown capacity mode {mode!r}")


@functools.lru_cache(maxsize=512)
def capacity_device(m: int, k: int, epsilon: float, mode: str) -> jnp.ndarray:
    """`capacity(...)` as a device-resident f32 scalar, cached on its inputs.

    Capacity depends only on (|E|, cfg); the supersteps call this instead of
    recomputing + re-`asarray`-ing it every step, so the same graph/config
    pair reuses one committed device buffer across the whole convergence
    loop (and across warm restarts in the streaming runner).
    """
    return jnp.asarray(capacity(m, k, epsilon, mode), jnp.float32)
