"""Host-side convergence loop (Section IV-D step 9) shared by all algorithms.

Runs jitted supersteps, tracks the paper's quality metrics each step, and
halts when the LP score fails to improve by `theta` for `patience`
consecutive steps (paper settings: theta=0.001, patience=5, max 290 steps).

Algorithm dispatch goes through the string-keyed registry
(`repro.core.registry`): any registered `engine.Algorithm` — revolver,
spinner, restream, or an out-of-tree rule — runs through the same
convergence loop, warm-start plumbing, schedule selection, and metric
fetching; `StaticAlgorithm` entries (hash, range) take the closed-form fast
path.

Host/device synchronization: materializing `state.score` as a python float
blocks on the device every superstep, serializing dispatch. The loop instead
buffers the per-step score arrays and fetches them with a single
`jax.device_get` every `sync_every` supersteps, letting XLA pipeline the
window; with `track_history=True` the per-step `local_edges` /
`max_norm_load` arrays are buffered and drained on the same window (no
per-step host sync there either). Convergence is then detected up to
`sync_every - 1` steps late (the extra steps are still valid partitioning
steps and are reflected in `PartitionResult.steps` and the history lists);
`sync_every=1` (the default) reproduces the fully synchronous behavior
exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import engine
from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    attach_halo,
    prepare_device_graph,
    prepare_sharded_device_graph,
    shard_device_graph,
    vertices_to_original,
)
from repro.core.halo import DEFAULT_HALO_THRESHOLD
from repro.core.metrics import local_edges, max_normalized_load
from repro.core.registry import StaticAlgorithm, get_algorithm
from repro.graphs.csr import Graph


@dataclasses.dataclass
class PartitionResult:
    algo: str
    k: int
    labels: np.ndarray                 # [n] final partition per vertex
    steps: int
    converged: bool
    local_edges: float
    max_norm_load: float
    history: Dict[str, List[float]]
    wall_s: float
    probs: Optional[np.ndarray] = None  # [n_blocks, block_v, k] final LA state
                                        # (probs-carrying algorithms with
                                        # keep_probs=True only; feeds warm
                                        # restarts)


def run_convergence_loop(
    step_fn,
    state,
    *,
    max_steps: int,
    patience: int,
    theta: float,
    sync_every: int = 1,
    on_step=None,
    on_score=None,
    on_drain=None,
    tracer=None,
    step0: int = 0,
):
    """Drive `step_fn` with the paper's score-stall halting (Section IV-D
    step 9): stop after `patience` consecutive steps whose score improves by
    less than `theta`. Scores are fetched from device in `sync_every`-sized
    windows (see module docstring); convergence is then detected up to
    `sync_every - 1` steps late. Shared by `run_partitioner` and the
    streaming `StreamRunner` so the halting semantics cannot drift.

    `on_step(state)` fires after every superstep (history tracking);
    `on_score(float)` fires for every drained score, in step order — every
    *executed* step's score is drained, including the up-to-`sync_every - 1`
    steps past the detected convergence point, so history lists stay aligned
    with `steps_executed`. `on_drain()` fires once per fetched window, after
    its scores; callers buffering their own per-step device arrays (e.g.
    `run_partitioner`'s history metrics) drain them there, on the same
    cadence as the score fetch.

    `tracer` (a `repro.obs.Tracer`; default no-op) records one "superstep"
    span per executed step — the *dispatch* cost; the device time of a
    window accrues to its blocking "device-sync" span — numbered from
    `step0` (streaming passes a global step offset so spans stay monotonic
    across deltas). Tracing changes no fetch cadence: the only blocking
    calls are the same windowed `device_get`s the untraced loop makes.

    Returns (state, steps_executed, converged).
    """
    tracer = tracer if tracer is not None else obs.NULL_TRACER
    prev_score, stall, converged = -np.inf, 0, False
    steps = 0
    pending: list = []
    for step in range(max_steps):
        with tracer.span("superstep", step=step0 + step):
            state = step_fn(state)
        steps = step + 1
        pending.append(state.score)
        if on_step is not None:
            on_step(state)
        if len(pending) < sync_every and steps < max_steps:
            continue
        with tracer.span("device-sync", steps=len(pending), what="scores"):
            scores = jax.device_get(pending)
        for score in (float(s) for s in scores):
            if on_score is not None:
                on_score(score)
            if converged:
                continue  # window tail past the detection point
            if score - prev_score < theta:
                stall += 1
                if stall >= patience:
                    converged = True
            else:
                stall = 0
            prev_score = score
        pending = []
        if on_drain is not None:
            on_drain()
        if converged:
            break
    return state, steps, converged


def _make_cfg(cls, k: int, max_steps: Optional[int], cfg_kwargs: dict):
    """Build an algorithm config, rejecting unknown keys loudly.

    The spinner branch used to silently drop revolver-only kwargs, which
    turned typos (e.g. `capacty_mode=`) into no-ops; every registered
    algorithm now raises TypeError on anything its config dataclass doesn't
    define.
    """
    valid = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(cfg_kwargs) - valid)
    if unknown:
        raise TypeError(
            f"unknown config kwargs for {cls.__name__}: {unknown}; "
            f"valid keys: {sorted(valid - {'k'})}"
        )
    cfg = cls(k=k, **cfg_kwargs)
    if max_steps is not None:
        cfg = dataclasses.replace(cfg, max_steps=max_steps)
    return cfg


def run_partitioner(
    algo: str,
    graph: Graph,
    k: int,
    *,
    seed: int = 0,
    n_blocks: int = 8,
    max_steps: Optional[int] = None,
    track_history: bool = True,
    dg: Optional[DeviceGraph] = None,
    mesh=None,
    assignment="contiguous",
    halo_threshold: float = DEFAULT_HALO_THRESHOLD,
    sync_every: int = 1,
    init_labels: Optional[np.ndarray] = None,
    init_probs: Optional[np.ndarray] = None,
    init_sharpen: float = 0.0,
    keep_probs: bool = False,
    trace=None,
    **cfg_kwargs,
) -> PartitionResult:
    """Partition `graph` into `k` parts with the named algorithm.

    algo: any key in the algorithm registry — "revolver" | "spinner" |
    "restream" | "hash" | "range" out of the box (see
    `repro.core.registry.available_algorithms`). Extra kwargs flow into the
    algorithm's config dataclass (unknown keys raise TypeError).
    `sync_every` batches device->host score fetches (see module docstring).
    `init_labels` (and, for probs-carrying algorithms, `init_probs` /
    `init_sharpen`) warm-start the state from a previous assignment — the
    streaming subsystem's incremental repartitioning path. Carrying labels
    without LA state leaves the automata uniform, whose first exploration
    steps can wreck the carried assignment; `init_sharpen > 0` blends the
    automata toward the carried labels to prevent that (see
    `revolver_init_from_labels`). `keep_probs=True` returns the final LA
    probability tensor in `PartitionResult.probs` (needed to chain warm
    restarts); it is off by default because fetching [n_pad, k] floats to
    host is a real cost at production scale.

    `chunk_schedule="sharded"` (a config knob on every superstep algorithm)
    runs the superstep data-parallel over a 1-D ``("blocks",)`` mesh —
    `mesh` selects it (default: all visible devices, see `make_blocks_mesh`);
    a passed `dg` is aligned and placed onto the mesh if it is not already a
    `ShardedDeviceGraph`. `chunk_schedule="halo"` is the sharded schedule
    with the full label all-gather replaced by the precomputed
    boundary-block exchange (`repro.core.halo`; `halo_threshold` sets the
    coverage above which it falls back to the full gather). `assignment`
    selects the block->shard mapping ("contiguous" | "locality" | explicit
    permutation, see `shard_device_graph`) — locality co-location shrinks
    the halo, making the exchanged traffic proportional to partition
    quality. Returned labels (and probs) are always in original vertex
    order, whatever the assignment.

    `trace` (a `repro.obs.Tracer`; default off) records the run into a
    perfetto-exportable trace: a "run-partitioner" root span, layout build,
    one span per superstep, the windowed device syncs, recompile events,
    and per-superstep counter series (`local_edges`, `max_norm_load`,
    `migrations`) that ride the existing `sync_every` drain windows — the
    traced loop issues exactly the same blocking device fetches as the
    untraced one, and with tracing off results are bit-identical (see
    `docs/observability.md`).
    """
    t0 = time.time()
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")
    algorithm = get_algorithm(algo)
    static = isinstance(algorithm, StaticAlgorithm)
    schedule = cfg_kwargs.get("chunk_schedule")
    sharded = schedule in ("sharded", "halo")
    if mesh is not None and not sharded:
        raise ValueError(
            "mesh is only meaningful with chunk_schedule='sharded'/'halo'")
    if not sharded and not (isinstance(assignment, str)
                            and assignment == "contiguous"):
        raise ValueError(
            "assignment is only meaningful with chunk_schedule="
            "'sharded'/'halo'")
    if static and cfg_kwargs:
        raise TypeError(f"{algo!r} runs no supersteps; it takes no config kwargs")
    tracer = trace if trace is not None else obs.NULL_TRACER
    with obs.use(tracer), \
            tracer.span("run-partitioner", algo=algo, k=k,
                        schedule=schedule or "sequential",
                        n=graph.n, m=graph.m):
        result = _run_partitioner_traced(
            tracer, algorithm, static, schedule, sharded,
            algo, graph, k, t0,
            seed=seed, n_blocks=n_blocks, max_steps=max_steps,
            track_history=track_history, dg=dg, mesh=mesh,
            assignment=assignment, halo_threshold=halo_threshold,
            sync_every=sync_every, init_labels=init_labels,
            init_probs=init_probs, init_sharpen=init_sharpen,
            keep_probs=keep_probs, cfg_kwargs=cfg_kwargs)
    if tracer.enabled:
        # run manifest: trace_report --validate checks one superstep span
        # per executed step against this
        tracer.meta.setdefault("runs", []).append({
            "algo": algo, "k": k, "schedule": schedule or "sequential",
            "steps": result.steps})
    return result


def _run_partitioner_traced(
    tracer, algorithm, static, schedule, sharded,
    algo: str, graph: Graph, k: int, t0: float, *,
    seed, n_blocks, max_steps, track_history, dg, mesh, assignment,
    halo_threshold, sync_every, init_labels, init_probs, init_sharpen,
    keep_probs, cfg_kwargs,
) -> PartitionResult:
    """Body of `run_partitioner`, running under `obs.use(tracer)` inside the
    root span (split out so the traced scope covers every early return)."""
    with tracer.span("prepare-layout", schedule=schedule or "sequential"):
        if sharded:
            halo = schedule == "halo"
            if mesh is None and isinstance(dg, ShardedDeviceGraph):
                mesh = dg.mesh
            if mesh is None:
                from repro.launch.mesh import make_blocks_mesh

                mesh = make_blocks_mesh()
            if dg is None:
                dg = prepare_sharded_device_graph(
                    graph, mesh, n_blocks=n_blocks, assignment=assignment,
                    halo=halo, halo_threshold=halo_threshold)
            elif not isinstance(dg, ShardedDeviceGraph):
                dg = shard_device_graph(dg, mesh, assignment=assignment,
                                        halo=halo, halo_threshold=halo_threshold)
            else:
                if not (isinstance(assignment, str)
                        and assignment == "contiguous"):
                    # a placed layout's assignment is baked into its storage
                    # order — silently running the contiguous layout here would
                    # fake locality measurements
                    raise ValueError(
                        "assignment cannot be applied to a pre-built "
                        "ShardedDeviceGraph; pass assignment= to "
                        "shard_device_graph / prepare_sharded_device_graph "
                        "when building the layout")
                if halo and dg.halo is None:
                    dg = attach_halo(dg, halo_threshold)
        elif dg is None:
            dg = prepare_device_graph(graph, n_blocks=n_blocks)
    if tracer.enabled and sharded:
        # static per-run exchange gauges from the precomputed plan — what
        # each superstep's gather moves, without touching the device
        n_fields = 1 if static else len(algorithm.vertex_fields)
        if dg.halo is not None:
            spec = dg.halo
            tracer.counter("halo_b_max", spec.b_max)
            tracer.counter("halo_coverage", spec.coverage)
            tracer.counter(
                "gathered_bytes_halo",
                spec.gathered_elems_per_device() * 4 * n_fields)
            tracer.counter(
                "gathered_bytes_full",
                spec.full_gather_elems_per_device() * 4 * n_fields)
        else:
            n_shards = int(dg.mesh.devices.size)
            per_dev = (n_shards - 1) * (dg.n_blocks // n_shards) * dg.block_v
            tracer.counter("gathered_bytes_full", per_dev * 4 * n_fields)
    key = jax.random.PRNGKey(seed)

    if static:
        if init_labels is not None or init_probs is not None or init_sharpen:
            raise TypeError(f"{algo!r} is stateless; warm-start args are meaningless")
        labels = jax.numpy.pad(algorithm.partition(graph.n, k),
                               (0, dg.n_pad - graph.n))
        le = float(local_edges(labels, dg.dir_src, dg.dir_dst))
        ml = float(max_normalized_load(labels[: graph.n], dg.deg_out[: graph.n], k))
        if tracer.enabled:
            tracer.counter("local_edges", le, step=0)
            tracer.counter("max_norm_load", ml, step=0)
        return PartitionResult(
            algo=algo, k=k, labels=np.asarray(labels[: graph.n]), steps=0,
            converged=True, local_edges=le, max_norm_load=ml,
            history={"local_edges": [le], "max_norm_load": [ml], "score": [0.0]},
            wall_s=time.time() - t0,
        )

    cfg = _make_cfg(algorithm.config_cls, k, max_steps, cfg_kwargs)
    if not algorithm.supports_probs:
        if init_probs is not None:
            raise TypeError(
                f"{algo!r} has no LA state; init_probs/init_sharpen are meaningless")
        if init_sharpen:
            raise TypeError(
                f"{algo!r} has no LA state; init_probs/init_sharpen are meaningless")
    if init_labels is not None:
        if algorithm.init_from_labels is None:
            raise TypeError(f"{algo!r} does not support warm starts")
        if algorithm.supports_probs:
            state = algorithm.init_from_labels(dg, cfg, key, init_labels,
                                               probs=init_probs,
                                               prob_sharpen=init_sharpen)
        else:
            state = algorithm.init_from_labels(dg, cfg, key, init_labels)
    else:
        if init_probs is not None:
            raise TypeError("init_probs requires init_labels")
        if init_sharpen:
            raise TypeError("init_sharpen requires init_labels")
        state = algorithm.init(dg, cfg, key)
    if sharded:
        state = engine.place_state(algorithm, state, dg)
    base_step = lambda s: engine.superstep(algorithm, dg, cfg, s)

    history: Dict[str, List[float]] = {"local_edges": [], "max_norm_load": [], "score": []}
    # per-step metric arrays stay on device and are drained on the same
    # sync_every window as the scores — neither history tracking nor tracing
    # forces a host sync per superstep
    pending_le: List[jax.Array] = []
    pending_ml: List[jax.Array] = []
    pending_mig: List[jax.Array] = []
    step_ts: List[float] = []    # dispatch timestamp per buffered step, so
                                 # drained counters are back-dated to the
                                 # superstep that produced them
    drained = [0]                # global index of the next drained step

    if tracer.enabled:
        def step_fn(s):
            # labels are donated into the superstep — copy *before* dispatch
            # (the copy is enqueued ahead of the overwrite) to count
            # migrations as a device-side reduction drained with the window
            prev = jnp.copy(s.labels)
            s2 = base_step(s)
            pending_mig.append(jnp.sum((s2.labels != prev) & dg.vmask))
            return s2
    else:
        step_fn = base_step

    collect = track_history or tracer.enabled

    def on_step(s):
        # labels and the dir_*/deg arrays live in the same (possibly
        # locality-permuted) index space; the load metric uses the full
        # padded arrays because real vertices are not a prefix under a
        # permuted assignment (padding carries zero degree, so the value is
        # unchanged on contiguous layouts)
        pending_le.append(local_edges(s.labels, dg.dir_src, dg.dir_dst))
        pending_ml.append(max_normalized_load(s.labels, dg.deg_out, k))
        if tracer.enabled:
            step_ts.append(tracer.now_us())

    def drain_metrics():
        # one bundled fetch per window, traced or not — the sync-count
        # contract pinned by tests/test_obs.py
        with tracer.span("device-sync", steps=len(pending_le), what="metrics"):
            le_v, ml_v, mig_v = jax.device_get(
                (pending_le, pending_ml, pending_mig))
        if track_history:
            history["local_edges"].extend(float(x) for x in le_v)
            history["max_norm_load"].extend(float(x) for x in ml_v)
        if tracer.enabled:
            for i in range(len(le_v)):
                step = drained[0] + i
                ts = step_ts[i] if i < len(step_ts) else None
                tracer.counter("local_edges", float(le_v[i]), step=step, ts=ts)
                tracer.counter("max_norm_load", float(ml_v[i]), step=step, ts=ts)
                if i < len(mig_v):
                    tracer.counter("migrations", float(mig_v[i]), step=step, ts=ts)
        drained[0] += len(le_v)
        pending_le.clear()
        pending_ml.clear()
        pending_mig.clear()
        step_ts.clear()

    state, steps, converged = run_convergence_loop(
        step_fn, state,
        max_steps=cfg.max_steps, patience=cfg.patience, theta=cfg.theta,
        sync_every=sync_every,
        on_step=on_step if collect else None,
        on_score=history["score"].append if track_history else None,
        on_drain=drain_metrics if collect else None,
        tracer=tracer,
    )

    # final fetch: one device_get for everything still needed. With history
    # tracking on, the final step's local_edges/max_norm_load already came
    # back through the windowed drain — reuse them instead of issuing two
    # extra blocking float(...) syncs after convergence. Labels/probs cross
    # the API boundary in original vertex order (identity gather on
    # unpermuted layouts).
    fetch = {"labels": vertices_to_original(dg, state.labels)[: graph.n]}
    if track_history and history["local_edges"]:
        le, ml = history["local_edges"][-1], history["max_norm_load"][-1]
    elif tracer.enabled and tracer.series.get("local_edges"):
        le = tracer.series["local_edges"][-1][1]
        ml = tracer.series["max_norm_load"][-1][1]
    else:
        fetch["le"] = local_edges(state.labels, dg.dir_src, dg.dir_dst)
        fetch["ml"] = max_normalized_load(state.labels, dg.deg_out, k)
    if keep_probs and algorithm.supports_probs:
        flat = state.probs.reshape(dg.n_pad, cfg.k)
        fetch["probs"] = vertices_to_original(dg, flat).reshape(
            dg.n_blocks, dg.block_v, cfg.k)
    with tracer.span("device-sync", what="result"):
        fetched = jax.device_get(fetch)
    if "le" in fetched:
        le, ml = float(fetched["le"]), float(fetched["ml"])
    return PartitionResult(
        algo=algo, k=k, labels=np.asarray(fetched["labels"]), steps=steps,
        converged=converged, local_edges=le, max_norm_load=ml, history=history,
        wall_s=time.time() - t0,
        probs=np.asarray(fetched["probs"]) if "probs" in fetched else None,
    )
