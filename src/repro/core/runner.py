"""Host-side convergence loop (Section IV-D step 9) shared by all algorithms.

Runs jitted supersteps, tracks the paper's quality metrics each step, and
halts when the LP score fails to improve by `theta` for `patience`
consecutive steps (paper settings: theta=0.001, patience=5, max 290 steps).

Algorithm dispatch goes through the string-keyed registry
(`repro.core.registry`): any registered `engine.Algorithm` — revolver,
spinner, restream, or an out-of-tree rule — runs through the same
convergence loop, warm-start plumbing, schedule selection, and metric
fetching; `StaticAlgorithm` entries (hash, range) take the closed-form fast
path.

Host/device synchronization: materializing `state.score` as a python float
blocks on the device every superstep, serializing dispatch. The loop instead
buffers the per-step score arrays and fetches them with a single
`jax.device_get` every `sync_every` supersteps, letting XLA pipeline the
window; with `track_history=True` the per-step `local_edges` /
`max_norm_load` arrays are buffered and drained on the same window (no
per-step host sync there either). Convergence is then detected up to
`sync_every - 1` steps late (the extra steps are still valid partitioning
steps and are reflected in `PartitionResult.steps` and the history lists);
`sync_every=1` (the default) reproduces the fully synchronous behavior
exactly.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.checkpoint import store as ckpt_store
from repro.core import engine
from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    attach_halo,
    prepare_device_graph,
    prepare_sharded_device_graph,
    shard_device_graph,
    vertices_to_original,
)
from repro.core.halo import (
    DEFAULT_HALO_THRESHOLD,
    HubConfig,
    build_halo_spec,
    interior_first_order,
)
from repro.core.metrics import local_edges, max_normalized_load
from repro.core.registry import StaticAlgorithm, get_algorithm
from repro.graphs.csr import Graph

_log = logging.getLogger("repro.core.runner")


class PartitionStateError(RuntimeError):
    """The drain-window state guard found corrupt partitioner state
    (non-finite LA probabilities or out-of-range labels) under the
    ``guard="raise"`` policy, or a recovery policy could not be applied
    (e.g. rollback with no usable checkpoint)."""


@dataclasses.dataclass
class PartitionResult:
    algo: str
    k: int
    labels: np.ndarray                 # [n] final partition per vertex
    steps: int
    converged: bool
    local_edges: float
    max_norm_load: float
    history: Dict[str, List[float]]
    wall_s: float
    probs: Optional[np.ndarray] = None  # [n_blocks, block_v, k] final LA state
                                        # (probs-carrying algorithms with
                                        # keep_probs=True only; feeds warm
                                        # restarts)
    resumed_from: int = 0               # global superstep of the checkpoint
                                        # this run resumed from (0 = fresh);
                                        # `steps` counts from superstep 0
                                        # either way


def run_convergence_loop(
    step_fn,
    state,
    *,
    max_steps: int,
    patience: int,
    theta: float,
    sync_every: int = 1,
    on_step=None,
    on_score=None,
    on_drain=None,
    tracer=None,
    step0: int = 0,
    prev_score: float = -np.inf,
    stall: int = 0,
):
    """Drive `step_fn` with the paper's score-stall halting (Section IV-D
    step 9): stop after `patience` consecutive steps whose score improves by
    less than `theta`. Scores are fetched from device in `sync_every`-sized
    windows (see module docstring); convergence is then detected up to
    `sync_every - 1` steps late. Shared by `run_partitioner` and the
    streaming `StreamRunner` so the halting semantics cannot drift.

    `on_step(state)` fires after every superstep (history tracking);
    `on_score(float)` fires for every drained score, in step order — every
    *executed* step's score is drained, including the up-to-`sync_every - 1`
    steps past the detected convergence point, so history lists stay aligned
    with `steps_executed`. `on_drain(state, steps, prev_score, stall)` fires
    once per fetched window, after its scores; callers buffering their own
    per-step device arrays (e.g. `run_partitioner`'s history metrics) drain
    them there, on the same cadence as the score fetch. It receives the
    loop's halting state so a checkpoint written at the drain can resume
    exactly; it may return a dict with any of ``state`` / ``prev_score`` /
    ``stall`` to *replace* the loop's state (the guard's rollback/reinit
    recovery path — a replacement also clears a convergence detected in the
    corrupted window).

    `prev_score` / `stall` seed the halting state — a resumed run passes the
    values its checkpoint recorded so the stall counter picks up exactly
    where the killed run left it; `step0` likewise offsets the superstep
    numbering (spans, fault-injection points) to the global step index.

    Fault injection (`repro.faults`): after each dispatched superstep the
    loop checks the ``superstep`` point with the global step index — a kill
    plan SIGKILLs here, a poison plan corrupts the state device-side (for
    guard testing). No-ops (one early-returning call) when no plan is
    active.

    `tracer` (a `repro.obs.Tracer`; default no-op) records one "superstep"
    span per executed step — the *dispatch* cost; the device time of a
    window accrues to its blocking "device-sync" span — numbered from
    `step0` (streaming passes a global step offset so spans stay monotonic
    across deltas). Tracing changes no fetch cadence: the only blocking
    calls are the same windowed `device_get`s the untraced loop makes.

    Returns (state, steps_executed, converged).
    """
    tracer = tracer if tracer is not None else obs.NULL_TRACER
    converged = False
    steps = 0
    pending: list = []
    for step in range(max_steps):
        with tracer.span("superstep", step=step0 + step):
            state = step_fn(state)
        act = faults.fire("superstep", step0 + step)
        if act is not None:
            state = faults.poison(state, act)
        steps = step + 1
        pending.append(state.score)
        if on_step is not None:
            on_step(state)
        if len(pending) < sync_every and steps < max_steps:
            continue
        with tracer.span("device-sync", steps=len(pending), what="scores"):
            scores = jax.device_get(pending)
        for score in (float(s) for s in scores):
            if on_score is not None:
                on_score(score)
            if converged:
                continue  # window tail past the detection point
            if score - prev_score < theta:
                stall += 1
                if stall >= patience:
                    converged = True
            else:
                stall = 0
            prev_score = score
        pending = []
        if on_drain is not None:
            replace = on_drain(state, steps, prev_score, stall)
            if replace is not None:
                state = replace.get("state", state)
                prev_score = replace.get("prev_score", prev_score)
                stall = replace.get("stall", stall)
                converged = False   # scores from corrupt state don't count
        if converged:
            break
    return state, steps, converged


def _make_cfg(cls, k: int, max_steps: Optional[int], cfg_kwargs: dict):
    """Build an algorithm config, rejecting unknown keys loudly.

    The spinner branch used to silently drop revolver-only kwargs, which
    turned typos (e.g. `capacty_mode=`) into no-ops; every registered
    algorithm now raises TypeError on anything its config dataclass doesn't
    define.
    """
    valid = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(cfg_kwargs) - valid)
    if unknown:
        raise TypeError(
            f"unknown config kwargs for {cls.__name__}: {unknown}; "
            f"valid keys: {sorted(valid - {'k'})}"
        )
    cfg = cls(k=k, **cfg_kwargs)
    if max_steps is not None:
        cfg = dataclasses.replace(cfg, max_steps=max_steps)
    return cfg


# ---------------------------------------------------------------------------
# crash safety: checkpointed resume (see docs/fault-tolerance.md)
# ---------------------------------------------------------------------------
def _is_vertex_field(algo, dg, name, value) -> bool:
    return ((name in algo.vertex_fields or name in algo.replicated_fields)
            and getattr(value, "ndim", 0) >= 1
            and value.shape[0] == dg.n_pad)


def _state_to_original(algo, state, dg) -> dict:
    """Checkpoint view of a state: every per-vertex / per-block field
    gathered into original vertex order (identity on unpermuted layouts,
    a device-side gather otherwise — enqueued at the drain so the fetch
    bundles with the window's metrics). A checkpoint is therefore
    layout-independent: restorable onto a different device count or
    block->shard assignment of the same graph."""
    if getattr(dg, "o2s", None) is None:
        # unpermuted layout: every conversion below is an identity
        # reshape/gather round-trip — skip the dispatch overhead entirely
        return dict(state._asdict())
    out = {}
    for name, v in state._asdict().items():
        if name in algo.block_fields:
            flat = v.reshape((dg.n_pad,) + v.shape[2:])
            out[name] = vertices_to_original(dg, flat).reshape(v.shape)
        elif _is_vertex_field(algo, dg, name, v):
            out[name] = vertices_to_original(dg, v)
        else:
            out[name] = v
    return out


def _state_from_original(algo, tree: dict, dg):
    """Inverse of `_state_to_original`: arrays in original vertex order ->
    a state NamedTuple in the layout's storage order (scatter via ``s2o``;
    identity on unpermuted layouts)."""
    s2o = getattr(dg, "s2o", None)
    out = {}
    for name, v in tree.items():
        if s2o is not None and name in algo.block_fields:
            flat = np.asarray(v).reshape((dg.n_pad,) + tuple(v.shape[2:]))
            out[name] = jnp.asarray(flat[np.asarray(s2o)]).reshape(v.shape)
        elif s2o is not None and _is_vertex_field(algo, dg, name, v):
            out[name] = jnp.asarray(np.asarray(v)[np.asarray(s2o)])
        else:
            out[name] = v
    return algo.state_cls(**out)


class _CheckpointManager:
    """Drain-window checkpointing for `run_partitioner`.

    Saves ride the existing ``sync_every`` drain windows: the state's
    original-order view is enqueued device-side and fetched **in the same
    bundled `jax.device_get`** as the window's metrics (zero additional
    blocking device fetches — the PR-6 sync-count contract), then written
    by an async writer thread while the loop keeps dispatching. One writer
    is in flight at a time; waiting on the previous handle before the next
    save (and at run end) both orders the atomic renames and re-raises
    write failures instead of swallowing them.
    """

    def __init__(self, ckpt_dir, every, keep, algorithm, dg, sharded,
                 meta, tracer):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self.algorithm = algorithm
        self.dg = dg
        self.sharded = sharded
        self.meta = meta
        self.tracer = tracer
        self.last_saved = 0
        self.saved = 0
        self._handles: list = []

    def _reap(self, block: bool = False):
        """Collect finished writer threads, re-raising any write failure
        (the satellite contract: a swallowed ENOSPC is a checkpoint that
        does not exist when the resume needs it). Non-blocking unless
        `block` — the convergence loop must never stall on an fsync."""
        alive = []
        for h in self._handles:
            if block or h._thread is None or not h._thread.is_alive():
                h.wait()
            else:
                alive.append(h)
        self._handles = alive

    def busy(self) -> bool:
        """True when the disk is falling behind (two writes already in
        flight); the due save is skipped rather than blocking the loop —
        the next drain window picks it up."""
        self._reap()
        return len(self._handles) >= 2

    def due(self, global_steps: int) -> bool:
        return self.every > 0 and global_steps - self.last_saved >= self.every

    def device_tree(self, state) -> dict:
        return _state_to_original(self.algorithm, state, self.dg)

    def save(self, global_steps: int, host_tree: dict, prev_score, stall):
        meta = dict(self.meta, steps=global_steps,
                    prev_score=float(prev_score), stall=int(stall),
                    converged=bool(stall >= self.meta.get("patience", 1 << 30)))
        with self.tracer.span("checkpoint-save", step=global_steps):
            self._handles.append(ckpt_store.save_checkpoint(
                self.dir, global_steps, host_tree, async_save=True,
                meta=meta, keep=self.keep))
        self.last_saved = global_steps
        self.saved += 1
        if self.tracer.enabled:
            self.tracer.counter("checkpoints_saved", float(self.saved),
                                step=global_steps)

    def finish(self):
        self._reap(block=True)

    # -- restore ---------------------------------------------------------- #

    def restore_latest(self, like_state):
        """Restore the newest usable checkpoint, falling back past corrupt
        or incompatible ones. Returns ``(state, steps, prev_score, stall,
        converged)`` or None when no checkpoint is usable."""
        for step in reversed(ckpt_store.all_steps(self.dir)):
            try:
                return self._restore(step, like_state)
            except (ckpt_store.CheckpointError, ValueError, KeyError) as e:
                _log.warning(
                    "checkpoint step %d in %s unusable (%s); trying the "
                    "previous one", step, self.dir, e)
        return None

    def _restore(self, step, like_state):
        manifest = ckpt_store.load_manifest(self.dir, step)
        meta = manifest.get("meta", {})
        for field in ("algo", "k", "n", "m"):
            if field in meta and field in self.meta \
                    and meta[field] != self.meta[field]:
                raise ValueError(
                    f"checkpoint step {step} was written by a different run: "
                    f"{field}={meta[field]!r} vs this run's "
                    f"{self.meta[field]!r}")
        algo, dg = self.algorithm, self.dg
        like = {name: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for name, v in like_state._asdict().items()}
        shardings = None
        if self.sharded and getattr(dg, "o2s", None) is None:
            # unpermuted layout: original order == storage order, so the
            # checkpoint lands directly on the mesh — the store's elastic
            # re-shard path, whatever device count wrote it
            shardings = engine.state_shardings(algo, like, dg.mesh)
        with self.tracer.span("checkpoint-restore", step=step):
            tree = ckpt_store.restore_checkpoint(self.dir, step, like,
                                                 shardings=shardings)
            if shardings is not None:
                state = algo.state_cls(**tree)
            else:
                state = _state_from_original(algo, tree, dg)
                if self.sharded:
                    state = engine.place_state(algo, state, dg)
        if self.tracer.enabled:
            self.tracer.instant("resumed", step=step)
        return (state, int(meta.get("steps", step)),
                float(meta.get("prev_score", -np.inf)),
                int(meta.get("stall", 0)), bool(meta.get("converged", False)))


_GUARD_POLICIES = ("off", "raise", "rollback", "reinit")
_GUARD_ALIASES = {"rollback-to-last-checkpoint": "rollback",
                  "reinit-affected-vertices": "reinit"}


def run_partitioner(
    algo: str,
    graph: Graph,
    k: int,
    *,
    seed: int = 0,
    n_blocks: int = 8,
    max_steps: Optional[int] = None,
    track_history: bool = True,
    dg: Optional[DeviceGraph] = None,
    mesh=None,
    assignment="contiguous",
    halo_threshold: float = DEFAULT_HALO_THRESHOLD,
    halo_granularity: str = "auto",
    hub_replication: bool = False,
    hub_quantile: float = 0.0,
    hub_target_coverage: Optional[float] = None,
    sync_every: int = 1,
    init_labels: Optional[np.ndarray] = None,
    init_probs: Optional[np.ndarray] = None,
    init_sharpen: float = 0.0,
    keep_probs: bool = False,
    trace=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 0,
    resume: bool = False,
    keep_checkpoints: int = 2,
    guard: str = "off",
    mode: str = "flat",
    coarse_n: Optional[int] = None,
    level_decay: Optional[float] = None,
    vcycle_sharpen: Optional[float] = None,
    **cfg_kwargs,
) -> PartitionResult:
    """Partition `graph` into `k` parts with the named algorithm.

    algo: any key in the algorithm registry — "revolver" | "spinner" |
    "restream" | "hash" | "range" out of the box (see
    `repro.core.registry.available_algorithms`). Extra kwargs flow into the
    algorithm's config dataclass (unknown keys raise TypeError).
    `sync_every` batches device->host score fetches (see module docstring).
    `init_labels` (and, for probs-carrying algorithms, `init_probs` /
    `init_sharpen`) warm-start the state from a previous assignment — the
    streaming subsystem's incremental repartitioning path. Carrying labels
    without LA state leaves the automata uniform, whose first exploration
    steps can wreck the carried assignment; `init_sharpen > 0` blends the
    automata toward the carried labels to prevent that (see
    `revolver_init_from_labels`). `keep_probs=True` returns the final LA
    probability tensor in `PartitionResult.probs` (needed to chain warm
    restarts); it is off by default because fetching [n_pad, k] floats to
    host is a real cost at production scale.

    `chunk_schedule="sharded"` (a config knob on every superstep algorithm)
    runs the superstep data-parallel over a 1-D ``("blocks",)`` mesh —
    `mesh` selects it (default: all visible devices, see `make_blocks_mesh`);
    a passed `dg` is aligned and placed onto the mesh if it is not already a
    `ShardedDeviceGraph`. `chunk_schedule="halo"` is the sharded schedule
    with the full label all-gather replaced by the precomputed
    boundary-block exchange (`repro.core.halo`; `halo_threshold` sets the
    coverage above which it falls back to the full gather). `assignment`
    selects the block->shard mapping ("contiguous" | "locality" | explicit
    permutation, see `shard_device_graph`) — locality co-location shrinks
    the halo, making the exchanged traffic proportional to partition
    quality. Returned labels (and probs) are always in original vertex
    order, whatever the assignment. `chunk_schedule="async"` is the halo
    schedule with the exchange overlapped onto each shard's interior block
    scan (the runner reorders blocks interior-first to widen the overlap
    window); `staleness_bound=0` (config default) refreshes the halo every
    superstep and stays bit-identical to "halo", while `staleness_bound=s`
    lets shards reuse a stale tail for up to `s` supersteps between
    refreshes — see `docs/async-superstep.md`.

    `mode="vcycle"` runs the METIS-style multilevel V-cycle
    (`repro.core.multilevel`): coarsen by heavy-edge matching down to
    `coarse_n` vertices, partition the coarsest graph to score-stall
    convergence, then uncoarsen level by level with `init_from_labels`
    warm starts under shrinking per-level superstep budgets (the finest
    level is capped at `level_decay * max_steps`; probs-carrying rules
    sharpen the projected labels by `vcycle_sharpen`). The schedule/mesh/assignment knobs apply to the
    finest level only; the V-cycle builds its own per-level layouts, so it
    is incompatible with a passed `dg`, warm-start args, checkpointing, and
    the state guard. See `docs/multilevel.md`.

    `halo_granularity` ("auto" | "block" | "vertex") picks the halo
    exchange unit: whole boundary blocks, or the exact per-vertex need
    lists moved by all-to-all with label-valued fields on an int8 wire
    (`repro.core.halo`; "auto" takes whichever moves fewer elements).
    `hub_replication=True` mirrors the top-degree vertices into every
    shard's replicated buffer region and reconciles their labels each
    superstep by a global weighted vote (`hub_quantile` /
    `hub_target_coverage` size the hub set, see `HubConfig`). On the
    sequential schedule hub replication runs the same plan on a 1-shard
    spec — the oracle trajectory the sharded hub mode is checked against;
    it is incompatible with `chunk_schedule="sharded"` (the full gather
    already replicates everything).

    `trace` (a `repro.obs.Tracer`; default off) records the run into a
    perfetto-exportable trace: a "run-partitioner" root span, layout build,
    one span per superstep, the windowed device syncs, recompile events,
    and per-superstep counter series (`local_edges`, `max_norm_load`,
    `migrations`) that ride the existing `sync_every` drain windows — the
    traced loop issues exactly the same blocking device fetches as the
    untraced one, and with tracing off results are bit-identical (see
    `docs/observability.md`).

    Crash safety (see `docs/fault-tolerance.md`): `checkpoint_dir` +
    `checkpoint_every=N` snapshot the full algorithm state (every state
    field, in original vertex order, plus the host-side score-stall
    counters) at the first drain window N or more supersteps after the last
    save — the state fetch rides the window's existing bundled
    `jax.device_get` (zero additional blocking fetches) and the disk write
    is async. `resume=True` restores the newest usable checkpoint (corrupt
    ones are skipped) and continues; a killed-and-resumed run is
    bit-identical to an uninterrupted one at the same arguments, including
    resuming on a different device count (sequential schedule; the sharded
    trajectory is device-count-specific, so its kill-resume exactness holds
    at an unchanged count and a count change matches a planned
    save/restore/continue migration). `keep_checkpoints` bounds the
    checkpoints kept on disk. `guard` checks state sanity (finite probs,
    in-range labels) at each drain window: "off" (default) | "raise" |
    "rollback"/"rollback-to-last-checkpoint" | "reinit"/
    "reinit-affected-vertices".
    """
    t0 = time.time()
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")
    guard = _GUARD_ALIASES.get(guard, guard)
    if guard not in _GUARD_POLICIES:
        raise ValueError(
            f"unknown guard policy {guard!r}; expected one of "
            f"{_GUARD_POLICIES} (or a long alias "
            f"{tuple(_GUARD_ALIASES)})")
    if checkpoint_every < 0:
        raise ValueError(
            f"checkpoint_every must be >= 0, got {checkpoint_every}")
    if checkpoint_dir is None and (checkpoint_every > 0 or resume):
        raise ValueError(
            "checkpoint_every/resume need a checkpoint_dir")
    if guard == "rollback" and checkpoint_dir is None:
        raise ValueError("guard='rollback' needs a checkpoint_dir")
    algorithm = get_algorithm(algo)
    static = isinstance(algorithm, StaticAlgorithm)
    schedule = cfg_kwargs.get("chunk_schedule")
    sharded = schedule in ("sharded", "halo", "async")
    if mesh is not None and not sharded:
        raise ValueError(
            "mesh is only meaningful with chunk_schedule='sharded'/'halo'/"
            "'async'")
    if not sharded and not (isinstance(assignment, str)
                            and assignment == "contiguous"):
        raise ValueError(
            "assignment is only meaningful with chunk_schedule="
            "'sharded'/'halo'/'async'")
    if halo_granularity not in ("auto", "block", "vertex"):
        raise ValueError(
            f"halo_granularity={halo_granularity!r} is not one of "
            "('auto', 'block', 'vertex')")
    if halo_granularity != "auto" and schedule not in ("halo", "async"):
        raise ValueError(
            "halo_granularity is only meaningful with chunk_schedule="
            "'halo'/'async'")
    if not hub_replication and (hub_quantile or hub_target_coverage is not None):
        raise ValueError(
            "hub_quantile/hub_target_coverage need hub_replication=True")
    if hub_replication and schedule == "sharded":
        raise ValueError(
            "hub_replication is incompatible with chunk_schedule='sharded' "
            "(the full gather already replicates every vertex); use "
            "chunk_schedule='halo' or the sequential schedule")
    hubs = (HubConfig(quantile=hub_quantile,
                      target_coverage=hub_target_coverage)
            if hub_replication else None)
    if static and cfg_kwargs:
        raise TypeError(f"{algo!r} runs no supersteps; it takes no config kwargs")
    if static and (checkpoint_dir is not None or guard != "off"):
        raise TypeError(
            f"{algo!r} runs no supersteps; checkpointing and the state guard "
            "are meaningless")
    if mode not in ("flat", "vcycle"):
        raise ValueError(f"mode={mode!r} is not one of ('flat', 'vcycle')")
    if mode != "vcycle" and (coarse_n is not None or level_decay is not None
                             or vcycle_sharpen is not None):
        raise ValueError(
            "coarse_n/level_decay/vcycle_sharpen are only meaningful with "
            "mode='vcycle'")
    if mode == "vcycle":
        if static:
            raise TypeError(
                f"{algo!r} runs no supersteps; mode='vcycle' refines through "
                "warm starts")
        if checkpoint_dir is not None or resume or guard != "off":
            raise ValueError(
                "mode='vcycle' is incompatible with checkpointing/resume/"
                "guard; its per-level runs are short — checkpoint a flat "
                "refinement from init_labels instead")
        if init_labels is not None or init_probs is not None or init_sharpen:
            raise ValueError(
                "mode='vcycle' derives its warm starts from the coarse "
                "levels; init_labels/init_probs/init_sharpen cannot be "
                "passed in")
        if dg is not None:
            raise ValueError(
                "mode='vcycle' builds its own per-level device layouts; "
                "dg= cannot be passed in")
        from repro.core import multilevel

        return multilevel.run_vcycle(
            algo, graph, k, seed=seed, n_blocks=n_blocks,
            max_steps=max_steps, track_history=track_history, mesh=mesh,
            assignment=assignment, halo_threshold=halo_threshold,
            halo_granularity=halo_granularity,
            hub_replication=hub_replication, hub_quantile=hub_quantile,
            hub_target_coverage=hub_target_coverage, sync_every=sync_every,
            keep_probs=keep_probs, trace=trace, coarse_n=coarse_n,
            level_decay=level_decay, vcycle_sharpen=vcycle_sharpen,
            cfg_kwargs=cfg_kwargs)
    tracer = trace if trace is not None else obs.NULL_TRACER
    with obs.use(tracer), \
            tracer.span("run-partitioner", algo=algo, k=k,
                        schedule=schedule or "sequential",
                        n=graph.n, m=graph.m):
        result = _run_partitioner_traced(
            tracer, algorithm, static, schedule, sharded,
            algo, graph, k, t0,
            seed=seed, n_blocks=n_blocks, max_steps=max_steps,
            track_history=track_history, dg=dg, mesh=mesh,
            assignment=assignment, halo_threshold=halo_threshold,
            halo_granularity=halo_granularity, hubs=hubs,
            sync_every=sync_every, init_labels=init_labels,
            init_probs=init_probs, init_sharpen=init_sharpen,
            keep_probs=keep_probs, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume,
            keep_checkpoints=keep_checkpoints, guard=guard,
            cfg_kwargs=cfg_kwargs)
    if tracer.enabled:
        # run manifest: trace_report --validate checks one superstep span
        # per executed step against this (resumed steps ran in an earlier
        # process — only the steps executed here have spans)
        tracer.meta.setdefault("runs", []).append({
            "algo": algo, "k": k, "schedule": schedule or "sequential",
            "steps": result.steps - result.resumed_from})
    return result


def _run_partitioner_traced(
    tracer, algorithm, static, schedule, sharded,
    algo: str, graph: Graph, k: int, t0: float, *,
    seed, n_blocks, max_steps, track_history, dg, mesh, assignment,
    halo_threshold, halo_granularity, hubs,
    sync_every, init_labels, init_probs, init_sharpen,
    keep_probs, checkpoint_dir, checkpoint_every, resume, keep_checkpoints,
    guard, cfg_kwargs,
) -> PartitionResult:
    """Body of `run_partitioner`, running under `obs.use(tracer)` inside the
    root span (split out so the traced scope covers every early return)."""
    with tracer.span("prepare-layout", schedule=schedule or "sequential"):
        if sharded:
            halo = schedule in ("halo", "async")
            if mesh is None and isinstance(dg, ShardedDeviceGraph):
                mesh = dg.mesh
            if mesh is None:
                from repro.launch.mesh import make_blocks_mesh

                mesh = make_blocks_mesh()
            if dg is None:
                dg = prepare_sharded_device_graph(
                    graph, mesh, n_blocks=n_blocks, assignment=assignment,
                    halo=halo, halo_threshold=halo_threshold,
                    halo_granularity=halo_granularity, hubs=hubs)
                if schedule == "async":
                    # interior-first storage order: pull each shard's
                    # interior blocks to the front so the phase-1 overlap
                    # window (interior_split) reaches min(interior_counts);
                    # boundary-ness only depends on ownership + hub set, so
                    # one rebuild with the composed permutation converges
                    order = interior_first_order(dg.halo)
                    if order is not None:
                        perm = (np.asarray(dg.block_perm)[order]
                                if dg.block_perm is not None else order)
                        dg = prepare_sharded_device_graph(
                            graph, mesh, n_blocks=n_blocks, assignment=perm,
                            halo=True, halo_threshold=halo_threshold,
                            halo_granularity=halo_granularity, hubs=hubs)
            elif not isinstance(dg, ShardedDeviceGraph):
                plain = dg
                dg = shard_device_graph(dg, mesh, assignment=assignment,
                                        halo=halo, halo_threshold=halo_threshold,
                                        halo_granularity=halo_granularity,
                                        hubs=hubs)
                if schedule == "async":
                    order = interior_first_order(dg.halo)
                    if order is not None:
                        perm = (np.asarray(dg.block_perm)[order]
                                if dg.block_perm is not None else order)
                        dg = shard_device_graph(
                            plain, mesh, assignment=perm, halo=True,
                            halo_threshold=halo_threshold,
                            halo_granularity=halo_granularity, hubs=hubs)
            else:
                if not (isinstance(assignment, str)
                        and assignment == "contiguous"):
                    # a placed layout's assignment is baked into its storage
                    # order — silently running the contiguous layout here would
                    # fake locality measurements
                    raise ValueError(
                        "assignment cannot be applied to a pre-built "
                        "ShardedDeviceGraph; pass assignment= to "
                        "shard_device_graph / prepare_sharded_device_graph "
                        "when building the layout")
                if halo and dg.halo is None:
                    dg = attach_halo(dg, halo_threshold,
                                     halo_granularity=halo_granularity,
                                     hubs=hubs)
        elif dg is None:
            dg = prepare_device_graph(graph, n_blocks=n_blocks)
    if tracer.enabled and sharded:
        # static per-run exchange gauges from the precomputed plan — what
        # each superstep's gather moves, without touching the device
        n_fields = 1 if static else len(algorithm.vertex_fields)
        if dg.halo is not None:
            spec = dg.halo
            # per-field wire width: label-valued fields ride the int8 wire
            # on the per-vertex exchange (exact for k <= 127), everything
            # else moves at storage width
            if static:
                wire_sum = 4 * n_fields
            else:
                wire_sum = sum(
                    spec.wire_bytes_per_elem(
                        k, f in algorithm.wire_int8_fields)
                    for f in algorithm.vertex_fields)
            tracer.counter("halo_b_max", spec.b_max)
            tracer.counter("halo_h_max", spec.h_max)
            tracer.counter("halo_coverage", spec.coverage)
            if schedule == "async":
                # trace_report --validate requires the overlap span pair
                # for async runs unless the plan fell back to the full
                # gather (no interior scan exists to overlap with)
                if spec.fallback:
                    tracer.meta["async_fallback"] = True
                tracer.counter("interior_split", spec.interior_split)
            tracer.counter(
                "gathered_bytes_halo",
                spec.gathered_elems_per_device() * wire_sum)
            tracer.counter(
                "gathered_bytes_full",
                spec.full_gather_elems_per_device() * 4 * n_fields)
            if spec.granularity == "vertex" and not spec.fallback:
                tracer.counter(
                    "pervertex_halo_bytes",
                    spec.gathered_elems_per_device() * wire_sum)
            tracer.counter("hub_count", spec.n_hubs)
            if spec.n_hubs:
                tracer.counter(
                    "replica_vote_bytes",
                    spec.hub_sync_elems_per_device(k, n_fields) * 4)
        else:
            n_shards = int(dg.mesh.devices.size)
            per_dev = (n_shards - 1) * (dg.n_blocks // n_shards) * dg.block_v
            tracer.counter("gathered_bytes_full", per_dev * 4 * n_fields)
    key = jax.random.PRNGKey(seed)

    if static:
        if init_labels is not None or init_probs is not None or init_sharpen:
            raise TypeError(f"{algo!r} is stateless; warm-start args are meaningless")
        labels = jax.numpy.pad(algorithm.partition(graph.n, k),
                               (0, dg.n_pad - graph.n))
        le = float(local_edges(labels, dg.dir_src, dg.dir_dst))
        ml = float(max_normalized_load(labels[: graph.n], dg.deg_out[: graph.n], k))
        if tracer.enabled:
            tracer.counter("local_edges", le, step=0)
            tracer.counter("max_norm_load", ml, step=0)
        return PartitionResult(
            algo=algo, k=k, labels=np.asarray(labels[: graph.n]), steps=0,
            converged=True, local_edges=le, max_norm_load=ml,
            history={"local_edges": [le], "max_norm_load": [ml], "score": [0.0]},
            wall_s=time.time() - t0,
        )

    cfg = _make_cfg(algorithm.config_cls, k, max_steps, cfg_kwargs)
    if not algorithm.supports_probs:
        if init_probs is not None:
            raise TypeError(
                f"{algo!r} has no LA state; init_probs/init_sharpen are meaningless")
        if init_sharpen:
            raise TypeError(
                f"{algo!r} has no LA state; init_probs/init_sharpen are meaningless")
    if init_labels is not None:
        if algorithm.init_from_labels is None:
            raise TypeError(f"{algo!r} does not support warm starts")
        if algorithm.supports_probs:
            state = algorithm.init_from_labels(dg, cfg, key, init_labels,
                                               probs=init_probs,
                                               prob_sharpen=init_sharpen)
        else:
            state = algorithm.init_from_labels(dg, cfg, key, init_labels)
    else:
        if init_probs is not None:
            raise TypeError("init_probs requires init_labels")
        if init_sharpen:
            raise TypeError("init_sharpen requires init_labels")
        state = algorithm.init(dg, cfg, key)
    if sharded:
        state = engine.place_state(algorithm, state, dg)
    seq_halo = None
    if hubs is not None and not sharded:
        # sequential hub oracle: run the same hub plan on a 1-shard spec —
        # the reference trajectory the sharded hub mode is checked against
        # bit-exactly (quantile hub selection is shard-count independent)
        seq_halo = build_halo_spec(
            np.asarray(dg.blk_dst), np.asarray(dg.blk_w), 1, dg.block_v,
            threshold=halo_threshold, hubs=hubs,
            deg=np.asarray(dg.deg_out), vmask=np.asarray(dg.vmask),
            blk_row=np.asarray(dg.blk_row))
    # async staleness driver: the engine only distinguishes fresh (cache is
    # None) from stale (reuse the returned tail); the *policy* lives here.
    # Refresh when the bound expires (g % (s+1) == 0 keeps any tail at most
    # staleness_bound supersteps old) and on every checkpoint window (g %
    # sync_every == 0), so a snapshot is always taken downstream of a fresh
    # exchange and kill-and-resume replays bit-identically even at s >= 1
    # (a resumed run starts with cache=None — the same forced refresh).
    async_box = {"cache": None, "g": None, "last_refresh": 0}
    if schedule == "async":
        staleness = getattr(cfg, "staleness_bound", 0)
        ckpt_windows = checkpoint_dir is not None and checkpoint_every > 0

        def base_step(s):
            if async_box["g"] is None:   # first call: resume-aware origin
                async_box["g"] = start_step
                async_box["last_refresh"] = start_step
            g = async_box["g"]
            refresh = (async_box["cache"] is None
                       or staleness == 0
                       or g % (staleness + 1) == 0
                       or (ckpt_windows and g % sync_every == 0))
            if refresh:
                async_box["cache"] = None
                async_box["last_refresh"] = g
            s2, async_box["cache"] = engine.async_superstep(
                algorithm, dg, cfg, s, cache=async_box["cache"])
            if tracer.enabled:
                tracer.counter("halo_staleness",
                               float(g - async_box["last_refresh"]), step=g)
            async_box["g"] = g + 1
            return s2
    else:
        base_step = lambda s: engine.superstep(algorithm, dg, cfg, s,
                                               halo=seq_halo)

    # ---- crash safety: checkpoint manager + resume -----------------------
    ckpt = None
    if checkpoint_dir is not None:
        run_meta = {"kind": "partition", "algo": algo, "k": k, "n": graph.n,
                    "m": graph.m, "schedule": schedule or "sequential",
                    "seed": seed, "sync_every": sync_every,
                    "patience": cfg.patience}
        ckpt = _CheckpointManager(checkpoint_dir, checkpoint_every,
                                  keep_checkpoints, algorithm, dg, sharded,
                                  run_meta, tracer)
    start_step, start_prev_score, start_stall = 0, -np.inf, 0
    resumed_converged = False
    if resume:
        restored = ckpt.restore_latest(state)
        if restored is not None:
            (state, start_step, start_prev_score, start_stall,
             resumed_converged) = restored
            ckpt.last_saved = start_step
        # no checkpoint yet -> a fresh run (so the same command line works
        # for the first launch and every relaunch)

    history: Dict[str, List[float]] = {"local_edges": [], "max_norm_load": [], "score": []}
    # per-step metric arrays stay on device and are drained on the same
    # sync_every window as the scores — neither history tracking nor tracing
    # forces a host sync per superstep
    pending_le: List[jax.Array] = []
    pending_ml: List[jax.Array] = []
    pending_mig: List[jax.Array] = []
    step_ts: List[float] = []    # dispatch timestamp per buffered step, so
                                 # drained counters are back-dated to the
                                 # superstep that produced them
    drained = [0]                # global index of the next drained step

    if tracer.enabled:
        def step_fn(s):
            # labels are donated into the superstep — copy *before* dispatch
            # (the copy is enqueued ahead of the overwrite) to count
            # migrations as a device-side reduction drained with the window
            prev = jnp.copy(s.labels)
            s2 = base_step(s)
            pending_mig.append(jnp.sum((s2.labels != prev) & dg.vmask))
            return s2
    else:
        step_fn = base_step

    collect = track_history or tracer.enabled

    def on_step(s):
        # labels and the dir_*/deg arrays live in the same (possibly
        # locality-permuted) index space; the load metric uses the full
        # padded arrays because real vertices are not a prefix under a
        # permuted assignment (padding carries zero degree, so the value is
        # unchanged on contiguous layouts)
        pending_le.append(local_edges(s.labels, dg.dir_src, dg.dir_dst))
        pending_ml.append(max_normalized_load(s.labels, dg.deg_out, k))
        if tracer.enabled:
            step_ts.append(tracer.now_us())

    def drain_metrics(dstate, loop_steps, prev_score, stall):
        # one bundled fetch per window, traced or not — the sync-count
        # contract pinned by tests/test_obs.py. Guard predicates and the
        # checkpoint snapshot ride the *same* device_get, so crash safety
        # adds zero blocking fetches.
        gsteps = start_step + loop_steps
        bundle = {"le": pending_le, "ml": pending_ml, "mig": pending_mig}
        if guard != "off":
            checks = {"labels": jnp.all(jnp.where(
                dg.vmask, (dstate.labels >= 0) & (dstate.labels < cfg.k), True))}
            if algorithm.supports_probs:
                checks["probs"] = jnp.all(jnp.isfinite(dstate.probs))
            bundle["guard"] = checks
        save_due = ckpt is not None and ckpt.due(gsteps) and not ckpt.busy()
        if save_due:
            bundle["ckpt"] = ckpt.device_tree(dstate)
        with tracer.span("device-sync", steps=len(pending_le), what="metrics"):
            fetched = jax.device_get(bundle)
        le_v, ml_v, mig_v = fetched["le"], fetched["ml"], fetched["mig"]
        if track_history:
            history["local_edges"].extend(float(x) for x in le_v)
            history["max_norm_load"].extend(float(x) for x in ml_v)
        if tracer.enabled:
            for i in range(len(le_v)):
                step = drained[0] + i
                ts = step_ts[i] if i < len(step_ts) else None
                tracer.counter("local_edges", float(le_v[i]), step=step, ts=ts)
                tracer.counter("max_norm_load", float(ml_v[i]), step=step, ts=ts)
                if i < len(mig_v):
                    tracer.counter("migrations", float(mig_v[i]), step=step, ts=ts)
        drained[0] += len(le_v)
        pending_le.clear()
        pending_ml.clear()
        pending_mig.clear()
        step_ts.clear()

        bad = [name for name, ok in fetched.get("guard", {}).items()
               if not bool(ok)]
        if bad:
            return _handle_guard_violation(bad, gsteps)
        if save_due:
            ckpt.save(gsteps, fetched["ckpt"], prev_score, stall)
        return None

    def _handle_guard_violation(bad, gsteps):
        # never checkpoint a corrupt state — the save for this window is
        # skipped no matter which recovery policy runs
        desc = ("non-finite probs" if "probs" in bad
                else "out-of-range labels")
        tracer.instant("guard-violation", step=gsteps, checks=",".join(bad))
        tracer.counter("guard_violations", 1)
        _log.warning("state guard tripped at step %d: %s", gsteps, desc)
        if guard == "raise":
            raise PartitionStateError(
                f"state guard tripped at step {gsteps}: {desc}")
        if guard == "rollback":
            restored = ckpt.restore_latest(state)
            if restored is None:
                raise PartitionStateError(
                    f"state guard tripped at step {gsteps} ({desc}) and no "
                    f"usable checkpoint exists in {checkpoint_dir} to roll "
                    f"back to")
            r_state, r_step, r_prev, r_stall, _ = restored
            tracer.instant("rollback", from_step=gsteps, to_step=r_step)
            _log.warning("rolled back to checkpoint step %d", r_step)
            # a cached halo tail was built from the now-discarded trajectory
            async_box["cache"] = None
            # loop step counting continues forward; only the halting state
            # and device state rewind
            return {"state": r_state, "prev_score": r_prev, "stall": r_stall}
        # reinit-affected-vertices: repair device-side — clamp labels into
        # range, rebuild loads from the repaired labels, and reset any
        # non-finite prob rows to uniform
        s = state_box[0]
        labels = jnp.clip(s.labels, 0, cfg.k - 1).astype(s.labels.dtype)
        fix = {"labels": labels}
        if hasattr(s, "loads"):
            fix["loads"] = engine.loads_from_labels(dg, cfg.k, labels)
        if algorithm.supports_probs:
            flat = s.probs.reshape(dg.n_pad, cfg.k)
            row_ok = jnp.all(jnp.isfinite(flat), axis=1, keepdims=True)
            uniform = jnp.full_like(flat, 1.0 / cfg.k)
            fix["probs"] = jnp.where(row_ok, flat, uniform).reshape(
                s.probs.shape)
        tracer.instant("reinit", step=gsteps)
        _log.warning("reinitialized affected vertices at step %d", gsteps)
        async_box["cache"] = None   # tail may carry the corrupt labels
        return {"state": s._replace(**fix), "prev_score": -np.inf, "stall": 0}

    # the reinit path needs the loop's current state object (drain_metrics
    # receives it); a one-slot box keeps the closure simple
    state_box = [state]

    def on_drain(dstate, loop_steps, prev_score, stall):
        state_box[0] = dstate
        return drain_metrics(dstate, loop_steps, prev_score, stall)

    need_drain = collect or ckpt is not None or guard != "off"
    remaining = cfg.max_steps - start_step
    if resumed_converged or remaining <= 0:
        # nothing left to run: the checkpoint already recorded the outcome
        # (hitting max_steps without a stall is converged=False, same as an
        # uninterrupted run)
        loop_steps, converged = 0, resumed_converged
    else:
        state, loop_steps, converged = run_convergence_loop(
            step_fn, state,
            max_steps=remaining, patience=cfg.patience, theta=cfg.theta,
            sync_every=sync_every,
            on_step=on_step if collect else None,
            on_score=history["score"].append if track_history else None,
            on_drain=on_drain if need_drain else None,
            tracer=tracer,
            step0=start_step, prev_score=start_prev_score, stall=start_stall,
        )
    if ckpt is not None:
        ckpt.finish()
    steps = start_step + loop_steps

    # final fetch: one device_get for everything still needed. With history
    # tracking on, the final step's local_edges/max_norm_load already came
    # back through the windowed drain — reuse them instead of issuing two
    # extra blocking float(...) syncs after convergence. Labels/probs cross
    # the API boundary in original vertex order (identity gather on
    # unpermuted layouts).
    fetch = {"labels": vertices_to_original(dg, state.labels)[: graph.n]}
    if track_history and history["local_edges"]:
        le, ml = history["local_edges"][-1], history["max_norm_load"][-1]
    elif tracer.enabled and tracer.series.get("local_edges"):
        le = tracer.series["local_edges"][-1][1]
        ml = tracer.series["max_norm_load"][-1][1]
    else:
        fetch["le"] = local_edges(state.labels, dg.dir_src, dg.dir_dst)
        fetch["ml"] = max_normalized_load(state.labels, dg.deg_out, k)
    if keep_probs and algorithm.supports_probs:
        flat = state.probs.reshape(dg.n_pad, cfg.k)
        fetch["probs"] = vertices_to_original(dg, flat).reshape(
            dg.n_blocks, dg.block_v, cfg.k)
    with tracer.span("device-sync", what="result"):
        fetched = jax.device_get(fetch)
    if "le" in fetched:
        le, ml = float(fetched["le"]), float(fetched["ml"])
    return PartitionResult(
        algo=algo, k=k, labels=np.asarray(fetched["labels"]), steps=steps,
        converged=converged, local_edges=le, max_norm_load=ml, history=history,
        wall_s=time.time() - t0,
        probs=np.asarray(fetched["probs"]) if "probs" in fetched else None,
        resumed_from=start_step,
    )
