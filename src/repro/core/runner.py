"""Host-side convergence loop (Section IV-D step 9) shared by all algorithms.

Runs jitted supersteps, tracks the paper's quality metrics each step, and
halts when the LP score fails to improve by `theta` for `patience`
consecutive steps (paper settings: theta=0.001, patience=5, max 290 steps).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.device_graph import DeviceGraph, prepare_device_graph
from repro.core.metrics import local_edges, max_normalized_load
from repro.core.revolver import RevolverConfig, revolver_init, revolver_superstep
from repro.core.spinner import SpinnerConfig, spinner_init, spinner_superstep
from repro.core.static_partitioners import hash_partition, range_partition
from repro.graphs.csr import Graph


@dataclasses.dataclass
class PartitionResult:
    algo: str
    k: int
    labels: np.ndarray                 # [n] final partition per vertex
    steps: int
    converged: bool
    local_edges: float
    max_norm_load: float
    history: Dict[str, List[float]]
    wall_s: float


def run_partitioner(
    algo: str,
    graph: Graph,
    k: int,
    *,
    seed: int = 0,
    n_blocks: int = 8,
    max_steps: Optional[int] = None,
    track_history: bool = True,
    dg: Optional[DeviceGraph] = None,
    **cfg_kwargs,
) -> PartitionResult:
    """Partition `graph` into `k` parts with the named algorithm.

    algo: "revolver" | "spinner" | "hash" | "range".
    Extra kwargs flow into the algorithm config dataclass.
    """
    t0 = time.time()
    if dg is None:
        dg = prepare_device_graph(graph, n_blocks=n_blocks)
    key = jax.random.PRNGKey(seed)

    if algo in ("hash", "range"):
        lab_fn = hash_partition if algo == "hash" else range_partition
        labels = jax.numpy.pad(lab_fn(graph.n, k), (0, dg.n_pad - graph.n))
        le = float(local_edges(labels, dg.dir_src, dg.dir_dst))
        ml = float(max_normalized_load(labels[: graph.n], dg.deg_out[: graph.n], k))
        return PartitionResult(
            algo=algo, k=k, labels=np.asarray(labels[: graph.n]), steps=0,
            converged=True, local_edges=le, max_norm_load=ml,
            history={"local_edges": [le], "max_norm_load": [ml], "score": [0.0]},
            wall_s=time.time() - t0,
        )

    if algo == "revolver":
        cfg = RevolverConfig(k=k, **cfg_kwargs)
        if max_steps is not None:
            cfg = dataclasses.replace(cfg, max_steps=max_steps)
        state = revolver_init(dg, cfg, key)
        step_fn = lambda s: revolver_superstep(dg, cfg, s)
    elif algo == "spinner":
        cfg = SpinnerConfig(k=k, **{k_: v for k_, v in cfg_kwargs.items()
                                    if k_ in {f.name for f in dataclasses.fields(SpinnerConfig)}})
        if max_steps is not None:
            cfg = dataclasses.replace(cfg, max_steps=max_steps)
        state = spinner_init(dg, cfg, key)
        step_fn = lambda s: spinner_superstep(dg, cfg, s)
    else:
        raise ValueError(f"unknown algorithm {algo!r}")

    history: Dict[str, List[float]] = {"local_edges": [], "max_norm_load": [], "score": []}
    prev_score, stall, converged = -np.inf, 0, False
    steps = 0
    for step in range(cfg.max_steps):
        state = step_fn(state)
        steps = step + 1
        score = float(state.score)
        if track_history:
            history["local_edges"].append(float(local_edges(state.labels, dg.dir_src, dg.dir_dst)))
            history["max_norm_load"].append(
                float(max_normalized_load(state.labels[: graph.n], dg.deg_out[: graph.n], k)))
            history["score"].append(score)
        # paper halting (Section IV-D step 9): halt after `patience`
        # consecutive steps with (S^i - S^{i-1}) < theta
        if score - prev_score < cfg.theta:
            stall += 1
            if stall >= cfg.patience:
                converged = True
                break
        else:
            stall = 0
        prev_score = score

    labels = np.asarray(state.labels[: graph.n])
    le = float(local_edges(state.labels, dg.dir_src, dg.dir_dst))
    ml = float(max_normalized_load(state.labels[: graph.n], dg.deg_out[: graph.n], k))
    return PartitionResult(
        algo=algo, k=k, labels=labels, steps=steps, converged=converged,
        local_edges=le, max_norm_load=ml, history=history, wall_s=time.time() - t0,
    )
