"""Label-propagation scoring: the paper's normalized LP (eqs. 10-12) and the
Spinner baseline scoring (eqs. 3-5).

Both scorers share one primitive — the *edge label histogram*: for every
vertex v accumulate, per partition l, the eq.-(4)-weighted count of neighbors
currently labeled l. `edge_histogram_jnp` is the XLA scatter-add reference;
`repro.kernels.edge_histogram` is the Pallas TPU kernel (one-hot matmul on
the MXU) with identical semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_histogram_jnp(
    rows: jax.Array,
    slots: jax.Array,
    vals: jax.Array,
    n_rows: int,
    k: int,
) -> jax.Array:
    """hist[r, s] = sum of vals[e] over edges with rows[e]==r, slots[e]==s.

    Args:
      rows: [E] int32 destination row per edge (local vertex index).
      slots: [E] int32 partition slot per edge (e.g. neighbor's label).
      vals: [E] float values (0.0 for padding edges).
      n_rows, k: histogram shape.
    """
    hist = jnp.zeros((n_rows, k), dtype=vals.dtype)
    return hist.at[rows, slots].add(vals)


def tau_term(hist: jax.Array, inv_wsum: jax.Array) -> jax.Array:
    """Eq. (11): neighborhood affinity normalized by the total edge weight."""
    return hist * inv_wsum[:, None]


def normalized_penalty(loads: jax.Array, capacity: float) -> jax.Array:
    """Eq. (12) with the footnote-1 negative shift.

    pi(l) = (1 - b(l)/C) normalized over partitions; if any term is negative
    (partition over capacity), shift by the minimum before normalizing.
    """
    pen = 1.0 - loads / capacity
    mn = jnp.min(pen)
    pen = jnp.where(mn < 0, pen - mn, pen)
    total = jnp.sum(pen)
    k = loads.shape[0]
    return jnp.where(total > 0, pen / jnp.where(total > 0, total, 1.0),
                     jnp.full_like(pen, 1.0 / k))


def revolver_scores(hist: jax.Array, inv_wsum: jax.Array, loads: jax.Array,
                    capacity: float) -> jax.Array:
    """Eq. (10): score(v,l) = (tau(v,l) + pi(l)) / 2."""
    tau = tau_term(hist, inv_wsum)
    pi = normalized_penalty(loads, capacity)
    return 0.5 * (tau + pi[None, :])


def spinner_penalty(loads: jax.Array, capacity: float) -> jax.Array:
    """Eq. (5): pi_hat(l) = b(l)/C (unnormalized; the term Spinner subtracts)."""
    return loads / capacity


def spinner_scores(hist: jax.Array, inv_wsum: jax.Array, loads: jax.Array,
                   capacity: float) -> jax.Array:
    """Eq. (3): score_hat(v,l) = tau_hat(v,l) - pi_hat(l)."""
    tau = tau_term(hist, inv_wsum)
    return tau - spinner_penalty(loads, capacity)[None, :]
