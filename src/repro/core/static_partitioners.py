"""The paper's static baselines (Section V-D): Hash and Range partitioning."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_partition(n: int, k: int) -> jax.Array:
    """v mod k."""
    return jnp.arange(n, dtype=jnp.int32) % k


def range_partition(n: int, k: int) -> jax.Array:
    """floor(v * k / |V|)."""
    v = jnp.arange(n, dtype=jnp.int64)
    return jnp.minimum((v * k) // n, k - 1).astype(jnp.int32)
