"""The paper's static baselines (Section V-D): Hash and Range partitioning.

Registered as ``StaticAlgorithm`` entries, so ``run_partitioner("hash")`` /
``("range")`` resolve through the same registry as the superstep
algorithms and every benchmark sweep gets the no-learning quality baseline
for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.registry import StaticAlgorithm, register


def hash_partition(n: int, k: int) -> jax.Array:
    """v mod k."""
    return jnp.arange(n, dtype=jnp.int32) % k


def range_partition(n: int, k: int) -> jax.Array:
    """floor(v * k / |V|)."""
    v = jnp.arange(n, dtype=jnp.int64)
    return jnp.minimum((v * k) // n, k - 1).astype(jnp.int32)


HASH = register(StaticAlgorithm(name="hash", partition=hash_partition))
RANGE = register(StaticAlgorithm(name="range", partition=range_partition))
