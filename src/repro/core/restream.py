"""Degree-prioritized restreaming partitioner (third engine rule).

Restreaming partitioners (Nishimura & Ugander; Awadelkarim & Ugander's
prioritized variant) repeatedly re-stream the vertex set through a greedy
one-shot assignment rule, letting each pass refine the previous one. Two
ingredients map directly onto the engine's chunk schedule:

  * the **greedy rule**: each vertex takes the FENNEL/LDG-style argmax of
    neighborhood affinity minus a load penalty,
    ``score(v,l) = tau(v,l) - gamma * b(l)/C``, against the freshest
    configuration — exactly the drifting view the asynchronous chunk scan
    provides (earlier chunks' moves are visible to later chunks, like
    earlier vertices in a stream);
  * the **priority order**: high-degree vertices are (re)streamed first,
    because their placement constrains the most edges. The block layout is
    fixed, so priority is expressed in *time* instead of stream position: a
    degree-rank gate unlocks the stream over ``priority_ramp`` supersteps —
    superstep t re-decides only the top ``(t+1)/priority_ramp`` degree
    quantile, so hubs settle while the tail is still frozen, then everyone
    refines.

A per-vertex **restream budget** (``restream_budget``, default 32) caps how
many times any one vertex is re-decided across the run: each active
re-decision spends one unit of the vertex's budget (tracked in the ``used``
block field), and an exhausted vertex's label is frozen — the bounded-churn
guarantee cloud re-streaming wants (a vertex cannot oscillate forever, and
the tail of the stream cannot be re-litigated without bound). ``0`` lifts
the cap.

The whole module is rule code: config/state/init plus one ``chunk_rule``.
Both execution schedules, warm starts through ``run_partitioner`` /
``StreamRunner``, donation, and sharded placement are inherited from
``repro.core.engine`` (see core/README.md) — nothing here knows a mesh
exists.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine
from repro.core.device_graph import CAPACITY_MODES, DeviceGraph, ShardedDeviceGraph  # noqa: F401  (re-exported API)
from repro.core.lp import edge_histogram_jnp, spinner_penalty, tau_term
from repro.core.registry import register

_CHUNK_SCHEDULES = ("sequential", "sharded", "halo", "async")


@dataclasses.dataclass(frozen=True)
class RestreamConfig:
    k: int
    epsilon: float = 0.05
    max_steps: int = 290
    patience: int = 5
    theta: float = 0.001
    capacity_mode: str = "spinner"
    chunk_schedule: str = "sequential"
    gamma: float = 1.0        # load-penalty weight in the greedy objective
    priority_ramp: int = 8    # supersteps over which the degree-ordered
                              # stream unlocks (1 = no prioritization)
    restream_budget: int = 32  # max re-decisions per vertex across the run
                               # (0 = unlimited); an exhausted vertex's
                               # label is frozen, bounding per-vertex churn
    staleness_bound: int = 0   # "async" schedule: supersteps a stale halo
                               # tail may be reused (0 = exact, see
                               # docs/async-superstep.md)

    def __post_init__(self):
        if self.capacity_mode not in CAPACITY_MODES:
            raise ValueError(
                f"RestreamConfig.capacity_mode={self.capacity_mode!r} is not "
                f"one of {CAPACITY_MODES}")
        if self.chunk_schedule not in _CHUNK_SCHEDULES:
            raise ValueError(
                f"RestreamConfig.chunk_schedule={self.chunk_schedule!r} is "
                f"not one of {_CHUNK_SCHEDULES}")
        if self.priority_ramp < 1:
            raise ValueError(
                f"RestreamConfig.priority_ramp must be >= 1, got "
                f"{self.priority_ramp}")
        if self.restream_budget < 0:
            raise ValueError(
                f"RestreamConfig.restream_budget must be >= 0 "
                f"(0 = unlimited), got {self.restream_budget}")
        if not isinstance(self.staleness_bound, int) or \
                self.staleness_bound < 0:
            raise ValueError(
                f"RestreamConfig.staleness_bound={self.staleness_bound!r} "
                "must be an int >= 0")
        if self.staleness_bound > 0 and self.chunk_schedule != "async":
            raise ValueError(
                "staleness_bound > 0 only applies to chunk_schedule='async' "
                f"(got chunk_schedule={self.chunk_schedule!r})")


class RestreamState(NamedTuple):
    labels: jnp.ndarray   # [n_pad] int32
    loads: jnp.ndarray    # [k] f32
    rank: jnp.ndarray     # [n_pad] f32 degree-rank percentile (1 = hub);
                          # constant across supersteps (engine-replicated)
    used: jnp.ndarray     # [n_blocks, block_v] int32 re-decisions spent per
                          # vertex (gates against cfg.restream_budget)
    key: jax.Array
    step: jnp.ndarray
    score: jnp.ndarray


def _degree_ranks(dg: DeviceGraph) -> jnp.ndarray:
    """Percentile of each vertex in the degree order (ties broken by id so
    the gate threshold moves through vertices one at a time)."""
    pos = jnp.argsort(jnp.argsort(dg.deg_out, stable=True), stable=True)
    return pos.astype(jnp.float32) / jnp.float32(max(dg.n_pad - 1, 1))


def restream_init(dg: DeviceGraph, cfg: RestreamConfig, key: jax.Array) -> RestreamState:
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    return RestreamState(
        labels=labels,
        loads=engine.loads_from_labels(dg, cfg.k, labels),
        rank=_degree_ranks(dg),
        used=jnp.zeros((dg.n_blocks, dg.block_v), jnp.int32),
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def restream_init_from_labels(
    dg: DeviceGraph, cfg: RestreamConfig, key: jax.Array, labels: jnp.ndarray
) -> RestreamState:
    """Warm-start from a previous assignment (streaming repartitioning): the
    carried partition is the stream being re-streamed, so the priority ramp
    replays hubs against it first — the prioritized-restream recovery the
    streaming runner wants after a delta."""
    k_lab, key = jax.random.split(key)
    lab = engine.warm_labels(dg, cfg.k, k_lab, labels)
    return RestreamState(
        labels=lab,
        loads=engine.loads_from_labels(dg, cfg.k, lab),
        rank=_degree_ranks(dg),
        used=jnp.zeros((dg.n_blocks, dg.block_v), jnp.int32),
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def _restream_chunk_rule(cfg: RestreamConfig, ctx: engine.ChunkContext,
                         vert, block, loads, cap, key) -> engine.ChunkUpdate:
    """Greedy restream step for one chunk of the (time-unrolled) stream."""
    labels = vert["labels"]
    bv = ctx.vmask.shape[0]
    k = cfg.k
    key, k_mig = jax.random.split(key)
    cur = jax.lax.dynamic_slice(labels, (ctx.v0,), (bv,))
    # rank is a replicated [n_pad] array in global vertex space — slice it by
    # the block's global offset (gv0 == v0 except under the halo schedule,
    # where v0 addresses the shard's local+halo buffer instead)
    rank = jax.lax.dynamic_slice(ctx.repl["rank"], (ctx.gv0,), (bv,))

    # degree-priority gate: superstep t re-decides only the top
    # (t+1)/priority_ramp degree quantile; after the ramp, everyone
    unlock = 1.0 - (ctx.step.astype(jnp.float32) + 1.0) / cfg.priority_ramp
    active = (rank >= unlock) & ctx.vmask
    # per-vertex restream budget: a vertex re-decided restream_budget times
    # is frozen at its current label — bounding how often any one vertex
    # churns across the run (0 = unlimited)
    used = block["used"]
    if cfg.restream_budget:
        active &= used < cfg.restream_budget
    used = used + active.astype(used.dtype)

    # greedy objective against the freshest configuration (async view)
    with obs.annotate("edge-phase", impl="jnp"):
        nbr_labels = labels[ctx.e_dst]
        hist = edge_histogram_jnp(ctx.e_row, nbr_labels, ctx.e_w, bv, k)
    scores = tau_term(hist, ctx.inv_wsum) \
        - cfg.gamma * spinner_penalty(loads, cap)[None, :]
    bump = jax.nn.one_hot(cur, k, dtype=scores.dtype) * 1e-6  # stay on ties
    cand = jnp.argmax(scores + bump, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)
    score = jnp.sum(jnp.where(ctx.vmask, best, 0.0))

    # capacity-gated migration (shared machinery with revolver/spinner).
    # The headroom is shard-rationed: restream's deterministic argmax
    # concentrates demand far more than revolver's LA sampling, so gating
    # against the raw drifting `cap - loads` under the Jacobi schedule lets
    # every shard spend the same remaining capacity — n_shards-fold
    # overshoot and oscillation (max_norm_load ~6 at 8 shards). See
    # engine.ChunkContext.shared_headroom.
    wants = (cand != cur) & active
    demand = jnp.zeros((k,), jnp.float32).at[cand].add(ctx.deg * wants)
    remaining = ctx.shared_headroom(cap, loads)
    p_mig = jnp.where(demand > 0,
                      jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
                      1.0)
    u = jax.random.uniform(k_mig, (bv,))
    migrate = wants & (u < p_mig[cand])
    new_lbl = jnp.where(migrate, cand, cur)

    dmig = ctx.deg * migrate
    loads = loads.at[cur].add(-dmig).at[cand].add(dmig)
    return engine.ChunkUpdate(
        vert={"labels": new_lbl},
        block={"used": used},
        loads=loads,
        key=key,
        score=score,
    )


RESTREAM = register(engine.Algorithm(
    name="restream",
    config_cls=RestreamConfig,
    state_cls=RestreamState,
    kind="chunk",
    vertex_fields=("labels",),
    wire_int8_fields=("labels",),
    block_fields=("used",),
    replicated_fields=("rank",),
    donate=("labels", "loads", "used"),
    init=restream_init,
    init_from_labels=restream_init_from_labels,
    chunk_rule=_restream_chunk_rule,
))


def place_restream_state(state: RestreamState, sdg: ShardedDeviceGraph) -> RestreamState:
    """Commit an initialized state to the sharded layout (see
    ``engine.place_state``)."""
    return engine.place_state(RESTREAM, state, sdg)


def restream_superstep(dg, cfg: RestreamConfig, state: RestreamState) -> RestreamState:
    """One restream pass (see ``engine.superstep``; labels/loads donated)."""
    return engine.superstep(RESTREAM, dg, cfg, state)
