"""Spinner baseline (Martella et al., ICDE'17) — eqs. (3)-(5) of the paper.

Synchronous BSP label propagation: all vertices score all partitions against
the *previous* step's labels/loads, pick the argmax candidate, and migrate
gated by remaining capacity — the paper's main comparison point.

This module is a **rule module** (see ``core/README.md``): it contributes
one ``shard_rule`` that processes a whole shard in a single BSP step; the
engine runs it over the blocked edge slabs either on one shard spanning the
graph (``chunk_schedule="sequential"``) or data-parallel under ``shard_map``
(``"sharded"``). Spinner is synchronous already, so sharding it changes no
visibility semantics — only where the histogram work runs. Cross-shard
reductions (candidate demand, score) go through the context's collectives,
which degenerate to identities on the sequential schedule; the eq.-(4)
weights are integer-valued, so the slab-ordered histogram accumulation is
exact and both schedules are bit-stable against the flat-array reference.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine
from repro.core.device_graph import CAPACITY_MODES, DeviceGraph, ShardedDeviceGraph  # noqa: F401  (re-exported API)
from repro.core.lp import edge_histogram_jnp, spinner_scores
from repro.core.registry import register

_CHUNK_SCHEDULES = ("sequential", "sharded", "halo")


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    k: int
    epsilon: float = 0.05
    max_steps: int = 290
    patience: int = 5
    theta: float = 0.001
    capacity_mode: str = "spinner"
    # "sequential": one shard spanning the whole graph; "sharded": BSP
    # data-parallel over the blocked slabs on a ("blocks",) mesh; "halo":
    # same, syncing only the precomputed boundary blocks (repro.core.halo).
    chunk_schedule: str = "sequential"

    def __post_init__(self):
        if self.capacity_mode not in CAPACITY_MODES:
            raise ValueError(
                f"SpinnerConfig.capacity_mode={self.capacity_mode!r} is not "
                f"one of {CAPACITY_MODES}")
        if self.chunk_schedule not in _CHUNK_SCHEDULES:
            raise ValueError(
                f"SpinnerConfig.chunk_schedule={self.chunk_schedule!r} is "
                f"not one of {_CHUNK_SCHEDULES}")


class SpinnerState(NamedTuple):
    labels: jnp.ndarray   # [n_pad] int32
    loads: jnp.ndarray    # [k] f32
    key: jax.Array
    step: jnp.ndarray
    score: jnp.ndarray


def spinner_init(dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array) -> SpinnerState:
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    loads = engine.loads_from_labels(dg, cfg.k, labels)
    return SpinnerState(labels, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def spinner_init_from_labels(
    dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array, labels: jnp.ndarray
) -> SpinnerState:
    """Warm-start from a previous assignment; new vertices draw random labels
    (mirrors `revolver_init_from_labels`, minus the LA state Spinner lacks)."""
    k_lab, key = jax.random.split(key)
    lab = engine.warm_labels(dg, cfg.k, k_lab, labels)
    loads = engine.loads_from_labels(dg, cfg.k, lab)
    return SpinnerState(lab, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def _spinner_shard_rule(cfg: SpinnerConfig, ctx: engine.ShardContext,
                        local, loads, cap, key) -> engine.ShardUpdate:
    """One BSP step over this shard's slabs — eq. (3) scores against the
    previous step's configuration, capacity-gated migration.

    Candidate demand is psum-merged so p_mig gates against the *global*
    contention, and the migration uniforms are drawn from the full [n_pad]
    stream then sliced — so the draw a vertex sees is independent of how
    many shards the mesh has (1-shard sharded == sequential bit-exactly).
    """
    labels = local["labels"]
    k = cfg.k
    key, k_mig = jax.random.split(key)
    labels_g = ctx.gather(labels)

    # eq. (3) histogram over the local slabs (same edges as the flat arrays)
    with obs.annotate("edge-phase", impl="jnp"):
        slots = labels_g[ctx.blk_dst.reshape(-1)]
        hist = edge_histogram_jnp(ctx.local_rows(), slots,
                                  ctx.blk_w.reshape(-1), ctx.local_n, k)
    scores = spinner_scores(hist, ctx.inv_wsum, loads, cap)
    # prefer the current label on ties (Spinner keeps vertices in place)
    bump = jax.nn.one_hot(labels, k, dtype=scores.dtype) * 1e-6
    cand = jnp.argmax(scores + bump, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)

    wants = (cand != labels) & ctx.vmask
    demand = ctx.psum(
        jnp.zeros((k,), jnp.float32).at[cand].add(ctx.deg * wants))      # m(l)
    remaining = cap - loads                                              # r(l)
    p_mig = jnp.where(demand > 0,
                      jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
                      1.0)
    u_full = jax.random.uniform(k_mig, (ctx.n_pad,))
    u = jax.lax.dynamic_slice(u_full, (ctx.v0,), (ctx.local_n,))
    migrate = wants & (u < p_mig[cand])
    new_labels = jnp.where(migrate, cand, labels)

    dmig = ctx.deg * migrate
    delta = jnp.zeros((k,), jnp.float32).at[labels].add(-dmig).at[cand].add(dmig)
    score = jnp.sum(jnp.where(ctx.vmask, best, 0.0))
    return engine.ShardUpdate(
        vert={"labels": new_labels},
        loads_delta=delta,
        key=key,
        score=score,
    )


SPINNER = register(engine.Algorithm(
    name="spinner",
    config_cls=SpinnerConfig,
    state_cls=SpinnerState,
    kind="shard",
    vertex_fields=("labels",),
    wire_int8_fields=("labels",),
    donate=("labels", "loads"),
    init=spinner_init,
    init_from_labels=spinner_init_from_labels,
    shard_rule=_spinner_shard_rule,
))


def place_spinner_state(state: SpinnerState, sdg: ShardedDeviceGraph) -> SpinnerState:
    """Commit an initialized state to the sharded layout (see
    ``engine.place_state``)."""
    return engine.place_state(SPINNER, state, sdg)


def spinner_superstep(dg, cfg: SpinnerConfig, state: SpinnerState) -> SpinnerState:
    """One BSP superstep (see ``engine.superstep``; labels/loads donated)."""
    return engine.superstep(SPINNER, dg, cfg, state)
