"""Spinner baseline (Martella et al., ICDE'17) — eqs. (3)-(5) of the paper.

Synchronous BSP label propagation: all vertices score all partitions against
the *previous* step's labels/loads, pick the argmax candidate, and migrate
gated by remaining capacity — the paper's main comparison point.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.device_graph import CAPACITY_MODES, DeviceGraph, capacity_device
from repro.core.lp import edge_histogram_jnp, spinner_scores


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    k: int
    epsilon: float = 0.05
    max_steps: int = 290
    patience: int = 5
    theta: float = 0.001
    capacity_mode: str = "spinner"

    def __post_init__(self):
        if self.capacity_mode not in CAPACITY_MODES:
            raise ValueError(
                f"SpinnerConfig.capacity_mode={self.capacity_mode!r} is not "
                f"one of {CAPACITY_MODES}")


class SpinnerState(NamedTuple):
    labels: jnp.ndarray   # [n_pad] int32
    loads: jnp.ndarray    # [k] f32
    key: jax.Array
    step: jnp.ndarray
    score: jnp.ndarray


def spinner_init(dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array) -> SpinnerState:
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[labels].add(dg.deg_out)
    return SpinnerState(labels, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def spinner_init_from_labels(
    dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array, labels: jnp.ndarray
) -> SpinnerState:
    """Warm-start from a previous assignment; new vertices draw random labels
    (mirrors `revolver_init_from_labels`, minus the LA state Spinner lacks)."""
    k_lab, key = jax.random.split(key)
    lab = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    carried = jnp.clip(jnp.asarray(labels, jnp.int32), 0, cfg.k - 1)
    m_keep = min(int(carried.shape[0]), dg.n_pad)
    lab = jax.lax.dynamic_update_slice(lab, carried[:m_keep], (0,))
    lab = jnp.where(dg.vmask, lab, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[lab].add(dg.deg_out)
    return SpinnerState(lab, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


@partial(jax.jit, static_argnames=("n", "n_pad", "cfg"))
def _spinner_impl(edge_src, edge_dst, edge_w, deg_out, inv_wsum, vmask, cap,
                  state: SpinnerState, *, n: int, n_pad: int, cfg: SpinnerConfig):
    labels, loads, key = state.labels, state.loads, state.key
    key, k_mig = jax.random.split(key)

    # eq. (3) scores against the previous step's configuration (synchronous)
    hist = edge_histogram_jnp(edge_src, labels[edge_dst], edge_w, n_pad, cfg.k)
    scores = spinner_scores(hist, inv_wsum, loads, cap)
    # prefer the current label on ties (Spinner keeps vertices in place)
    bump = jax.nn.one_hot(labels, cfg.k, dtype=scores.dtype) * 1e-6
    cand = jnp.argmax(scores + bump, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)

    wants = (cand != labels) & vmask
    demand = jnp.zeros((cfg.k,), jnp.float32).at[cand].add(deg_out * wants)   # m(l)
    remaining = cap - loads                                                   # r(l)
    p_mig = jnp.where(demand > 0,
                      jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
                      1.0)
    u = jax.random.uniform(k_mig, (n_pad,))
    migrate = wants & (u < p_mig[cand])
    new_labels = jnp.where(migrate, cand, labels)

    dmig = deg_out * migrate
    loads = loads.at[labels].add(-dmig).at[cand].add(dmig)

    score = jnp.sum(jnp.where(vmask, best, 0.0)) / n
    return SpinnerState(new_labels, loads, key, state.step + 1, score)


def spinner_superstep(dg: DeviceGraph, cfg: SpinnerConfig, state: SpinnerState) -> SpinnerState:
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    return _spinner_impl(
        dg.edge_src, dg.edge_dst, dg.edge_w, dg.deg_out, dg.inv_wsum, dg.vmask,
        cap, state, n=dg.n, n_pad=dg.n_pad, cfg=cfg,
    )
