"""Spinner baseline (Martella et al., ICDE'17) — eqs. (3)-(5) of the paper.

Synchronous BSP label propagation: all vertices score all partitions against
the *previous* step's labels/loads, pick the argmax candidate, and migrate
gated by remaining capacity — the paper's main comparison point.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.device_graph import (
    CAPACITY_MODES,
    DeviceGraph,
    ShardedDeviceGraph,
    capacity_device,
)
from repro.core.lp import edge_histogram_jnp, spinner_scores
from repro.parallel.collectives import gather_shards, psum_delta_merge

_CHUNK_SCHEDULES = ("sequential", "sharded")


@dataclasses.dataclass(frozen=True)
class SpinnerConfig:
    k: int
    epsilon: float = 0.05
    max_steps: int = 290
    patience: int = 5
    theta: float = 0.001
    capacity_mode: str = "spinner"
    # "sequential": one device over the flat edge arrays; "sharded": BSP
    # data-parallel over the blocked slabs on a ("blocks",) mesh. Spinner is
    # synchronous already, so sharding it changes no visibility semantics —
    # only the histogram layout (slabs instead of flat) and where the work
    # runs.
    chunk_schedule: str = "sequential"

    def __post_init__(self):
        if self.capacity_mode not in CAPACITY_MODES:
            raise ValueError(
                f"SpinnerConfig.capacity_mode={self.capacity_mode!r} is not "
                f"one of {CAPACITY_MODES}")
        if self.chunk_schedule not in _CHUNK_SCHEDULES:
            raise ValueError(
                f"SpinnerConfig.chunk_schedule={self.chunk_schedule!r} is "
                f"not one of {_CHUNK_SCHEDULES}")


class SpinnerState(NamedTuple):
    labels: jnp.ndarray   # [n_pad] int32
    loads: jnp.ndarray    # [k] f32
    key: jax.Array
    step: jnp.ndarray
    score: jnp.ndarray


def spinner_init(dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array) -> SpinnerState:
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[labels].add(dg.deg_out)
    return SpinnerState(labels, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


def spinner_init_from_labels(
    dg: DeviceGraph, cfg: SpinnerConfig, key: jax.Array, labels: jnp.ndarray
) -> SpinnerState:
    """Warm-start from a previous assignment; new vertices draw random labels
    (mirrors `revolver_init_from_labels`, minus the LA state Spinner lacks)."""
    k_lab, key = jax.random.split(key)
    lab = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    carried = jnp.clip(jnp.asarray(labels, jnp.int32), 0, cfg.k - 1)
    m_keep = min(int(carried.shape[0]), dg.n_pad)
    lab = jax.lax.dynamic_update_slice(lab, carried[:m_keep], (0,))
    lab = jnp.where(dg.vmask, lab, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[lab].add(dg.deg_out)
    return SpinnerState(lab, loads, key, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))


@partial(jax.jit, static_argnames=("n", "n_pad", "cfg"))
def _spinner_impl(edge_src, edge_dst, edge_w, deg_out, inv_wsum, vmask, cap,
                  state: SpinnerState, *, n: int, n_pad: int, cfg: SpinnerConfig):
    labels, loads, key = state.labels, state.loads, state.key
    key, k_mig = jax.random.split(key)

    # eq. (3) scores against the previous step's configuration (synchronous)
    hist = edge_histogram_jnp(edge_src, labels[edge_dst], edge_w, n_pad, cfg.k)
    scores = spinner_scores(hist, inv_wsum, loads, cap)
    # prefer the current label on ties (Spinner keeps vertices in place)
    bump = jax.nn.one_hot(labels, cfg.k, dtype=scores.dtype) * 1e-6
    cand = jnp.argmax(scores + bump, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)

    wants = (cand != labels) & vmask
    demand = jnp.zeros((cfg.k,), jnp.float32).at[cand].add(deg_out * wants)   # m(l)
    remaining = cap - loads                                                   # r(l)
    p_mig = jnp.where(demand > 0,
                      jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
                      1.0)
    u = jax.random.uniform(k_mig, (n_pad,))
    migrate = wants & (u < p_mig[cand])
    new_labels = jnp.where(migrate, cand, labels)

    dmig = deg_out * migrate
    loads = loads.at[labels].add(-dmig).at[cand].add(dmig)

    score = jnp.sum(jnp.where(vmask, best, 0.0)) / n
    return SpinnerState(new_labels, loads, key, state.step + 1, score)


def _spinner_shard_body(
    blk_dst, blk_row, blk_w, deg, inv_wsum, vmask, cap,
    labels, loads, key,
    *, n_pad: int, block_v: int, blocks_per_shard: int, cfg: SpinnerConfig,
):
    """Per-shard BSP step: identical semantics to `_spinner_impl`, with the
    histogram taken over the shard's blocked slabs, candidate demand and
    load deltas psum-merged, and the migration uniforms drawn from the full
    [n_pad] stream then sliced — so the draw a vertex sees is independent of
    how many shards the mesh has."""
    idx = jax.lax.axis_index("blocks")
    local_n = blocks_per_shard * block_v
    k = cfg.k
    key, k_mig = jax.random.split(key)
    labels_g = gather_shards(labels, "blocks")

    # eq. (3) histogram over the local slabs (same edges as the flat arrays)
    rows_local = (
        jnp.arange(blocks_per_shard, dtype=jnp.int32)[:, None] * block_v
        + blk_row
    ).reshape(-1)
    slots = labels_g[blk_dst.reshape(-1)]
    hist = edge_histogram_jnp(rows_local, slots, blk_w.reshape(-1), local_n, k)
    scores = spinner_scores(hist, inv_wsum, loads, cap)
    bump = jax.nn.one_hot(labels, k, dtype=scores.dtype) * 1e-6
    cand = jnp.argmax(scores + bump, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)

    wants = (cand != labels) & vmask
    demand = psum_delta_merge(
        jnp.zeros((k,), jnp.float32),
        jnp.zeros((k,), jnp.float32).at[cand].add(deg * wants),
        "blocks")
    remaining = cap - loads
    p_mig = jnp.where(demand > 0,
                      jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
                      1.0)
    u_full = jax.random.uniform(k_mig, (n_pad,))
    u = jax.lax.dynamic_slice(u_full, (idx * local_n,), (local_n,))
    migrate = wants & (u < p_mig[cand])
    new_labels = jnp.where(migrate, cand, labels)

    dmig = deg * migrate
    delta = jnp.zeros((k,), jnp.float32).at[labels].add(-dmig).at[cand].add(dmig)
    loads_new = psum_delta_merge(loads, delta, "blocks")
    score_sum = jax.lax.psum(jnp.sum(jnp.where(vmask, best, 0.0)), "blocks")
    return new_labels, loads_new, key, score_sum


@partial(jax.jit,
         static_argnames=("mesh", "n", "n_pad", "block_v",
                          "blocks_per_shard", "cfg"),
         donate_argnames=("labels", "loads"))
def _spinner_sharded_impl(
    blk_dst, blk_row, blk_w, deg_out, inv_wsum, vmask, cap,
    labels, loads, key, step,
    *, mesh, n: int, n_pad: int, block_v: int, blocks_per_shard: int,
    cfg: SpinnerConfig,
):
    body = partial(
        _spinner_shard_body,
        n_pad=n_pad, block_v=block_v, blocks_per_shard=blocks_per_shard,
        cfg=cfg,
    )
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(
            P("blocks", None), P("blocks", None), P("blocks", None),
            P("blocks"), P("blocks"), P("blocks"),
            P(),
            P("blocks"), P(), P(),
        ),
        out_specs=(P("blocks"), P(), P(), P()),
        check_rep=False,
    )
    labels, loads, key, score_sum = sharded(
        blk_dst, blk_row, blk_w, deg_out, inv_wsum, vmask, cap,
        labels, loads, key)
    return SpinnerState(labels, loads, key, step + 1, score_sum / n)


def place_spinner_state(state: SpinnerState, sdg: ShardedDeviceGraph) -> SpinnerState:
    """Commit an initialized state to the sharded layout (labels sliced onto
    their owning device, the rest replicated)."""
    mesh = sdg.mesh

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return SpinnerState(
        labels=put(state.labels, P("blocks")),
        loads=put(state.loads, P()),
        key=put(state.key, P()),
        step=put(state.step, P()),
        score=put(state.score, P()),
    )


def spinner_superstep(dg, cfg: SpinnerConfig, state: SpinnerState) -> SpinnerState:
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    if cfg.chunk_schedule == "sharded":
        if not isinstance(dg, ShardedDeviceGraph):
            raise TypeError(
                "chunk_schedule='sharded' needs a ShardedDeviceGraph "
                "(see prepare_sharded_device_graph); got a plain DeviceGraph")
        return _spinner_sharded_impl(
            dg.blk_dst, dg.blk_row, dg.blk_w, dg.deg_out, dg.inv_wsum,
            dg.vmask, cap, state.labels, state.loads, state.key, state.step,
            mesh=dg.mesh, n=dg.n, n_pad=dg.n_pad, block_v=dg.block_v,
            blocks_per_shard=dg.blocks_per_shard, cfg=cfg,
        )
    if isinstance(dg, ShardedDeviceGraph):
        dg = dg.dg
    return _spinner_impl(
        dg.edge_src, dg.edge_dst, dg.edge_w, dg.deg_out, dg.inv_wsum, dg.vmask,
        cap, state, n=dg.n, n_pad=dg.n_pad, cfg=cfg,
    )
