"""Revolver core: weighted learning automata, normalized LP, partitioners."""
from repro.core.la import classic_la_update, weighted_la_update
from repro.core.lp import edge_histogram_jnp, normalized_penalty, spinner_penalty
from repro.core.metrics import local_edges, max_normalized_load, partition_loads
from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    prepare_device_graph,
    prepare_sharded_device_graph,
    shard_device_graph,
)
from repro.core.revolver import (
    RevolverConfig,
    RevolverState,
    revolver_init,
    revolver_init_from_labels,
    revolver_superstep,
)
from repro.core.spinner import (
    SpinnerConfig,
    SpinnerState,
    spinner_init,
    spinner_init_from_labels,
    spinner_superstep,
)
from repro.core.static_partitioners import hash_partition, range_partition
from repro.core.runner import PartitionResult, run_convergence_loop, run_partitioner

__all__ = [
    "classic_la_update",
    "weighted_la_update",
    "edge_histogram_jnp",
    "normalized_penalty",
    "spinner_penalty",
    "local_edges",
    "max_normalized_load",
    "partition_loads",
    "DeviceGraph",
    "ShardedDeviceGraph",
    "prepare_device_graph",
    "prepare_sharded_device_graph",
    "shard_device_graph",
    "RevolverConfig",
    "RevolverState",
    "revolver_init",
    "revolver_init_from_labels",
    "revolver_superstep",
    "SpinnerConfig",
    "SpinnerState",
    "spinner_init",
    "spinner_init_from_labels",
    "spinner_superstep",
    "hash_partition",
    "range_partition",
    "PartitionResult",
    "run_convergence_loop",
    "run_partitioner",
]
