"""Revolver core: one superstep engine, pluggable partitioning algorithms.

Layering (see core/README.md): `engine` owns the execution schedules
(sequential async scan, sharded shard_map superstep with full-gather or
halo-exchange label sync), `registry` maps
algorithm names to rule modules (`revolver`, `spinner`, `restream`,
`static_partitioners`), and `runner` drives the shared convergence loop.
"""
from repro.core.la import classic_la_update, weighted_la_update
from repro.core.lp import edge_histogram_jnp, normalized_penalty, spinner_penalty
from repro.core.metrics import local_edges, max_normalized_load, partition_loads
from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    attach_halo,
    permute_blocks,
    prepare_device_graph,
    prepare_sharded_device_graph,
    shard_device_graph,
    vertices_to_original,
)
from repro.core.halo import HaloSpec, build_halo_spec
from repro.core.engine import Algorithm, place_state, superstep
from repro.core.registry import (
    StaticAlgorithm,
    available_algorithms,
    get_algorithm,
    register,
    superstep_algorithms,
)
from repro.core.revolver import (
    RevolverConfig,
    RevolverState,
    revolver_init,
    revolver_init_from_labels,
    revolver_superstep,
)
from repro.core.spinner import (
    SpinnerConfig,
    SpinnerState,
    spinner_init,
    spinner_init_from_labels,
    spinner_superstep,
)
from repro.core.restream import (
    RestreamConfig,
    RestreamState,
    restream_init,
    restream_init_from_labels,
    restream_superstep,
)
from repro.core.static_partitioners import hash_partition, range_partition
from repro.core.runner import PartitionResult, run_convergence_loop, run_partitioner

__all__ = [
    "classic_la_update",
    "weighted_la_update",
    "edge_histogram_jnp",
    "normalized_penalty",
    "spinner_penalty",
    "local_edges",
    "max_normalized_load",
    "partition_loads",
    "DeviceGraph",
    "ShardedDeviceGraph",
    "attach_halo",
    "permute_blocks",
    "prepare_device_graph",
    "prepare_sharded_device_graph",
    "shard_device_graph",
    "vertices_to_original",
    "HaloSpec",
    "build_halo_spec",
    "Algorithm",
    "StaticAlgorithm",
    "place_state",
    "superstep",
    "available_algorithms",
    "get_algorithm",
    "register",
    "superstep_algorithms",
    "RevolverConfig",
    "RevolverState",
    "revolver_init",
    "revolver_init_from_labels",
    "revolver_superstep",
    "SpinnerConfig",
    "SpinnerState",
    "spinner_init",
    "spinner_init_from_labels",
    "spinner_superstep",
    "RestreamConfig",
    "RestreamState",
    "restream_init",
    "restream_init_from_labels",
    "restream_superstep",
    "hash_partition",
    "range_partition",
    "PartitionResult",
    "run_convergence_loop",
    "run_partitioner",
]
