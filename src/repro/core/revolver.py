"""Revolver: the paper's partitioning superstep (Section IV-D, steps 1-9).

This module is a **rule module**: it contributes Revolver's per-block local
rule (the nine steps below), its config/state, and its warm-start path; the
execution schedules — the sequential asynchronous scan, the ``shard_map``
Jacobi superstep, buffer donation, state placement — live in
``repro.core.engine`` and are shared with every other registered algorithm
(see ``core/README.md``).

Execution model — TPU adaptation of the paper's asynchrony (DESIGN.md §3):
vertices are processed in `n_blocks` chunks via the engine's `lax.scan`.
Label migrations, load updates and freshly-computed argmax labels (lambda)
from chunk i are visible to chunk i+1 *within the same superstep* — exactly
the incremental visibility the paper credits for its balanced partitions.
`n_blocks=1` degenerates to a synchronous (Spinner-like BSP) schedule; the
async-vs-sync ablation in benchmarks/fig4_convergence.py sweeps this knob.

Per chunk, the nine steps of Section IV-D:
  1. LA action selection (roulette wheel == Gumbel-max categorical sampling)
  2. migration probability  p_mig(l) = clip((C - b(l)) / m(l), 0, 1)
  3. normalized LP scores (eq. 10) and lambda(v) = argmax_l score(v,l)
  4. gated migration (action != label and U(0,1) < p_mig(action))
  5. weight accumulation from neighbors' lambda (eq. 13)
  6. mean-split reinforcement signals + per-half normalization
  7. weighted-LA probability update (eqs. 8/9)
  8. exact load update (the chunk's migrations are applied immediately)
  9. convergence score accumulation (mean best LP score)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine
from repro.core.device_graph import CAPACITY_MODES, DeviceGraph, ShardedDeviceGraph  # noqa: F401  (re-exported API)
from repro.core.la import split_weights_and_signals, weighted_la_update
from repro.core.lp import edge_histogram_jnp, revolver_scores
from repro.core.registry import register

# valid values per config knob; typos used to silently fall back to the jnp
# path (e.g. la_impl="palas"), now they raise at construction
_VALID_CHOICES = {
    "la_impl": ("jnp", "pallas"),
    "hist_impl": ("jnp", "pallas"),
    "weight_mode": ("self_lambda", "neighbor_lambda"),
    "capacity_mode": CAPACITY_MODES,
    "chunk_schedule": ("sequential", "sharded", "halo", "async"),
}


@dataclasses.dataclass(frozen=True)
class RevolverConfig:
    """Hyper-parameters; defaults match Section V-F of the paper."""

    k: int
    alpha: float = 1.0            # LA reward rate
    beta: float = 0.1             # LA penalty rate
    epsilon: float = 0.05         # imbalance ratio
    max_steps: int = 290
    patience: int = 5             # consecutive non-improving steps to halt
    theta: float = 0.001          # min score improvement
    capacity_mode: str = "spinner"  # see device_graph.capacity
    renorm: bool = True           # simplex re-projection after eqs. (8)/(9)
    la_impl: str = "jnp"          # "jnp" | "pallas"
    hist_impl: str = "jnp"        # "jnp" (scatter-add) | "pallas" (fused
                                  # dual-histogram edge-phase kernel)
    # eq. (13) ambiguity (DESIGN.md §10): which W slot a neighbor u reinforces.
    #   "self_lambda":     the literal LHS w(v, lambda(v)) — each neighbor
    #                      contributes to v's own argmax-score slot.
    #   "neighbor_lambda": slot lambda(u) — v accumulates a histogram of its
    #                      neighbors' argmax labels.
    weight_mode: str = "self_lambda"
    # superstep execution schedule (owned by the engine):
    #   "sequential": one device, lax.scan over all vertex blocks — the PR-2
    #                 async semantics, bit-identical at fixed seed.
    #   "sharded":    shard_map over a 1-D ("blocks",) mesh — each device
    #                 scans only its own blocks (async within the shard),
    #                 labels are all-gathered and load deltas psum-merged
    #                 once per superstep (Jacobi sync across shards).
    #   "halo":       the sharded schedule with the full label all-gather
    #                 replaced by a precomputed boundary-block exchange
    #                 (O(halo) traffic; exact — see repro.core.halo).
    #   "async":      the halo schedule with the exchange overlapped onto
    #                 the interior block scan; staleness_bound=0 is
    #                 bit-identical to "halo" (see docs/async-superstep.md).
    chunk_schedule: str = "sequential"
    # how many supersteps a shard may run against a stale halo tail before
    # the runner forces a refresh ("async" schedule only). 0 = refresh every
    # superstep, which keeps the bit-identity contract with "halo"; s >= 1
    # trades exactness for overlap and is gated on converged quality in the
    # scaling bench.
    staleness_bound: int = 0

    def __post_init__(self):
        for name, valid in _VALID_CHOICES.items():
            value = getattr(self, name)
            if value not in valid:
                raise ValueError(
                    f"RevolverConfig.{name}={value!r} is not one of {valid}")
        if not isinstance(self.staleness_bound, int) or \
                self.staleness_bound < 0:
            raise ValueError(
                f"RevolverConfig.staleness_bound={self.staleness_bound!r} "
                "must be an int >= 0")
        if self.staleness_bound > 0 and self.chunk_schedule != "async":
            raise ValueError(
                "staleness_bound > 0 only applies to chunk_schedule='async' "
                f"(got chunk_schedule={self.chunk_schedule!r})")


class RevolverState(NamedTuple):
    labels: jnp.ndarray    # [n_pad] int32 current partition per vertex
    lam: jnp.ndarray       # [n_pad] int32 latest argmax-score label (lambda)
    probs: jnp.ndarray     # [n_blocks, block_v, k] f32 LA probability vectors
    loads: jnp.ndarray     # [k] f32 b(l)
    key: jax.Array
    step: jnp.ndarray      # int32
    score: jnp.ndarray     # f32 mean best LP score (convergence metric)


def revolver_init(dg: DeviceGraph, cfg: RevolverConfig, key: jax.Array) -> RevolverState:
    """Random initial labels; uniform 1/k LA probabilities (Section IV-C)."""
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    loads = engine.loads_from_labels(dg, cfg.k, labels)
    probs = jnp.full((dg.n_blocks, dg.block_v, cfg.k), 1.0 / cfg.k, jnp.float32)
    # lam is a *copy*: labels and lam are separately donated superstep
    # buffers, so the initial state must not alias them to one buffer
    return RevolverState(
        labels=labels,
        lam=jnp.copy(labels),
        probs=probs,
        loads=loads,
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def revolver_init_from_labels(
    dg: DeviceGraph,
    cfg: RevolverConfig,
    key: jax.Array,
    labels: jnp.ndarray,
    probs: jnp.ndarray | None = None,
    prob_sharpen: float = 0.0,
) -> RevolverState:
    """Warm-start state from a previous assignment (streaming repartitioning).

    `labels` carries the partition of up to `len(labels)` surviving vertices
    (clipped to [0, k)); vertices beyond it — newly arrived in the stream —
    draw a random label, exactly like a cold `revolver_init` would. `probs`
    optionally carries the LA probability tensor of a previous state
    ([n_blocks', block_v', k]); surviving vertices keep their learned
    automata, new vertices start at the uniform 1/k of Section IV-C. Loads
    are recomputed from the (possibly changed) degree vector, so the
    invariant b(l) == sum deg over labels==l holds from step 0.

    Both `labels` and `probs` are indexed by **original vertex id** (row v =
    vertex v); on a locality-permuted layout they are scattered to each
    vertex's storage position, mirroring how `run_partitioner` /
    `StreamRunner` return them in original order.

    `prob_sharpen` in [0, 1) blends every automaton toward a one-hot on its
    carried label: p <- (1-s) p + s onehot(label). Carried probabilities
    from a refinement that halted early are still diffuse, which makes the
    roulette wheel re-explore settled vertices; sharpening converts the
    carried assignment into LA confidence so refinement spends its steps on
    genuinely contested vertices. s=0 (default) carries state untouched.
    """
    if not 0.0 <= prob_sharpen < 1.0:
        raise ValueError(f"prob_sharpen must be in [0, 1), got {prob_sharpen}")
    k_lab, key = jax.random.split(key)
    lab = engine.warm_labels(dg, cfg.k, k_lab, labels)
    loads = engine.loads_from_labels(dg, cfg.k, lab)

    flat = jnp.full((dg.n_pad, cfg.k), 1.0 / cfg.k, jnp.float32)
    if probs is not None:
        p = jnp.asarray(probs, jnp.float32)
        if p.shape[-1] != cfg.k:
            raise ValueError(
                f"carried probs have k={p.shape[-1]}, config expects k={cfg.k}")
        p = p.reshape(-1, cfg.k)
        p_keep = min(int(p.shape[0]), dg.n_pad)
        o2s = getattr(dg, "o2s", None)
        if o2s is None:
            flat = jax.lax.dynamic_update_slice(flat, p[:p_keep], (0, 0))
        else:  # carried rows are original-order; scatter to storage slots
            flat = flat.at[jnp.asarray(o2s[:p_keep])].set(p[:p_keep])
    if prob_sharpen > 0.0:
        onehot = jax.nn.one_hot(lab, cfg.k, dtype=jnp.float32)
        flat = (1.0 - prob_sharpen) * flat + prob_sharpen * onehot
    return RevolverState(
        labels=lab,
        lam=jnp.copy(lab),   # no aliasing: both buffers are donated
        probs=flat.reshape(dg.n_blocks, dg.block_v, cfg.k),
        loads=loads,
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def _revolver_chunk_rule(cfg: RevolverConfig, ctx: engine.ChunkContext,
                         vert, block, loads, cap, key) -> engine.ChunkUpdate:
    """The nine steps of Section IV-D for one asynchronous chunk.

    `vert` is the engine's drifting per-vertex view (labels + lambda, fresh
    with every earlier chunk's updates); `block` carries this chunk's LA
    probability tile. The rule returns the chunk's new label/lambda slices —
    the engine splices them into the drifting view — plus the updated loads,
    PRNG chain, and score contribution.
    """
    labels, lam = vert["labels"], vert["lam"]
    probs = block["probs"]
    bv, k = probs.shape
    if (cfg.hist_impl, cfg.la_impl) != ("jnp", "jnp"):
        from repro.kernels.ops import superstep_kernels

        fused_op, la_op = superstep_kernels(cfg.hist_impl, cfg.la_impl)
    else:  # pure-XLA lowering stays importable without the kernel package
        fused_op, la_op = None, None

    key, k_act, k_mig = jax.random.split(key, 3)
    cur = jax.lax.dynamic_slice(labels, (ctx.v0,), (bv,))

    # -- 1. LA action selection (roulette wheel) -----------------------------
    logits = jnp.log(jnp.clip(probs, 1e-30, 1.0))
    action = jax.random.categorical(k_act, logits, axis=-1).astype(jnp.int32)
    action = jnp.where(ctx.vmask, action, cur)

    # -- 2. migration probability per partition ------------------------------
    wants = (action != cur) & ctx.vmask
    demand = jnp.zeros((k,), jnp.float32).at[action].add(ctx.deg * wants)  # m(l)
    remaining = cap - loads                                                # r(l)
    p_mig = jnp.where(
        demand > 0,
        jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
        1.0,
    )

    # -- 3. + 5. edge phase: LP-score histogram + eq.-13 accumulation --------
    # Both histograms read the same edge slab. Every input they need
    # (labels, lam, action, p_mig) exists *before* the edge phase, so the
    # pallas path computes both in one fused slab pass (see
    # kernels/edge_phase.py; for weight_mode="self_lambda" the kernel
    # returns the per-row (A, N) factorization and the lambda(v) one-hot
    # scatter is finished below once scores exist). The jnp path is the
    # two-scatter-add reference with identical semantics.
    with obs.annotate("edge-phase", impl=cfg.hist_impl):
        if fused_op is not None:
            feasible_f = (p_mig > 0).astype(jnp.float32)
            hist, w_acc = fused_op(
                ctx.e_dst[None], ctx.e_row[None], ctx.e_w[None], labels, lam,
                action[None], feasible_f[None],
                block_v=bv, k=k, weight_mode=cfg.weight_mode)
            hist, w_acc = hist[0], w_acc[0]
        else:
            nbr_labels = labels[ctx.e_dst]           # async: freshest labels
            hist = edge_histogram_jnp(ctx.e_row, nbr_labels, ctx.e_w, bv, k)
            w_acc = None

    scores = revolver_scores(hist, ctx.inv_wsum, loads, cap)
    lam_chunk = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)
    score = jnp.sum(jnp.where(ctx.vmask, best, 0.0))

    # -- 4. gated migration ---------------------------------------------------
    u = jax.random.uniform(k_mig, (bv,))
    migrate = wants & (u < p_mig[action])
    new_lbl = jnp.where(migrate, action, cur)

    # -- 8. exact load update (visible to the next chunk) --------------------
    dmig = ctx.deg * migrate
    loads = loads.at[cur].add(-dmig).at[action].add(dmig)

    # -- 5. eq. (13) weight accumulation --------------------------------------
    # Each neighbor u of v contributes
    #   w_hat(u,v)           if psi(v) == lambda(u)      (agreement)
    #   1                    else if the slot is feasible (p_mig > 0)
    # psi(v) is the label assigned by the LA — the *selected action* (the
    # paper defines psi: A -> L), so a capacity-denied migration still
    # counts as agreement for the reinforcement signal.
    # The slot written depends on cfg.weight_mode (eq. 13 ambiguity):
    #   self_lambda     -> slot lambda(v) (the literal LHS w(v, lambda(v)))
    #   neighbor_lambda -> slot lambda(u)
    with obs.annotate("edge-phase", impl=cfg.hist_impl, part="weights"):
        if w_acc is not None:
            if cfg.weight_mode == "self_lambda":
                # finish the kernel's (A, N) packing: every edge of row v
                # lands in slot lambda(v), feasibility is a per-row scalar
                contrib = w_acc[:, 0] + jnp.where(
                    p_mig[lam_chunk] > 0, w_acc[:, 1], 0.0)
                w_raw = jax.nn.one_hot(
                    lam_chunk, k, dtype=jnp.float32) * contrib[:, None]
            else:
                w_raw = w_acc                        # finished in-kernel
        else:
            lam_nbr = lam[ctx.e_dst]
            agree = (action[ctx.e_row] == lam_nbr)
            if cfg.weight_mode == "self_lambda":
                slot = lam_chunk[ctx.e_row]
            else:
                slot = lam_nbr
            feasible = p_mig[slot] > 0
            val = jnp.where(agree, ctx.e_w, jnp.where(feasible, 1.0, 0.0))
            val = jnp.where(ctx.e_w > 0, val, 0.0)  # kill padding slots
            w_raw = edge_histogram_jnp(ctx.e_row, slot, val, bv, k)

    # -- 6./7. reinforcement signals + weighted LA update ---------------------
    with obs.annotate("la-update", impl=cfg.la_impl):
        w_norm, r = split_weights_and_signals(w_raw)
        if la_op is not None:
            new_probs = la_op(probs, w_norm, r, cfg.alpha, cfg.beta,
                              renorm=cfg.renorm)
        else:
            new_probs = weighted_la_update(probs, w_norm, r, cfg.alpha,
                                           cfg.beta, renorm=cfg.renorm)

    return engine.ChunkUpdate(
        vert={"labels": new_lbl, "lam": lam_chunk},
        block={"probs": new_probs},
        loads=loads,
        key=key,
        score=score,
    )


REVOLVER = register(engine.Algorithm(
    name="revolver",
    config_cls=RevolverConfig,
    state_cls=RevolverState,
    kind="chunk",
    vertex_fields=("labels", "lam"),
    wire_int8_fields=("labels", "lam"),   # both in [0, k)
    block_fields=("probs",),
    donate=("labels", "lam", "probs", "loads"),
    init=revolver_init,
    init_from_labels=revolver_init_from_labels,
    supports_probs=True,
    chunk_rule=_revolver_chunk_rule,
))


def place_revolver_state(state: RevolverState, sdg: ShardedDeviceGraph) -> RevolverState:
    """Commit a freshly-initialized state to the sharded layout (see
    ``engine.place_state``)."""
    return engine.place_state(REVOLVER, state, sdg)


def revolver_superstep(dg, cfg: RevolverConfig, state: RevolverState) -> RevolverState:
    """One full superstep over all chunks (see ``engine.superstep``).

    `cfg.chunk_schedule` selects the execution plan: "sequential" scans all
    blocks on one device (`dg` is a plain DeviceGraph); "sharded" runs the
    per-shard scans data-parallel under shard_map (`dg` must be a
    ShardedDeviceGraph, see `prepare_sharded_device_graph`).

    The state's labels / lam / probs / loads buffers are **donated** under
    either schedule; the passed-in `state` must not be reused after this
    call (every caller in the repo rebinds,
    ``state = revolver_superstep(...)``).
    """
    return engine.superstep(REVOLVER, dg, cfg, state)
