"""Revolver: the paper's partitioning superstep (Section IV-D, steps 1-9).

Execution model — TPU adaptation of the paper's asynchrony (DESIGN.md §3):
vertices are processed in `n_blocks` chunks via `lax.scan`. Label migrations,
load updates and freshly-computed argmax labels (lambda) from chunk i are
visible to chunk i+1 *within the same superstep* — exactly the incremental
visibility the paper credits for its balanced partitions. `n_blocks=1`
degenerates to a synchronous (Spinner-like BSP) schedule; the async-vs-sync
ablation in benchmarks/fig4_convergence.py sweeps this knob.

Per chunk, the nine steps of Section IV-D:
  1. LA action selection (roulette wheel == Gumbel-max categorical sampling)
  2. migration probability  p_mig(l) = clip((C - b(l)) / m(l), 0, 1)
  3. normalized LP scores (eq. 10) and lambda(v) = argmax_l score(v,l)
  4. gated migration (action != label and U(0,1) < p_mig(action))
  5. weight accumulation from neighbors' lambda (eq. 13)
  6. mean-split reinforcement signals + per-half normalization
  7. weighted-LA probability update (eqs. 8/9)
  8. exact load update (the chunk's migrations are applied immediately)
  9. convergence score accumulation (mean best LP score)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.device_graph import (
    CAPACITY_MODES,
    DeviceGraph,
    ShardedDeviceGraph,
    capacity_device,
)
from repro.core.la import split_weights_and_signals, weighted_la_update
from repro.core.lp import edge_histogram_jnp, revolver_scores
from repro.parallel.collectives import (
    gather_shards,
    psum_delta_merge,
    replicated_chain_key,
    shard_chain_key,
)

# valid values per config knob; typos used to silently fall back to the jnp
# path (e.g. la_impl="palas"), now they raise at construction
_VALID_CHOICES = {
    "la_impl": ("jnp", "pallas"),
    "hist_impl": ("jnp", "pallas"),
    "weight_mode": ("self_lambda", "neighbor_lambda"),
    "capacity_mode": CAPACITY_MODES,
    "chunk_schedule": ("sequential", "sharded"),
}


@dataclasses.dataclass(frozen=True)
class RevolverConfig:
    """Hyper-parameters; defaults match Section V-F of the paper."""

    k: int
    alpha: float = 1.0            # LA reward rate
    beta: float = 0.1             # LA penalty rate
    epsilon: float = 0.05         # imbalance ratio
    max_steps: int = 290
    patience: int = 5             # consecutive non-improving steps to halt
    theta: float = 0.001          # min score improvement
    capacity_mode: str = "spinner"  # see device_graph.capacity
    renorm: bool = True           # simplex re-projection after eqs. (8)/(9)
    la_impl: str = "jnp"          # "jnp" | "pallas"
    hist_impl: str = "jnp"        # "jnp" (scatter-add) | "pallas" (fused
                                  # dual-histogram edge-phase kernel)
    # eq. (13) ambiguity (DESIGN.md §10): which W slot a neighbor u reinforces.
    #   "self_lambda":     the literal LHS w(v, lambda(v)) — each neighbor
    #                      contributes to v's own argmax-score slot.
    #   "neighbor_lambda": slot lambda(u) — v accumulates a histogram of its
    #                      neighbors' argmax labels.
    weight_mode: str = "self_lambda"
    # superstep execution schedule:
    #   "sequential": one device, lax.scan over all vertex blocks — the PR-2
    #                 async semantics, bit-identical at fixed seed.
    #   "sharded":    shard_map over a 1-D ("blocks",) mesh — each device
    #                 scans only its own blocks (async within the shard),
    #                 labels are all-gathered and load deltas psum-merged
    #                 once per superstep (Jacobi sync across shards).
    chunk_schedule: str = "sequential"

    def __post_init__(self):
        for name, valid in _VALID_CHOICES.items():
            value = getattr(self, name)
            if value not in valid:
                raise ValueError(
                    f"RevolverConfig.{name}={value!r} is not one of {valid}")


class RevolverState(NamedTuple):
    labels: jnp.ndarray    # [n_pad] int32 current partition per vertex
    lam: jnp.ndarray       # [n_pad] int32 latest argmax-score label (lambda)
    probs: jnp.ndarray     # [n_blocks, block_v, k] f32 LA probability vectors
    loads: jnp.ndarray     # [k] f32 b(l)
    key: jax.Array
    step: jnp.ndarray      # int32
    score: jnp.ndarray     # f32 mean best LP score (convergence metric)


def revolver_init(dg: DeviceGraph, cfg: RevolverConfig, key: jax.Array) -> RevolverState:
    """Random initial labels; uniform 1/k LA probabilities (Section IV-C)."""
    k_lab, key = jax.random.split(key)
    labels = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    labels = jnp.where(dg.vmask, labels, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[labels].add(dg.deg_out)
    probs = jnp.full((dg.n_blocks, dg.block_v, cfg.k), 1.0 / cfg.k, jnp.float32)
    # lam is a *copy*: labels and lam are separately donated superstep
    # buffers, so the initial state must not alias them to one buffer
    return RevolverState(
        labels=labels,
        lam=jnp.copy(labels),
        probs=probs,
        loads=loads,
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def revolver_init_from_labels(
    dg: DeviceGraph,
    cfg: RevolverConfig,
    key: jax.Array,
    labels: jnp.ndarray,
    probs: jnp.ndarray | None = None,
    prob_sharpen: float = 0.0,
) -> RevolverState:
    """Warm-start state from a previous assignment (streaming repartitioning).

    `labels` carries the partition of up to `len(labels)` surviving vertices
    (clipped to [0, k)); vertices beyond it — newly arrived in the stream —
    draw a random label, exactly like a cold `revolver_init` would. `probs`
    optionally carries the LA probability tensor of a previous state
    ([n_blocks', block_v', k]); surviving vertices keep their learned
    automata, new vertices start at the uniform 1/k of Section IV-C. Loads
    are recomputed from the (possibly changed) degree vector, so the
    invariant b(l) == sum deg over labels==l holds from step 0.

    `prob_sharpen` in [0, 1) blends every automaton toward a one-hot on its
    carried label: p <- (1-s) p + s onehot(label). Carried probabilities
    from a refinement that halted early are still diffuse, which makes the
    roulette wheel re-explore settled vertices; sharpening converts the
    carried assignment into LA confidence so refinement spends its steps on
    genuinely contested vertices. s=0 (default) carries state untouched.
    """
    if not 0.0 <= prob_sharpen < 1.0:
        raise ValueError(f"prob_sharpen must be in [0, 1), got {prob_sharpen}")
    k_lab, key = jax.random.split(key)
    lab = jax.random.randint(k_lab, (dg.n_pad,), 0, cfg.k, dtype=jnp.int32)
    carried = jnp.clip(jnp.asarray(labels, jnp.int32), 0, cfg.k - 1)
    m_keep = min(int(carried.shape[0]), dg.n_pad)
    lab = jax.lax.dynamic_update_slice(lab, carried[:m_keep], (0,))
    lab = jnp.where(dg.vmask, lab, 0)
    loads = jnp.zeros((cfg.k,), jnp.float32).at[lab].add(dg.deg_out)

    flat = jnp.full((dg.n_pad, cfg.k), 1.0 / cfg.k, jnp.float32)
    if probs is not None:
        p = jnp.asarray(probs, jnp.float32)
        if p.shape[-1] != cfg.k:
            raise ValueError(
                f"carried probs have k={p.shape[-1]}, config expects k={cfg.k}")
        p = p.reshape(-1, cfg.k)
        p_keep = min(int(p.shape[0]), dg.n_pad)
        flat = jax.lax.dynamic_update_slice(flat, p[:p_keep], (0, 0))
    if prob_sharpen > 0.0:
        onehot = jax.nn.one_hot(lab, cfg.k, dtype=jnp.float32)
        flat = (1.0 - prob_sharpen) * flat + prob_sharpen * onehot
    return RevolverState(
        labels=lab,
        lam=jnp.copy(lab),   # no aliasing: both buffers are donated
        probs=flat.reshape(dg.n_blocks, dg.block_v, cfg.k),
        loads=loads,
        key=key,
        step=jnp.zeros((), jnp.int32),
        score=jnp.zeros((), jnp.float32),
    )


def _chunk_step(cfg: RevolverConfig, block_v: int, carry: Tuple, xs: Tuple):
    """Process one asynchronous chunk (see module docstring).

    Besides the drifting load view, the carry tracks `delta` — the same
    migration updates accumulated from zero. The sequential schedule drops
    it (XLA dead-code-eliminates the chain); the sharded schedule psum-merges
    the per-shard deltas into the global loads at the superstep boundary.
    """
    labels, lam, loads, delta, cap, key, score_sum = carry
    (blk_idx, e_dst, e_row, e_w, probs, deg, inv_wsum, vmask) = xs
    bv, k = probs.shape

    key, k_act, k_mig = jax.random.split(key, 3)
    v0 = blk_idx * block_v
    cur = jax.lax.dynamic_slice(labels, (v0,), (bv,))

    # -- 1. LA action selection (roulette wheel) -----------------------------
    logits = jnp.log(jnp.clip(probs, 1e-30, 1.0))
    action = jax.random.categorical(k_act, logits, axis=-1).astype(jnp.int32)
    action = jnp.where(vmask, action, cur)

    # -- 2. migration probability per partition ------------------------------
    wants = (action != cur) & vmask
    demand = jnp.zeros((k,), jnp.float32).at[action].add(deg * wants)  # m(l)
    remaining = cap - loads                                            # r(l)
    p_mig = jnp.where(
        demand > 0,
        jnp.clip(remaining / jnp.maximum(demand, 1e-9), 0.0, 1.0),
        1.0,
    )

    # -- 3. + 5. edge phase: LP-score histogram + eq.-13 accumulation --------
    # Both histograms read the same edge slab. Every input they need
    # (labels, lam, action, p_mig) exists *before* the edge phase, so the
    # pallas path computes both in one fused slab pass (see
    # kernels/edge_phase.py; for weight_mode="self_lambda" the kernel
    # returns the per-row (A, N) factorization and the lambda(v) one-hot
    # scatter is finished below once scores exist). The jnp path is the
    # two-scatter-add reference with identical semantics.
    if cfg.hist_impl == "pallas":
        from repro.kernels.ops import fused_edge_phase

        feasible_f = (p_mig > 0).astype(jnp.float32)
        hist, w_acc = fused_edge_phase(
            e_dst[None], e_row[None], e_w[None], labels, lam,
            action[None], feasible_f[None],
            block_v=bv, k=k, weight_mode=cfg.weight_mode)
        hist, w_acc = hist[0], w_acc[0]
    else:
        nbr_labels = labels[e_dst]                   # async: freshest labels
        hist = edge_histogram_jnp(e_row, nbr_labels, e_w, bv, k)
        w_acc = None

    scores = revolver_scores(hist, inv_wsum, loads, cap)
    lam_chunk = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best = jnp.max(scores, axis=-1)
    score_sum = score_sum + jnp.sum(jnp.where(vmask, best, 0.0))

    # -- 4. gated migration ---------------------------------------------------
    u = jax.random.uniform(k_mig, (bv,))
    migrate = wants & (u < p_mig[action])
    new_lbl = jnp.where(migrate, action, cur)

    # -- 8. exact load update (visible to the next chunk) --------------------
    dmig = deg * migrate
    loads = loads.at[cur].add(-dmig).at[action].add(dmig)
    delta = delta.at[cur].add(-dmig).at[action].add(dmig)
    labels = jax.lax.dynamic_update_slice(labels, new_lbl, (v0,))

    # -- 5. eq. (13) weight accumulation --------------------------------------
    # Each neighbor u of v contributes
    #   w_hat(u,v)           if psi(v) == lambda(u)      (agreement)
    #   1                    else if the slot is feasible (p_mig > 0)
    # psi(v) is the label assigned by the LA — the *selected action* (the
    # paper defines psi: A -> L), so a capacity-denied migration still
    # counts as agreement for the reinforcement signal.
    # The slot written depends on cfg.weight_mode (eq. 13 ambiguity):
    #   self_lambda     -> slot lambda(v) (the literal LHS w(v, lambda(v)))
    #   neighbor_lambda -> slot lambda(u)
    if w_acc is not None:
        if cfg.weight_mode == "self_lambda":
            # finish the kernel's (A, N) packing: every edge of row v lands
            # in slot lambda(v), feasibility is a per-row scalar
            contrib = w_acc[:, 0] + jnp.where(
                p_mig[lam_chunk] > 0, w_acc[:, 1], 0.0)
            w_raw = jax.nn.one_hot(
                lam_chunk, k, dtype=jnp.float32) * contrib[:, None]
        else:
            w_raw = w_acc                            # finished in-kernel
    else:
        lam_nbr = lam[e_dst]
        agree = (action[e_row] == lam_nbr)
        if cfg.weight_mode == "self_lambda":
            slot = lam_chunk[e_row]
        else:
            slot = lam_nbr
        feasible = p_mig[slot] > 0
        val = jnp.where(agree, e_w, jnp.where(feasible, 1.0, 0.0))
        val = jnp.where(e_w > 0, val, 0.0)  # kill padding slots
        w_raw = edge_histogram_jnp(e_row, slot, val, bv, k)

    # async lambda visibility for later chunks
    lam = jax.lax.dynamic_update_slice(lam, lam_chunk, (v0,))

    # -- 6./7. reinforcement signals + weighted LA update ---------------------
    w_norm, r = split_weights_and_signals(w_raw)
    if cfg.la_impl == "pallas":
        from repro.kernels.ops import la_update as la_update_op

        new_probs = la_update_op(probs, w_norm, r, cfg.alpha, cfg.beta, renorm=cfg.renorm)
    else:
        new_probs = weighted_la_update(probs, w_norm, r, cfg.alpha, cfg.beta, renorm=cfg.renorm)

    return (labels, lam, loads, delta, cap, key, score_sum), new_probs


@partial(jax.jit, static_argnames=("n", "n_blocks", "block_v", "cfg"),
         donate_argnames=("labels", "lam", "probs", "loads"))
def _superstep_impl(
    blk_dst, blk_row, blk_w, deg_out, inv_wsum, vmask, cap,
    labels, lam, probs, loads, key, step,
    *, n: int, n_blocks: int, block_v: int, cfg: RevolverConfig,
):
    deg_b = deg_out.reshape(n_blocks, block_v)
    inv_b = inv_wsum.reshape(n_blocks, block_v)
    msk_b = vmask.reshape(n_blocks, block_v)
    xs = (
        jnp.arange(n_blocks, dtype=jnp.int32),
        blk_dst,
        blk_row,
        blk_w,
        probs,
        deg_b,
        inv_b,
        msk_b,
    )
    carry = (labels, lam, loads, jnp.zeros_like(loads), cap, key,
             jnp.zeros((), jnp.float32))
    step_fn = partial(_chunk_step, cfg, block_v)
    (labels, lam, loads, _, _, key, score_sum), probs = jax.lax.scan(step_fn, carry, xs)
    return RevolverState(
        labels=labels,
        lam=lam,
        probs=probs,
        loads=loads,
        key=key,
        step=step + 1,
        score=score_sum / n,
    )


def _sharded_shard_body(
    blk_dst, blk_row, blk_w, deg, inv_wsum, vmask, cap,
    labels, lam, probs, loads, key,
    *, block_v: int, blocks_per_shard: int, cfg: RevolverConfig,
):
    """Per-shard superstep body (runs under shard_map on the "blocks" mesh).

    Jacobi across shards, async within: every shard all-gathers the
    start-of-superstep labels/lam once, then scans its own blocks exactly
    like the sequential schedule — its local migrations and argmax labels
    are visible to its later blocks, remote shards' are not until the next
    superstep. The drifting load view each shard scores against is the
    global start-of-superstep loads plus its own migrations; the exact
    global loads are restored at the boundary by psum-merging the per-shard
    deltas (integer-valued degree sums, so the merge is exact and, on one
    shard, bit-identical to the sequential update chain).
    """
    idx = jax.lax.axis_index("blocks")
    local_n = blocks_per_shard * block_v
    labels_g = gather_shards(labels, "blocks")        # [n_pad] Jacobi view
    lam_g = gather_shards(lam, "blocks")
    key_shard = shard_chain_key(key, "blocks")        # shard 0 keeps `key`

    xs = (
        idx * blocks_per_shard + jnp.arange(blocks_per_shard, dtype=jnp.int32),
        blk_dst,
        blk_row,
        blk_w,
        probs,
        deg.reshape(blocks_per_shard, block_v),
        inv_wsum.reshape(blocks_per_shard, block_v),
        vmask.reshape(blocks_per_shard, block_v),
    )
    carry = (labels_g, lam_g, loads, jnp.zeros_like(loads), cap, key_shard,
             jnp.zeros((), jnp.float32))
    step_fn = partial(_chunk_step, cfg, block_v)
    (labels_g, lam_g, _, delta, _, key_fin, score_sum), probs = \
        jax.lax.scan(step_fn, carry, xs)

    v0 = idx * local_n
    labels_local = jax.lax.dynamic_slice(labels_g, (v0,), (local_n,))
    lam_local = jax.lax.dynamic_slice(lam_g, (v0,), (local_n,))
    loads_new = psum_delta_merge(loads, delta, "blocks")
    score_sum = jax.lax.psum(score_sum, "blocks")
    key_new = replicated_chain_key(key_fin, "blocks")
    return labels_local, lam_local, probs, loads_new, key_new, score_sum


@partial(jax.jit,
         static_argnames=("mesh", "n", "block_v", "blocks_per_shard", "cfg"),
         donate_argnames=("labels", "lam", "probs", "loads"))
def _sharded_superstep_impl(
    blk_dst, blk_row, blk_w, deg_out, inv_wsum, vmask, cap,
    labels, lam, probs, loads, key, step,
    *, mesh, n: int, block_v: int, blocks_per_shard: int, cfg: RevolverConfig,
):
    body = partial(
        _sharded_shard_body,
        block_v=block_v, blocks_per_shard=blocks_per_shard, cfg=cfg,
    )
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(
            P("blocks", None), P("blocks", None), P("blocks", None),  # slabs
            P("blocks"), P("blocks"), P("blocks"),                    # vertex
            P(),                                                      # cap
            P("blocks"), P("blocks"),                                 # labels/lam
            P("blocks", None, None),                                  # probs
            P(), P(),                                                 # loads/key
        ),
        out_specs=(P("blocks"), P("blocks"), P("blocks", None, None),
                   P(), P(), P()),
        check_rep=False,
    )
    labels, lam, probs, loads, key, score_sum = sharded(
        blk_dst, blk_row, blk_w, deg_out, inv_wsum, vmask, cap,
        labels, lam, probs, loads, key)
    return RevolverState(
        labels=labels,
        lam=lam,
        probs=probs,
        loads=loads,
        key=key,
        step=step + 1,
        score=score_sum / n,
    )


def place_revolver_state(state: RevolverState, sdg: ShardedDeviceGraph) -> RevolverState:
    """Commit a freshly-initialized state to the sharded layout: per-vertex
    buffers sliced onto their owning device, loads/key/scalars replicated —
    so the donated superstep buffers are reused in place from step one."""
    mesh = sdg.mesh

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return RevolverState(
        labels=put(state.labels, P("blocks")),
        lam=put(state.lam, P("blocks")),
        probs=put(state.probs, P("blocks", None, None)),
        loads=put(state.loads, P()),
        key=put(state.key, P()),
        step=put(state.step, P()),
        score=put(state.score, P()),
    )


def revolver_superstep(dg, cfg: RevolverConfig, state: RevolverState) -> RevolverState:
    """One full superstep over all chunks. Jitted; static on (dg shape, cfg).

    `cfg.chunk_schedule` selects the execution plan: "sequential" scans all
    blocks on one device (`dg` is a plain DeviceGraph); "sharded" runs the
    per-shard scans data-parallel under shard_map (`dg` must be a
    ShardedDeviceGraph, see `prepare_sharded_device_graph`).

    The state's labels / lam / probs / loads buffers are **donated** under
    either schedule: the [n_blocks, block_v, k] probability tensor and the
    label vectors are updated in place instead of copied every superstep
    (per-shard slices in the sharded schedule). The passed-in `state` must
    therefore not be reused after this call (every caller in the repo
    rebinds, `state = revolver_superstep(...)`); the small `key` / `step` /
    `score` leaves stay valid, so the convergence loop's windowed score
    buffering is unaffected.
    """
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    if cfg.chunk_schedule == "sharded":
        if not isinstance(dg, ShardedDeviceGraph):
            raise TypeError(
                "chunk_schedule='sharded' needs a ShardedDeviceGraph "
                "(see prepare_sharded_device_graph); got a plain DeviceGraph")
        return _sharded_superstep_impl(
            dg.blk_dst, dg.blk_row, dg.blk_w, dg.deg_out, dg.inv_wsum,
            dg.vmask, cap, state.labels, state.lam, state.probs, state.loads,
            state.key, state.step,
            mesh=dg.mesh, n=dg.n, block_v=dg.block_v,
            blocks_per_shard=dg.blocks_per_shard, cfg=cfg,
        )
    if isinstance(dg, ShardedDeviceGraph):
        dg = dg.dg   # sequential schedule over a sharded layout's arrays
    return _superstep_impl(
        dg.blk_dst, dg.blk_row, dg.blk_w, dg.deg_out, dg.inv_wsum, dg.vmask,
        cap, state.labels, state.lam, state.probs, state.loads, state.key,
        state.step,
        n=dg.n, n_blocks=dg.n_blocks, block_v=dg.block_v, cfg=cfg,
    )
