"""String-keyed algorithm registry: ``run_partitioner(algo="...")`` lookups.

Two entry kinds live here:

  * ``engine.Algorithm`` — superstep algorithms (revolver, spinner,
    restream) the engine drives through the shared convergence loop;
  * ``StaticAlgorithm`` — closed-form baselines (hash, range) that emit a
    partition in one shot with no supersteps.

Rule modules register themselves at import time
(``REVOLVER = register(engine.Algorithm(...))``); ``get_algorithm`` imports
the built-in modules lazily on first lookup so the registry has no import
cycle with the rules it serves. Out-of-tree algorithms call ``register``
directly and are immediately reachable from ``run_partitioner``, the
streaming runner, and the launch CLI.

Execution schedules are owned by the engine, not the rules: a registered
chunk-kind ``Algorithm`` inherits every ``chunk_schedule`` — including the
overlapped ``"async"`` superstep (docs/async-superstep.md) — for free; its
rule body never sees which schedule ran it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple, Union

from repro.core.engine import Algorithm


@dataclasses.dataclass(frozen=True, eq=False)
class StaticAlgorithm:
    """A stateless one-shot partitioner: ``partition(n, k) -> [n] labels``."""

    name: str
    partition: Callable


AnyAlgorithm = Union[Algorithm, StaticAlgorithm]

_REGISTRY: Dict[str, AnyAlgorithm] = {}


def register(algo: AnyAlgorithm) -> AnyAlgorithm:
    """Add an algorithm to the registry (last registration wins) and return
    it, so rule modules can use the ``NAME = register(...)`` idiom."""
    _REGISTRY[algo.name] = algo
    return algo


def _ensure_builtins() -> None:
    # the built-in rule modules self-register on import; imported lazily so
    # `import repro.core.registry` never cycles back through the rules
    from repro.core import restream, revolver, spinner, static_partitioners  # noqa: F401


def get_algorithm(name: str) -> AnyAlgorithm:
    """Look up a registered algorithm; unknown names raise ValueError with
    the available keys (the old hand-rolled dispatch raised the same)."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {name!r}; available: {available_algorithms()}"
        ) from None


def available_algorithms() -> Tuple[str, ...]:
    """Sorted names of every registered algorithm."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def superstep_algorithms() -> Tuple[str, ...]:
    """Sorted names of the engine-driven (non-static) algorithms."""
    _ensure_builtins()
    return tuple(sorted(n for n, a in _REGISTRY.items()
                        if isinstance(a, Algorithm)))


def warm_startable_algorithms() -> Tuple[str, ...]:
    """Sorted names of the superstep algorithms that accept
    ``init_from_labels`` warm starts — the set eligible for
    ``run_partitioner(mode="vcycle")`` uncoarsening refinement."""
    _ensure_builtins()
    return tuple(sorted(
        n for n, a in _REGISTRY.items()
        if isinstance(a, Algorithm) and a.init_from_labels is not None))
