"""Schedule-agnostic partitioner engine: one superstep core, pluggable rules.

Revolver's LA+LP superstep, the Spinner baseline, and prioritized
restreaming are all instances of one family: a **local rule** (how a vertex
scores partitions and decides to migrate) driven by a **global schedule**
(in what order vertices see each other's decisions, and where the work
runs). This module owns everything schedule-shaped, so an algorithm module
contributes only its rule:

  rule      (algorithm module, e.g. core/revolver.py)
      a config dataclass, a state NamedTuple, ``init`` /
      ``init_from_labels``, and either a per-block ``chunk_rule`` or a
      per-shard ``shard_rule``;
  schedule  (this module)
      the sequential asynchronous ``lax.scan`` over vertex blocks, the
      ``shard_map`` Jacobi superstep on a 1-D ``("blocks",)`` mesh (label
      all-gather, psum load-delta merge, per-shard PRNG chains), the
      ``"halo"`` variant of the Jacobi superstep that syncs only the
      precomputed boundary blocks (``repro.core.halo``; an exact,
      traffic-proportional-to-edge-cut optimization of the full gather),
      the ``"async"`` variant that splits each shard's scan into interior
      blocks (no remote/hub references — scanned while the halo exchange
      is still in flight) and boundary blocks (scanned after the sync),
      with a bounded-staleness halo cache (``async_superstep``),
      buffer donation, and sharded state placement;
  kernel    (repro/kernels, routed via ``ops.superstep_kernels``)
      the fused Pallas edge phase and LA update behind the ``hist_impl`` /
      ``la_impl`` config knobs; the jnp scatter-add reference lives in
      core/lp.py.

See ``src/repro/core/README.md`` for the full contract an algorithm
implements and what it inherits.

Rule kinds
----------
``kind="chunk"`` (Revolver, restream): the rule processes one vertex block
at a time inside a scan; migrations and per-vertex updates from block i are
visible to block i+1 within the same superstep (the paper's asynchrony,
DESIGN.md §3). Under the sharded schedule each device scans only its own
blocks (async within the shard, Jacobi across shards) and the engine
all-gathers the declared ``vertex_fields`` once per superstep, psum-merges
the ``[k]`` load delta, and re-replicates shard 0's PRNG chain.

``kind="shard"`` (Spinner): the rule processes its whole shard in one BSP
step against the previous superstep's configuration, calling the context's
collectives (``gather`` / ``psum``) where cross-shard reductions are
needed. The sequential schedule runs the same rule with identity
collectives on a single shard spanning the whole graph — one rule, both
schedules.

Load-delta accounting lives here too: rules mutate their drifting ``loads``
view freely; the engine recovers the shard's superstep delta as
``loads_end - loads_start`` (exact — loads are sums of integer-valued
degrees in f32) and psum-merges it at the superstep boundary. The
sequential path simply keeps ``loads_end``, so sequential rules no longer
carry sharded-only accumulator slots (the dead ``delta`` chain the PR-3
scan threaded through every chunk is gone).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    capacity_device,
)
from repro.parallel.collectives import (
    gather_shards,
    hub_gather,
    psum_delta_merge,
    replicated_chain_key,
    shard_chain_key,
    vertex_halo_exchange,
)

AXIS = "blocks"   # the 1-D mesh axis every sharded superstep runs over


# ---------------------------------------------------------------------------
# algorithm protocol
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class Algorithm:
    """A partitioning algorithm as the engine sees it.

    Frozen with identity hashing (``eq=False``): instances are module-level
    singletons and serve as jit static arguments.

    Attributes:
      name: registry key ("revolver", "spinner", ...).
      config_cls: frozen config dataclass. The engine reads ``k``,
        ``epsilon``, ``capacity_mode``, ``chunk_schedule``, ``max_steps``,
        ``patience``, ``theta``; everything else is rule-private.
      state_cls: state NamedTuple. Must carry ``labels`` ([n_pad] int32),
        ``loads`` ([k] f32), ``key``, ``step``, ``score``; may add more.
      kind: "chunk" or "shard" (see module docstring).
      vertex_fields: state fields holding per-vertex [n_pad] arrays that the
        schedule synchronizes (all-gathered each sharded superstep, updated
        by the rule per block/shard). Must include "labels".
      block_fields: state fields holding per-block [n_blocks, ...] tensors
        (e.g. Revolver's LA probabilities) scanned alongside the edge slabs;
        chunk-kind only.
      replicated_fields: state fields the schedule passes through replicated
        and untouched (per-superstep constants, e.g. restream's degree
        ranks). Available to rules via the context.
      wire_int8_fields: vertex_fields whose values always fit int8 (label-
        valued, i.e. in [0, k)): when ``cfg.k <= 127`` the per-vertex halo
        exchange moves them on an int8 wire — an exact round trip, 4x fewer
        bytes. Fields not listed ride the wire at their storage width.
      donate: state fields whose buffers the jitted superstep donates
        (updated in place; callers must rebind ``state = superstep(...)``).
      init: ``(dg, cfg, key) -> state`` cold start.
      init_from_labels: ``(dg, cfg, key, labels, probs=None,
        prob_sharpen=0.0) -> state`` warm start, or None if unsupported.
      supports_probs: whether the algorithm carries an LA probability tensor
        (enables ``keep_probs`` / ``init_probs`` / ``init_sharpen`` in the
        runner and probability carrying in the streaming path).
      chunk_rule / shard_rule: the local rule (exactly one, per ``kind``).
    """

    name: str
    config_cls: type
    state_cls: type
    kind: str
    init: Callable
    vertex_fields: Tuple[str, ...] = ("labels",)
    block_fields: Tuple[str, ...] = ()
    replicated_fields: Tuple[str, ...] = ()
    wire_int8_fields: Tuple[str, ...] = ()
    donate: Tuple[str, ...] = ("labels", "loads")
    init_from_labels: Optional[Callable] = None
    supports_probs: bool = False
    chunk_rule: Optional[Callable] = None
    shard_rule: Optional[Callable] = None

    def __post_init__(self):
        if self.kind not in ("chunk", "shard"):
            raise ValueError(f"Algorithm.kind={self.kind!r}")
        if "labels" not in self.vertex_fields:
            raise ValueError(f"{self.name}: vertex_fields must include 'labels'")
        if (self.chunk_rule is None) == (self.kind == "chunk"):
            raise ValueError(f"{self.name}: kind={self.kind!r} needs exactly "
                             "the matching rule callable")
        if (self.shard_rule is None) == (self.kind == "shard"):
            raise ValueError(f"{self.name}: kind={self.kind!r} needs exactly "
                             "the matching rule callable")
        required = {"labels", "loads", "key", "step", "score"}
        missing = required - set(self.state_cls._fields)
        if missing:
            raise ValueError(f"{self.name}: state_cls lacks {sorted(missing)}")
        stray = set(self.wire_int8_fields) - set(self.vertex_fields)
        if stray:
            raise ValueError(
                f"{self.name}: wire_int8_fields {sorted(stray)} are not "
                "vertex_fields")


class ChunkContext(NamedTuple):
    """What a chunk rule sees for one vertex block.

    ``repl`` carries the full replicated_fields arrays; per-vertex slices of
    the block are taken with ``v0``. ``step`` is the 0-based superstep index
    (rules may schedule on it, e.g. restream's priority ramp).

    ``v0`` addresses the *drifting per-vertex view* the rule slices and the
    engine splices (the full ``[n_pad]`` vector under the sequential and
    full-gather schedules; the shard's ``local + halo`` buffer under
    ``chunk_schedule="halo"``, where the block's edge slab ids are likewise
    pre-rewritten into buffer space). ``gv0`` is the block's *global* vertex
    offset, for slicing replicated ``[n_pad]`` arrays in ``repl`` (restream's
    degree ranks); the two coincide except under the halo schedule.

    ``n_shards`` tells the rule how many shards are drifting this superstep
    concurrently (1 under the sequential schedule). A rule that rations
    shared capacity against its drifting ``loads`` view must divide the
    remaining headroom by it: under the Jacobi schedule every shard sees
    the same start-of-superstep loads, so an un-rationed greedy rule lets
    each shard independently spend the *whole* remaining capacity of a
    popular partition — n_shards-fold overshoot and oscillation (restream
    collapsed to max_norm_load ~6 at 8 shards before this).
    """

    blk_idx: jnp.ndarray    # scalar int32 global block index
    v0: jnp.ndarray         # scalar int32 block offset into the drifting view
    gv0: jnp.ndarray        # scalar int32 global vertex offset of the block
    e_dst: jnp.ndarray      # [e_max] int32 neighbor ids (0 pad)
    e_row: jnp.ndarray      # [e_max] int32 local row in the block (0 pad)
    e_w: jnp.ndarray        # [e_max] f32 eq.(4) weights (0.0 pad)
    deg: jnp.ndarray        # [block_v] f32 outdegrees
    inv_wsum: jnp.ndarray   # [block_v] f32 1/sum w_hat
    vmask: jnp.ndarray      # [block_v] bool real-vertex mask
    step: jnp.ndarray       # scalar int32 superstep index
    n_shards: int           # static: concurrent Jacobi shards (1 sequential)
    loads0: jnp.ndarray     # [k] start-of-superstep loads (the Jacobi base
                            # every shard drifts from; == the drifting loads
                            # arg at the first chunk of a sequential scan)
    repl: Dict[str, jnp.ndarray]

    def shared_headroom(self, cap, loads) -> jnp.ndarray:
        """Per-partition capacity this block may spend without cross-shard
        overshoot: the shard's 1/n_shards share of the start-of-superstep
        global headroom, plus whatever capacity the shard itself freed
        since (its outflows are in its drifting ``loads`` view; remote
        shards' are not until the Jacobi merge). Degenerates to the plain
        ``cap - loads`` under the sequential schedule."""
        if self.n_shards == 1:
            return cap - loads
        return (cap - self.loads0) / self.n_shards + (self.loads0 - loads)


class ChunkUpdate(NamedTuple):
    """A chunk rule's output: the engine applies ``vert`` slices to the
    drifting per-vertex arrays (visible to later blocks in the superstep),
    stacks ``block`` as the scan output, and threads loads/key/score."""

    vert: Dict[str, jnp.ndarray]    # vertex_field -> [block_v] new values
    block: Dict[str, jnp.ndarray]   # block_field -> updated block tensor
    loads: jnp.ndarray              # [k] updated drifting load view
    key: jnp.ndarray                # chained PRNG key
    score: jnp.ndarray              # scalar score sum over the block


@dataclasses.dataclass(frozen=True)
class ShardContext:
    """What a shard rule sees: its slice of the blocked layout plus
    collectives that degenerate to identities on the sequential schedule.

    Under ``chunk_schedule="halo"`` the slab neighbor ids in ``blk_dst`` are
    pre-rewritten into the shard's ``local + halo`` buffer space and
    ``gather`` returns that buffer (own slice first, then the exchanged
    boundary slabs) instead of the full ``[n_pad]`` vector — rules that only
    index the gather result through ``blk_dst`` (the contract) run unchanged
    under all three schedules.
    """

    axis: Optional[str]     # mesh axis name, or None (sequential)
    idx: jnp.ndarray        # scalar int32 shard index (0 when sequential)
    n: int                  # real vertex count
    n_pad: int              # global padded vertex count
    local_n: int            # vertices owned by this shard
    block_v: int
    blocks: int             # blocks owned by this shard
    v0: jnp.ndarray         # scalar int32 global offset of the local range
    blk_dst: jnp.ndarray    # [blocks, e_max] local edge slabs
    blk_row: jnp.ndarray
    blk_w: jnp.ndarray
    deg: jnp.ndarray        # [local_n]
    inv_wsum: jnp.ndarray   # [local_n]
    vmask: jnp.ndarray      # [local_n]
    step: jnp.ndarray
    repl: Dict[str, jnp.ndarray]
    halo_rows: Optional[jnp.ndarray] = None   # [S, b_max] boundary plan
    send_ids: Optional[jnp.ndarray] = None    # [S, S, h_max] per-vertex plan
    hub_owner: Optional[jnp.ndarray] = None   # [hub_pad] hub replication plan
    hub_local: Optional[jnp.ndarray] = None
    wire_int8: bool = False    # label-valued gathers may ride an int8 wire

    def gather(self, x):
        """Make every vertex id in ``blk_dst`` resolvable: the full
        all-gather, the boundary-block halo exchange, or the per-vertex
        all-to-all when the layout carries the matching plan (identity on
        the sequential schedule), plus the replicated hub region when hub
        replication is on. Rules gather label-valued fields only (the
        contract), so ``wire_int8`` applies to every per-vertex gather."""
        if self.halo_rows is not None:
            with obs.annotate("halo-exchange", kind="halo"):
                y = halo_exchange(x, self.halo_rows, self.idx, self.blocks,
                                  self.block_v, self.axis)
        elif self.send_ids is not None:
            with obs.annotate("halo-exchange", kind="per-vertex"):
                wire = jnp.int8 if (self.wire_int8
                                    and x.dtype == jnp.int32) else None
                tail = vertex_halo_exchange(x, self.send_ids, self.axis,
                                            wire_dtype=wire)
                y = jnp.concatenate([x, tail]) if tail.shape[0] else x
        elif self.axis:
            with obs.annotate("halo-exchange", kind="full-gather"):
                y = gather_shards(x, self.axis)
        else:
            y = x
        if self.hub_owner is not None:
            with obs.annotate("halo-exchange", kind="hub-assemble"):
                y = jnp.concatenate(
                    [y, hub_gather(x, self.hub_owner, self.hub_local,
                                   self.axis)])
        return y

    def psum(self, x):
        """Sum a shard-local reduction across shards."""
        return jax.lax.psum(x, self.axis) if self.axis else x

    def local_rows(self) -> jnp.ndarray:
        """[blocks * e_max] local row ids for a flat slab histogram."""
        base = jnp.arange(self.blocks, dtype=jnp.int32)[:, None] * self.block_v
        return (base + self.blk_row).reshape(-1)


class ShardUpdate(NamedTuple):
    vert: Dict[str, jnp.ndarray]    # vertex_field -> [local_n] new values
    loads_delta: jnp.ndarray        # [k] this shard's load delta
    key: jnp.ndarray                # chained PRNG key (replicated semantics)
    score: jnp.ndarray              # scalar score sum over the shard


class _Layout(NamedTuple):
    """Static shape info (hashable jit key)."""

    n: int
    n_pad: int
    n_blocks: int
    block_v: int
    blocks_per_shard: int


def _graph_arrays(dg: DeviceGraph) -> Dict[str, jnp.ndarray]:
    return {
        "blk_dst": dg.blk_dst, "blk_row": dg.blk_row, "blk_w": dg.blk_w,
        "deg": dg.deg_out, "inv_wsum": dg.inv_wsum, "vmask": dg.vmask,
    }


_GRAPH_SPECS = {
    "blk_dst": P(AXIS, None), "blk_row": P(AXIS, None), "blk_w": P(AXIS, None),
    "deg": P(AXIS), "inv_wsum": P(AXIS), "vmask": P(AXIS),
    "halo_rows": P(),   # replicated boundary plan (block-halo schedule)
    "send_ids": P(),    # replicated per-vertex exchange plan
    # hub replication: the plan vectors are replicated, the per-shard vote
    # slabs are sharded like the edge slabs they were cut from
    "hub_owner": P(), "hub_local": P(), "hub_deg": P(),
    "hub_src": P(AXIS, None), "hub_slot": P(AXIS, None),
    "hub_w": P(AXIS, None),
}


def _state_spec(algo: Algorithm, name: str, value) -> P:
    """Sharding spec for one state field (block axis leads block tensors)."""
    if name in algo.vertex_fields:
        return P(AXIS)
    if name in algo.block_fields:
        return P(AXIS, *([None] * (value.ndim - 1)))
    return P()


# ---------------------------------------------------------------------------
# the superstep body (shared by the schedules; axis=None == sequential)
# ---------------------------------------------------------------------------
def halo_exchange(x, halo_rows, idx, bps, block_v, axis):
    """Boundary-only label sync: each shard contributes the `[b_max]`
    blocks of its slice that remote slabs reference (`halo_rows[idx]`,
    precomputed — see `repro.core.halo`), one all-gather moves them, and
    the result is appended to the shard's own slice. Cross-device traffic
    is O(b_max * block_v) per field instead of O(n_pad); the remote slabs
    received are the same start-of-superstep snapshots the full gather
    would deliver, so the halo schedule is an *exact* optimization of the
    full-gather Jacobi sync."""
    if halo_rows.shape[1] == 0:        # no cross-shard references at all
        return x
    rows = jnp.take(halo_rows, idx, axis=0)                   # [b_max]
    contrib = jnp.take(x.reshape(bps, block_v), rows, axis=0)
    gathered = jax.lax.all_gather(contrib, axis)              # [S, b_max, bv]
    return jnp.concatenate([x, gathered.reshape(-1)])


def _hub_reconcile(graph, k, cap, axis, idx, labels, loads, local_n):
    """Per-superstep hub vote reconciliation — O(hub_pad * k), never O(E).

    Hubs are frozen during the scan (`vmask_nonhub`), so at this point every
    shard holds the same start-of-superstep hub labels. Each shard
    accumulates weighted one-hot votes from its local slab slots that point
    at hubs (`hub_src` / `hub_slot` / `hub_w`, precomputed host-side), one
    psum merges the `[hub_pad, k]` vote table, and an identical
    deterministic capacity-gated scan runs on every shard: per slot, the
    argmax label wins (ties break to the lowest partition index), gated on
    the merged global loads so hub migrations never breach capacity. All
    inputs are replicated, so every shard computes the same winners and the
    same updated loads — each owner then scatters its hubs' winners into
    its local slice. With ``axis=None`` the psums are identities and the
    same arithmetic runs on the single shard (the sequential hub schedule),
    which is why 1-shard hub runs match the sequential reference
    bit-for-bit.
    """
    owner = graph["hub_owner"]               # [hub_pad] replicated
    local = graph["hub_local"]
    hdeg = graph["hub_deg"]
    src = graph["hub_src"][0]                # this shard's vote slab
    slot = graph["hub_slot"][0]
    w = graph["hub_w"][0]
    hub_pad = owner.shape[0]

    # current hub labels: exactly one owner contributes per slot
    cur = jnp.where(owner == idx, jnp.take(labels, local), 0)
    lab_src = jnp.take(labels, src)
    votes = jnp.zeros((hub_pad, k), jnp.float32).at[slot, lab_src].add(w)
    if axis:
        with obs.annotate("halo-exchange", kind="hub-votes"):
            cur = jax.lax.psum(cur, axis)
            votes = jax.lax.psum(votes, axis)
    valid = owner >= 0
    total = votes.sum(axis=1)
    cand = jnp.argmax(votes, axis=1).astype(labels.dtype)

    def decide(carry_loads, j):
        c, p, d = cand[j], cur[j], hdeg[j]
        ok = valid[j] & (total[j] > 0) & (c != p) & (carry_loads[c] + d <= cap)
        new = jnp.where(ok, c, p)
        delta = jnp.where(ok, d, 0.0)
        carry_loads = carry_loads.at[p].add(-delta).at[new].add(delta)
        return carry_loads, new

    loads, winners = jax.lax.scan(decide, loads,
                                  jnp.arange(hub_pad, dtype=jnp.int32))
    # scatter winners into the owner's slice (non-owned slots hit a dummy
    # extension row that is trimmed right back off)
    safe = jnp.where(owner == idx, local, local_n)
    ext = jnp.concatenate([labels, jnp.zeros((1,), labels.dtype)])
    return ext.at[safe].set(winners)[:local_n], loads


def _expand_vertex_field(x, graph, idx, bps, block_v, axis, wire_dtype=None):
    """Build one field's drifting view: the (local) slice, then the halo
    tail the layout's plan exchanges, then the replicated hub region."""
    if "halo_rows" in graph:
        y = halo_exchange(x, graph["halo_rows"], idx, bps, block_v, axis)
    elif "send_ids" in graph:
        tail = vertex_halo_exchange(x, graph["send_ids"], axis,
                                    wire_dtype=wire_dtype)
        y = jnp.concatenate([x, tail]) if tail.shape[0] else x
    elif axis:
        y = gather_shards(x, axis)
    else:
        y = x
    if "hub_owner" in graph:
        y = jnp.concatenate(
            [y, hub_gather(x, graph["hub_owner"], graph["hub_local"], axis)])
    return y


def _exchange_tail(x, graph, idx, bps, block_v, axis, wire_dtype=None):
    """The exchanged part of one field's drifting view — everything past the
    shard's own slice: the halo tail the layout's plan moves, then the
    replicated hub region. ``_expand_vertex_field(x, ...)`` equals
    ``concat([x, _exchange_tail(x, ...)])`` whenever a plan is attached
    (the async schedule assembles the two halves at different times)."""
    parts = []
    if "halo_rows" in graph:
        halo_rows = graph["halo_rows"]
        if halo_rows.shape[1]:
            rows = jnp.take(halo_rows, idx, axis=0)
            contrib = jnp.take(x.reshape(bps, block_v), rows, axis=0)
            parts.append(jax.lax.all_gather(contrib, axis).reshape(-1))
    elif "send_ids" in graph:
        tail = vertex_halo_exchange(x, graph["send_ids"], axis,
                                    wire_dtype=wire_dtype)
        if tail.shape[0]:
            parts.append(tail)
    if "hub_owner" in graph:
        parts.append(
            hub_gather(x, graph["hub_owner"], graph["hub_local"], axis))
    if not parts:
        return jnp.zeros((0,), x.dtype)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def _chunk_superstep(algo, cfg, layout, axis, graph, cap, state, step):
    """Scan the (local) blocks with the algorithm's chunk rule.

    Sequential: one shard spanning every block, identity collectives, the
    state key used directly — the PR-2 semantics. Sharded: Jacobi across
    shards (gather once, scan local blocks, slice back, merge the exact
    load delta, re-replicate shard 0's chained key). Halo: the Jacobi
    schedule with the full label gather replaced by the boundary-block or
    per-vertex exchange — the drifting view is the shard's `local + halo`
    buffer (own slice first, so intra-shard asynchrony is untouched) and
    the slab ids in `graph["blk_dst"]` are pre-rewritten into buffer
    space. Hub replication appends the psum-assembled hub region to the
    buffer, freezes hubs during the scan (the layout swapped `vmask` for
    `vmask_nonhub`), and reconciles their labels by weighted votes after
    the load merge (`_hub_reconcile`) — also runnable with `axis=None`,
    where every collective degenerates to the identity (the sequential hub
    schedule, the 1-shard bit-identity oracle).
    """
    idx = jax.lax.axis_index(axis) if axis else jnp.zeros((), jnp.int32)
    bps = layout.blocks_per_shard if axis else layout.n_blocks
    n_shards = layout.n_blocks // layout.blocks_per_shard if axis else 1
    block_v = layout.block_v
    halo = "halo_rows" in graph or "send_ids" in graph
    hub_on = "hub_owner" in graph
    kind = ("halo" if "halo_rows" in graph
            else "per-vertex" if "send_ids" in graph
            else "full-gather" if axis else "local")
    wire_ok = cfg.k <= 127
    if axis or halo or hub_on:
        with obs.annotate("halo-exchange", kind=kind, hubs=int(hub_on),
                          fields=len(algo.vertex_fields)):
            vert = {f: _expand_vertex_field(
                        state[f], graph, idx, bps, block_v, axis,
                        wire_dtype=(jnp.int8 if wire_ok and
                                    f in algo.wire_int8_fields else None))
                    for f in algo.vertex_fields}
    else:
        vert = {f: state[f] for f in algo.vertex_fields}
    key = shard_chain_key(state["key"], axis) if axis else state["key"]
    repl = {f: state[f] for f in algo.replicated_fields}
    loads0 = state["loads"]

    xs = (
        idx * bps + jnp.arange(bps, dtype=jnp.int32),
        graph["blk_dst"], graph["blk_row"], graph["blk_w"],
        {f: state[f] for f in algo.block_fields},
        graph["deg"].reshape(bps, block_v),
        graph["inv_wsum"].reshape(bps, block_v),
        graph["vmask"].reshape(bps, block_v),
    )

    def scan_step(carry, x):
        vert, loads, key, score_sum = carry
        blk_idx, e_dst, e_row, e_w, block, deg, inv_wsum, vmask = x
        gv0 = blk_idx * block_v
        v0 = (blk_idx - idx * bps) * block_v if halo else gv0
        ctx = ChunkContext(
            blk_idx=blk_idx, v0=v0, gv0=gv0, e_dst=e_dst, e_row=e_row,
            e_w=e_w, deg=deg, inv_wsum=inv_wsum, vmask=vmask, step=step,
            n_shards=n_shards, loads0=loads0, repl=repl)
        upd = algo.chunk_rule(cfg, ctx, vert, block, loads, cap, key)
        vert = {f: jax.lax.dynamic_update_slice(vert[f], upd.vert[f], (ctx.v0,))
                for f in vert}
        return (vert, upd.loads, upd.key, score_sum + upd.score), upd.block

    carry = (vert, loads0, key, jnp.zeros((), jnp.float32))
    (vert, loads_end, key_end, score_sum), block_out = \
        jax.lax.scan(scan_step, carry, xs)

    local_n = bps * block_v
    if halo or hub_on:
        # the (local) slice leads its buffer; the halo tail and hub region
        # are read-only within the scan
        vert = {f: v[:local_n] for f, v in vert.items()}
    elif axis:
        v0 = idx * local_n
        vert = {f: jax.lax.dynamic_slice(v, (v0,), (local_n,))
                for f, v in vert.items()}
    if axis:
        # the shard's migrations, recovered exactly (integer-valued f32)
        loads_end = psum_delta_merge(loads0, loads_end - loads0, axis)
        score_sum = jax.lax.psum(score_sum, axis)
        key_end = replicated_chain_key(key_end, axis)
    if hub_on:
        vert["labels"], loads_end = _hub_reconcile(
            graph, cfg.k, cap, axis, idx, vert["labels"], loads_end, local_n)
    return {**vert, **block_out, "loads": loads_end, "key": key_end,
            "score": score_sum}


def _async_chunk_superstep(algo, cfg, layout, split, refresh, axis,
                           graph, cap, state, cache, step):
    """The halo chunk superstep with the scan split at ``split``: interior
    blocks first, carrying only the shard's own slice, then the boundary
    blocks against the full ``local + halo + hub`` buffer.

    Interior blocks reference no exchanged and no hub-replicated vertex
    (their rewritten slab ids are all ``< local_n`` — the classification in
    `repro.core.halo.build_halo_spec`), so the phase-1 scan has no data
    dependency on the exchange; XLA is free to overlap the collective with
    the interior compute. The tail is assembled from the start-of-superstep
    state either way, and the scan processes the blocks in the same order
    with the same loads/key/score chaining as `_chunk_superstep`, so a
    refreshing async superstep is **bit-identical** to the halo schedule.

    ``refresh`` (static) selects the tail source: True assembles it with the
    plan's collectives; False reuses ``cache`` — the tail of an earlier
    superstep, up to ``staleness_bound`` steps old (the refresh policy lives
    in the caller; the engine only distinguishes fresh from cached). The
    tail actually read is returned as the new cache either way.
    """
    idx = jax.lax.axis_index(axis)
    bps = layout.blocks_per_shard
    n_shards = layout.n_blocks // layout.blocks_per_shard
    block_v = layout.block_v
    local_n = bps * block_v
    hub_on = "hub_owner" in graph
    kind = ("halo" if "halo_rows" in graph
            else "per-vertex" if "send_ids" in graph else "hub-only")
    wire_ok = cfg.k <= 127

    key = shard_chain_key(state["key"], axis)
    repl = {f: state[f] for f in algo.replicated_fields}
    loads0 = state["loads"]

    xs = (
        idx * bps + jnp.arange(bps, dtype=jnp.int32),
        graph["blk_dst"], graph["blk_row"], graph["blk_w"],
        {f: state[f] for f in algo.block_fields},
        graph["deg"].reshape(bps, block_v),
        graph["inv_wsum"].reshape(bps, block_v),
        graph["vmask"].reshape(bps, block_v),
    )
    head_xs = jax.tree_util.tree_map(lambda a: a[:split], xs)
    tail_xs = jax.tree_util.tree_map(lambda a: a[split:], xs)

    def scan_step(carry, x):
        vert, loads, key, score_sum = carry
        blk_idx, e_dst, e_row, e_w, block, deg, inv_wsum, vmask = x
        gv0 = blk_idx * block_v
        v0 = (blk_idx - idx * bps) * block_v
        ctx = ChunkContext(
            blk_idx=blk_idx, v0=v0, gv0=gv0, e_dst=e_dst, e_row=e_row,
            e_w=e_w, deg=deg, inv_wsum=inv_wsum, vmask=vmask, step=step,
            n_shards=n_shards, loads0=loads0, repl=repl)
        upd = algo.chunk_rule(cfg, ctx, vert, block, loads, cap, key)
        vert = {f: jax.lax.dynamic_update_slice(vert[f], upd.vert[f], (ctx.v0,))
                for f in vert}
        return (vert, upd.loads, upd.key, score_sum + upd.score), upd.block

    # phase 1: interior blocks drift on the shard's own slice while the
    # exchange is in flight (the nested spans are the overlap contract the
    # trace validator checks — see tools/trace_report.py --validate)
    local = {f: state[f] for f in algo.vertex_fields}
    with obs.annotate("interior-scan", schedule="async", blocks=split,
                      refresh=int(refresh)):
        if refresh:
            with obs.annotate("halo-exchange", kind=kind, hubs=int(hub_on),
                              fields=len(algo.vertex_fields), overlap=1):
                halo_tail = {
                    f: _exchange_tail(
                        state[f], graph, idx, bps, block_v, axis,
                        wire_dtype=(jnp.int8 if wire_ok and
                                    f in algo.wire_int8_fields else None))
                    for f in algo.vertex_fields}
        else:
            halo_tail = {f: cache[f] for f in algo.vertex_fields}
        carry = (local, loads0, key, jnp.zeros((), jnp.float32))
        (local, loads_mid, key_mid, score_mid), block_head = \
            jax.lax.scan(scan_step, carry, head_xs)

    # phase 2: boundary blocks see the synced (or cached) tail; intra-shard
    # drift continues — phase 1's updates lead the buffer
    vert = {f: jnp.concatenate([local[f], halo_tail[f]])
            if halo_tail[f].shape[0] else local[f]
            for f in algo.vertex_fields}
    carry = (vert, loads_mid, key_mid, score_mid)
    (vert, loads_end, key_end, score_sum), block_tail = \
        jax.lax.scan(scan_step, carry, tail_xs)
    block_out = {f: jnp.concatenate([block_head[f], block_tail[f]], axis=0)
                 for f in algo.block_fields}

    vert = {f: v[:local_n] for f, v in vert.items()}
    loads_end = psum_delta_merge(loads0, loads_end - loads0, axis)
    score_sum = jax.lax.psum(score_sum, axis)
    key_end = replicated_chain_key(key_end, axis)
    if hub_on:
        vert["labels"], loads_end = _hub_reconcile(
            graph, cfg.k, cap, axis, idx, vert["labels"], loads_end, local_n)
    out = {**vert, **block_out, "loads": loads_end, "key": key_end,
           "score": score_sum}
    return out, halo_tail


def _shard_superstep(algo, cfg, layout, axis, graph, cap, state, step):
    """Run the algorithm's BSP shard rule once over the (local) slabs."""
    idx = jax.lax.axis_index(axis) if axis else jnp.zeros((), jnp.int32)
    bps = layout.blocks_per_shard if axis else layout.n_blocks
    local_n = bps * layout.block_v
    ctx = ShardContext(
        axis=axis, idx=idx, n=layout.n, n_pad=layout.n_pad, local_n=local_n,
        block_v=layout.block_v, blocks=bps, v0=idx * local_n,
        blk_dst=graph["blk_dst"], blk_row=graph["blk_row"],
        blk_w=graph["blk_w"], deg=graph["deg"], inv_wsum=graph["inv_wsum"],
        vmask=graph["vmask"], step=step,
        repl={f: state[f] for f in algo.replicated_fields},
        halo_rows=graph.get("halo_rows"), send_ids=graph.get("send_ids"),
        hub_owner=graph.get("hub_owner"), hub_local=graph.get("hub_local"),
        wire_int8=bool(algo.wire_int8_fields) and cfg.k <= 127)
    local = {f: state[f] for f in algo.vertex_fields}
    upd = algo.shard_rule(cfg, ctx, local, state["loads"], cap, state["key"])
    loads = psum_delta_merge(state["loads"], upd.loads_delta, axis) if axis \
        else state["loads"] + upd.loads_delta
    score = jax.lax.psum(upd.score, axis) if axis else upd.score
    vert = dict(upd.vert)
    if "hub_owner" in graph:
        vert["labels"], loads = _hub_reconcile(
            graph, cfg.k, cap, axis, idx, vert["labels"], loads, local_n)
    return {**vert, "loads": loads, "key": upd.key, "score": score}


_BODIES = {"chunk": _chunk_superstep, "shard": _shard_superstep}


def _finish(algo, layout, state_in, out, step):
    out = dict(out)
    score_sum = out.pop("score")
    return algo.state_cls(
        **out,
        **{f: state_in[f] for f in algo.replicated_fields},
        step=step + 1,
        score=score_sum / layout.n,
    )


@partial(jax.jit, static_argnames=("algo", "cfg", "layout"),
         donate_argnames=("donated",))
def _sequential_superstep(algo, cfg, layout, graph, cap, donated, kept):
    # this body runs only while XLA traces it — i.e. exactly once per
    # jit-cache miss — so this records every (re)compile, with its static
    # shape signature for cause attribution (no-op when tracing is off)
    obs.record_compile(
        "superstep", algo=algo.name, schedule="sequential",
        n_blocks=layout.n_blocks, block_v=layout.block_v,
        e_max=int(graph["blk_dst"].shape[-1]),
        hub_pad=(int(graph["hub_owner"].shape[0])
                 if "hub_owner" in graph else None))
    state = {**donated, **kept}
    step = state.pop("step")
    state.pop("score")
    out = _BODIES[algo.kind](algo, cfg, layout, None, graph, cap, state, step)
    return _finish(algo, layout, state, out, step)


@partial(jax.jit, static_argnames=("algo", "cfg", "mesh", "layout"),
         donate_argnames=("donated",))
def _sharded_superstep(algo, cfg, mesh, layout, graph, cap, donated, kept):
    obs.record_compile(
        "superstep", algo=algo.name, schedule=cfg.chunk_schedule,
        n_shards=layout.n_blocks // layout.blocks_per_shard,
        n_blocks=layout.n_blocks, block_v=layout.block_v,
        e_max=int(graph["blk_dst"].shape[-1]),
        b_max=(int(graph["halo_rows"].shape[-1])
               if "halo_rows" in graph else None),
        h_max=(int(graph["send_ids"].shape[-1])
               if "send_ids" in graph else None),
        hub_pad=(int(graph["hub_owner"].shape[0])
                 if "hub_owner" in graph else None))
    state = {**donated, **kept}
    step = state.pop("step")
    state.pop("score")
    state_specs = {f: _state_spec(algo, f, v) for f, v in state.items()}
    out_specs = {f: state_specs[f] for f in state
                 if f not in algo.replicated_fields}
    out_specs["score"] = P()
    body = partial(_BODIES[algo.kind], algo, cfg, layout, AXIS)
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=({k: _GRAPH_SPECS[k] for k in graph}, P(), state_specs, P()),
        out_specs=out_specs,
        check_rep=False,
    )
    out = sharded(graph, cap, state, step)
    return _finish(algo, layout, state, out, step)


@partial(jax.jit,
         static_argnames=("algo", "cfg", "mesh", "layout", "split", "refresh"),
         donate_argnames=("donated",))
def _async_sharded_superstep(algo, cfg, mesh, layout, split, refresh,
                             graph, cap, donated, kept, cache):
    obs.record_compile(
        "superstep", algo=algo.name, schedule="async", refresh=bool(refresh),
        split=split,
        n_shards=layout.n_blocks // layout.blocks_per_shard,
        n_blocks=layout.n_blocks, block_v=layout.block_v,
        e_max=int(graph["blk_dst"].shape[-1]),
        b_max=(int(graph["halo_rows"].shape[-1])
               if "halo_rows" in graph else None),
        h_max=(int(graph["send_ids"].shape[-1])
               if "send_ids" in graph else None),
        hub_pad=(int(graph["hub_owner"].shape[0])
                 if "hub_owner" in graph else None))
    state = {**donated, **kept}
    step = state.pop("step")
    state.pop("score")
    state_specs = {f: _state_spec(algo, f, v) for f, v in state.items()}
    out_specs = {f: state_specs[f] for f in state
                 if f not in algo.replicated_fields}
    out_specs["score"] = P()
    # the cache is the per-shard exchanged tail: sharded over the mesh like
    # every other per-shard buffer, empty under refresh (it is rebuilt)
    cache_specs = {f: P(AXIS) for f in cache}
    tail_specs = {f: P(AXIS) for f in algo.vertex_fields}
    body = partial(_async_chunk_superstep, algo, cfg, layout, split, refresh,
                   AXIS)
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=({k: _GRAPH_SPECS[k] for k in graph}, P(), state_specs,
                  cache_specs, P()),
        out_specs=(out_specs, tail_specs),
        check_rep=False,
    )
    out, new_cache = sharded(graph, cap, state, cache, step)
    return _finish(algo, layout, state, out, step), new_cache


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def _apply_halo_plan(graph: Dict[str, jnp.ndarray], spec) -> None:
    """Swap the layout's plan arrays into the superstep's graph dict: the
    rewritten slabs, the chosen exchange plan, and — when the plan carries
    hubs — the vote slabs plus the hub-frozen vertex mask."""
    graph["blk_dst"] = spec.blk_dst_halo
    if spec.granularity == "vertex":
        graph["send_ids"] = spec.send_ids
    else:
        graph["halo_rows"] = spec.boundary_rows
    if spec.hub_owner is not None:
        graph["vmask"] = spec.vmask_nonhub
        graph["hub_owner"] = spec.hub_owner
        graph["hub_local"] = spec.hub_local
        graph["hub_deg"] = spec.hub_deg
        graph["hub_src"] = spec.hub_src
        graph["hub_slot"] = spec.hub_slot
        graph["hub_w"] = spec.hub_w


def superstep(algo: Algorithm, dg, cfg, state, halo=None):
    """One full superstep of ``algo`` under ``cfg.chunk_schedule``.

    "sequential" runs on one device (``dg`` is a plain DeviceGraph, or a
    ShardedDeviceGraph whose arrays are consumed directly); "sharded" runs
    under shard_map on the graph's ``("blocks",)`` mesh (``dg`` must be a
    ShardedDeviceGraph, see ``prepare_sharded_device_graph``); "halo" is the
    sharded schedule with the full label all-gather replaced by the
    precomputed exchange plan in ``dg.halo`` — boundary-block slabs or
    per-vertex rows per the plan's granularity, plus hub replication when
    the plan carries a hub set (``shard_device_graph(..., halo=True,
    hubs=...)``); a plan whose coverage exceeded its threshold runs the
    full gather, bit-identically.

    ``halo`` passes a 1-shard `HaloSpec` to the *sequential* schedule — the
    hub-replication oracle: the sequential scan then runs on the same
    rewritten slabs, frozen hubs, and vote reconciliation as a 1-shard halo
    run, bit-for-bit (`run_partitioner(hub_replication=True)` builds it).

    The state fields named in ``algo.donate`` are **donated** under every
    schedule (buffers updated in place); the passed-in state must not be
    reused after this call — every caller rebinds
    ``state = superstep(...)``. Small undonated leaves (key/step/score and
    any replicated fields) stay valid, so the convergence loop's windowed
    score buffering is unaffected.
    """
    if cfg.chunk_schedule == "async":
        # the always-refresh call: every superstep rebuilds its halo tail,
        # which is exactly the staleness_bound=0 (bit-identical-to-halo)
        # semantics; callers that exploit the staleness bound thread the
        # cache through async_superstep themselves (core/runner.py)
        return async_superstep(algo, dg, cfg, state)[0]
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    sd = state._asdict()
    donated = {f: sd.pop(f) for f in algo.donate}
    if cfg.chunk_schedule in ("sharded", "halo"):
        if not isinstance(dg, ShardedDeviceGraph):
            raise TypeError(
                f"chunk_schedule={cfg.chunk_schedule!r} needs a "
                "ShardedDeviceGraph (see prepare_sharded_device_graph); got "
                "a plain DeviceGraph")
        layout = _Layout(dg.n, dg.n_pad, dg.n_blocks, dg.block_v,
                         dg.blocks_per_shard)
        graph = _graph_arrays(dg.dg)
        if cfg.chunk_schedule == "halo":
            spec = dg.halo
            if spec is None:
                raise ValueError(
                    "chunk_schedule='halo' needs a halo-enabled layout: "
                    "build it with shard_device_graph(..., halo=True) / "
                    "attach_halo, or let run_partitioner build it")
            if not spec.fallback:
                _apply_halo_plan(graph, spec)
            # fallback: coverage too high for the exchange to win — run the
            # full-gather Jacobi schedule (same trajectory, bit-for-bit;
            # hub replication is off under fallback, there is no halo left)
        return _sharded_superstep(algo, cfg, dg.mesh, layout, graph, cap,
                                  donated, sd)
    if isinstance(dg, ShardedDeviceGraph):
        dg = dg.dg
    layout = _Layout(dg.n, dg.n_pad, dg.n_blocks, dg.block_v, dg.n_blocks)
    graph = _graph_arrays(dg)
    if halo is not None and halo.hub_owner is not None and not halo.fallback:
        if halo.n_shards != 1:
            raise ValueError(
                "the sequential schedule takes a 1-shard halo plan; got "
                f"n_shards={halo.n_shards}")
        _apply_halo_plan(graph, halo)
        # a 1-shard plan has no exchange tail (b_max == h_max == 0); drop
        # the empty plan arrays so only the hub machinery engages
        graph.pop("halo_rows", None)
        graph.pop("send_ids", None)
    return _sequential_superstep(algo, cfg, layout, graph, cap,
                                 donated, sd)


def async_superstep(algo: Algorithm, dg, cfg, state, cache=None):
    """One ``chunk_schedule="async"`` superstep; returns ``(state, cache)``.

    The async schedule is the halo schedule with the per-shard block scan
    split in two: the leading **interior** blocks (no remote and no
    hub-replicated references — ``dg.halo.interior_split`` of them, see
    `repro.core.halo`) scan against the shard's own slice while the halo
    exchange is still in flight; the **boundary** blocks scan after the
    sync, against the full ``local + halo + hub`` buffer. The exchanged
    tail is built from the same start-of-superstep snapshot the halo
    schedule would move, and the blocks run in the same order with the same
    loads/key/score chaining — a refreshing async superstep is
    **bit-identical** to ``chunk_schedule="halo"`` on the same layout.

    ``cache`` is the bounded-staleness knob: ``None`` (the default) forces
    a refresh — the tail is rebuilt with the plan's collectives; passing
    the cache returned by an earlier call reuses that superstep's tail
    verbatim, skipping the exchange entirely. The *policy* (how many
    supersteps a tail may be reused — ``cfg.staleness_bound``) lives in the
    caller (`core/runner.py`'s refresh closure, the streaming runner); the
    engine only distinguishes fresh from cached, so the jit cache holds
    exactly two entries per layout. Under a fallback plan (coverage too
    high) the full-gather schedule runs instead, bit-identical to the halo
    fallback, and the returned cache is ``None`` — staleness is vacuous
    when every superstep already moves everything.

    Donation matches `superstep`: the fields in ``algo.donate`` are updated
    in place; rebind both results. The cache buffers are *not* donated — a
    stale superstep returns its input cache unchanged.
    """
    if algo.kind != "chunk":
        raise ValueError(
            f"chunk_schedule='async' overlaps the interior *block scan* "
            f"with the halo exchange; {algo.name} is kind={algo.kind!r} "
            "and has no block scan (use 'sharded' or 'halo')")
    if not isinstance(dg, ShardedDeviceGraph):
        raise TypeError(
            "chunk_schedule='async' needs a ShardedDeviceGraph (see "
            "prepare_sharded_device_graph); got a plain DeviceGraph")
    spec = dg.halo
    if spec is None:
        raise ValueError(
            "chunk_schedule='async' needs a halo-enabled layout: build it "
            "with shard_device_graph(..., halo=True) / attach_halo, or let "
            "run_partitioner build it")
    cap = capacity_device(dg.m, cfg.k, cfg.epsilon, cfg.capacity_mode)
    sd = state._asdict()
    donated = {f: sd.pop(f) for f in algo.donate}
    layout = _Layout(dg.n, dg.n_pad, dg.n_blocks, dg.block_v,
                     dg.blocks_per_shard)
    graph = _graph_arrays(dg.dg)
    if spec.fallback:
        # coverage too high for any exchange to win: run the full-gather
        # Jacobi schedule, exactly like the halo schedule's fallback
        return (_sharded_superstep(algo, cfg, dg.mesh, layout, graph, cap,
                                   donated, sd), None)
    _apply_halo_plan(graph, spec)
    refresh = cache is None
    return _async_sharded_superstep(
        algo, cfg, dg.mesh, layout, spec.interior_split, refresh,
        graph, cap, donated, sd, {} if refresh else cache)


def place_state(algo: Algorithm, state, sdg: ShardedDeviceGraph):
    """Commit a freshly-initialized state to the sharded layout per the
    algorithm's declared specs: vertex fields sliced onto their owning
    device, block tensors likewise, everything else replicated — so the
    donated superstep buffers are reused in place from step one."""
    mesh = sdg.mesh
    placed = {
        name: jax.device_put(
            value, NamedSharding(mesh, _state_spec(algo, name, value)))
        for name, value in state._asdict().items()
    }
    return algo.state_cls(**placed)


def state_shardings(algo: Algorithm, state, mesh):
    """`NamedSharding`s for every state field per the algorithm's declared
    specs — the elastic-restore companion of `place_state`: hand them to
    `repro.checkpoint.restore_checkpoint(shardings=)` and a checkpoint
    lands directly on the current mesh, whatever mesh wrote it. Accepts a
    state NamedTuple (or pytree dict) of arrays or ShapeDtypeStructs and
    returns the matching structure of shardings."""
    items = (state._asdict() if hasattr(state, "_asdict") else state).items()
    made = {name: NamedSharding(mesh, _state_spec(algo, name, value))
            for name, value in items}
    return algo.state_cls(**made) if hasattr(state, "_asdict") else made


# ---------------------------------------------------------------------------
# shared warm-start helpers (every rule's init_from_labels uses these)
# ---------------------------------------------------------------------------
def warm_labels(dg, k: int, key: jax.Array, labels) -> jnp.ndarray:
    """Carried labels for surviving vertices, random draws for new ones.

    ``labels`` covers up to ``len(labels)`` surviving vertices **in original
    vertex order** (clipped to [0, k)); vertices beyond it — newly arrived
    in a stream — draw a random label exactly like a cold init would. On a
    locality-permuted layout the carried slice is scattered to each
    vertex's storage position (``dg.o2s``); the unpermuted path is the
    original contiguous splice, bit-for-bit.
    """
    lab = jax.random.randint(key, (dg.n_pad,), 0, k, dtype=jnp.int32)
    carried = jnp.clip(jnp.asarray(labels, jnp.int32), 0, k - 1)
    m_keep = min(int(carried.shape[0]), dg.n_pad)
    o2s = getattr(dg, "o2s", None)
    if o2s is None:
        lab = jax.lax.dynamic_update_slice(lab, carried[:m_keep], (0,))
    else:
        lab = lab.at[jnp.asarray(o2s[:m_keep])].set(carried[:m_keep])
    return jnp.where(dg.vmask, lab, 0)


def loads_from_labels(dg, k: int, labels) -> jnp.ndarray:
    """Recompute b(l) from the degree vector so the invariant
    b(l) == sum deg over labels==l holds from step 0."""
    return jnp.zeros((k,), jnp.float32).at[labels].add(dg.deg_out)


__all__ = [
    "AXIS",
    "Algorithm",
    "ChunkContext",
    "ChunkUpdate",
    "ShardContext",
    "ShardUpdate",
    "halo_exchange",
    "superstep",
    "async_superstep",
    "place_state",
    "state_shardings",
    "warm_labels",
    "loads_from_labels",
]
