"""Learning-automata update rules.

`classic_la_update` implements the textbook variable-structure LA (eqs. 6/7):
one action is rewarded or penalized per step.

`weighted_la_update` implements the paper's contribution (eqs. 8/9): the
reinforcement is distributed over *all* m actions through a weight vector W
(sum(W)=2: the reward half and the penalty half each sum to 1). As stated in
Section IV-A, the update is executed m times — pass i applies eq. (8) if
r_i = 0 (reward) or eq. (9) if r_i = 1 (penalty), each pass touching all m
probabilities — m^2 elementary updates in total.

These are the pure-jnp reference implementations; `repro.kernels.la_update`
provides the Pallas TPU kernel with identical semantics (VMEM-resident
probability tile across the m passes).

Note on the simplex: eqs. (8)/(9) only keep sum(p)=1 approximately (the
paper's half-normalization argument is not exact). With `renorm=True`
(default) we project back to the simplex after the m passes; the drift is
measured in tests/test_la.py and stays below ~1e-3 per superstep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def classic_la_update(
    p: jax.Array, action: jax.Array, penalty: jax.Array, alpha: float, beta: float
) -> jax.Array:
    """Eqs. (6)/(7). p: [..., m]; action: [...] int; penalty: [...] {0,1}."""
    m = p.shape[-1]
    onehot = jax.nn.one_hot(action, m, dtype=p.dtype)
    # reward (r=0): p_i += alpha (1-p_i); p_j *= (1-alpha)
    p_rew = jnp.where(onehot > 0, p + alpha * (1.0 - p), p * (1.0 - alpha))
    # penalty (r=1): p_i *= (1-beta); p_j = p_j (1-beta) + beta/(m-1)
    p_pen = jnp.where(onehot > 0, p * (1.0 - beta), p * (1.0 - beta) + beta / (m - 1))
    return jnp.where(penalty[..., None] > 0, p_pen, p_rew)


def weighted_la_update(
    p: jax.Array,
    w: jax.Array,
    r: jax.Array,
    alpha: float,
    beta: float,
    *,
    renorm: bool = True,
    pass_order: str = "penalty_first",
) -> jax.Array:
    """Eqs. (8)/(9), executed as m sequential passes (pass i keyed by r_i).

    Pass order disambiguation (DESIGN.md §10): the paper does not specify the
    order of the m passes. With the paper's alpha=1, running reward passes
    before penalty passes caps max(p) at ~(1-beta)^n_pen each step — the
    automaton provably can never become decisive and Revolver cannot reach
    the paper's reported local-edges. We therefore default to
    "penalty_first" (penalty passes, then reward passes), which converges;
    "ascending" (index order, per the literal reading) is kept for the
    ablation in tests/test_la.py.

    Args:
      p: [..., m] probability vectors (rows on the simplex).
      w: [..., m] weight vector; reward half sums to 1, penalty half sums to 1.
      r: [..., m] reinforcement signals; 0 = reward, 1 = penalty.
      alpha, beta: reward / penalty learning rates (paper: 1.0 / 0.1).
      renorm: project back onto the simplex after the passes.
      pass_order: "penalty_first" | "ascending".

    Returns:
      Updated [..., m] probability vectors.
    """
    m = p.shape[-1]
    iota = jnp.arange(m)

    if pass_order == "penalty_first":
        # per-row pass schedule: penalties (r=1) first, rewards (r=0) last,
        # stable within each class. argsort(-r) is descending-r stable.
        order = jnp.argsort(-r, axis=-1, stable=True)
    elif pass_order == "ascending":
        order = jnp.broadcast_to(iota, r.shape)
    else:
        raise ValueError(f"unknown pass_order {pass_order!r}")

    def pass_t(t, p):
        i = jnp.take(order, t, axis=-1)              # [...] per-row action id
        mask = iota == i[..., None]                  # [..., m] one-hot
        w_i = jnp.sum(jnp.where(mask, w, 0.0), axis=-1, keepdims=True)
        # eq. (8): reward pass for action i
        p_rew = jnp.where(mask, p + alpha * w * (1.0 - p), p * (1.0 - alpha * w))
        # eq. (9): penalty pass for action i; the redistribution floor is
        # scaled by the recipient's weight ("reinforcement proportional to
        # w" — see module docstring / DESIGN.md §10)
        floor = beta * w / (m - 1)
        p_pen = jnp.where(mask, p * (1.0 - beta * w), p * (1.0 - beta * w) + floor)
        is_pen = jnp.sum(jnp.where(mask, r, 0.0), axis=-1, keepdims=True) > 0
        p_new = jnp.where(is_pen, p_pen, p_rew)
        # a slot with zero weight carries no reinforcement signal: skip pass
        return jnp.where(w_i > 0, p_new, p)

    p = jax.lax.fori_loop(0, m, pass_t, p)
    if renorm:
        p = jnp.clip(p, _EPS, 1.0)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p


def split_weights_and_signals(w_raw: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Step 6 of Section IV-D: mean-split W into reward/penalty halves and
    normalize each half to sum to 1 (so sum(W)=2 as eqs. (8)/(9) require).

    Args:
      w_raw: [..., m] non-negative accumulated weights (eq. 13 histogram).

    Returns:
      (w_norm, r): normalized weights and reinforcement signals
      (r=0 reward where w_i > mean(W), r=1 penalty otherwise).
    """
    mean = jnp.mean(w_raw, axis=-1, keepdims=True)
    r = (w_raw <= mean).astype(w_raw.dtype)  # 1 = penalty
    rew_mask = 1.0 - r
    rew_sum = jnp.sum(w_raw * rew_mask, axis=-1, keepdims=True)
    pen_sum = jnp.sum(w_raw * r, axis=-1, keepdims=True)
    # A half whose accumulated weight is zero carries no reinforcement
    # signal: its slots keep w=0 and their passes are skipped by
    # weighted_la_update (a zero-signal slot must not perturb the simplex;
    # see module docstring). Nonzero halves are normalized to sum to 1.
    w_rew = jnp.where(rew_sum > 0, w_raw / jnp.where(rew_sum > 0, rew_sum, 1.0), 0.0)
    w_pen = jnp.where(pen_sum > 0, w_raw / jnp.where(pen_sum > 0, pen_sum, 1.0), 0.0)
    w_norm = jnp.where(r > 0, w_pen, w_rew)
    return w_norm.astype(w_raw.dtype), r
