"""Multilevel V-cycle: coarsen -> partition the coarsest -> uncoarsen.

METIS-style multilevel partitioning (Sanders & Seemaier, *Distributed
Unconstrained Local Search for Multilevel Graph Partitioning*) mapped onto
the engine's existing machinery — ``run_partitioner(mode="vcycle")`` lands
here:

  1. **Coarsen** (`build_level_stack`): repeated heavy-edge matching +
     contraction (`repro.graphs.csr.heavy_edge_matching` /
     `contract_graph`) down to a ``coarse_n``-vertex graph. Every level
     keeps the fine graph's balance semantics exactly — aggregated vertex
     weights with ``m`` pinned to the fine edge count, so the engine's
     capacity ``C = (1+eps)|E|/k`` prices coarse loads in fine-edge units
     and a balanced coarse partition *is* a balanced fine partition.
  2. **Coarse solve**: any registered superstep rule (revolver / spinner /
     restream) runs to score-stall convergence on the coarsest graph —
     cheap, it is 10–100x smaller than the input.
  3. **Uncoarsen**: labels project through each level's fine->coarse vertex
     map and refine with the engine's ``init_from_labels`` warm start under
     a shrinking superstep budget (the finest level is capped at
     ``level_decay * max_steps``, intermediate levels interpolate up to the
     coarsest's full budget — see `level_budgets`). For
     probs-carrying rules the carried labels are sharpened into LA
     confidence (``vcycle_sharpen``, see `revolver_init_from_labels`) so
     refinement spends its steps on genuinely contested vertices instead of
     re-exploring settled ones.

Only the finest level runs under the caller's schedule / mesh / assignment;
coarse levels always run the sequential schedule (they are too small to
amortize a shard_map launch). Checkpointing, resume, and state guards are
flat-mode features — the V-cycle's per-level runs are short; checkpoint the
fine-level refinement by running it flat from ``init_labels`` if you need
crash safety around a V-cycle.

Observability: one ``coarsen`` span around the stack build, one
``coarse-solve`` span, one ``uncoarsen-level-i`` span per projection+refine,
and a ``level_n_vertices`` counter series indexed by level (0 = finest);
each per-level `run_partitioner` call appends its own run manifest, so
`tools/trace_report.py --validate` holds for V-cycle traces unchanged.
"""
from __future__ import annotations

import logging
from typing import List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.registry import get_algorithm
from repro.graphs.csr import Graph, contract_graph, heavy_edge_matching

_log = logging.getLogger("repro.core.multilevel")

DEFAULT_COARSE_N = 512
DEFAULT_LEVEL_DECAY = 0.12
DEFAULT_VCYCLE_SHARPEN = 0.8

# stop coarsening when a matching pass shrinks the level by less than this
# factor — degenerate families (stars, already-tiny graphs) would otherwise
# stack near-identical levels
_REDUCTION_STALL = 0.95
_MAX_LEVELS = 32


def build_level_stack(
    g: Graph, coarse_n: int, max_levels: int = _MAX_LEVELS,
) -> Tuple[List[Graph], List[np.ndarray]]:
    """Coarsen `g` by repeated heavy-edge matching down to ``coarse_n``.

    Returns ``(graphs, cmaps)`` with ``graphs[0] is g`` (finest first) and
    ``cmaps[i]`` mapping level-``i`` vertices to level-``i+1`` vertices, so
    ``len(cmaps) == len(graphs) - 1``. Stops early when a matching pass
    fails to shrink the level by at least ``1 - _REDUCTION_STALL`` (the
    degenerate 1-level case: the stack is just ``[g]``).
    """
    if coarse_n < 1:
        raise ValueError(f"coarse_n must be >= 1, got {coarse_n}")
    graphs: List[Graph] = [g]
    cmaps: List[np.ndarray] = []
    while graphs[-1].n > coarse_n and len(graphs) <= max_levels:
        cur = graphs[-1]
        cmap, n_coarse = heavy_edge_matching(cur)
        if n_coarse > cur.n * _REDUCTION_STALL:
            _log.info(
                "coarsening stalled at level %d (%d -> %d vertices); "
                "keeping a %d-level stack",
                len(graphs) - 1, cur.n, n_coarse, len(graphs))
            break
        coarse, _ = contract_graph(cur, cmap, n_coarse)
        graphs.append(coarse)
        cmaps.append(cmap)
    return graphs, cmaps


def level_budgets(max_steps: int, n_levels: int, level_decay: float,
                  patience: int) -> List[int]:
    """Per-level superstep caps, finest first.

    The coarsest level gets the full ``max_steps`` (its supersteps are
    cheap and it runs from a cold start); the finest gets
    ``level_decay * max_steps`` — the cap the bench's 0.5x-of-flat gate
    leans on, deliberately *independent of stack depth* so a deeper stack
    cannot inflate the fine-level budget. Intermediate levels interpolate
    geometrically between the two endpoints. Every cap is floored at
    ``patience + 3`` so the score-stall halt can still fire; warm-started
    refinement normally stalls well before the cap.
    """
    if n_levels == 1:
        return [max_steps]
    span = n_levels - 1
    budgets = [max(patience + 3,
                   int(round(max_steps * level_decay ** ((span - i) / span))))
               for i in range(n_levels)]
    budgets[-1] = max_steps
    return budgets


def run_vcycle(
    algo: str,
    graph: Graph,
    k: int,
    *,
    seed: int = 0,
    n_blocks: int = 8,
    max_steps: Optional[int] = None,
    track_history: bool = True,
    mesh=None,
    assignment="contiguous",
    halo_threshold: Optional[float] = None,
    halo_granularity: str = "auto",
    hub_replication: bool = False,
    hub_quantile: float = 0.0,
    hub_target_coverage: Optional[float] = None,
    sync_every: int = 1,
    keep_probs: bool = False,
    trace=None,
    coarse_n: Optional[int] = None,
    level_decay: Optional[float] = None,
    vcycle_sharpen: Optional[float] = None,
    cfg_kwargs: Optional[dict] = None,
):
    """Drive one V-cycle. Called by ``run_partitioner(mode="vcycle")``;
    returns the finest level's `PartitionResult` (its ``steps`` are the
    fine-level supersteps — the quantity the bench gate caps at 0.5x of
    flat refinement)."""
    from repro.core import runner  # lazy: runner imports us the same way

    cfg_kwargs = dict(cfg_kwargs or {})
    coarse_n = DEFAULT_COARSE_N if coarse_n is None else int(coarse_n)
    level_decay = (DEFAULT_LEVEL_DECAY if level_decay is None
                   else float(level_decay))
    vcycle_sharpen = (DEFAULT_VCYCLE_SHARPEN if vcycle_sharpen is None
                      else float(vcycle_sharpen))
    if coarse_n < k:
        raise ValueError(
            f"coarse_n={coarse_n} < k={k}: the coarsest graph could not "
            "hold one vertex per partition")
    if not 0.0 < level_decay <= 1.0:
        raise ValueError(
            f"level_decay must be in (0, 1], got {level_decay}")
    if not 0.0 <= vcycle_sharpen < 1.0:
        raise ValueError(
            f"vcycle_sharpen must be in [0, 1), got {vcycle_sharpen}")
    algorithm = get_algorithm(algo)
    if algorithm.init_from_labels is None:
        raise TypeError(
            f"{algo!r} does not support warm starts; mode='vcycle' refines "
            "projected labels through init_from_labels")
    tracer = trace if trace is not None else obs.NULL_TRACER

    # schedule/mesh knobs apply to the finest level only; coarse levels are
    # too small to amortize a shard_map launch and always run sequential
    fine_kwargs = dict(cfg_kwargs)
    coarse_cfg = dict(cfg_kwargs)
    coarse_cfg.pop("chunk_schedule", None)
    cfg = runner._make_cfg(algorithm.config_cls, k, max_steps, fine_kwargs)
    budget_base = cfg.max_steps
    patience = cfg.patience

    with tracer.span("coarsen", coarse_n=coarse_n, n=graph.n):
        graphs, cmaps = build_level_stack(graph, coarse_n)
    n_levels = len(graphs)
    if tracer.enabled:
        for lvl, g in enumerate(graphs):
            tracer.counter("level_n_vertices", g.n, step=lvl)

    fine_run_kwargs = dict(
        n_blocks=n_blocks, track_history=track_history, mesh=mesh,
        assignment=assignment, halo_granularity=halo_granularity,
        hub_replication=hub_replication, hub_quantile=hub_quantile,
        hub_target_coverage=hub_target_coverage, sync_every=sync_every,
        keep_probs=keep_probs, trace=trace)
    if halo_threshold is not None:
        fine_run_kwargs["halo_threshold"] = halo_threshold

    if n_levels == 1:
        # degenerate stack (graph already at/below coarse_n, or matching
        # stalled immediately): a V-cycle is just the flat run
        _log.info("graph has %d vertices (<= coarse_n=%d or matching "
                  "stalled); running flat", graph.n, coarse_n)
        return runner.run_partitioner(
            algo, graph, k, seed=seed, max_steps=budget_base,
            **fine_run_kwargs, **cfg_kwargs)

    budgets = level_budgets(budget_base, n_levels, level_decay, patience)
    steps_per_level = [0] * n_levels

    with tracer.span("coarse-solve", level=n_levels - 1, n=graphs[-1].n,
                     budget=budgets[-1]):
        res = runner.run_partitioner(
            algo, graphs[-1], k, seed=seed, max_steps=budgets[-1],
            n_blocks=n_blocks, track_history=False, sync_every=sync_every,
            trace=trace, **coarse_cfg)
    steps_per_level[-1] = res.steps

    for lvl in range(n_levels - 2, -1, -1):
        fine = lvl == 0
        projected = np.asarray(res.labels)[cmaps[lvl]]
        sharpen = vcycle_sharpen if algorithm.supports_probs else 0.0
        with tracer.span(f"uncoarsen-level-{lvl}", n=graphs[lvl].n,
                         budget=budgets[lvl]):
            if fine:
                res = runner.run_partitioner(
                    algo, graphs[lvl], k, seed=seed, max_steps=budgets[lvl],
                    init_labels=projected, init_sharpen=sharpen,
                    **fine_run_kwargs, **cfg_kwargs)
            else:
                res = runner.run_partitioner(
                    algo, graphs[lvl], k, seed=seed, max_steps=budgets[lvl],
                    n_blocks=n_blocks, track_history=False,
                    sync_every=sync_every, trace=trace,
                    init_labels=projected, init_sharpen=sharpen,
                    **coarse_cfg)
        steps_per_level[lvl] = res.steps

    if tracer.enabled:
        tracer.meta.setdefault("vcycle", []).append({
            "algo": algo, "k": k,
            "level_n_vertices": [g.n for g in graphs],
            "budgets": budgets,
            "steps_per_level": steps_per_level,
        })
    return res
