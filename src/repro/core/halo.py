"""Static halo-exchange plan for the sharded superstep.

The full-gather Jacobi schedule (``chunk_schedule="sharded"``) all-gathers
every per-vertex state field once per superstep — O(n_pad) cross-device
traffic regardless of how local the partition's block->shard assignment is.
But the set of *remote* vertices a shard's edge slabs actually reference is
static (it depends only on the graph layout, not on labels), so the sync can
be precomputed. Three exchange granularities exist, picked per layout:

**Block halo** (the PR-5 plan): each shard contributes only its **boundary
blocks** (blocks some other shard references) to one all-gather of shape
``[b_max, block_v]`` per field, and every slab's neighbor ids are rewritten
host-side to index the shard's assembled ``local + halo`` buffer directly.
Traffic per superstep per field drops from ``(S-1) * blocks_per_shard *
block_v`` to ``(S-1) * b_max * block_v`` elements per device — proportional
to the block-level edge cut, i.e. to partition quality.

**Per-vertex halo** (``granularity="vertex"``): the remote need set is
resolved to individual vertices. ``send_ids[s, t]`` lists the local rows
shard ``s`` sends to shard ``t`` (the transpose of ``t``'s need list),
padded to a common ragged bound ``h_max``; one ``all_to_all`` moves exactly
those rows (``parallel.collectives.vertex_halo_exchange``). Traffic is
``(S-1) * h_max`` elements per field — on power-law graphs where one hot
boundary block inflates ``b_max`` to the whole shard, the per-vertex plan
still moves only the rows actually read. ``granularity="auto"`` (the
default) picks whichever plan moves fewer elements (ties prefer the block
plan, preserving the PR-5 layouts bit-for-bit).

**Hub replication** (``hubs=HubConfig(...)``): the top-H "hub" vertices —
the handful of high-degree vertices that make *every* block a boundary
block on WIKI/LJ-style graphs — are excluded from the halo need sets
entirely and instead mirrored into a replicated region appended to every
shard's buffer. Each superstep assembles the region with one O(hub_pad)
psum from the owners' slices (exact: one contributor per slot), and after
the scan a per-superstep psum over weighted one-hot label **votes**
(O(hub_pad * k), never O(E)) reconciles each hub to a single winner label
with a deterministic capacity-gated argmax (ties break to the lowest
partition index). Hubs are frozen during the scan (``vmask_nonhub``), so
every shard reads a consistent snapshot; see ``engine._hub_reconcile``.

Exactness: without hubs, both halo granularities deliver the same
start-of-superstep snapshots of remote vertices that the full gather would,
and the shard's own (drifting) slice sits at the front of the buffer, so a
chunk rule sees bit-identical values through the rewritten indices —
``"halo"`` is an exact optimization of ``"sharded"``'s sync, gated
bit-for-bit by tests and the scaling bench. With hubs on, the vote
reconciliation is itself exact arithmetic, so the 1-shard hub plan matches
the sequential hub plan bit-for-bit, but multi-shard hub runs follow a
different (better-scaling) trajectory than hub-less runs and are gated on
converged quality/balance instead (see docs/observability.md).

When the chosen exchange would move nearly as much as the plain all-gather
(``coverage`` at or above ``threshold``) the spec records ``fallback=True``
and the engine runs the full-gather schedule instead (hub replication is
disabled too — there is no halo left to shrink).

**Interior/boundary split** (``chunk_schedule="async"``): the slab rewrite
also classifies every block. A block whose rewritten neighbor ids all fall
inside the shard's own slice (``< local_n``) is *interior* — it reads no
exchanged and no hub-replicated vertex, so the async schedule can scan it
while the halo exchange for this superstep is still in flight. Every other
block is *boundary* (it reads the tail or the hub region) and must wait for
the exchange. ``interior_split`` is the number of leading interior blocks
common to every shard — the static phase-1 scan length of the async
superstep (`engine.async_superstep`); `interior_first_order` returns the
intra-shard reorder that maximizes it. The classification is derived from
``mapped`` (the same array the rewrite ships), so the split invariants are
structural, pinned by the property suite in ``tests/test_async.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_HALO_THRESHOLD = 0.75
DEFAULT_HUB_MAX_FRAC = 0.05


@dataclasses.dataclass(frozen=True)
class HubConfig:
    """Hub-replication knobs (Spinner-style high-degree mirroring).

    ``quantile > 0`` selects every real vertex at or above that outdegree
    quantile (deterministic and shard-count independent, so a 1-shard run
    replicates the same hubs as the sequential reference). ``quantile == 0``
    (the default) sizes the set automatically: H doubles from 1 until the
    per-vertex halo coverage *excluding* hubs drops below
    ``target_coverage`` (default: the plan's fallback ``threshold`` capped
    at `DEFAULT_HALO_THRESHOLD`, so threshold > 1 "never fall back" plans
    still grow a useful hub set),
    ranking candidates by how many remote shards reference them (ties by
    degree, then id). Either way the set is capped at ``max_frac`` of the
    real vertices — replicas cost O(hub_pad * (fields + k)) psum traffic
    per superstep, so the cap keeps the cure cheaper than the disease.
    """

    quantile: float = 0.0
    target_coverage: Optional[float] = None
    max_frac: float = DEFAULT_HUB_MAX_FRAC

    def __post_init__(self):
        if not 0.0 <= self.quantile < 1.0:
            raise ValueError(f"quantile must be in [0, 1), got {self.quantile}")
        if not 0.0 < self.max_frac <= 1.0:
            raise ValueError(f"max_frac must be in (0, 1], got {self.max_frac}")


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Precomputed halo-exchange plan for one (layout, n_shards) pair.

    Built host-side by `build_halo_spec`; consumed by the engine's halo
    superstep. All ids are in the layout's storage space (i.e. *after* any
    locality permutation — see `device_graph.permute_blocks`).
    """

    n_shards: int
    blocks_per_shard: int
    block_v: int
    b_max: int              # padded boundary-block count per shard
    coverage: float         # chosen exchange elems / full-gather elems
    threshold: float        # fallback trigger the spec was built with
    fallback: bool          # True -> engine runs the full-gather schedule
    halo_blocks: Tuple[int, ...]      # per shard: #remote blocks it references
    boundary_blocks: Tuple[int, ...]  # per shard: #own blocks others reference
    boundary_rows: jax.Array          # [S, b_max] int32 local block index
                                      # within the owner (0-padded)
    blk_dst_halo: Optional[jax.Array]  # [n_blocks, e_max] int32 neighbor ids
                                       # rewritten into local+halo buffer space
                                       # (None when fallback)
    # --- per-vertex (sub-block) exchange plan ---------------------------- #
    granularity: str = "block"         # chosen: "block" | "vertex"
    h_max: int = 0                     # padded per-pair need-list length
    send_ids: Optional[jax.Array] = None  # [S, S, h_max] int32 local rows
                                          # shard s sends to shard t
                                          # (vertex granularity only)
    # --- hub replication plan -------------------------------------------- #
    n_hubs: int = 0
    hub_pad: int = 0                   # replicated-region length (>= n_hubs)
    hub_ids: Tuple[int, ...] = ()      # storage vertex ids, ascending
    hub_owner: Optional[jax.Array] = None  # [hub_pad] int32 owner shard (-1 pad)
    hub_local: Optional[jax.Array] = None  # [hub_pad] int32 local row in owner
    hub_deg: Optional[jax.Array] = None    # [hub_pad] f32 outdegree (0 pad)
    he_max: int = 0                    # padded per-shard hub-edge count
    hub_src: Optional[jax.Array] = None    # [S, he_max] int32 local src row
    hub_slot: Optional[jax.Array] = None   # [S, he_max] int32 hub slot
    hub_w: Optional[jax.Array] = None      # [S, he_max] f32 vote weight (0 pad)
    vmask_nonhub: Optional[jax.Array] = None  # [n_pad] bool vmask minus hubs
    # --- interior/boundary split (chunk_schedule="async") ----------------- #
    block_is_boundary: Tuple[bool, ...] = ()  # [n_blocks] True iff the block
                                              # reads the exchanged tail or
                                              # the replicated hub region
                                              # (empty when fallback)
    interior_counts: Tuple[int, ...] = ()     # per shard: #interior blocks
    interior_split: int = 0                   # leading interior blocks common
                                              # to every shard — the async
                                              # schedule's phase-1 scan length

    @property
    def local_n(self) -> int:
        return self.blocks_per_shard * self.block_v

    @property
    def exchange_len(self) -> int:
        """Length of the exchanged tail appended to the shard's own slice."""
        if self.granularity == "vertex":
            return self.n_shards * self.h_max
        return self.n_shards * self.b_max * self.block_v

    @property
    def buf_len(self) -> int:
        """Length of the per-shard drifting buffer: the shard's own slice,
        the exchanged halo tail, then the replicated hub region."""
        return self.local_n + self.exchange_len + self.hub_pad

    @property
    def decision(self) -> str:
        """What the engine actually runs: "full-gather" | "block-halo" |
        "per-vertex" (the satellite observability knob for BENCH_scaling)."""
        if self.fallback:
            return "full-gather"
        return "per-vertex" if self.granularity == "vertex" else "block-halo"

    def gathered_elems_per_device(self) -> int:
        """Per-vertex-field elements a device receives per superstep (the
        chosen halo exchange if active, the full gather under fallback)."""
        if self.fallback:
            return self.full_gather_elems_per_device()
        if self.granularity == "vertex":
            return (self.n_shards - 1) * self.h_max
        return (self.n_shards - 1) * self.b_max * self.block_v

    def full_gather_elems_per_device(self) -> int:
        return (self.n_shards - 1) * self.blocks_per_shard * self.block_v

    def wire_bytes_per_elem(self, k: int, int8_field: bool = True) -> int:
        """Wire width of one exchanged element. The per-vertex tail moves
        label-valued fields (``Algorithm.wire_int8_fields``) on an int8
        wire when every value fits (``k <= 127``) — exact, 4x narrower;
        the block exchange and the full gather move storage-width int32."""
        if (self.granularity == "vertex" and not self.fallback
                and int8_field and k <= 127):
            return 1
        return 4

    def hub_sync_elems_per_device(self, k: int, n_fields: int) -> int:
        """Elements per device per superstep spent on hub replication: one
        [hub_pad] assembly psum per synchronized field, one [hub_pad]
        current-label psum, and the [hub_pad, k] vote psum. Honest traffic
        accounting — the bench counts this against the halo's reduction."""
        if self.hub_pad == 0 or self.fallback:
            return 0
        return self.hub_pad * (n_fields + 1 + k)


def _select_hubs(
    cfg: HubConfig,
    *,
    deg: np.ndarray,
    vmask: np.ndarray,
    need_count: np.ndarray,
    pair_lists: Sequence[np.ndarray],
    local_n: int,
    floor_ids: np.ndarray,
    threshold: float,
) -> np.ndarray:
    """Pick the hub id set (ascending, floor ids always included)."""
    n_pad = deg.shape[0]
    is_floor = np.zeros(n_pad, dtype=bool)
    is_floor[floor_ids] = True
    n_real = int(np.count_nonzero(vmask))
    cap = max(int(cfg.max_frac * n_real), 1)

    if cfg.quantile > 0.0:
        cand = np.flatnonzero(vmask & (deg > 0) & ~is_floor)
        selected = np.empty(0, dtype=np.int64)
        if cand.size:
            thr = np.quantile(deg[cand], cfg.quantile)
            sel = cand[deg[cand] >= thr]
            # highest degree first, ties by id; cap applies to new picks only
            sel = sel[np.lexsort((sel, -deg[sel]))]
            selected = sel[:cap].astype(np.int64)
        return np.unique(np.concatenate([floor_ids, selected]))

    # auto: rank remote-referenced vertices by (#needing shards, degree, id)
    eligible = np.flatnonzero((need_count > 0) & vmask & ~is_floor)
    ranked = eligible[np.lexsort(
        (eligible, -deg[eligible], -need_count[eligible]))]
    rank_of = np.full(n_pad, np.iinfo(np.int64).max, dtype=np.int64)
    rank_of[ranked] = np.arange(ranked.size)
    rank_of[floor_ids] = -1         # floor hubs are always excluded
    pair_ranks = [np.sort(rank_of[ids]) for ids in pair_lists]

    def hmax_at(h: int) -> int:
        m = 0
        for pr in pair_ranks:
            m = max(m, int(pr.size - np.searchsorted(pr, h)))
        return m

    # The bench convention sets threshold > 1 to pin the halo schedule on
    # (never fall back); a coverage *goal* above 1 would make hub selection
    # a no-op exactly where hubs matter, so the auto target caps at the
    # default fallback threshold.
    target = cfg.target_coverage if cfg.target_coverage is not None \
        else min(threshold, DEFAULT_HALO_THRESHOLD)
    H = 0
    if local_n > 0 and hmax_at(0) / local_n >= target:
        H = 1
        while H < cap and hmax_at(H) / local_n >= target:
            H *= 2
    H = min(H, cap, ranked.size)
    return np.unique(np.concatenate([floor_ids, ranked[:H].astype(np.int64)]))


def build_halo_spec(
    blk_dst: np.ndarray,
    blk_w: np.ndarray,
    n_shards: int,
    block_v: int,
    *,
    threshold: float = DEFAULT_HALO_THRESHOLD,
    granularity: str = "auto",
    b_max_floor: int = 0,
    h_max_floor: int = 0,
    hubs: Optional[HubConfig] = None,
    deg: Optional[np.ndarray] = None,
    vmask: Optional[np.ndarray] = None,
    blk_row: Optional[np.ndarray] = None,
    hub_ids_floor: Sequence[int] = (),
    hub_pad_floor: int = 0,
    he_max_floor: int = 0,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> HaloSpec:
    """Compute the static halo sets and the buffer-space slab rewrite.

    `blk_dst` / `blk_w` are the (host) padded edge slabs in storage order;
    shard s owns the contiguous block range [s*bps, (s+1)*bps). Padding
    slots (w == 0) are ignored for set membership and their rewritten index
    is clamped to 0 — they are only ever read under a zero weight.

    `granularity` selects the exchange plan ("auto" | "block" | "vertex",
    see module docstring); `hubs` enables hub replication, which needs the
    per-vertex `deg` / `vmask` arrays and the `blk_row` slabs (to build the
    vote tables). The `*_floor` arguments let streaming callers keep the
    exchange shapes and hub set stable while halo sets evolve (growth past
    a floor recompiles, like a slab re-pad; the hub set only ever grows —
    `hub_ids_floor` carries the previous deltas' hubs). `mesh` commits the
    plan's device arrays (replicated plan vectors, block-sharded slabs) so
    the jitted superstep reuses them without per-call transfers.
    """
    blk_dst = np.asarray(blk_dst)
    blk_w = np.asarray(blk_w)
    nb, e_max = blk_dst.shape
    if nb % n_shards != 0:
        raise ValueError(f"n_blocks={nb} not divisible by n_shards={n_shards}")
    if granularity not in ("auto", "block", "vertex"):
        raise ValueError(
            f"granularity must be 'auto' | 'block' | 'vertex', "
            f"got {granularity!r}")
    bps = nb // n_shards
    local_n = bps * block_v
    n_pad = nb * block_v
    owner = np.arange(nb, dtype=np.int64) // bps      # shard of each slab row
    row_owner = np.broadcast_to(owner[:, None], (nb, e_max))
    dst = blk_dst.astype(np.int64)
    dst_blk = dst // block_v
    dst_owner = dst_blk // bps
    real = blk_w > 0
    remote = dst_owner != row_owner

    # ---- hub selection (from the raw remote-reference structure) -------- #
    floor_ids = np.unique(np.asarray(sorted(int(h) for h in hub_ids_floor),
                                     dtype=np.int64))
    hub_ids = floor_ids
    # unique (needer shard, vertex) remote-reference pairs; sorted by
    # (needer, vertex), so per-(needer, owner) runs are contiguous
    rmask = real & remote
    pair_keys = np.unique(
        row_owner[rmask].astype(np.int64) * n_pad + dst[rmask])
    pair_needer = pair_keys // n_pad
    pair_vertex = pair_keys % n_pad
    if hubs is not None:
        if deg is None or vmask is None:
            raise ValueError("hub replication needs deg= and vmask= arrays")
        if blk_row is None:
            raise ValueError("hub replication needs the blk_row= slabs")
        deg = np.asarray(deg, dtype=np.float32)
        vmask = np.asarray(vmask, dtype=bool)
        need_count = np.bincount(pair_vertex, minlength=n_pad)
        pair_owner = pair_vertex // local_n
        pair_group = pair_needer * n_shards + pair_owner
        pair_lists = [pair_vertex[pair_group == gid]
                      for gid in np.unique(pair_group)]
        hub_ids = _select_hubs(
            hubs, deg=deg, vmask=vmask, need_count=need_count,
            pair_lists=pair_lists, local_n=local_n, floor_ids=floor_ids,
            threshold=threshold)
    n_hubs = int(hub_ids.size)
    hub_pad = max(n_hubs, hub_pad_floor)
    is_hub = np.zeros(n_pad, dtype=bool)
    is_hub[hub_ids] = True
    slot_of = np.full(n_pad, -1, dtype=np.int64)
    slot_of[hub_ids] = np.arange(n_hubs)
    hub_ref = is_hub[dst]            # [nb, e_max] slab slots served by hubs
    ref_ok = real & ~hub_ref         # slots the halo exchange must cover

    # ---- block-granularity sets (hub refs excluded) --------------------- #
    need = [set() for _ in range(n_shards)]
    for b in range(nb):
        refs = np.unique(dst_blk[b][ref_ok[b]])
        need[int(owner[b])].update(int(r) for r in refs)
    halo_blocks = []
    for s in range(n_shards):
        need[s] = sorted(d for d in need[s] if owner[d] != s)
        halo_blocks.append(len(need[s]))
    send = [set() for _ in range(n_shards)]
    for s in range(n_shards):
        for d in need[s]:
            send[int(owner[d])].add(d)
    send = [sorted(t) for t in send]
    boundary_blocks = tuple(len(t) for t in send)
    b_max = max(max(boundary_blocks, default=0), b_max_floor)

    # ---- vertex-granularity sets (hub refs excluded) -------------------- #
    vmask_ok = ref_ok & remote
    vkeys = np.unique(row_owner[vmask_ok].astype(np.int64) * n_pad
                      + dst[vmask_ok])
    v_needer = vkeys // n_pad
    v_vertex = vkeys % n_pad
    v_owner = v_vertex // local_n
    v_group = v_needer * n_shards + v_owner
    pair_counts = np.bincount(v_group, minlength=n_shards * n_shards)
    h_max = max(int(pair_counts.max(initial=0)), h_max_floor)

    # ---- granularity decision ------------------------------------------- #
    block_elems = (n_shards - 1) * b_max * block_v
    vertex_elems = (n_shards - 1) * h_max
    full_elems = (n_shards - 1) * bps * block_v
    if granularity == "auto":
        chosen = "vertex" if vertex_elems < block_elems else "block"
    else:
        chosen = granularity
    chosen_elems = vertex_elems if chosen == "vertex" else block_elems
    coverage = chosen_elems / full_elems if full_elems else 0.0
    fallback = coverage >= threshold

    boundary_rows = np.zeros((n_shards, max(b_max, 0)), dtype=np.int32)
    for t, blocks in enumerate(send):
        boundary_rows[t, : len(blocks)] = [b - t * bps for b in blocks]

    blk_dst_halo = None
    send_ids = None
    hub_owner = hub_local = hub_deg = None
    hub_src = hub_slot = hub_w = vmask_nonhub = None
    he_max = 0
    boundary_flag = None
    if fallback:
        # no halo left to shrink: run the plain full gather, hubs off
        n_hubs, hub_pad, hub_ids = 0, 0, np.empty(0, dtype=np.int64)
    else:
        hub_base = local_n + (n_shards * h_max if chosen == "vertex"
                              else n_shards * b_max * block_v)
        if chosen == "vertex":
            # per-(needer, owner) need lists -> the all_to_all send plan and
            # the needer-side buffer positions of every remote vertex
            send_ids = np.zeros((n_shards, n_shards, h_max), dtype=np.int32)
            buf_pos = np.full((n_shards, n_pad), -1, dtype=np.int64)
            for gid in np.unique(v_group):
                s, t = int(gid) // n_shards, int(gid) % n_shards
                ids = v_vertex[v_group == gid]          # ascending
                send_ids[t, s, : ids.size] = (ids - t * local_n).astype(
                    np.int32)
                buf_pos[s, ids] = local_n + t * h_max + np.arange(ids.size)
            pos = buf_pos[row_owner, dst]
            local_row = dst - row_owner * local_n
            mapped = np.where(
                real & hub_ref,
                hub_base + slot_of[dst],
                np.where(dst_owner == row_owner, local_row,
                         np.where(pos >= 0, pos, 0)))
            mapped = np.where(real, mapped, np.maximum(mapped, 0))
            unresolved = ref_ok & remote & (pos < 0)
        else:
            # position of each boundary block inside the gathered [S,b_max,bv]
            rslot = np.full(nb, -1, dtype=np.int64)
            for t, blocks in enumerate(send):
                for p, b in enumerate(blocks):
                    rslot[b] = t * b_max + p
            off = dst - dst_blk * block_v
            is_local = dst_owner == row_owner
            halo_pos = rslot[dst_blk]
            mapped = np.where(
                real & hub_ref,
                hub_base + slot_of[dst],
                np.where(
                    is_local,
                    (dst_blk - row_owner * bps) * block_v + off,
                    np.where(halo_pos >= 0,
                             local_n + halo_pos * block_v + off, 0),
                ))
            unresolved = ref_ok & ~is_local & (halo_pos < 0)
        if unresolved.any():
            raise AssertionError("halo sets do not cover a real slab reference")
        blk_dst_halo = mapped.astype(np.int32)
        # interior/boundary classification for the async schedule: a block
        # is boundary iff any *real* slab slot resolves past the shard's own
        # slice — into the exchanged tail or the hub region. Derived from
        # the very `mapped` array the rewrite ships, so "interior blocks
        # read only local vertices" holds by construction.
        boundary_flag = np.any(real & (mapped >= local_n), axis=1)

        if n_hubs or hub_pad:
            hub_owner = np.full(hub_pad, -1, dtype=np.int32)
            hub_owner[:n_hubs] = hub_ids // local_n
            hub_local = np.zeros(hub_pad, dtype=np.int32)
            hub_local[:n_hubs] = hub_ids - (hub_ids // local_n) * local_n
            hub_deg = np.zeros(hub_pad, dtype=np.float32)
            hub_deg[:n_hubs] = deg[hub_ids]
            vmask_nonhub = vmask & ~is_hub
            # per-shard vote slabs: every real slab slot whose dst is a hub
            blk_row = np.asarray(blk_row)
            hb, he = np.nonzero(real & hub_ref)
            src_local = ((hb - owner[hb] * bps) * block_v
                         + blk_row[hb, he].astype(np.int64))
            shard_of = owner[hb]
            counts = np.bincount(shard_of, minlength=n_shards)
            he_max = max(int(counts.max(initial=0)), he_max_floor)
            hub_src = np.zeros((n_shards, he_max), dtype=np.int32)
            hub_slot = np.zeros((n_shards, he_max), dtype=np.int32)
            hub_w = np.zeros((n_shards, he_max), dtype=np.float32)
            for s in range(n_shards):
                m = shard_of == s
                c = int(np.count_nonzero(m))
                hub_src[s, :c] = src_local[m]
                hub_slot[s, :c] = slot_of[dst[hb[m], he[m]]]
                hub_w[s, :c] = blk_w[hb[m], he[m]]

    interior_counts: Tuple[int, ...] = ()
    interior_split = 0
    if boundary_flag is not None:
        per_shard = boundary_flag.reshape(n_shards, bps)
        interior_counts = tuple(int(c) for c in (~per_shard).sum(axis=1))
        # first boundary block per shard (bps when a shard has none); the
        # scan length must be SPMD-uniform, so the split is the min
        firsts = np.where(per_shard.any(axis=1),
                          per_shard.argmax(axis=1), bps)
        interior_split = int(firsts.min())

    if mesh is not None:
        repl = NamedSharding(mesh, P())
        rows = NamedSharding(mesh, P("blocks", None))
        boundary_rows = jax.device_put(boundary_rows, repl)
        if blk_dst_halo is not None:
            blk_dst_halo = jax.device_put(blk_dst_halo, rows)
        if send_ids is not None:
            send_ids = jax.device_put(send_ids, repl)
        if hub_owner is not None:
            hub_owner = jax.device_put(hub_owner, repl)
            hub_local = jax.device_put(hub_local, repl)
            hub_deg = jax.device_put(hub_deg, repl)
            hub_src = jax.device_put(hub_src, rows)
            hub_slot = jax.device_put(hub_slot, rows)
            hub_w = jax.device_put(hub_w, rows)
            vmask_nonhub = jax.device_put(
                vmask_nonhub, NamedSharding(mesh, P("blocks")))

    return HaloSpec(
        n_shards=n_shards,
        blocks_per_shard=bps,
        block_v=block_v,
        b_max=b_max,
        coverage=coverage,
        threshold=threshold,
        fallback=fallback,
        halo_blocks=tuple(halo_blocks),
        boundary_blocks=boundary_blocks,
        boundary_rows=boundary_rows,
        blk_dst_halo=blk_dst_halo,
        granularity=chosen,
        h_max=h_max,
        send_ids=send_ids,
        n_hubs=n_hubs,
        hub_pad=hub_pad if hub_owner is not None else 0,
        hub_ids=tuple(int(h) for h in hub_ids),
        hub_owner=hub_owner,
        hub_local=hub_local,
        hub_deg=hub_deg,
        he_max=he_max,
        hub_src=hub_src,
        hub_slot=hub_slot,
        hub_w=hub_w,
        vmask_nonhub=vmask_nonhub,
        block_is_boundary=(tuple(bool(b) for b in boundary_flag)
                           if boundary_flag is not None else ()),
        interior_counts=interior_counts,
        interior_split=interior_split,
    )


def interior_first_order(spec: HaloSpec) -> Optional[np.ndarray]:
    """Intra-shard stable reorder putting every shard's interior blocks
    first, in the spec's storage block space (or None when it changes
    nothing, including under fallback).

    Which blocks are boundary depends only on the block->shard ownership
    (which vertices are remote) and the hub set, not on the order of blocks
    *within* a shard — so re-sharding the same assignment with this
    permutation composed on top preserves the halo/boundary structure while
    raising ``interior_split`` to ``min(interior_counts)``, the largest
    phase-1 window the assignment admits. The async runner applies it
    before building the layout it actually runs (`core/runner.py`); parity
    legs compare the halo and async schedules on that same layout, so the
    reorder never weakens the staleness_bound=0 bit-identity contract.
    """
    if spec.fallback or not spec.block_is_boundary:
        return None
    flags = np.asarray(spec.block_is_boundary, dtype=bool)
    bps = spec.blocks_per_shard
    order = []
    for s in range(spec.n_shards):
        local = np.arange(s * bps, (s + 1) * bps, dtype=np.int64)
        f = flags[local]
        order.append(np.concatenate([local[~f], local[f]]))
    perm = np.concatenate(order)
    if np.array_equal(perm, np.arange(flags.size)):
        return None
    return perm


__all__ = ["HaloSpec", "HubConfig", "build_halo_spec", "interior_first_order",
           "DEFAULT_HALO_THRESHOLD", "DEFAULT_HUB_MAX_FRAC"]
