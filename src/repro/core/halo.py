"""Static halo-exchange plan for the sharded superstep.

The full-gather Jacobi schedule (``chunk_schedule="sharded"``) all-gathers
every per-vertex state field once per superstep — O(n_pad) cross-device
traffic regardless of how local the partition's block->shard assignment is.
But the set of *remote* vertices a shard's edge slabs actually reference is
static (it depends only on the graph layout, not on labels), so the sync can
be precomputed: each shard contributes only its **boundary blocks** (blocks
some other shard references) to one all-gather of shape ``[b_max, block_v]``
per field, and every slab's neighbor ids are rewritten host-side to index
the shard's assembled ``local + halo`` buffer directly. Traffic per
superstep per field drops from ``(S-1) * blocks_per_shard * block_v`` to
``(S-1) * b_max * block_v`` elements per device — proportional to the
block-level edge cut, i.e. to partition quality, which is the paper's cloud
argument closed end-to-end (locality-aware assignment -> smaller halo ->
less traffic).

Exactness: the halo buffer carries the same start-of-superstep snapshots of
remote labels that the full gather would, and the shard's own (drifting)
slice sits at the front of the buffer, so a chunk rule sees bit-identical
values through the rewritten indices — ``"halo"`` is an exact optimization
of ``"sharded"``'s sync, gated bit-for-bit by tests and the scaling bench.

When the boundary set approaches the full shard (``coverage = b_max /
blocks_per_shard`` above ``threshold``), the exchange would move as much
data as the plain all-gather while paying an extra gather/concat — the spec
records ``fallback=True`` and the engine runs the full-gather schedule
instead.

The exchange granularity is the *union* of boundary blocks: one
``all_gather`` delivers every shard's boundary set to everyone, so a shard
may receive slabs it never reads. True point-to-point (per-pair ppermute
rounds) would shave that further at the cost of S-1 sequenced collectives;
on the target topologies (ring/torus all-gather is bandwidth-optimal) the
union exchange is the right first cut, and the recorded
``gathered-bytes/superstep`` in BENCH_scaling.json models exactly what this
implementation moves.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_HALO_THRESHOLD = 0.75


@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Precomputed halo-exchange plan for one (layout, n_shards) pair.

    Built host-side by `build_halo_spec`; consumed by the engine's halo
    superstep. All ids are in the layout's storage space (i.e. *after* any
    locality permutation — see `device_graph.permute_blocks`).
    """

    n_shards: int
    blocks_per_shard: int
    block_v: int
    b_max: int              # padded boundary-block count per shard
    coverage: float         # b_max / blocks_per_shard (1.0 = no win)
    threshold: float        # fallback trigger the spec was built with
    fallback: bool          # True -> engine runs the full-gather schedule
    halo_blocks: Tuple[int, ...]      # per shard: #remote blocks it references
    boundary_blocks: Tuple[int, ...]  # per shard: #own blocks others reference
    boundary_rows: jax.Array          # [S, b_max] int32 local block index
                                      # within the owner (0-padded)
    blk_dst_halo: Optional[jax.Array]  # [n_blocks, e_max] int32 neighbor ids
                                       # rewritten into local+halo buffer space
                                       # (None when fallback)

    @property
    def local_n(self) -> int:
        return self.blocks_per_shard * self.block_v

    @property
    def buf_len(self) -> int:
        """Length of the per-shard drifting buffer: the shard's own slice
        followed by the gathered boundary slabs of every shard."""
        return self.local_n + self.n_shards * self.b_max * self.block_v

    def gathered_elems_per_device(self) -> int:
        """Per-vertex-field elements a device receives per superstep (the
        halo exchange if active, the full gather under fallback)."""
        per_shard = self.b_max if not self.fallback else self.blocks_per_shard
        return (self.n_shards - 1) * per_shard * self.block_v

    def full_gather_elems_per_device(self) -> int:
        return (self.n_shards - 1) * self.blocks_per_shard * self.block_v


def build_halo_spec(
    blk_dst: np.ndarray,
    blk_w: np.ndarray,
    n_shards: int,
    block_v: int,
    *,
    threshold: float = DEFAULT_HALO_THRESHOLD,
    b_max_floor: int = 0,
    mesh: Optional[jax.sharding.Mesh] = None,
) -> HaloSpec:
    """Compute the static halo sets and the buffer-space slab rewrite.

    `blk_dst` / `blk_w` are the (host) padded edge slabs in storage order;
    shard s owns the contiguous block range [s*bps, (s+1)*bps). Padding
    slots (w == 0) are ignored for set membership and their rewritten index
    is clamped to 0 — they are only ever read under a zero weight.

    `b_max_floor` lets streaming callers keep the exchange shape stable
    while halo sets evolve (growth past the floor recompiles, like a slab
    re-pad). `mesh` commits the plan's device arrays (`boundary_rows`
    replicated, `blk_dst_halo` block-sharded) so the jitted superstep reuses
    them without per-call transfers.
    """
    blk_dst = np.asarray(blk_dst)
    blk_w = np.asarray(blk_w)
    nb, e_max = blk_dst.shape
    if nb % n_shards != 0:
        raise ValueError(f"n_blocks={nb} not divisible by n_shards={n_shards}")
    bps = nb // n_shards
    local_n = bps * block_v
    owner = np.arange(nb, dtype=np.int64) // bps
    dst_blk = blk_dst.astype(np.int64) // block_v
    real = blk_w > 0

    # per-shard remote-reference sets (the halo each shard must receive)
    need = [set() for _ in range(n_shards)]
    for b in range(nb):
        refs = np.unique(dst_blk[b][real[b]])
        need[int(owner[b])].update(int(r) for r in refs)
    halo_blocks = []
    for s in range(n_shards):
        need[s] = sorted(d for d in need[s] if owner[d] != s)
        halo_blocks.append(len(need[s]))

    # per-shard boundary sets (the blocks each shard must send)
    send = [set() for _ in range(n_shards)]
    for s in range(n_shards):
        for d in need[s]:
            send[int(owner[d])].add(d)
    send = [sorted(t) for t in send]
    boundary_blocks = tuple(len(t) for t in send)
    b_max = max(max(boundary_blocks, default=0), b_max_floor)
    coverage = b_max / bps if bps else 1.0
    fallback = coverage >= threshold

    boundary_rows = np.zeros((n_shards, max(b_max, 0)), dtype=np.int32)
    for t, blocks in enumerate(send):
        boundary_rows[t, : len(blocks)] = [b - t * bps for b in blocks]

    blk_dst_halo = None
    if not fallback:
        # position of each boundary block inside the gathered [S, b_max, bv]
        rslot = np.full(nb, -1, dtype=np.int64)
        for t, blocks in enumerate(send):
            for p, b in enumerate(blocks):
                rslot[b] = t * b_max + p
        off = blk_dst.astype(np.int64) - dst_blk * block_v
        own = owner[:, None]                       # shard owning the slab row
        is_local = owner[dst_blk] == own
        halo_pos = rslot[dst_blk]
        unresolved = real & ~is_local & (halo_pos < 0)
        if unresolved.any():
            raise AssertionError("halo sets do not cover a real slab reference")
        mapped = np.where(
            is_local,
            (dst_blk - own * bps) * block_v + off,
            np.where(halo_pos >= 0, local_n + halo_pos * block_v + off, 0),
        )
        blk_dst_halo = mapped.astype(np.int32)

    if mesh is not None:
        boundary_rows = jax.device_put(
            boundary_rows, NamedSharding(mesh, P()))
        if blk_dst_halo is not None:
            blk_dst_halo = jax.device_put(
                blk_dst_halo, NamedSharding(mesh, P("blocks", None)))

    return HaloSpec(
        n_shards=n_shards,
        blocks_per_shard=bps,
        block_v=block_v,
        b_max=b_max,
        coverage=coverage,
        threshold=threshold,
        fallback=fallback,
        halo_blocks=tuple(halo_blocks),
        boundary_blocks=boundary_blocks,
        boundary_rows=boundary_rows,
        blk_dst_halo=blk_dst_halo,
    )


__all__ = ["HaloSpec", "build_halo_spec", "DEFAULT_HALO_THRESHOLD"]
