"""Edge-stream ingestion: timestamped arrivals batched into fixed-size deltas.

The cloud workload the ROADMAP targets does not hand us a finished graph: it
hands us an unbounded sequence of edge events (a follow, a hyperlink, a new
RPC dependency), occasionally retractions. This module is the thin front door
of the streaming subsystem:

  * `EdgeDelta` — one immutable batch of insertions (+ optional deletions),
    the unit everything downstream consumes;
  * `StreamBuffer` — accumulates arriving events and emits a delta every
    `delta_size` insertions (cloud ingestion loops call `push` from their
    event source and drain `pop_delta`);
  * `stream_from_graph` — replays a static benchmark graph as a timestamped
    stream (each directed edge gets a pseudo-arrival time), so any Table-I
    dataset doubles as a streaming workload.
"""
from __future__ import annotations

from typing import Iterator, List, NamedTuple, Optional

import numpy as np

from repro.graphs.csr import Graph


class EdgeDelta(NamedTuple):
    """One batch of edge events. Arrays are int32 vertex ids, equal lengths
    within each (add, delete) pair; deletions may be empty."""

    add_src: np.ndarray
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray

    @property
    def n_add(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def n_del(self) -> int:
        return int(self.del_src.shape[0])

    @staticmethod
    def inserts(src: np.ndarray, dst: np.ndarray) -> "EdgeDelta":
        empty = np.empty(0, dtype=np.int32)
        return EdgeDelta(
            add_src=np.asarray(src, dtype=np.int32),
            add_dst=np.asarray(dst, dtype=np.int32),
            del_src=empty,
            del_dst=empty,
        )


class StreamBuffer:
    """Accumulate edge events; emit an `EdgeDelta` per `delta_size` inserts.

    Events are kept in arrival order, and emission preserves per-edge
    event order. `EdgeDelta` semantics apply deletions before insertions,
    so a delta must never contain a deletion of an edge inserted *earlier
    in the same window* (the pair would resolve present instead of absent)
    — when such a conflict arises the window is cut short and the deletion
    (plus everything after it) waits for the next delta. A delta may
    therefore carry fewer than `delta_size` insertions; `flush` drains the
    longest order-preserving prefix and is called repeatedly until None.
    """

    def __init__(self, delta_size: int, *, n: Optional[int] = None):
        if delta_size <= 0:
            raise ValueError(f"delta_size must be positive, got {delta_size}")
        if n is not None and n <= 0:
            raise ValueError(f"vertex space must be positive, got {n}")
        self.delta_size = delta_size
        self.n = n          # optional vertex-space bound checked at push
        # arrival-ordered (src, dst, is_delete) chunks
        self._events: List[tuple] = []
        self._n_add = 0
        self._pushed = 0    # events accepted so far (error attribution)

    def _as_ids(self, name: str, a) -> np.ndarray:
        """Validate one endpoint array at the front door. A stream source
        feeding garbage (sensor NaNs, floats, ids outside the declared
        vertex space) should fail loudly here, at the event that carried
        it, not as a corrupt partition three subsystems later."""
        a = np.atleast_1d(np.asarray(a))
        where = f"{name} in push #{self._pushed}"
        if a.dtype.kind == "f":
            if not np.isfinite(a).all():
                raise ValueError(f"{where} contains NaN/inf edge data")
            if np.any(a != np.floor(a)):
                raise ValueError(
                    f"{where} has non-integral float vertex ids")
        elif a.dtype.kind not in "iu":
            raise ValueError(
                f"{where} has non-numeric dtype {a.dtype} for vertex ids")
        if a.size and int(a.min()) < 0:
            raise ValueError(
                f"{where} contains negative vertex ids (min {int(a.min())})")
        if self.n is not None and a.size and int(a.max()) >= self.n:
            raise ValueError(
                f"{where} contains vertex ids >= n={self.n} "
                f"(max {int(a.max())})")
        return a.astype(np.int32)

    def push(self, src, dst, *, delete: bool = False) -> None:
        """Buffer one event or a vector of events. Malformed events —
        shape or dtype-kind mismatch between src and dst, NaN/inf data,
        negative or (when `n` was declared) out-of-range vertex ids —
        raise ValueError naming the offending push."""
        src_raw = np.atleast_1d(np.asarray(src))
        dst_raw = np.atleast_1d(np.asarray(dst))
        if src_raw.dtype.kind != dst_raw.dtype.kind:
            raise ValueError(
                f"src/dst dtype mismatch in push #{self._pushed}: "
                f"{src_raw.dtype} vs {dst_raw.dtype}")
        src = self._as_ids("src", src_raw)
        dst = self._as_ids("dst", dst_raw)
        if src.shape != dst.shape:
            raise ValueError(
                f"src/dst shape mismatch in push #{self._pushed}: "
                f"{src.shape} vs {dst.shape}")
        self._pushed += 1
        if src.shape[0] == 0:
            return
        self._events.append((src, dst, delete))
        if not delete:
            self._n_add += src.shape[0]

    def ready(self) -> bool:
        return self._n_add >= self.delta_size

    def pop_delta(self) -> Optional[EdgeDelta]:
        """Emit up to the oldest `delta_size` insertions (+ the deletions
        interleaved with them), or None if fewer insertions are buffered.
        May emit fewer insertions when an insert/delete conflict cuts the
        window (see class docstring)."""
        if not self.ready():
            return None
        return self._emit(self.delta_size)

    def flush(self) -> Optional[EdgeDelta]:
        """Emit the longest order-preserving prefix of what is buffered
        (end-of-stream); call repeatedly until it returns None."""
        if not self._events:
            return None
        return self._emit(None)

    @staticmethod
    def _pack(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        return (src.astype(np.int64) << 32) | dst.astype(np.int64)

    def _emit(self, take: Optional[int]) -> EdgeDelta:
        """Drain events in arrival order until `take` insertions are
        consumed (None = drain everything), cutting the window before any
        deletion that targets an edge inserted earlier in it."""
        adds: List[tuple] = []
        dels: List[tuple] = []
        taken = 0
        rest: List[tuple] = []
        for i, (src, dst, is_del) in enumerate(self._events):
            if take is not None and taken >= take:
                rest = self._events[i:]
                break
            if is_del:
                if adds and np.isin(
                    self._pack(src, dst),
                    np.concatenate([self._pack(a[0], a[1]) for a in adds]),
                ).any():
                    rest = self._events[i:]
                    break
                dels.append((src, dst))
                continue
            need = src.shape[0] if take is None else min(src.shape[0], take - taken)
            adds.append((src[:need], dst[:need]))
            taken += need
            if need < src.shape[0]:
                rest = [(src[need:], dst[need:], False)] + self._events[i + 1:]
                break
        self._events = rest
        self._n_add -= taken

        empty = np.empty(0, dtype=np.int32)
        return EdgeDelta(
            add_src=np.concatenate([a[0] for a in adds]) if adds else empty,
            add_dst=np.concatenate([a[1] for a in adds]) if adds else empty,
            del_src=np.concatenate([d[0] for d in dels]) if dels else empty,
            del_dst=np.concatenate([d[1] for d in dels]) if dels else empty,
        )


def stream_from_graph(
    g: Graph,
    n_deltas: int,
    *,
    order: str = "timestamp",
    seed: int = 0,
) -> Iterator[EdgeDelta]:
    """Replay a static graph's directed edges as `n_deltas` insertion batches.

    order:
      "timestamp" — edges get a random pseudo-arrival time (the usual model
                    for benchmark graphs without real timestamps);
      "arrival"   — CSR order (all of vertex 0's out-edges first, ...), a
                    pathological best case for locality;
    """
    src = np.repeat(
        np.arange(g.n, dtype=np.int32), np.diff(g.row_ptr).astype(np.int64)
    )
    dst = g.col_idx.astype(np.int32, copy=True)
    if order == "timestamp":
        perm = np.random.default_rng(seed).permutation(g.m)
        src, dst = src[perm], dst[perm]
    elif order != "arrival":
        raise ValueError(f"unknown stream order {order!r}")

    n_deltas = max(1, min(n_deltas, max(g.m, 1)))
    bounds = np.linspace(0, g.m, n_deltas + 1).astype(np.int64)
    for i in range(n_deltas):
        lo, hi = int(bounds[i]), int(bounds[i + 1])
        yield EdgeDelta.inserts(src[lo:hi], dst[lo:hi])
