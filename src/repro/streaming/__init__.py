"""Streaming graph ingestion + incremental Revolver repartitioning.

Lifecycle: **delta -> merge -> warm-start -> refine**.

  * `stream` — `EdgeDelta` batches, the `StreamBuffer` front door, and
    `stream_from_graph` to replay any static dataset as a timestamped stream;
  * `delta_graph` — `IncrementalGraph` (sorted-key CSR maintenance, O(m + d
    log m) per delta) and `IncrementalDeviceGraph` (shape-stable padded
    device layout, dirty-block slab rewrites, headroom re-pads);
  * `runner` — `StreamRunner`, which warm-starts any registered engine
    algorithm (`algo="revolver"` default) from the carried labels — plus LA
    probabilities where the rule has them — after each merge and refines
    for a handful of supersteps, with an optional prioritized
    (high-degree-first) restream pass.

See README.md in this directory for the design rationale.
"""
from repro.streaming.stream import EdgeDelta, StreamBuffer, stream_from_graph
from repro.streaming.delta_graph import (
    IncrementalDeviceGraph,
    IncrementalGraph,
    MergeInfo,
)
from repro.streaming.runner import DeltaReport, StreamConfig, StreamRunner

__all__ = [
    "EdgeDelta",
    "StreamBuffer",
    "stream_from_graph",
    "IncrementalGraph",
    "IncrementalDeviceGraph",
    "MergeInfo",
    "StreamConfig",
    "StreamRunner",
    "DeltaReport",
]
