"""Incremental graph state: merge edge deltas without a full host rebuild.

Batch mode pays O(m log m) in `build_graph` (sort + dedup of the whole edge
list) and a full re-block per graph. Streaming cannot afford that per delta.
This module maintains three sorted structures across deltas:

  * `dir_keys`  — sorted int64 keys of the directed edge set;
  * `sym_keys`, `sym_w` — sorted keys + eq.-(4) weights of the symmetrized
    adjacency (weight 1 = one direction present, 2 = both);
  * the padded block slabs of the `DeviceGraph` (blk_dst / blk_row / blk_w).

A delta of d events merges in O(m + d log m): canonicalize the delta (sort +
dedup of d keys only), splice it into the maintained arrays with
searchsorted-based inserts/deletes, recompute the eq.-(4) weights for the
touched vertex *pairs* only, and rewrite only the block slabs owning a
touched vertex. The device-side block layout (n_pad, block_v, e_max) is
reused across deltas, so the jitted Revolver superstep never recompiles —
until a block overflows `e_max`, at which point the slabs are re-padded with
headroom (`e_headroom`) and one recompile is paid.

The vertex space is declared up front (`n`): cloud deployments know their id
space (or reserve headroom); vertices materialize implicitly as edges touch
them and contribute nothing while isolated.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.device_graph import (
    DeviceGraph,
    ShardedDeviceGraph,
    block_vertex_perms,
)
from repro.core.halo import DEFAULT_HALO_THRESHOLD, HubConfig, build_halo_spec
from repro.graphs.blocking import (
    block_adjacency,
    block_slab_sizes,
    fill_block_slab,
    locality_block_order,
)
from repro.graphs.csr import (
    Graph,
    canonicalize_edges,
    decode_edge_keys,
    graph_from_sorted_state,
    merge_sorted_keys,
    remove_sorted_keys,
    sorted_isin,
)
from repro.streaming.stream import EdgeDelta


@dataclasses.dataclass
class MergeInfo:
    """What one delta merge did (diagnostics + StreamRunner reporting)."""

    added: int = 0              # directed edges actually inserted
    deleted: int = 0            # directed edges actually removed
    dup_dropped: int = 0        # insertions already present (or in-delta dups)
    missing_dropped: int = 0    # deletions of absent edges
    touched_vertices: Optional[np.ndarray] = None   # endpoints of changed pairs
    dirty_blocks: int = 0       # block slabs rewritten (device layer)
    repadded: bool = False      # e_max overflow forced a full re-pad
    m: int = 0                  # |E| after the merge


class IncrementalGraph:
    """Host-side CSR state maintained across deltas (see module docstring)."""

    def __init__(self, n: int):
        if n <= 0:
            raise ValueError(f"vertex space must be positive, got {n}")
        self.n = n
        self.dir_keys = np.empty(0, dtype=np.int64)
        self.sym_keys = np.empty(0, dtype=np.int64)
        self.sym_w = np.empty(0, dtype=np.float32)
        self.deltas_applied = 0

    @property
    def m(self) -> int:
        return int(self.dir_keys.size)

    def _check_delta(self, delta: EdgeDelta):
        """Reject malformed deltas before any state is touched, naming the
        delta so a bad producer in a long stream is attributable."""
        idx = self.deltas_applied
        pairs = (("add_src", delta.add_src, "add_dst", delta.add_dst),
                 ("del_src", delta.del_src, "del_dst", delta.del_dst))
        for sname, s, dname, d in pairs:
            s, d = np.asarray(s), np.asarray(d)
            if s.shape != d.shape:
                raise ValueError(
                    f"delta {idx}: {sname}/{dname} shape mismatch "
                    f"{s.shape} vs {d.shape}")
            for name, a in ((sname, s), (dname, d)):
                if a.dtype.kind == "f" and not np.isfinite(a).all():
                    raise ValueError(
                        f"delta {idx}: {name} contains NaN/inf edge data")
                if a.dtype.kind not in "iu" and not (
                        a.dtype.kind == "f"
                        and (not a.size or (a == np.floor(a)).all())):
                    raise ValueError(
                        f"delta {idx}: {name} dtype {a.dtype} is not a "
                        "vertex-id array")
                if a.size and int(a.min()) < 0:
                    raise ValueError(
                        f"delta {idx}: {name} contains negative vertex ids "
                        f"(min {int(a.min())})")
                if a.size and int(a.max()) >= self.n:
                    raise ValueError(
                        f"delta {idx}: {name} contains vertex ids >= "
                        f"n={self.n} (max {int(a.max())})")

    def apply(self, delta: EdgeDelta) -> MergeInfo:
        """Merge one delta. Deletions apply before insertions, so an edge
        deleted and re-added within the same delta ends up present.
        Malformed deltas (id out of [0, n), NaN/inf data, shape-mismatched
        src/dst) raise ValueError naming the delta index, before any state
        is modified."""
        self._check_delta(delta)
        n = self.n
        info = MergeInfo()

        dels = canonicalize_edges(delta.del_src, delta.del_dst, n)
        dels = dels[sorted_isin(self.dir_keys, dels)]
        info.missing_dropped = delta.n_del - int(dels.size)
        dir_mid = remove_sorted_keys(self.dir_keys, dels)

        adds = canonicalize_edges(delta.add_src, delta.add_dst, n)
        adds = adds[~sorted_isin(dir_mid, adds)]
        info.dup_dropped = delta.n_add - int(adds.size)
        self.dir_keys = merge_sorted_keys(dir_mid, adds)
        info.added, info.deleted = int(adds.size), int(dels.size)
        info.m = self.m

        # ---- eq.-(4) weight maintenance for the touched pairs only --------
        changed = np.concatenate([dels, adds])
        if changed.size:
            u, v = decode_edge_keys(changed, n)
            pu, pv = np.minimum(u, v).astype(np.int64), np.maximum(u, v).astype(np.int64)
            pairs = np.unique(pu * n + pv)
            pu, pv = decode_edge_keys(pairs, n)
            pu, pv = pu.astype(np.int64), pv.astype(np.int64)
            fwd, rev = pu * n + pv, pv * n + pu
            cnt = (
                sorted_isin(self.dir_keys, fwd).astype(np.int8)
                + sorted_isin(self.dir_keys, rev).astype(np.int8)
            )
            present = sorted_isin(self.sym_keys, fwd)

            # slots to drop: pair lost its last direction
            gone = present & (cnt == 0)
            if gone.any():
                drop = np.sort(np.concatenate([fwd[gone], rev[gone]]))
                idx = np.searchsorted(self.sym_keys, drop)
                self.sym_keys = np.delete(self.sym_keys, idx)
                self.sym_w = np.delete(self.sym_w, idx)

            # weight rewrites: pair survives with a (possibly) new direction count
            upd = present & (cnt > 0)
            if upd.any():
                keys = np.concatenate([fwd[upd], rev[upd]])
                w = np.concatenate([cnt[upd], cnt[upd]]).astype(np.float32)
                self.sym_w[np.searchsorted(self.sym_keys, keys)] = w

            # fresh slots: pair gained its first direction
            new = (~present) & (cnt > 0)
            if new.any():
                keys = np.concatenate([fwd[new], rev[new]])
                w = np.concatenate([cnt[new], cnt[new]]).astype(np.float32)
                order = np.argsort(keys)
                keys, w = keys[order], w[order]
                idx = np.searchsorted(self.sym_keys, keys)
                self.sym_keys = np.insert(self.sym_keys, idx, keys)
                self.sym_w = np.insert(self.sym_w, idx, w)

            info.touched_vertices = np.unique(np.concatenate([pu, pv])).astype(np.int64)
        else:
            info.touched_vertices = np.empty(0, dtype=np.int64)
        self.deltas_applied += 1
        return info

    def to_graph(self) -> Graph:
        """O(m) materialization of the standard `Graph` container."""
        return graph_from_sorted_state(self.n, self.dir_keys, self.sym_keys, self.sym_w)


class IncrementalDeviceGraph:
    """Pads an evolving graph into a shape-stable `DeviceGraph`.

    `apply(delta)` returns a fresh `DeviceGraph` whose blocked arrays keep
    their shapes across deltas (jit-cache friendly); only slabs of blocks
    owning a touched vertex are rewritten. The flat metric arrays
    (dir_src/dir_dst, edge_*) track the true edge count and therefore change
    length — they feed cheap eager metrics, not the jitted superstep.

    **Locality-aware assignment** (`assignment="locality"` or an explicit
    block permutation; requires `mesh`): the maintained slabs live in
    permuted *storage* order with neighbor ids rewritten into the permuted
    space, so a rewritten dirty slab still transfers straight to the shard
    that owns the block under the permuted assignment. A "locality"
    permutation is decided once, from the block-level edge-cut matrix of the
    first merged delta (typically the bulk load), and then held fixed for
    the whole stream — the carried labels/probabilities and the jit cache
    depend on a stable layout; a drifting graph that outgrows its
    assignment is a re-shard event, not a per-delta adjustment.

    **Halo** (`as_sharded(halo=True)`): the boundary-exchange plan is
    rebuilt per delta from the current slabs (same O(n_blocks * e_max) host
    cost class as the per-delta array uploads), with `b_max` only ever
    growing (monotonic floor) so the jitted halo superstep keeps its shapes
    until the halo genuinely widens — the same recompile discipline as an
    `e_max` re-pad.
    """

    def __init__(
        self,
        n: int,
        *,
        n_blocks: int = 8,
        block_multiple: int = 8,
        edge_chunk: int = 256,
        e_headroom: float = 1.5,
        mesh=None,
        assignment: Union[str, np.ndarray, None] = "contiguous",
    ):
        self.inc = IncrementalGraph(n)
        n_blocks = max(1, min(n_blocks, n))
        block_v = -(-n // n_blocks)
        block_v = -(-block_v // block_multiple) * block_multiple
        self.block_v = block_v
        self.n_blocks = -(-n // block_v)
        # blocks that can ever hold a real vertex (slab rewrites stop here;
        # alignment blocks beyond stay all-zero for the whole stream)
        self._real_blocks = self.n_blocks
        # mesh-aligned streaming (sharded chunk_schedule): pad to a multiple
        # of the mesh size with empty blocks up front, so every delta's
        # device layout is already device-aligned and each rewritten dirty
        # slab transfers straight to its owning device
        self.mesh = mesh
        if mesh is not None:
            n_shards = int(mesh.shape["blocks"])
            self.n_blocks += (-self.n_blocks) % n_shards
        self.n_pad = self.n_blocks * block_v
        self.edge_chunk = edge_chunk
        self.e_headroom = float(e_headroom)
        self.e_max = 0
        self._blk_dst = np.zeros((self.n_blocks, 0), dtype=np.int32)
        self._blk_row = np.zeros((self.n_blocks, 0), dtype=np.int32)
        self._blk_w = np.zeros((self.n_blocks, 0), dtype=np.float32)
        self.graph: Optional[Graph] = None
        self.device_graph: Optional[DeviceGraph] = None
        # block->shard assignment state (storage permutation)
        if isinstance(assignment, str) and assignment not in (
                "contiguous", "locality"):
            raise ValueError(
                f"unknown assignment {assignment!r}; expected 'contiguous', "
                "'locality', or an explicit block permutation")
        if not isinstance(assignment, str) and assignment is not None:
            assignment = np.asarray(assignment, dtype=np.int64)
        if mesh is None and (
                (isinstance(assignment, str) and assignment == "locality")
                or isinstance(assignment, np.ndarray)):
            raise ValueError("a block->shard assignment needs a mesh")
        self._assignment = assignment
        self.block_perm: Optional[np.ndarray] = None  # storage -> orig block
        self._pos: Optional[np.ndarray] = None        # orig block -> storage
        self.o2s: Optional[np.ndarray] = None
        self.s2o: Optional[np.ndarray] = None
        # "locality" is decided once, from the first non-empty merge; the
        # flag (not `block_perm is None` — the decision may legitimately be
        # the identity) keeps it from being re-litigated every delta
        self._perm_decided = not (isinstance(assignment, str)
                                  and assignment == "locality")
        if isinstance(assignment, np.ndarray):
            self._set_perm(assignment)
        self._b_max_floor = 0
        # per-vertex / hub plan floors — same monotonic discipline as
        # _b_max_floor: the jitted superstep's shapes only change when an
        # exchange genuinely outgrows its padding or the hub set grows
        self._h_max_floor = 0
        self._hub_pad_floor = 0
        self._he_max_floor = 0
        self._hub_ids: Tuple[int, ...] = ()
        # host copies of the per-vertex arrays (storage order), kept for the
        # hub-selection pass in as_sharded
        self._deg_host: Optional[np.ndarray] = None
        self._vmask_host: Optional[np.ndarray] = None

    def _set_perm(self, perm: np.ndarray):
        perm = np.asarray(perm, dtype=np.int64)
        if perm.shape != (self.n_blocks,) or not np.array_equal(
                np.sort(perm), np.arange(self.n_blocks)):
            raise ValueError(
                f"perm must be a permutation of range({self.n_blocks})")
        if np.array_equal(perm, np.arange(self.n_blocks)):
            return
        self.block_perm = perm
        self._pos = np.empty(self.n_blocks, dtype=np.int64)
        self._pos[perm] = np.arange(self.n_blocks)
        self.o2s, self.s2o = block_vertex_perms(perm, self.block_v)

    def _storage_row(self, blk: int) -> int:
        return int(self._pos[blk]) if self._pos is not None else int(blk)

    @property
    def n(self) -> int:
        return self.inc.n

    @property
    def b_max_floor(self) -> int:
        """Monotonic halo width (padded boundary blocks per shard) the jitted
        halo superstep is compiled for; growth means a recompile
        (`StreamRunner` attributes it as a "halo-widen" event)."""
        return self._b_max_floor

    @property
    def h_max_floor(self) -> int:
        """Monotonic per-vertex need-list padding (per shard pair) — the
        vertex-granularity analogue of `b_max_floor`."""
        return self._h_max_floor

    @property
    def hub_pad_floor(self) -> int:
        """Monotonic replicated-hub-region length; growth means the hub set
        was promoted (`StreamRunner` attributes it as a "hub-promote"
        event)."""
        return self._hub_pad_floor

    @property
    def hub_ids(self) -> Tuple[int, ...]:
        """The replicated hub set (monotonic across deltas — once a vertex
        is mirrored everywhere, demoting it would reshuffle every shard's
        buffer layout for no traffic win)."""
        return self._hub_ids

    def _round_e(self, need: int) -> int:
        return -(-max(need, 1) // self.edge_chunk) * self.edge_chunk

    def _fill(self, g: Graph, blk: int):
        fill_block_slab(g, blk, self.block_v, self._blk_dst, self._blk_row,
                        self._blk_w, out_blk=self._storage_row(blk),
                        dst_map=self.o2s)

    def apply(self, delta: EdgeDelta) -> Tuple[DeviceGraph, MergeInfo]:
        info = self.inc.apply(delta)
        g = self.inc.to_graph()
        self.graph = g

        sizes = block_slab_sizes(g.adj_ptr, g.n, self.block_v, self._real_blocks)
        need = int(sizes.max()) if sizes.size else 0
        if need > self.e_max or self.e_max == 0:
            # overflow: re-pad every slab with headroom (one jit recompile)
            self.e_max = self._round_e(int(need * self.e_headroom))
            self._blk_dst = np.zeros((self.n_blocks, self.e_max), dtype=np.int32)
            self._blk_row = np.zeros((self.n_blocks, self.e_max), dtype=np.int32)
            self._blk_w = np.zeros((self.n_blocks, self.e_max), dtype=np.float32)
            dirty = np.arange(self._real_blocks)
            info.repadded = True
        else:
            touched = info.touched_vertices
            dirty = np.unique(touched // self.block_v) if touched.size else np.empty(0, np.int64)
        for blk in dirty:
            self._fill(g, int(blk))
        info.dirty_blocks = int(len(dirty))

        if not self._perm_decided and g.m > 0:
            # decide the stream's assignment from the first non-empty merge
            # (slabs are still in natural order at this point), then rebuild
            # every slab into permuted storage — a one-time full rewrite,
            # same cost class as the initial fill
            adj = block_adjacency(self._blk_dst, self._blk_w, self.block_v)
            perm = locality_block_order(adj, int(self.mesh.shape["blocks"]))
            self._perm_decided = True
            self._set_perm(perm)
            if self.block_perm is not None:
                self._blk_dst[:] = 0
                self._blk_row[:] = 0
                self._blk_w[:] = 0.0
                for blk in range(self._real_blocks):
                    self._fill(g, blk)

        self.device_graph = self._to_device(g)
        return self.device_graph, info

    def as_sharded(
        self,
        *,
        halo: bool = False,
        halo_threshold: float = DEFAULT_HALO_THRESHOLD,
        halo_granularity: str = "auto",
        hubs: Optional[HubConfig] = None,
    ) -> ShardedDeviceGraph:
        """Wrap the latest device layout for the sharded/halo schedules.

        The arrays are already mesh-aligned, permuted, and placed; this
        attaches the assignment metadata (so carried labels/probs convert
        at the API boundary) and, for `halo=True`, the exchange plan
        rebuilt against the current slabs (`halo_granularity` / `hubs` as
        in `build_halo_spec`). Every exchange shape is floored at its
        historical maximum — `b_max`, the per-vertex `h_max`, the hub
        region `hub_pad`, and the vote-table `he_max` — so the jitted
        superstep keeps its shapes while the halo only drifts; growth past
        a floor recompiles (a "halo-widen" or, for the hub region, a
        "hub-promote" event). The hub set itself is monotonic: hubs
        promoted by an earlier delta stay replicated.
        """
        if self.mesh is None:
            raise ValueError("as_sharded needs a mesh-aligned layout")
        if self.device_graph is None:
            raise ValueError("no device layout yet; apply a delta first")
        n_shards = int(self.mesh.shape["blocks"])
        spec = None
        if halo:
            spec = build_halo_spec(
                self._blk_dst, self._blk_w, n_shards, self.block_v,
                threshold=halo_threshold, granularity=halo_granularity,
                b_max_floor=self._b_max_floor,
                h_max_floor=self._h_max_floor,
                hubs=hubs,
                deg=self._deg_host, vmask=self._vmask_host,
                blk_row=self._blk_row,
                hub_ids_floor=self._hub_ids,
                hub_pad_floor=self._hub_pad_floor,
                he_max_floor=self._he_max_floor,
                mesh=self.mesh)
            self._b_max_floor = spec.b_max
            self._h_max_floor = spec.h_max
            if not spec.fallback:
                self._hub_ids = tuple(int(h) for h in spec.hub_ids)
                self._hub_pad_floor = max(self._hub_pad_floor, spec.hub_pad)
                self._he_max_floor = max(self._he_max_floor, spec.he_max)
        return ShardedDeviceGraph(
            dg=self.device_graph,
            mesh=self.mesh,
            n_shards=n_shards,
            blocks_per_shard=self.n_blocks // n_shards,
            block_perm=(tuple(int(b) for b in self.block_perm)
                        if self.block_perm is not None else None),
            o2s=self.o2s,
            s2o=self.s2o,
            halo=spec,
        )

    def _to_device(self, g: Graph) -> DeviceGraph:
        n_pad = self.n_pad
        deg_out = np.zeros(n_pad, dtype=np.float32)
        deg_out[: g.n] = g.deg_out.astype(np.float32)
        wsum = np.zeros(n_pad, dtype=np.float32)
        np.add.at(
            wsum,
            np.repeat(np.arange(g.n), np.diff(g.adj_ptr).astype(np.int64)),
            g.adj_w,
        )
        inv_wsum = np.where(wsum > 0, 1.0 / np.maximum(wsum, 1e-30), 0.0).astype(np.float32)
        vmask = np.zeros(n_pad, dtype=bool)
        vmask[: g.n] = True
        src_flat = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.adj_ptr).astype(np.int64))
        dir_src = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.row_ptr).astype(np.int64))
        edge_dst, dir_dst = g.adj_idx, g.col_idx
        if self.block_perm is not None:
            # storage-permuted layout: per-vertex arrays follow their block,
            # flat metric ids are rewritten so metrics read the same space
            # the (permuted) labels live in. This mirrors
            # device_graph.permute_blocks field-for-field (the incremental
            # path permutes incrementally instead of wholesale); a
            # DeviceGraph field added to one site must be added to the
            # other — tests/test_halo.py pins the two layouts equal.
            perm = self.block_perm

            def pv(a):
                return a.reshape(self.n_blocks, self.block_v)[perm].reshape(-1)

            deg_out, inv_wsum, vmask = pv(deg_out), pv(inv_wsum), pv(vmask)
            src_flat = self.o2s[src_flat]
            edge_dst = self.o2s[edge_dst]
            dir_src = self.o2s[dir_src]
            dir_dst = self.o2s[dir_dst]
        # storage-order host copies feed the hub-selection pass (as_sharded)
        self._deg_host, self._vmask_host = deg_out, vmask
        if self.mesh is not None:
            # device-aligned placement: each slab row / per-vertex slice goes
            # straight from host to its owning device; flat metric arrays
            # are replicated so eager metrics stay SPMD-legal
            def put_blocked(a):
                return jax.device_put(a, NamedSharding(self.mesh, P("blocks", None)))

            def put_vertex(a):
                return jax.device_put(a, NamedSharding(self.mesh, P("blocks")))

            def put_flat(a):
                return jax.device_put(np.asarray(a), NamedSharding(self.mesh, P()))
        else:
            put_blocked = put_vertex = put_flat = jnp.asarray
        return DeviceGraph(
            n=g.n,
            n_pad=n_pad,
            m=g.m,
            n_blocks=self.n_blocks,
            block_v=self.block_v,
            e_max=self.e_max,
            edge_src=put_flat(src_flat),
            edge_dst=put_flat(edge_dst),
            edge_w=put_flat(g.adj_w),
            dir_src=put_flat(dir_src),
            dir_dst=put_flat(dir_dst),
            blk_dst=put_blocked(self._blk_dst),
            blk_row=put_blocked(self._blk_row),
            blk_w=put_blocked(self._blk_w),
            deg_out=put_vertex(deg_out),
            inv_wsum=put_vertex(inv_wsum),
            vmask=put_vertex(vmask),
        )
