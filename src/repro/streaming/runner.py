"""StreamRunner: ingest -> warm-start refine -> metrics, per delta.

The streaming lifecycle (see the package README):

  1. an `EdgeDelta` arrives (from a `StreamBuffer` or `stream_from_graph`);
  2. `IncrementalDeviceGraph.apply` merges it — sorted-key splice on the
     host, dirty-block slab rewrite on the device layout;
  3. the refine algorithm is warm-started from the previous assignment
     (surviving vertices keep their labels — and, for probs-carrying
     algorithms like Revolver, their learned LA probabilities — new
     vertices start cold) and refined for a handful of supersteps with the
     paper's score-stall halting;
  4. quality metrics are reported per delta (`DeltaReport`).

The refine algorithm is any engine-driven entry in the algorithm registry
(`algo="revolver"` by default; "spinner" and "restream" work unchanged
because warm starts, schedules, and donation all come from the shared
engine). Because the block layout is shape-stable across deltas, the jitted
superstep compiles once for the whole stream (plus once more per e_max
re-pad), and a warm start typically converges in ~patience supersteps
instead of the hundreds a cold batch run needs.

Restream mode (`StreamConfig.restream=True`) follows the prioritized
restreaming idea (Awadelkarim & Ugander): after each merge the highest-degree
vertices — the ones whose placement matters most for edge locality — are
replayed in priority-ordered chunks. Replaying a chunk resets its vertices'
LA probabilities to uniform (they re-decide from scratch against the current
configuration) and runs a couple of supersteps before the next chunk, then
the normal refine loop finishes the pass. (It requires a probs-carrying
algorithm; with `algo="restream"` the degree-priority ramp is built into
the rule itself, so the replay pass would be redundant.)
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.checkpoint import store as ckpt_store
from repro.core import engine
from repro.core.device_graph import vertices_to_original
from repro.core.halo import DEFAULT_HALO_THRESHOLD, HubConfig
from repro.core.metrics import local_edges, max_normalized_load
from repro.core.registry import Algorithm, get_algorithm
from repro.core.runner import run_convergence_loop
from repro.streaming.delta_graph import IncrementalDeviceGraph
from repro.streaming.stream import EdgeDelta

_log = logging.getLogger("repro.streaming")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs for the incremental repartitioning loop."""

    k: int
    n_blocks: int = 8
    refine_max_steps: int = 40      # superstep budget per delta
    refine_patience: int = 3        # score-stall halting within a delta
    theta: float = 0.001
    sync_every: int = 4             # device->host score fetch window
    restream: bool = False          # prioritized high-degree replay per delta
    restream_frac: float = 0.25     # fraction of vertices replayed
    restream_chunks: int = 4        # priority chunks per replay pass
    restream_steps_per_chunk: int = 2
    warm_sharpen: float = 0.0       # blend carried LA probs toward carried
                                    # labels (see revolver_init_from_labels)
    e_headroom: float = 1.5         # slack factor when a block re-pads


@dataclasses.dataclass
class DeltaReport:
    """Per-delta outcome: merge stats + refinement cost + partition quality."""

    delta_idx: int
    m: int                   # |E| after the merge
    added: int
    deleted: int
    steps: int               # supersteps spent refining this delta
    converged: bool
    local_edges: float
    max_norm_load: float
    dirty_blocks: int
    repadded: bool
    wall_s: float


class StreamRunner:
    """Keeps a partition fresh over an edge stream.

    The runner owns the incremental graph state plus the carried assignment
    (labels, and LA probabilities when the algorithm has them). Each
    `ingest(delta)` returns a `DeltaReport`; `run(stream)` drains an
    iterator of deltas.

    `algo` names any engine-driven algorithm in the registry; `**algo_kwargs`
    flow into its config dataclass, so the kernel dispatch knobs plumb
    through the streaming path exactly as in the batch runner:
    `StreamRunner(n, cfg, hist_impl="pallas", la_impl="pallas")` refines
    every delta through the fused dual-histogram edge-phase kernel and the
    Pallas LA update (typos raise at construction, see the config
    `__post_init__` validation).

    `chunk_schedule="sharded"` runs every refine superstep data-parallel on
    a ``("blocks",)`` mesh (pass `mesh=`, default all visible devices). The
    incremental layout is mesh-aligned up front, so a delta's rewritten
    dirty slabs transfer straight to their owning device and the jitted
    sharded superstep stays shape-stable across the stream.
    `chunk_schedule="halo"` syncs only the precomputed boundary blocks each
    superstep (`repro.core.halo`; the plan is refreshed per delta with a
    monotonic `b_max` so shapes stay jit-stable), and `assignment=
    "locality"` permutes the block->shard mapping so densely connected
    blocks share a shard — decided once from the first merged delta and
    held fixed, with dirty slabs still landing directly on their owning
    shard under the permuted layout. Carried labels/probabilities stay in
    original vertex order regardless of the assignment.

    `halo_granularity` / `hub_replication` (+ `hub_quantile` /
    `hub_target_coverage`) select the per-vertex exchange plan and hub
    replication exactly as in `run_partitioner`, rebuilt per delta with
    monotonic shape floors: `h_max` / `b_max` growth is a "halo-widen"
    recompile, hub-region growth a "hub-promote" one, and the hub set only
    ever grows across the stream (promoted hubs stay replicated). The
    floors and hub set ride the stream checkpoints, so a resumed runner
    compiles the same shapes and continues bit-identically.
    """

    def __init__(self, n: int, cfg: StreamConfig, *, algo: str = "revolver",
                 seed: int = 0, mesh=None, assignment="contiguous",
                 halo_threshold: float = DEFAULT_HALO_THRESHOLD,
                 halo_granularity: str = "auto",
                 hub_replication: bool = False,
                 hub_quantile: float = 0.0,
                 hub_target_coverage: Optional[float] = None,
                 trace=None, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1, resume: bool = False,
                 keep_checkpoints: int = 2, **algo_kwargs):
        self.cfg = cfg
        # `trace` (a repro.obs.Tracer; default off) records the whole stream:
        # one "delta" span per ingest with merge/warm-start/superstep children
        # numbered globally across deltas, per-delta counters, and recompile
        # events with attributed causes ("e_max-repad" / "halo-widen"); with
        # tracing off the shape-change recompiles log a one-line warning
        # instead of passing silently
        self.tracer = trace if trace is not None else obs.NULL_TRACER
        self.algo = get_algorithm(algo)
        if not isinstance(self.algo, Algorithm):
            raise ValueError(
                f"{algo!r} runs no supersteps; streaming refinement needs an "
                "engine-driven algorithm")
        if self.algo.init_from_labels is None:
            raise ValueError(f"{algo!r} does not support warm starts")
        if cfg.restream and not self.algo.supports_probs:
            raise ValueError(
                "StreamConfig.restream replays vertices by resetting their LA "
                f"probabilities, which {algo!r} does not carry (use "
                "algo='restream' for a rule with a built-in priority ramp)")
        if cfg.warm_sharpen and not self.algo.supports_probs:
            raise ValueError(
                f"StreamConfig.warm_sharpen needs LA state; {algo!r} has none")
        # one config for every refine call -> one jit cache entry per layout
        self.rcfg = self.algo.config_cls(
            k=cfg.k,
            max_steps=cfg.refine_max_steps,
            patience=cfg.refine_patience,
            theta=cfg.theta,
            **algo_kwargs,
        )
        sharded = self.rcfg.chunk_schedule in ("sharded", "halo", "async")
        if sharded and mesh is None:
            from repro.launch.mesh import make_blocks_mesh

            mesh = make_blocks_mesh()
        if mesh is not None and not sharded:
            raise ValueError(
                "mesh is only meaningful with chunk_schedule='sharded'/"
                "'halo'/'async'")
        if not sharded and not (isinstance(assignment, str)
                                and assignment == "contiguous"):
            raise ValueError(
                "assignment is only meaningful with chunk_schedule="
                "'sharded'/'halo'/'async'")
        self.mesh = mesh
        self._halo = self.rcfg.chunk_schedule in ("halo", "async")
        self._halo_threshold = halo_threshold
        if halo_granularity not in ("auto", "block", "vertex"):
            raise ValueError(
                f"halo_granularity={halo_granularity!r} is not one of "
                "('auto', 'block', 'vertex')")
        if halo_granularity != "auto" and not self._halo:
            raise ValueError(
                "halo_granularity is only meaningful with "
                "chunk_schedule='halo'")
        if not hub_replication and (hub_quantile
                                    or hub_target_coverage is not None):
            raise ValueError(
                "hub_quantile/hub_target_coverage need hub_replication=True")
        if hub_replication and not self._halo:
            raise ValueError(
                "streaming hub replication rides the halo exchange plan; "
                "use chunk_schedule='halo'")
        self._halo_granularity = halo_granularity
        self._hubs = (HubConfig(quantile=hub_quantile,
                                target_coverage=hub_target_coverage)
                      if hub_replication else None)
        # async staleness driver (chunk_schedule="async"): the cached halo
        # tail indexes one layout's slabs, so it is invalidated whenever the
        # incremental layout grows/rebuilds (tracked by object identity)
        self._async_cache = None
        self._async_dg = None
        self._async_g = 0
        self._async_last_refresh = 0
        self.idg = IncrementalDeviceGraph(
            n, n_blocks=cfg.n_blocks, e_headroom=cfg.e_headroom, mesh=mesh,
            assignment=assignment,
        )
        self._key = jax.random.PRNGKey(seed)
        self.labels: Optional[np.ndarray] = None   # [n_active] carried labels
        self.probs: Optional[np.ndarray] = None    # carried LA probabilities
        self.reports: List[DeltaReport] = []
        # crash safety (docs/fault-tolerance.md): per-delta durability — each
        # checkpoint captures the incremental CSR + block slabs + carried
        # assignment + PRNG key, so a resumed runner continues the stream
        # bit-identically without replaying already-ingested deltas
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1 (deltas), got {checkpoint_every}")
        if checkpoint_dir is None and resume:
            raise ValueError("resume needs a checkpoint_dir")
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.keep_checkpoints = keep_checkpoints
        self._ckpt_handle: Optional[ckpt_store.Handle] = None
        self.delta_base = 0      # deltas ingested by earlier processes
        self._steps_base = 0     # their supersteps (keeps span numbering and
                                 # total_steps monotonic across a resume)
        if resume:
            self._restore_latest()

    @property
    def total_steps(self) -> int:
        """Supersteps across the whole stream, including deltas ingested
        before a resume (their per-delta reports live with the process that
        ran them; only the counters survive the crash)."""
        return self._steps_base + sum(r.steps for r in self.reports)

    @property
    def deltas_ingested(self) -> int:
        return self.delta_base + len(self.reports)

    def ingest(
        self,
        delta: EdgeDelta,
        *,
        max_steps: Optional[int] = None,
        patience: Optional[int] = None,
    ) -> DeltaReport:
        """Merge one delta and refine. `max_steps` / `patience` override the
        config for this delta only — callers that know the stream's shape
        (e.g. a quiet period ahead, or the initial bulk load) can spend
        their superstep budget unevenly."""
        tracer = self.tracer
        with obs.use(tracer), tracer.span("delta", idx=self.deltas_ingested):
            try:
                return self._ingest(delta, max_steps=max_steps,
                                    patience=patience)
            finally:
                # a noted cause no compile consumed (e.g. the repadded layout
                # hit a still-cached shape) must not mis-attribute a later,
                # unrelated recompile
                tracer.clear_recompile_cause()

    def _ingest(
        self,
        delta: EdgeDelta,
        *,
        max_steps: Optional[int],
        patience: Optional[int],
    ) -> DeltaReport:
        t0 = time.time()
        cfg = self.cfg
        tracer = self.tracer
        idx = self.deltas_ingested   # global index across resumes
        faults.fire("delta", idx)
        step0 = self.total_steps   # superstep spans numbered across deltas
        max_steps = cfg.refine_max_steps if max_steps is None else max_steps
        patience = cfg.refine_patience if patience is None else patience
        with tracer.span("merge", idx=idx):
            dg, info = self.idg.apply(delta)
            if info.repadded and idx > 0:
                # shape change -> the jitted refine superstep recompiles on
                # dispatch; attribute it (or at least say so out loud). The
                # first delta's "re-pad" is the initial allocation — that
                # compile is a plain first-compile, not a recompile.
                tracer.note_recompile_cause("e_max-repad")
                if not tracer.enabled:
                    _log.warning(
                        "delta %d: e_max re-pad to %d recompiles the refine "
                        "superstep (pass trace= for attributed recompile "
                        "events)", idx, self.idg.e_max)
            if self.mesh is not None:
                # arrays are already aligned, permuted, and placed
                # (IncrementalDeviceGraph owns the mesh and the assignment);
                # this wraps them with the metadata the sharded/halo schedules
                # and the label-order conversions need
                prev_b = self.idg.b_max_floor
                prev_h = self.idg.h_max_floor
                prev_hub = self.idg.hub_pad_floor
                dg = self.idg.as_sharded(
                    halo=self._halo, halo_threshold=self._halo_threshold,
                    halo_granularity=self._halo_granularity, hubs=self._hubs)
                widened = (0 < prev_b < self.idg.b_max_floor
                           or 0 < prev_h < self.idg.h_max_floor)
                promoted = 0 < prev_hub < self.idg.hub_pad_floor
                if self._halo and promoted:
                    # the hub region outgrew its padding: new hubs were
                    # promoted into every shard's replicated buffer
                    tracer.note_recompile_cause("hub-promote")
                    if not tracer.enabled:
                        _log.warning(
                            "delta %d: hub set grew to hub_pad=%d, "
                            "recompiling the refine superstep (pass trace= "
                            "for attributed recompile events)",
                            idx, self.idg.hub_pad_floor)
                elif self._halo and widened:
                    tracer.note_recompile_cause("halo-widen")
                    if not tracer.enabled:
                        _log.warning(
                            "delta %d: halo widened to b_max=%d/h_max=%d, "
                            "recompiling the refine superstep (pass trace= "
                            "for attributed recompile events)",
                            idx, self.idg.b_max_floor, self.idg.h_max_floor)
        if tracer.enabled:
            tracer.counter("delta_m", info.m, step=idx)
            tracer.counter("delta_added_edges", info.added, step=idx)
            tracer.counter("delta_deleted_edges", info.deleted, step=idx)
            tracer.counter("delta_dirty_blocks", info.dirty_blocks, step=idx)
            if self._halo and getattr(dg, "halo", None) is not None:
                spec = dg.halo
                n_fields = len(self.algo.vertex_fields)
                k = self.cfg.k
                wire_sum = sum(
                    spec.wire_bytes_per_elem(
                        k, f in self.algo.wire_int8_fields)
                    for f in self.algo.vertex_fields)
                tracer.counter("halo_b_max", spec.b_max, step=idx)
                tracer.counter("halo_h_max", spec.h_max, step=idx)
                tracer.counter("halo_coverage", spec.coverage, step=idx)
                tracer.counter(
                    "gathered_bytes_halo",
                    spec.gathered_elems_per_device() * wire_sum, step=idx)
                tracer.counter(
                    "gathered_bytes_full",
                    spec.full_gather_elems_per_device() * 4 * n_fields,
                    step=idx)
                if spec.granularity == "vertex" and not spec.fallback:
                    tracer.counter(
                        "pervertex_halo_bytes",
                        spec.gathered_elems_per_device() * wire_sum, step=idx)
                tracer.counter("hub_count", spec.n_hubs, step=idx)
                if spec.n_hubs:
                    tracer.counter(
                        "replica_vote_bytes",
                        spec.hub_sync_elems_per_device(k, n_fields) * 4,
                        step=idx)

        with tracer.span("warm-start", idx=idx, cold=self.labels is None):
            self._key, k_init = jax.random.split(self._key)
            if self.labels is None:
                state = self.algo.init(dg, self.rcfg, k_init)
            elif self.algo.supports_probs:
                state = self.algo.init_from_labels(
                    dg, self.rcfg, k_init, self.labels, probs=self.probs,
                    prob_sharpen=cfg.warm_sharpen,
                )
            else:
                state = self.algo.init_from_labels(dg, self.rcfg, k_init, self.labels)
            if self.mesh is not None:
                state = engine.place_state(self.algo, state, dg)

        steps = 0
        if cfg.restream and self.labels is not None:
            state, replay_steps = self._replay_prioritized(dg, state, step0)
            steps += replay_steps
        state, refine_steps, converged = self._refine(
            dg, state, max_steps, patience, step0 + steps)
        steps += refine_steps

        # carried state crosses the delta boundary in original vertex order
        # (identity on unpermuted layouts); metrics read the storage space
        # the labels and dir_*/deg arrays share
        self.labels = np.asarray(vertices_to_original(dg, state.labels)[: dg.n])
        if self.algo.supports_probs:
            flat = state.probs.reshape(dg.n_pad, cfg.k)
            self.probs = np.asarray(
                vertices_to_original(dg, flat).reshape(state.probs.shape))

        le = float(local_edges(state.labels, dg.dir_src, dg.dir_dst))
        ml = float(max_normalized_load(state.labels, dg.deg_out, cfg.k))
        if tracer.enabled:
            tracer.counter("delta_local_edges", le, step=idx)
            tracer.counter("delta_max_norm_load", ml, step=idx)
            tracer.counter("delta_steps", steps, step=idx)
        report = DeltaReport(
            delta_idx=idx,
            m=info.m,
            added=info.added,
            deleted=info.deleted,
            steps=steps,
            converged=converged,
            local_edges=le,
            max_norm_load=ml,
            dirty_blocks=info.dirty_blocks,
            repadded=info.repadded,
            wall_s=time.time() - t0,
        )
        self.reports.append(report)
        if tracer.enabled:
            # run manifest: trace_report --validate checks one superstep span
            # per executed step against this
            tracer.meta.setdefault("runs", []).append({
                "algo": self.algo.name, "k": cfg.k,
                "schedule": self.rcfg.chunk_schedule, "delta": idx,
                "steps": steps})
        if (self.checkpoint_dir is not None
                and self.deltas_ingested % self.checkpoint_every == 0):
            self._save_checkpoint()
        return report

    def run(self, stream: Iterable[EdgeDelta]) -> List[DeltaReport]:
        """Drain an iterator of deltas. On a resumed runner the first
        `delta_base` deltas are skipped — callers replay the *source* stream
        from the top and the runner fast-forwards past what the crashed
        process already ingested and checkpointed."""
        reports = []
        for i, delta in enumerate(stream):
            if i < self.delta_base:
                continue
            reports.append(self.ingest(delta))
        return reports

    def finish(self):
        """Block until the in-flight async checkpoint write (if any) is
        durable; re-raises writer failures."""
        if self._ckpt_handle is not None:
            self._ckpt_handle.wait()
            self._ckpt_handle = None

    # -- durability ---------------------------------------------------- #

    def _ckpt_meta(self) -> dict:
        idg = self.idg
        return {
            "kind": "stream", "algo": self.algo.name, "k": self.cfg.k,
            "n": idg.n, "m": idg.inc.m,
            "deltas": self.deltas_ingested, "steps": self.total_steps,
            "e_max": idg.e_max, "b_max_floor": idg.b_max_floor,
            "h_max_floor": idg.h_max_floor,
            "hub_pad_floor": idg.hub_pad_floor,
            "he_max_floor": idg._he_max_floor,
            "hub_ids": [int(h) for h in idg.hub_ids],
            "perm_decided": idg._perm_decided,
            "n_blocks": idg.n_blocks, "block_v": idg.block_v,
        }

    def _save_checkpoint(self):
        """One durable snapshot per `checkpoint_every` deltas: the host-side
        incremental CSR (sorted key/weight arrays), the padded block slabs,
        the carried assignment (labels + LA probs, original vertex order),
        and the PRNG key chain. Written async (atomic rename underneath);
        one writer in flight at a time."""
        if self._ckpt_handle is not None:
            self._ckpt_handle.wait()
        idg = self.idg
        tree = {
            "key": np.asarray(self._key),
            "dir_keys": idg.inc.dir_keys,
            "sym_keys": idg.inc.sym_keys,
            "sym_w": idg.inc.sym_w,
            "blk_dst": idg._blk_dst,
            "blk_row": idg._blk_row,
            "blk_w": idg._blk_w,
        }
        if self.labels is not None:
            tree["labels"] = self.labels
        if self.probs is not None:
            tree["probs"] = self.probs
        if idg.block_perm is not None:
            tree["block_perm"] = idg.block_perm
        with self.tracer.span("checkpoint-save", delta=self.deltas_ingested):
            self._ckpt_handle = ckpt_store.save_checkpoint(
                self.checkpoint_dir, self.deltas_ingested, tree,
                async_save=True, meta=self._ckpt_meta(),
                keep=self.keep_checkpoints)
        if self.tracer.enabled:
            self.tracer.counter("stream_checkpoints_saved",
                                float(self.deltas_ingested))

    def _restore_latest(self):
        """Resume from the newest usable checkpoint (corrupt ones skipped).
        No checkpoint at all -> a fresh stream, so the same construction
        works for the first launch and every relaunch."""
        for step in reversed(ckpt_store.all_steps(self.checkpoint_dir)):
            try:
                self._restore(step)
                return
            except (ckpt_store.CheckpointError, ValueError, KeyError) as e:
                _log.warning(
                    "stream checkpoint delta %d in %s unusable (%s); trying "
                    "the previous one", step, self.checkpoint_dir, e)

    def _restore(self, step: int):
        arrays, manifest = ckpt_store.load_checkpoint_arrays(
            self.checkpoint_dir, step)
        meta = manifest.get("meta", {})
        for field, mine in (("algo", self.algo.name), ("k", self.cfg.k),
                            ("n", self.idg.n)):
            if field in meta and meta[field] != mine:
                raise ValueError(
                    f"stream checkpoint delta {step} belongs to a different "
                    f"stream: {field}={meta[field]!r} vs this runner's "
                    f"{mine!r}")
        idg = self.idg
        for field in ("n_blocks", "block_v"):
            if field in meta and meta[field] != getattr(idg, field):
                raise ValueError(
                    f"stream checkpoint delta {step} has {field}="
                    f"{meta[field]} but this runner's layout uses "
                    f"{getattr(idg, field)} (layout knobs must match across "
                    "a resume)")
        required = ("key", "dir_keys", "sym_keys", "sym_w",
                    "blk_dst", "blk_row", "blk_w")
        missing = [k for k in required if k not in arrays]
        if missing:
            raise KeyError(f"stream checkpoint missing arrays: {missing}")
        with self.tracer.span("checkpoint-restore", delta=step):
            inc = idg.inc
            inc.dir_keys = arrays["dir_keys"].astype(np.int64)
            inc.sym_keys = arrays["sym_keys"].astype(np.int64)
            inc.sym_w = arrays["sym_w"].astype(np.float32)
            idg.e_max = int(meta.get("e_max", arrays["blk_dst"].shape[1]))
            if arrays["blk_dst"].shape != (idg.n_blocks, idg.e_max):
                raise ValueError(
                    f"stream checkpoint slab shape {arrays['blk_dst'].shape} "
                    f"vs expected {(idg.n_blocks, idg.e_max)}")
            idg._blk_dst = arrays["blk_dst"].astype(np.int32)
            idg._blk_row = arrays["blk_row"].astype(np.int32)
            idg._blk_w = arrays["blk_w"].astype(np.float32)
            idg._b_max_floor = int(meta.get("b_max_floor", 0))
            idg._h_max_floor = int(meta.get("h_max_floor", 0))
            idg._hub_pad_floor = int(meta.get("hub_pad_floor", 0))
            idg._he_max_floor = int(meta.get("he_max_floor", 0))
            idg._hub_ids = tuple(int(h) for h in meta.get("hub_ids", ()))
            if "block_perm" in arrays:
                idg._set_perm(arrays["block_perm"].astype(np.int64))
            idg._perm_decided = bool(meta.get("perm_decided", True))
            idg.graph = inc.to_graph()
            idg.device_graph = idg._to_device(idg.graph)
            self._key = jnp.asarray(arrays["key"])
            self.labels = (arrays["labels"].copy()
                           if "labels" in arrays else None)
            self.probs = (arrays["probs"].copy()
                          if "probs" in arrays else None)
            self.delta_base = int(meta.get("deltas", step))
            self._steps_base = int(meta.get("steps", 0))
            inc.deltas_applied = self.delta_base  # global error attribution
        if self.tracer.enabled:
            self.tracer.instant("resumed", delta=self.delta_base)
        _log.info("resumed stream at delta %d (%d supersteps) from %s",
                  self.delta_base, self._steps_base, self.checkpoint_dir)

    # ------------------------------------------------------------------ #

    def _superstep(self, dg, state):
        if self.rcfg.chunk_schedule != "async":
            return engine.superstep(self.algo, dg, self.rcfg, state)
        if dg is not self._async_dg:
            self._async_dg, self._async_cache = dg, None
        bound = getattr(self.rcfg, "staleness_bound", 0)
        g = self._async_g
        refresh = (self._async_cache is None or bound == 0
                   or g % (bound + 1) == 0)
        if refresh:
            self._async_cache = None
            self._async_last_refresh = g
        state, self._async_cache = engine.async_superstep(
            self.algo, dg, self.rcfg, state, cache=self._async_cache)
        if self.tracer.enabled:
            self.tracer.counter(
                "halo_staleness", float(g - self._async_last_refresh), step=g)
        self._async_g = g + 1
        return state

    def _refine(self, dg, state, max_steps: int, patience: int,
                step0: int = 0):
        """Warm refinement via the shared score-stall convergence loop
        (same halting semantics as `run_partitioner`, windowed host sync)."""
        return run_convergence_loop(
            lambda s: self._superstep(dg, s), state,
            max_steps=max_steps, patience=patience, theta=self.rcfg.theta,
            sync_every=self.cfg.sync_every,
            tracer=self.tracer, step0=step0,
        )

    def _replay_prioritized(self, dg, state, step0: int = 0) -> Tuple[object, int]:
        """Restream pass: reset the LA state of high-degree vertices in
        priority-ordered chunks, letting each chunk re-decide before the
        next is released (high-degree-first, per the restreaming paper)."""
        cfg = self.cfg
        # full padded degree vector: real vertices are not a prefix under a
        # permuted assignment, and padding (degree 0) never wins the top-k;
        # the selected positions are storage ids, matching the probs rows
        deg = np.asarray(dg.deg_out)
        n_replay = int(cfg.restream_frac * dg.n)
        if n_replay == 0:
            return state, 0
        order = np.argsort(-deg, kind="stable")[:n_replay]
        chunks = np.array_split(order, min(cfg.restream_chunks, n_replay))
        steps = 0
        for chunk in chunks:
            flat = state.probs.reshape(dg.n_pad, cfg.k)
            flat = flat.at[jnp.asarray(chunk)].set(1.0 / cfg.k)
            state = state._replace(probs=flat.reshape(dg.n_blocks, dg.block_v, cfg.k))
            for _ in range(cfg.restream_steps_per_chunk):
                with self.tracer.span("superstep", step=step0 + steps,
                                      replay=True):
                    state = self._superstep(dg, state)
                steps += 1
        return state, steps
