"""Trainer: the fault-tolerant training driver.

Features exercised by tests/test_train.py and examples/train_lm.py:
  * checkpoint every N steps (async, atomic-rename) + auto-resume
  * simulated node failure (SimulatedFailure at a given step) — a
    restarted Trainer resumes bit-exact (deterministic data pipeline +
    restored optimizer state)
  * straggler detection: EMA of step wall-time; steps slower than
    ``straggler_factor`` x EMA are counted and surfaced so the launcher
    can rotate the slow host out (mitigation hook)
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.checkpoint.store import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, make_batch
from repro.optim.adamw import OptConfig
from repro.train.step import init_train_state, make_train_step
from repro.utils.logging import MetricLogger


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, cfg, opt_cfg: OptConfig, data_cfg: DataConfig, *,
                 ckpt_dir: str, ckpt_every: int = 50, microbatch: int = 1,
                 straggler_factor: float = 3.0, inject_failure_at: int | None = None,
                 logger: MetricLogger | None = None, host_id: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.inject_failure_at = inject_failure_at
        self.straggler_factor = straggler_factor
        self.host_id = host_id
        self.log = logger or MetricLogger()
        self.straggler_events = 0
        self._ema = None
        self._pending_save = None
        self._step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                                microbatch=microbatch),
                                donate_argnums=(0,))
        self.state = None
        self.step = 0

    # -- lifecycle -----------------------------------------------------------
    def init_or_resume(self, key):
        last = latest_step(self.ckpt_dir)
        if last is None:
            self.state = init_train_state(self.cfg, self.opt_cfg, key)
            self.step = 0
            self.log.log("init", resumed=False, step=0)
        else:
            like = jax.eval_shape(
                lambda k: init_train_state(self.cfg, self.opt_cfg, k), key)
            self.state = restore_checkpoint(self.ckpt_dir, last, like)
            self.step = last
            self.log.log("init", resumed=True, step=last)
        return self

    # -- straggler detection ---------------------------------------------------
    def _observe_time(self, dt: float) -> bool:
        is_straggler = (self._ema is not None
                        and dt > self.straggler_factor * self._ema)
        self._ema = dt if self._ema is None else 0.9 * self._ema + 0.1 * dt
        if is_straggler:
            self.straggler_events += 1
        return is_straggler

    # -- main loop --------------------------------------------------------------
    def run(self, num_steps: int):
        history = []
        while self.step < num_steps:
            if self.inject_failure_at is not None and \
                    self.step == self.inject_failure_at:
                self.inject_failure_at = None     # fail once
                raise SimulatedFailure(f"injected at step {self.step}")
            batch = make_batch(self.data_cfg, self.step, self.host_id)
            t0 = time.monotonic()
            self.state, metrics = self._step_fn(self.state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            straggler = self._observe_time(dt)
            self.step += 1
            history.append(loss)
            self.log.log("step", step=self.step, loss=loss, dt=round(dt, 4),
                         straggler=straggler)
            if self.step % self.ckpt_every == 0:
                if self._pending_save is not None:
                    self._pending_save.wait()
                self._pending_save = save_checkpoint(
                    self.ckpt_dir, self.step, self.state, async_save=True)
        if self._pending_save is not None:
            self._pending_save.wait()
        save_checkpoint(self.ckpt_dir, self.step, self.state)
        return history
