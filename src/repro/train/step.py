"""train_step / serve_step builders.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function: CE loss -> grads (optionally microbatched via lax.scan
accumulation) -> global-norm clip -> AdamW on f32 masters -> bf16 params.
State = {"params", "opt", "step"}; donate it at jit time.

``make_prefill_step`` / ``make_decode_step`` are the serving entry
points the dry-run lowers for the prefill_* / decode_* / long_* cells.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import init_cache, init_lm, lm_decode_step, lm_loss, lm_prefill
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state


def init_train_state(cfg, opt_cfg: OptConfig, key, *, ef_compression=False):
    params = init_lm(cfg, key)
    return {"params": params,
            "opt": init_opt_state(params, ef_compression=ef_compression),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg, opt_cfg: OptConfig, *, microbatch: int = 1):
    def loss_fn(params, mb):
        loss, metrics = lm_loss(params, cfg, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatch > 1:
            mbatch = jax.tree.map(
                lambda x: x.reshape((microbatch, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatch,
                    g_acc, g)
                return (g_acc, l_acc + loss / microbatch), None

            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), mbatch)
        else:
            (loss, _), grads = grad_fn(params, batch)

        new_params, opt, om = adamw_update(
            grads, state["opt"], opt_cfg, param_dtype=cfg.pdt)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": opt,
                "step": state["step"] + 1}, metrics

    return train_step


def make_prefill_step(cfg, s_max: int):
    def prefill_step(params, batch):
        b = batch["tokens"].shape[0]
        cache = init_cache(cfg, b, s_max)
        logits, cache = lm_prefill(params, cfg, cache, batch)
        return logits, cache
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, cache, token):
        return lm_decode_step(params, cfg, cache, token)
    return decode_step
