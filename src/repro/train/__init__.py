"""Training loop substrate: step builders + fault-tolerant Trainer."""
from repro.train.step import init_train_state, make_decode_step, make_prefill_step, make_train_step
from repro.train.trainer import SimulatedFailure, Trainer
__all__ = ["make_train_step", "init_train_state", "make_prefill_step", "make_decode_step", "Trainer", "SimulatedFailure"]
