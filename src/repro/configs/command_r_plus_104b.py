"""command-r-plus-104b — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01;
unverified]. 64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000.

Cohere block structure: parallel attention+FFN from a single LayerNorm,
tied embeddings, scaled logits. Pure full attention: long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=33792,
    vocab=256000,
    norm="layer",
    parallel_block=True,
    tie_embeddings=True,
    logit_scale=0.0625,
    rope_theta=75_000_000.0,
)
