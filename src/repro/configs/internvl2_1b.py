"""internvl2-1b — InternViT + InternLM2/Qwen2-0.5B backbone
[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655. The ViT frontend is a STUB: input_specs() provides 256
precomputed patch embeddings per example, merged before layer 0.

Pure full attention: long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151655,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    n_patches=256,
)
