"""zamba2-7b — Mamba2 + shared attn blocks [arXiv:2411.15242; unverified].

81L d_model=3584 32H d_ff=14336 vocab=32000, ssm_state=64. Layout:
13 x [shared-attn, 5 x mamba2] + 3 trailing mamba2 = 81 layers; the
attention block runs at 2*d width on concat(h, h0) with per-application
LoRA (rank 128) on q/k/v. Hybrid => long_500k RUNS (SSM state + 13
seq-sharded KV caches).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_head=64,
    n_attn_groups=13,
    mamba_per_group=5,
    trailing_mamba=3,
    lora_rank=128,
    rope_theta=10000.0,
)
