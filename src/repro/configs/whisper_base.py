"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified]. 6L(enc)+6L(dec) d_model=512 8H (MHA) d_ff=2048 vocab=51865.

The mel/conv frontend is a STUB: input_specs() provides 1500 precomputed
frame embeddings per example. Shape cells apply to the DECODER sequence.
Pure full attention: long_500k skipped. (The learned decoder position
table is sized for the 32k cells — far beyond the real 448 — which is a
consequence of the assigned backbone x shape grid, not of Whisper.)
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_ff=2048,
    vocab=51865,
    norm="layer",
    norm_bias=True,
    mlp_kind="gelu",
    tie_embeddings=True,
    enc_seq=1500,
    max_pos=36864,
)
