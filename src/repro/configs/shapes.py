"""Assigned input-shape set (identical for all 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len-deep cache), not ``train_step``; ``long_500k`` only runs for
sub-quadratic architectures (SSM / hybrid / SWA) — the skip matrix lives
in ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_NAMES = tuple(SHAPES)
