"""Architecture registry: the 10 assigned archs, the 4 shapes, the
skip matrix, and ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, SHAPE_NAMES, ShapeSpec
from repro.models.config import ModelConfig

ARCHS = {
    "tinyllama-1.1b": "tinyllama_1_1b",
    "command-r-plus-104b": "command_r_plus_104b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "stablelm-1.6b": "stablelm_1_6b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-7b": "zamba2_7b",
    "internvl2-1b": "internvl2_1b",
    "whisper-base": "whisper_base",
    "rwkv6-3b": "rwkv6_3b",
}

# archs with a sub-quadratic long-context mechanism run long_500k
_SUBQUADRATIC = {"h2o-danube-3-4b", "zamba2-7b", "rwkv6-3b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    return mod.CONFIG


def cell_skip_reason(arch: str, shape: str) -> str | None:
    """None = the (arch, shape) cell runs; otherwise the documented skip."""
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return ("pure full-attention arch: no sub-quadratic mechanism for a "
                "524k-token cache (DESIGN.md §6)")
    return None


def all_cells():
    """Yield (arch, shape, skip_reason) for the full 40-cell grid."""
    for arch in ARCHS:
        for shape in SHAPE_NAMES:
            yield arch, shape, cell_skip_reason(arch, shape)


def runnable_cells():
    return [(a, s) for a, s, skip in all_cells() if skip is None]


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# --------------------------------------------------------------------------
def _frontend_spec(cfg: ModelConfig, batch: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.n_patches, cfg.d_model), cfg.cdt)
    if cfg.family == "encdec":
        return jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), cfg.cdt)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str):
    """Batch ShapeDtypeStructs for a shape cell.

    train:   {"tokens": [B,S] i32, "labels": [B,S] i32, ("frontend")}
    prefill: {"tokens": [B,S] i32, ("frontend")}
    decode:  {"token":  [B]   i32}  (cache specs come from init_cache)
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b,), i32)}
    else:
        raise ValueError(shape.kind)
    fe = _frontend_spec(cfg, b)
    if fe is not None:
        specs["frontend"] = fe
    return specs


def reduced_shape(shape: ShapeSpec | str, *, seq: int = 32, batch: int = 2):
    if isinstance(shape, str):
        shape = SHAPES[shape]
    return ShapeSpec(shape.name, seq, batch, shape.kind)
