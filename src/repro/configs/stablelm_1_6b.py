"""stablelm-1.6b — [hf:stabilityai/stablelm-2-1_6b; unverified].

24L d_model=2048 32H (MHA: kv=32) d_ff=5632 vocab=100352. LayerNorm,
partial rotary (25%), qkv bias. Pure full attention: long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=5632,
    vocab=100352,
    norm="layer",
    norm_bias=True,
    rope_frac=0.25,
    qkv_bias=True,
    rope_theta=10000.0,
)
