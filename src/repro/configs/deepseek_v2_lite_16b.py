"""deepseek-v2-lite-16b — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf]. 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400; layer 0 dense (d_ff=10944); no q LoRA.

Pure full attention over the (compressed) cache: long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=10944,              # dense first layer
    vocab=102400,
    attn_kind="mla",
    q_lora_rank=0,
    kv_lora_rank=512,
    mla_d_nope=128,
    mla_d_rope=64,
    mla_d_v=128,
    moe=True,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared_experts=2,
    first_dense=1,
    routed_scale=1.0,
    rope_theta=10000.0,
)
