"""h2o-danube-3-4b — llama+mistral mix, SWA [arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding window
4096. SWA makes decode cache window-bounded, so long_500k RUNS for this
arch (ring-buffer cache of 4096).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv=8,
    d_ff=10240,
    vocab=32000,
    window=4096,
    rope_theta=10000.0,
)
