"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]. 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400; layer 0 is dense (d_ff=12288); q_lora_rank=1536.

Pure full attention over the (compressed) cache: long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv=128,
    d_ff=12288,              # dense first layer
    vocab=102400,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    mla_d_nope=128,
    mla_d_rope=64,
    mla_d_v=128,
    moe=True,
    n_experts=160,
    top_k=6,
    d_ff_expert=1536,
    n_shared_experts=2,
    first_dense=1,
    routed_scale=16.0,
    rope_theta=10000.0,
)
