"""rwkv6-3b "Finch" — data-dependent decay, attention-free
[arXiv:2404.05892; hf]. 32L d_model=2560 d_ff=8960 vocab=65536.

Head layout adaptation (DESIGN.md): upstream Finch uses 64-dim heads
(40 heads at d=2560); we use 32 heads x 80 so the head axis divides the
16-way model mesh axis. O(1) state => long_500k RUNS.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv=0,
    rwkv_heads=32,
    d_ff=8960,
    vocab=65536,
    norm="layer",
    mix_rank=32,
    decay_rank=64,
)
