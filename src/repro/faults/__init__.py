"""Deterministic fault injection (see `repro.faults.inject`).

Production code calls `fire(point, index)` at its injection points; the
call is a no-op early return unless a plan is active (the ``REPRO_FAULTS``
env var or a `use_plan` scope), so crash-safety hooks cost nothing when
nothing is being injected.
"""
from repro.faults.inject import (
    ENV_VAR,
    FaultAction,
    FaultPlan,
    active_plan,
    fire,
    parse_faults,
    poison,
    use_plan,
)

__all__ = [
    "ENV_VAR",
    "FaultAction",
    "FaultPlan",
    "active_plan",
    "fire",
    "parse_faults",
    "poison",
    "use_plan",
]
