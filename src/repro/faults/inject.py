"""Deterministic fault injection for crash-safety testing.

A *fault plan* is a comma-separated spec, normally supplied through the
``REPRO_FAULTS`` environment variable so a real subprocess run can be killed
and resumed from the outside (the CI kill-and-resume legs), or installed
programmatically with `use_plan` for in-process tests:

  kill@superstep=12    SIGKILL the process right after superstep 12 is
                       dispatched (global step numbering — streaming refines
                       count across deltas)
  kill@delta=2         SIGKILL before delta 2 is merged (stream checkpoints
                       for deltas 0..1 are on disk)
  kill@save            SIGKILL mid checkpoint save, after the payload +
                       manifest are written but *before* the atomic rename —
                       leaves a ``.tmp`` dir a resume must ignore
  kill@save-payload    SIGKILL after the npz payload, before the manifest —
                       a torn write inside the ``.tmp`` dir
  kill@save=1          index a repeated point: kill at the *second* save
  nan@superstep=8      poison the LA probability tensor with NaN after
                       step 8 (exercises the drain-window guard)
  badlabel@superstep=8 poison ``labels[0]`` with an out-of-range value

Injection points are checked with `fire(point, index)`; when no plan is
active the check is a single attribute load and an early return, so the
hooks cost nothing in production paths. Kill actions never return; poison
actions return their name and the caller applies `poison` to its state.
All injection is deterministic: the same plan and the same run produce the
same failure, which is what lets CI assert *exact* recovery.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import signal
import sys
from collections import defaultdict
from typing import Optional, Tuple

ENV_VAR = "REPRO_FAULTS"

_ACTIONS = ("kill", "nan", "badlabel")
_POINTS = ("superstep", "delta", "save", "save-payload")


@dataclasses.dataclass(frozen=True)
class FaultAction:
    action: str            # "kill" | "nan" | "badlabel"
    point: str             # "superstep" | "delta" | "save" | "save-payload"
    index: Optional[int]   # None = first time the point is hit


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    actions: Tuple[FaultAction, ...]


def parse_faults(spec: str) -> FaultPlan:
    """Parse a ``REPRO_FAULTS`` spec string (see module docstring)."""
    actions = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(
                f"bad fault spec {item!r}: expected action@point[=index]")
        action, _, rest = item.partition("@")
        point, eq, idx = rest.partition("=")
        if action not in _ACTIONS:
            raise ValueError(
                f"unknown fault action {action!r}; expected one of {_ACTIONS}")
        if point not in _POINTS:
            raise ValueError(
                f"unknown fault point {point!r}; expected one of {_POINTS}")
        index = None
        if eq:
            try:
                index = int(idx)
            except ValueError:
                raise ValueError(f"bad fault index in {item!r}") from None
        if action in ("nan", "badlabel") and point != "superstep":
            raise ValueError(f"{action!r} faults only apply at 'superstep'")
        actions.append(FaultAction(action, point, index))
    return FaultPlan(tuple(actions))


# module state: the active plan (lazily parsed from the environment once),
# per-point hit counters for index matching, and the consumed-action set so
# a poison fires exactly once
_plan: Optional[FaultPlan] = None
_env_loaded = False
_counts: dict = defaultdict(int)
_consumed: set = set()


def active_plan() -> Optional[FaultPlan]:
    global _plan, _env_loaded
    if not _env_loaded:
        _env_loaded = True
        spec = os.environ.get(ENV_VAR)
        if spec:
            _plan = parse_faults(spec)
    return _plan


@contextlib.contextmanager
def use_plan(plan):
    """Install a plan (a `FaultPlan` or spec string) for the scope — the
    in-process test hook mirroring the env var."""
    global _plan, _env_loaded
    if isinstance(plan, str):
        plan = parse_faults(plan)
    prev, prev_loaded = _plan, _env_loaded
    prev_counts, prev_consumed = dict(_counts), set(_consumed)
    _plan, _env_loaded = plan, True
    _counts.clear()
    _consumed.clear()
    try:
        yield plan
    finally:
        _plan, _env_loaded = prev, prev_loaded
        _counts.clear()
        _counts.update(prev_counts)
        _consumed.clear()
        _consumed.update(prev_consumed)


def _kill():
    # SIGKILL, not sys.exit: the point is an unhandleable crash — no atexit,
    # no finally blocks, no flushing beyond what we do here
    sys.stdout.flush()
    sys.stderr.flush()
    os.kill(os.getpid(), signal.SIGKILL)


def fire(point: str, index: Optional[int] = None) -> Optional[str]:
    """Check an injection point. Returns None (no matching fault), never
    returns (kill), or the poison action name for the caller to apply.

    ``index``: the caller's own deterministic counter (superstep / delta
    number). When the caller passes None the point keeps its own hit count,
    so ``kill@save=1`` means "the second save".
    """
    plan = active_plan()
    if plan is None:
        return None
    if index is None:
        index = _counts[point]
        _counts[point] += 1
    for i, act in enumerate(plan.actions):
        if act.point != point or i in _consumed:
            continue
        if act.index is not None and act.index != index:
            continue
        _consumed.add(i)
        if act.action == "kill":
            _kill()
        return act.action
    return None


def poison(state, action: str):
    """Apply a poison action to an algorithm state NamedTuple (device-side;
    the corruption is detected later, at a drain window, by the guard)."""
    import jax.numpy as jnp

    if action == "nan" and hasattr(state, "probs"):
        probs = state.probs
        flat = probs.reshape(-1)
        flat = flat.at[0].set(jnp.nan)
        return state._replace(probs=flat.reshape(probs.shape))
    if action in ("nan", "badlabel"):
        labels = state.labels
        return state._replace(labels=labels.at[0].set(jnp.int32(2**30)))
    raise ValueError(f"unknown poison action {action!r}")
