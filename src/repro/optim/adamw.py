"""AdamW + warmup-cosine schedule + global-norm clipping, mixed precision.

Optimizer state keeps f32 master weights and f32 moments; model params
may be bf16 (cast down after each update). State layout:

  {"master": f32 params, "m": f32, "v": f32, "count": i32,
   ("ef_err": f32 — error-feedback residuals when compression is on)}
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, *, ef_compression: bool = False):
    # copy=True: with f32 params, astype would alias the param buffer and
    # break buffer donation (same buffer donated twice)
    f32 = lambda p: jnp.array(p, jnp.float32, copy=True)
    state = {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if ef_compression:
        state["ef_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, opt_state, cfg: OptConfig, *, param_dtype):
    """Returns (new_params_in_param_dtype, new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g,
                     opt_state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                     opt_state["v"], grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - b1 ** c
    bc2 = 1 - b2 ** c

    def upd(p, mm, vv):
        step = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        return p - lr * (step + cfg.weight_decay * p)

    master = jax.tree.map(upd, opt_state["master"], m, v)
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), master)
    new_state = dict(opt_state, master=master, m=m, v=v, count=count)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
