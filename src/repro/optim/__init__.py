"""Optimizer: AdamW + schedule + clipping (+ EF-int8 compression hooks)."""
from repro.optim.adamw import OptConfig, adamw_update, clip_by_global_norm, init_opt_state, schedule
__all__ = ["OptConfig", "adamw_update", "clip_by_global_norm", "init_opt_state", "schedule"]
