"""LM zoo: composable raw-JAX model definitions for the 10 assigned
architectures (scan-over-layers, pluggable attention impls, serve caches)."""
from repro.models.api import (init_cache, init_lm, lm_decode_step, lm_loss,
                              lm_prefill)
from repro.models.config import ModelConfig

__all__ = ["ModelConfig", "init_lm", "lm_loss", "init_cache", "lm_prefill",
           "lm_decode_step"]
