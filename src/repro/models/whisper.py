"""Whisper-style encoder-decoder (arXiv:2212.04356), backbone only.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, enc_seq, d]. Encoder:
bidirectional MHA + GELU MLP, sinusoidal positions. Decoder: learned
positions, causal self-attention + cross-attention + GELU MLP. The
decoder output projection ties to the token embedding.

Serving cache: self-attn KV (grows with generated tokens) + cross-attn
KV (computed once from the encoder memory at prefill).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (apply_norm, chunked_cross_entropy, dense,
                                 embed_init, norm_init, sinusoid_pos)
from repro.models.config import ModelConfig


def enc_spec(cfg: ModelConfig) -> attn.AttnSpec:
    return attn.AttnSpec(
        d_model=cfg.d_model, n_q=cfg.n_heads, n_kv=cfg.n_kv,
        d_head=cfg.head_dim, causal=False, rope_frac=0.0,
        qkv_bias=True, o_bias=True, impl=cfg.impl,
        block_q=cfg.block_q, block_k=cfg.block_k)


def dec_spec(cfg: ModelConfig) -> attn.AttnSpec:
    return attn.AttnSpec(
        d_model=cfg.d_model, n_q=cfg.n_heads, n_kv=cfg.n_kv,
        d_head=cfg.head_dim, causal=True, rope_frac=0.0,
        qkv_bias=True, o_bias=True, impl=cfg.impl,
        block_q=cfg.block_q, block_k=cfg.block_k)


def _init_mlp(key, cfg):
    from repro.models.mlp import init_mlp
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.pdt, kind="gelu", bias=True)


def _init_enc_block(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    ln = lambda: norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True)
    return {"ln1": ln(), "attn": attn.init_attention(k1, enc_spec(cfg), cfg.pdt),
            "ln2": ln(), "mlp": _init_mlp(k2, cfg)}


def _init_dec_block(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    ln = lambda: norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True)
    return {"ln1": ln(), "self": attn.init_attention(k1, dec_spec(cfg), cfg.pdt),
            "ln2": ln(), "cross": attn.init_attention(k2, enc_spec(cfg), cfg.pdt),
            "ln3": ln(), "mlp": _init_mlp(k3, cfg)}


def init_whisper(cfg: ModelConfig, key):
    keys = jax.random.split(key, 4)
    return {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.pdt),
        "dec_pos": (jax.random.normal(keys[3], (cfg.max_pos, cfg.d_model),
                                      jnp.float32) * 0.01).astype(cfg.pdt),
        "enc_blocks": jax.vmap(partial(_init_enc_block, cfg))(
            jax.random.split(keys[1], cfg.n_enc_layers)),
        "enc_ln": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
        "dec_blocks": jax.vmap(partial(_init_dec_block, cfg))(
            jax.random.split(keys[2], cfg.n_layers)),
        "dec_ln": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
    }


def _mlp_apply(p, x):
    from repro.models.mlp import apply_mlp
    return apply_mlp(p, x, kind="gelu")


def encode(params, cfg: ModelConfig, frames):
    """frames [B, enc_seq, d] (stub embeddings) -> memory [B, enc_seq, d]."""
    h = frames.astype(cfg.cdt) + sinusoid_pos(frames.shape[1], cfg.d_model,
                                              cfg.cdt)

    def body(hh, pp):
        a = apply_norm(pp["ln1"], hh, kind="layer", eps=cfg.norm_eps)
        hh = hh + attn.apply_attention(pp["attn"], enc_spec(cfg), a,
                                       jnp.arange(hh.shape[1]))
        b2 = apply_norm(pp["ln2"], hh, kind="layer", eps=cfg.norm_eps)
        return hh + _mlp_apply(pp["mlp"], b2), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return apply_norm(params["enc_ln"], h, kind="layer", eps=cfg.norm_eps)


def _dec_block(cfg, pp, h, positions, memory):
    a = apply_norm(pp["ln1"], h, kind="layer", eps=cfg.norm_eps)
    h = h + attn.apply_attention(pp["self"], dec_spec(cfg), a, positions)
    b2 = apply_norm(pp["ln2"], h, kind="layer", eps=cfg.norm_eps)
    h = h + attn.apply_cross_attention(pp["cross"], enc_spec(cfg), b2, memory)
    c = apply_norm(pp["ln3"], h, kind="layer", eps=cfg.norm_eps)
    return h + _mlp_apply(pp["mlp"], c)


def whisper_hidden(params, cfg: ModelConfig, tokens, frames):
    memory = encode(params, cfg, frames)
    b, s = tokens.shape
    h = (params["embed"]["emb"][tokens].astype(cfg.cdt)
         + params["dec_pos"][:s].astype(cfg.cdt))
    positions = jnp.arange(s)
    body = lambda hh, pp: (_dec_block(cfg, pp, hh, positions, memory), None)
    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    return apply_norm(params["dec_ln"], h, kind="layer", eps=cfg.norm_eps)


def whisper_loss(params, cfg: ModelConfig, batch):
    h = whisper_hidden(params, cfg, batch["tokens"], batch["frontend"])
    loss = chunked_cross_entropy(h, params["embed"]["emb"], batch["labels"],
                                 chunk=cfg.logits_chunk)
    return loss, {"loss": loss}


def whisper_init_cache(cfg: ModelConfig, batch: int, s_max: int):
    l, hkv, dh = cfg.n_layers, cfg.n_kv, cfg.head_dim
    return {
        "self": (jnp.zeros((l, batch, hkv, s_max, dh), cfg.cdt),
                 jnp.zeros((l, batch, hkv, s_max, dh), cfg.cdt)),
        "cross": (jnp.zeros((l, batch, hkv, cfg.enc_seq, dh), cfg.cdt),
                  jnp.zeros((l, batch, hkv, cfg.enc_seq, dh), cfg.cdt)),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def whisper_prefill(params, cfg: ModelConfig, tokens, cache, frames):
    memory = encode(params, cfg, frames)
    b, s = tokens.shape
    h = (params["embed"]["emb"][tokens].astype(cfg.cdt)
         + params["dec_pos"][:s].astype(cfg.cdt))
    positions = jnp.arange(s)
    dspec, espec = dec_spec(cfg), enc_spec(cfg)

    def body(hh, pp):
        a = apply_norm(pp["ln1"], hh, kind="layer", eps=cfg.norm_eps)
        y, (k, v) = attn.apply_attention(pp["self"], dspec, a, positions,
                                         return_kv=True)
        hh = hh + y
        b2 = apply_norm(pp["ln2"], hh, kind="layer", eps=cfg.norm_eps)
        ck = attn._split_heads(dense(pp["cross"]["wk"], memory), espec.n_kv,
                               espec.d_head)
        cv = attn._split_heads(dense(pp["cross"]["wv"], memory), espec.n_kv,
                               espec.d_head)
        hh = hh + attn.apply_cross_attention(pp["cross"], espec, b2, (ck, cv),
                                             from_cache=True)
        c = apply_norm(pp["ln3"], hh, kind="layer", eps=cfg.norm_eps)
        return hh + _mlp_apply(pp["mlp"], c), (k, v, ck, cv)

    h, (k, v, ck, cv) = jax.lax.scan(body, h, params["dec_blocks"])
    sk, sv = cache["self"]
    sk = jax.lax.dynamic_update_slice_in_dim(sk, k, 0, axis=3)
    sv = jax.lax.dynamic_update_slice_in_dim(sv, v, 0, axis=3)
    cache.update(self=(sk, sv), cross=(ck, cv),
                 pos=jnp.full((b,), s, jnp.int32))
    h = apply_norm(params["dec_ln"], h, kind="layer", eps=cfg.norm_eps)
    return (h[:, -1] @ params["embed"]["emb"].T).astype(jnp.float32), cache


def whisper_decode_step(params, cfg: ModelConfig, cache, token):
    pos = cache["pos"]
    b = token.shape[0]
    h = (params["embed"]["emb"][token[:, None]].astype(cfg.cdt)
         + params["dec_pos"][pos][:, None].astype(cfg.cdt))
    dspec, espec = dec_spec(cfg), enc_spec(cfg)

    def body(hh, xs):
        pp, sk, sv, ck, cv = xs
        a = apply_norm(pp["ln1"], hh, kind="layer", eps=cfg.norm_eps)
        y, sk, sv = attn.decode_self_attention(pp["self"], dspec, a, sk, sv, pos)
        hh = hh + y
        b2 = apply_norm(pp["ln2"], hh, kind="layer", eps=cfg.norm_eps)
        hh = hh + attn.apply_cross_attention(pp["cross"], espec, b2, (ck, cv),
                                             from_cache=True)
        c = apply_norm(pp["ln3"], hh, kind="layer", eps=cfg.norm_eps)
        return hh + _mlp_apply(pp["mlp"], c), (sk, sv)

    h, (sk, sv) = jax.lax.scan(
        body, h, (params["dec_blocks"],) + tuple(cache["self"])
        + tuple(cache["cross"]))
    cache.update(self=(sk, sv), pos=pos + 1)
    h = apply_norm(params["dec_ln"], h, kind="layer", eps=cfg.norm_eps)
    return (h[:, 0] @ params["embed"]["emb"].T).astype(jnp.float32), cache
