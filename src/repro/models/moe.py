"""Mixture-of-Experts layer (DeepSeek-V2 style: shared + routed top-k).

Dispatch is sort-based ("dropless-with-capacity"): the [T*K] (token,
choice) pairs are sorted by expert id, each expert takes up to C slots
(capacity factor over the mean load), overflow is dropped. This lowers
to gather/scatter + one batched [E, C, d] x [E, d, ff] matmul — no
[T, E, C] one-hot dispatch tensor, so it scales to the 1M-token
train_4k cells. With expert weights sharded over the ``model`` axis
(expert parallelism) the scatter into the [E*C, d] buffer is XLA's
all-to-all.

The router also exposes per-expert load and co-activation statistics —
the input of the Revolver expert-placement integration
(core/placement.py): experts that co-activate on the same token want to
live on the same device so the combine step stays local.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense, dense_init, swiglu
from repro.models.mlp import apply_mlp, init_mlp


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    n_experts: int             # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared experts (always active)
    capacity_factor: float = 1.25
    norm_topk: bool = False    # renormalize top-k gates to sum to 1
    routed_scale: float = 1.0  # DeepSeek routed_scaling_factor


def init_moe(key, spec: MoESpec, dtype):
    ks = jax.random.split(key, 5)
    scale = 1.0 / (spec.d_model ** 0.5)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (spec.d_model, spec.n_experts),
                                           jnp.float32) * scale).astype(jnp.float32)},
        "w_gate": (jax.random.normal(ks[1], (spec.n_experts, spec.d_model,
                                             spec.d_ff_expert), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (spec.n_experts, spec.d_model,
                                           spec.d_ff_expert), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (spec.n_experts, spec.d_ff_expert,
                                             spec.d_model), jnp.float32)
                   * (1.0 / spec.d_ff_expert ** 0.5)).astype(dtype),
    }
    if spec.n_shared:
        p["shared"] = init_mlp(ks[4], spec.d_model,
                               spec.d_ff_expert * spec.n_shared, dtype)
    return p


def route(p_router, x2d, spec: MoESpec):
    """x2d [T, d] -> (gates [T, K] f32, idx [T, K] i32, probs [T, E])."""
    logits = (x2d.astype(jnp.float32) @ p_router["w"])            # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, spec.top_k)
    if spec.norm_topk:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    gates = gates * spec.routed_scale
    return gates, idx.astype(jnp.int32), probs


def moe_capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(n_tokens * spec.top_k * spec.capacity_factor / spec.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def apply_moe(p, x, spec: MoESpec, *, return_stats: bool = False):
    """x [B, S, d] (or [T, d]) -> same shape.

    Dispatch selection: under a mesh context with a model axis, uses the
    shard_map expert-parallel path (local dispatch + one psum — the same
    collective cost as a dense megatron MLP, since activations are
    TP-replicated anyway). Otherwise the single-device sort-based path.
    """
    from repro.parallel.act_sharding import get_ctx
    ctx = get_ctx()
    if ctx is not None and not return_stats:
        mesh = ctx.mesh
        psz = int(mesh.shape.get("pod", 1))
        msz = int(mesh.shape.get("model", 1))
        if (ctx.moe_ep2d and psz > 1
                and spec.n_experts % (psz * msz) == 0):
            return _apply_moe_ep2d(p, x, spec, mesh)
        if (ctx.moe_shardmap and msz > 1
                and spec.n_experts % msz == 0):
            return _apply_moe_shardmap(p, x, spec, mesh)
    return _apply_moe_local(p, x, spec, return_stats=return_stats)


def _apply_moe_local(p, x, spec: MoESpec, *, return_stats: bool = False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    t, d = x2.shape
    e, k = spec.n_experts, spec.top_k
    cap = moe_capacity(t, spec)

    gates, idx, probs = route(p["router"], x2, spec)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = idx.reshape(-1)                                  # [T*K]
    order = jnp.argsort(flat_e, stable=True)                  # [T*K]
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))     # [E]
    pos = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, e * cap)     # drop -> OOB
    token_of = order // k                                     # [T*K]

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].set(x2[token_of], mode="drop")         # all-to-all under EP

    # ---- expert computation (batched over E; weights sharded on E) ----------
    h = buf.reshape(e, cap, d)
    act = swiglu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]),
                 jnp.einsum("ecd,edf->ecf", h, p["w_up"]))
    out = jnp.einsum("ecf,efd->ecd", act, p["w_down"]).reshape(e * cap, d)

    # ---- combine -------------------------------------------------------------
    gathered = jnp.where(keep[:, None], out.at[slot].get(mode="fill",
                                                         fill_value=0), 0)
    gate_sorted = gates.reshape(-1)[order]
    y2 = jnp.zeros((t, d), x.dtype).at[token_of].add(
        gathered * gate_sorted[:, None].astype(x.dtype))

    if spec.n_shared:
        y2 = y2 + apply_mlp(p["shared"], x2)

    y = y2.reshape(shape)
    if return_stats:
        load = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
        dropped = jnp.sum(~keep)
        return y, {"expert_load": load, "dropped": dropped,
                   "router_probs_mean": jnp.mean(probs, axis=0),
                   "top_idx": idx}
    return y


def _apply_moe_shardmap(p, x, spec: MoESpec, mesh):
    """Expert-parallel MoE via shard_map.

    Key observation: under megatron TP the [B,S,d] activations are
    replicated across the "model" axis, so EP dispatch needs NO
    all-to-all — every model rank already holds every token. Each rank
    packs the tokens routed to ITS E/msz experts (sort-based, capacity-
    bounded), runs its expert matmuls, scatter-adds gated outputs into a
    [T,d] partial, and a single psum over "model" (fused with the shared-
    expert megatron partial) completes the layer. Wire cost per layer =
    one [B,S,d] all-reduce — identical to a dense MLP block.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.act_sharding import dp_axes_of

    dp = dp_axes_of(mesh)
    msz = int(mesh.shape["model"])
    e_loc = spec.n_experts // msz
    shape = x.shape
    batch_ok = shape[0] % int(np.prod([mesh.shape[a] for a in dp])) == 0
    xspec = P(*((dp if batch_ok else None,) + (None,) * (len(shape) - 1)))

    pspec = {
        "router": {"w": P(None, None)},
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if spec.n_shared:
        pspec["shared"] = {
            "w_gate": {"w": P(None, "model")},
            "w_up": {"w": P(None, "model")},
            "w_down": {"w": P("model", None)},
        }

    def local(p_loc, x_loc):
        t_shape = x_loc.shape
        x2 = x_loc.reshape(-1, t_shape[-1])
        t, d = x2.shape
        k = spec.top_k
        cap = moe_capacity(t, spec)

        gates, idx, _ = route(p_loc["router"], x2, spec)
        m_rank = jax.lax.axis_index("model")
        rel = idx - m_rank * e_loc                       # [T, K]
        mine = (rel >= 0) & (rel < e_loc)
        flat_rel = jnp.where(mine, rel, e_loc).reshape(-1)   # e_loc = trash

        order = jnp.argsort(flat_rel, stable=True)
        sorted_e = flat_rel[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1))
        pos = jnp.arange(t * k, dtype=jnp.int32) - seg_start[sorted_e]
        keep = (pos < cap) & (sorted_e < e_loc)
        slot = jnp.where(keep, sorted_e * cap + pos, e_loc * cap)
        token_of = order // k

        buf = jnp.zeros((e_loc * cap, d), x_loc.dtype)
        buf = buf.at[slot].set(x2[token_of], mode="drop")
        h = buf.reshape(e_loc, cap, d)
        act = swiglu(jnp.einsum("ecd,edf->ecf", h, p_loc["w_gate"]),
                     jnp.einsum("ecd,edf->ecf", h, p_loc["w_up"]))
        out = jnp.einsum("ecf,efd->ecd", act, p_loc["w_down"]).reshape(
            e_loc * cap, d)

        gathered = jnp.where(keep[:, None],
                             out.at[slot].get(mode="fill", fill_value=0), 0)
        gate_sorted = gates.reshape(-1)[order]
        y2 = jnp.zeros((t, d), x_loc.dtype).at[token_of].add(
            gathered * gate_sorted[:, None].astype(x_loc.dtype))

        if spec.n_shared:                        # megatron partial (local f/msz)
            y2 = y2 + apply_mlp(p_loc["shared"], x2)
        y2 = jax.lax.psum(y2, "model")
        return y2.reshape(t_shape)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(pspec, xspec),
                         out_specs=xspec,
                         check_vma=False)(
        {k: p[k] for k in pspec}, x)


def _dispatch_local(x2, flat_e, flat_w, e_loc, cap, wg, wu, wd, dtype):
    """Sort-pack [T*] (row, expert, weight) onto this rank's e_loc experts
    (ids already rank-relative; out-of-range = drop), run the expert
    matmuls, and return the weighted per-row outputs [T*, d]."""
    t = x2.shape[0]
    d = x2.shape[1]
    inside = (flat_e >= 0) & (flat_e < e_loc)
    key = jnp.where(inside, flat_e, e_loc)
    order = jnp.argsort(key, stable=True)
    sorted_e = key[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1))
    pos = jnp.arange(t, dtype=jnp.int32) - seg_start[sorted_e]
    keep = (pos < cap) & (sorted_e < e_loc)
    slot = jnp.where(keep, sorted_e * cap + pos, e_loc * cap)
    row_of = order

    buf = jnp.zeros((e_loc * cap, d), dtype)
    buf = buf.at[slot].set(x2[row_of], mode="drop")
    h = buf.reshape(e_loc, cap, d)
    act = swiglu(jnp.einsum("ecd,edf->ecf", h, wg),
                 jnp.einsum("ecd,edf->ecf", h, wu))
    out = jnp.einsum("ecf,efd->ecd", act, wd).reshape(e_loc * cap, d)

    gathered = jnp.where(keep[:, None],
                         out.at[slot].get(mode="fill", fill_value=0), 0)
    y = jnp.zeros((t, d), dtype).at[row_of].add(
        gathered * flat_w[order][:, None].astype(dtype))
    return y


def _apply_moe_ep2d(p, x, spec: MoESpec, mesh):
    """Cross-pod expert parallelism (EP over pod x model; §Perf C3).

    Expert storage divides by pod_sz*model_sz (236b: 29 GB -> 7.3 GB per
    device on the 512-chip mesh); the price is one pod-level all_to_all
    each way for the tokens routed to the remote pod's experts. Tokens
    are packed per destination pod with a fixed capacity, exchanged,
    dispatched through the local-expert path (k=1, pre-applied gates),
    psum'd over "model", and returned through the inverse all_to_all.
    """
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    psz = int(mesh.shape["pod"])
    msz = int(mesh.shape["model"])
    e_pod = spec.n_experts // psz            # experts per pod
    e_loc = e_pod // msz                     # experts per device
    shape = x.shape
    xspec = P(*((dp,) + (None,) * (len(shape) - 1)))
    pspec = {
        "router": {"w": P(None, None)},
        "w_gate": P(("pod", "model"), None, None),
        "w_up": P(("pod", "model"), None, None),
        "w_down": P(("pod", "model"), None, None),
    }
    if spec.n_shared:
        pspec["shared"] = {
            "w_gate": {"w": P(None, "model")},
            "w_up": {"w": P(None, "model")},
            "w_down": {"w": P("model", None)},
        }

    def local(p_loc, x_loc):
        t_shape = x_loc.shape
        x2 = x_loc.reshape(-1, t_shape[-1])
        t, d = x2.shape
        k = spec.top_k
        # per-destination-pod slots: mean load t*k/psz x capacity factor
        cap_x = int(t * k * spec.capacity_factor / psz)
        cap_x = max(8, min(t * k, -(-cap_x // 8) * 8))

        gates, idx, _ = route(p_loc["router"], x2, spec)
        flat_e = idx.reshape(-1)
        dest = flat_e // e_pod                              # [T*K] pod id
        rel_pod = flat_e % e_pod                            # within-pod id

        # pack per destination pod
        order = jnp.argsort(dest, stable=True)
        sorted_d = dest[order]
        seg = jnp.searchsorted(sorted_d, jnp.arange(psz + 1))
        pos = jnp.arange(t * k, dtype=jnp.int32) - seg[sorted_d]
        keep = pos < cap_x
        slot = jnp.where(keep, sorted_d * cap_x + pos, psz * cap_x)
        tok_of = order // k

        send_x = jnp.zeros((psz * cap_x, d), x_loc.dtype)
        send_x = send_x.at[slot].set(x2[tok_of], mode="drop")
        send_e = jnp.full((psz * cap_x,), -1, jnp.int32)
        send_e = send_e.at[slot].set(rel_pod[order], mode="drop")

        # exchange over the pod axis (2-way swap at pod=2)
        recv_x = jax.lax.all_to_all(send_x.reshape(psz, cap_x, d), "pod",
                                    split_axis=0, concat_axis=0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e.reshape(psz, cap_x), "pod",
                                    split_axis=0, concat_axis=0, tiled=False)
        recv_x = recv_x.reshape(psz * cap_x, d)
        recv_e = recv_e.reshape(psz * cap_x)

        # dispatch through MY pod's experts (model-sharded within the pod)
        m_rank = jax.lax.axis_index("model")
        rel_here = jnp.where(recv_e >= 0, recv_e - m_rank * e_loc, -1)
        cap2 = max(8, -(-psz * cap_x * 2 // e_pod) // 8 * 8)
        out = _dispatch_local(recv_x, rel_here,
                              jnp.ones((psz * cap_x,), jnp.float32),
                              e_loc, cap2, p_loc["w_gate"], p_loc["w_up"],
                              p_loc["w_down"], x_loc.dtype)
        out = jax.lax.psum(out, "model")

        # return results to the senders (inverse exchange)
        back = jax.lax.all_to_all(out.reshape(psz, cap_x, d), "pod",
                                  split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(psz * cap_x, d)

        contrib = jnp.where(keep[:, None],
                            back.at[slot].get(mode="fill", fill_value=0), 0)
        y2 = jnp.zeros((t, d), x_loc.dtype).at[tok_of].add(
            contrib * gates.reshape(-1)[order][:, None].astype(x_loc.dtype))

        if spec.n_shared:
            y2 = y2 + jax.lax.psum(apply_mlp(p_loc["shared"], x2), "model")
        return y2.reshape(t_shape)

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(pspec, xspec),
                         out_specs=xspec,
                         check_vma=False)(
        {k: p[k] for k in pspec}, x)


def moe_ref(p, x, spec: MoESpec):
    """O(T*E) dense oracle (no capacity drops) for tests."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    gates, idx, _ = route(p["router"], x2, spec)
    y2 = jnp.zeros_like(x2)
    for j in range(spec.n_experts):
        w = jnp.sum(jnp.where(idx == j, gates, 0.0), axis=-1)   # [T]
        act = swiglu(x2 @ p["w_gate"][j], x2 @ p["w_up"][j])
        y2 = y2 + (act @ p["w_down"][j]) * w[:, None].astype(x2.dtype)
    if spec.n_shared:
        y2 = y2 + apply_mlp(p["shared"], x2)
    return y2.reshape(shape)
