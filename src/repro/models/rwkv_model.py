"""RWKV6 full model stack (the attention-free ``ssm`` family).

Blocks = time-mix + channel-mix with pre-LayerNorms; ln0 after the
embedding (RWKV convention). Serving state per layer: the [B,H,N,N] wkv
state plus the two token-shift buffers — O(1) in sequence length, which
is why rwkv6-3b runs the long_500k cell.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm, chunked_cross_entropy, embed_init, norm_init
from repro.models.config import ModelConfig
from repro.models.rwkv6 import (RWKV6Spec, apply_rwkv6_channel,
                                apply_rwkv6_time, init_rwkv6_channel,
                                init_rwkv6_time)


def rwkv_spec(cfg: ModelConfig) -> RWKV6Spec:
    return RWKV6Spec(d_model=cfg.d_model, n_heads=cfg.rwkv_heads,
                     d_ffn=cfg.d_ff, mix_rank=cfg.mix_rank,
                     decay_rank=cfg.decay_rank, chunk=cfg.rwkv_chunk)


def _init_block(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    spec = rwkv_spec(cfg)
    return {
        "ln1": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
        "ln2": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
        "time": init_rwkv6_time(k1, spec, cfg.pdt),
        "chan": init_rwkv6_channel(k2, spec, cfg.pdt),
    }


def init_rwkv(cfg: ModelConfig, key):
    keys = jax.random.split(key, 3)
    p = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.pdt),
        "ln0": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
        "ln_f": norm_init(cfg.d_model, cfg.pdt, kind="layer", bias=True),
        "blocks": jax.vmap(partial(_init_block, cfg))(
            jax.random.split(keys[1], cfg.n_layers)),
        "unembed": embed_init(keys[2], cfg.vocab, cfg.d_model, cfg.pdt),
    }
    return p


def _block(cfg, p, h, *, states=None, impl="chunked"):
    """One block; states = (x_time, wkv, x_chan) or None (zero init)."""
    spec = rwkv_spec(cfg)
    xt, wkv, xc = states if states is not None else (None, None, None)
    a = apply_norm(p["ln1"], h, kind="layer", eps=cfg.norm_eps)
    y, (last_xt, wkv) = apply_rwkv6_time(p["time"], spec, a, x_prev=xt,
                                         wkv_state=wkv, impl=impl)
    h = h + y
    b = apply_norm(p["ln2"], h, kind="layer", eps=cfg.norm_eps)
    y2, last_xc = apply_rwkv6_channel(p["chan"], b, x_prev=xc)
    return h + y2, (last_xt, wkv, last_xc)


def rwkv_hidden(params, cfg: ModelConfig, tokens):
    h = apply_norm(params["ln0"],
                   params["embed"]["emb"][tokens].astype(cfg.cdt),
                   kind="layer", eps=cfg.norm_eps)
    body = lambda hh, pp: (_block(cfg, pp, hh)[0], None)
    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["blocks"])
    return apply_norm(params["ln_f"], h, kind="layer", eps=cfg.norm_eps)


def rwkv_loss(params, cfg: ModelConfig, batch):
    h = rwkv_hidden(params, cfg, batch["tokens"])
    loss = chunked_cross_entropy(h, params["unembed"]["emb"],
                                 batch["labels"], chunk=cfg.logits_chunk)
    return loss, {"loss": loss}


def rwkv_init_cache(cfg: ModelConfig, batch: int, s_max: int):
    spec = rwkv_spec(cfg)
    l = cfg.n_layers
    return {
        "x_time": jnp.zeros((l, batch, 1, cfg.d_model), cfg.cdt),
        "wkv": jnp.zeros((l, batch, spec.n_heads, spec.d_head, spec.d_head),
                         jnp.float32),
        "x_chan": jnp.zeros((l, batch, 1, cfg.d_model), cfg.cdt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def _run_with_states(params, cfg, h, cache, impl):
    def body(hh, xs):
        pp, xt, wkv, xc = xs
        hh, (nxt, nwkv, nxc) = _block(cfg, pp, hh,
                                      states=(xt, wkv, xc), impl=impl)
        return hh, (nxt, nwkv, nxc)
    h, (xt, wkv, xc) = jax.lax.scan(
        body, h, (params["blocks"], cache["x_time"], cache["wkv"],
                  cache["x_chan"]))
    cache = dict(cache, x_time=xt, wkv=wkv, x_chan=xc)
    return h, cache


def rwkv_prefill(params, cfg: ModelConfig, tokens, cache):
    h = apply_norm(params["ln0"],
                   params["embed"]["emb"][tokens].astype(cfg.cdt),
                   kind="layer", eps=cfg.norm_eps)
    h, cache = _run_with_states(params, cfg, h, cache, "chunked")
    cache["pos"] = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    h = apply_norm(params["ln_f"], h, kind="layer", eps=cfg.norm_eps)
    return (h[:, -1] @ params["unembed"]["emb"].T).astype(jnp.float32), cache


def rwkv_decode_step(params, cfg: ModelConfig, cache, token):
    h = apply_norm(params["ln0"],
                   params["embed"]["emb"][token[:, None]].astype(cfg.cdt),
                   kind="layer", eps=cfg.norm_eps)
    h, cache = _run_with_states(params, cfg, h, cache, "scan")
    cache["pos"] = cache["pos"] + 1
    h = apply_norm(params["ln_f"], h, kind="layer", eps=cfg.norm_eps)
    return (h[:, 0] @ params["unembed"]["emb"].T).astype(jnp.float32), cache
