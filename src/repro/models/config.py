"""ModelConfig — the single config surface for every architecture family.

One frozen dataclass covers dense / MoE / hybrid / SSM / enc-dec / VLM;
family-specific fields are ignored by other families. Exact per-arch
instantiations live in ``repro/configs/<arch>.py``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int = 0
    d_head: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    norm: str = "rms"            # rms | layer
    norm_eps: float = 1e-5
    norm_bias: bool = False
    rope_theta: float = 10000.0
    rope_frac: float = 1.0       # stablelm: partial rotary
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    parallel_block: bool = False  # cohere: attn/MLP from the same norm
    logit_scale: float = 1.0
    window: int | None = None    # sliding-window attention
    attn_kind: str = "gqa"       # gqa | mla
    # --- MLA (deepseek-v2) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    mla_d_nope: int = 128
    mla_d_rope: int = 64
    mla_d_v: int = 128
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    first_dense: int = 0         # leading dense-FFN layers (deepseek: 1)
    capacity_factor: float = 1.25
    norm_topk: bool = False
    routed_scale: float = 1.0
    # --- SSM / hybrid (mamba2 / zamba2) ---
    ssm_state: int = 64
    ssm_head: int = 64
    n_attn_groups: int = 0       # zamba2: shared-attn applications
    mamba_per_group: int = 0
    trailing_mamba: int = 0
    lora_rank: int = 0           # zamba2 per-application LoRA
    # --- rwkv6 ---
    rwkv_heads: int = 0
    mix_rank: int = 32
    decay_rank: int = 64
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0             # stubbed frame embeddings per example
    # --- vlm ---
    n_patches: int = 0           # stubbed patch embeddings per example
    # --- execution knobs ---
    impl: str = "xla"            # attention inner impl: naive | xla | pallas
    block_q: int = 512
    block_k: int = 1024
    ssm_chunk: int = 128
    rwkv_chunk: int = 64
    seq_chunk: int = 0           # mixer sequence chunking (0 = whole seq)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    logits_chunk: int = 512
    max_pos: int = 1 << 20       # learned-pos table bound (whisper decoder)

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized sibling of this config (same family/topology
        knobs, tiny dims). Used by per-arch smoke tests on CPU."""
        d_head = min(self.head_dim, 16)
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv or n_heads, n_heads))
        if self.n_kv and self.n_kv >= self.n_heads:   # MHA stays MHA
            n_kv = n_heads
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=n_heads * d_head,
            n_heads=n_heads,
            n_kv=n_kv,
            d_head=d_head,
            d_ff=64,
            vocab=min(self.vocab, 128) or 0,
            q_lora_rank=min(self.q_lora_rank, 24),
            kv_lora_rank=min(self.kv_lora_rank, 16),
            mla_d_nope=16, mla_d_rope=8, mla_d_v=16,
            n_experts=min(self.n_experts, 8) if self.moe else 0,
            top_k=min(self.top_k, 2) if self.moe else 0,
            d_ff_expert=32 if self.moe else 0,
            # dropless at smoke scale: capacity drops would make
            # prefill+decode differ from the teacher-forced pass
            capacity_factor=8.0 if self.moe else self.capacity_factor,
            n_shared_experts=min(self.n_shared_experts, 1),
            ssm_state=min(self.ssm_state, 16),
            ssm_head=min(self.ssm_head, 16),
            n_attn_groups=min(self.n_attn_groups, 2),
            mamba_per_group=min(self.mamba_per_group, 2),
            trailing_mamba=min(self.trailing_mamba, 1),
            lora_rank=min(self.lora_rank, 8),
            rwkv_heads=min(self.rwkv_heads, 4) if self.rwkv_heads else 0,
            mix_rank=8, decay_rank=8,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=min(self.enc_seq, 16) or 0,
            n_patches=min(self.n_patches, 8) or 0,
            window=min(self.window, 16) if self.window else None,
            block_q=16, block_k=16, ssm_chunk=8, rwkv_chunk=8,
            logits_chunk=16,
            param_dtype="float32", compute_dtype="float32",
            remat=False,
        )
        if self.family == "ssm":
            small["d_model"] = 64
            small["rwkv_heads"] = 4
            small["d_ff"] = 128
        if self.family == "hybrid":
            small["n_layers"] = (small["n_attn_groups"] * small["mamba_per_group"]
                                 + small["n_attn_groups"] + small["trailing_mamba"])
        small.update(overrides)
        return dataclasses.replace(self, **small)
