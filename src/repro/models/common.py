"""Shared model primitives: inits, norms, rotary embeddings, losses.

Everything is raw-JAX functional style: params are nested dicts of
arrays, built by ``init_*`` helpers and consumed by pure ``apply``
functions. Layer stacks store params with a leading ``[L, ...]`` axis so
the forward pass is a single ``lax.scan`` (O(1) HLO size in depth —
required for the 512-device dry-run to compile in reasonable time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None,
               bias: bool = False):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embed_init(key, vocab: int, d: int, dtype):
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def norm_init(d: int, dtype, *, kind: str = "rms", bias: bool = False):
    p = {"g": jnp.ones((d,), dtype)}
    if kind == "layer" and bias:
        p["b"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, *, kind: str = "rms", eps: float = 1e-5):
    """Normalization with f32 STATS but activation-dtype application: the
    [B,S,1] statistics are computed in f32 (stability), while the [B,S,d]
    tensor itself never materializes in f32 — measured 7% of train-step
    HBM traffic on command-r (§Perf A3)."""
    if kind == "rms":
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                      keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps).astype(x.dtype)
    elif kind == "layer":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - mu * mu
        y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(
            var + eps).astype(x.dtype)
    else:
        raise ValueError(kind)
    y = y * p["g"]
    if "b" in p:
        y = y + p["b"]
    return y


# --------------------------------------------------------------------------
# rotary position embeddings (full or partial)
# --------------------------------------------------------------------------
def rope_freqs(d_rot: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *, d_rot: int | None = None,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, D]; positions: broadcastable to [..., S]. Rotates the
    first ``d_rot`` channels (pairwise halves convention), passthrough rest."""
    d = x.shape[-1]
    if d_rot is None:
        d_rot = d
    inv = rope_freqs(d_rot, theta)                       # [d_rot/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, d_rot/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    r = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([r.astype(x.dtype), x_pass], axis=-1)


def sinusoid_pos(n: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    tab = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(tab, dtype)


# --------------------------------------------------------------------------
# activation / loss
# --------------------------------------------------------------------------
def swiglu(gate, up):
    from repro.parallel.act_sharding import get_ctx
    ctx = get_ctx()
    if ctx is not None and ctx.bf16_silu:
        # perf knob (§Perf): silu in the activation dtype — kills the
        # [*, d_ff] f32 intermediate (2x HBM traffic on the FFN path)
        return jax.nn.silu(gate) * up
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def chunked_cross_entropy(h: jnp.ndarray, emb: jnp.ndarray, labels: jnp.ndarray,
                          *, chunk: int = 256, logit_scale: float = 1.0):
    """Mean next-token CE without materializing [B, S, V] logits.

    Scans over sequence chunks; each chunk computes [B, chunk, V] logits,
    its CE contribution, and is discarded. h: [B, S, d]; emb: [V, d];
    labels: [B, S] int32 (-100 = masked).
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    while s % chunk:          # auto-adjust for non-multiple lengths (vlm)
        chunk //= 2
    hs = h.reshape(b, s // chunk, chunk, d).swapaxes(0, 1)      # [nc, B, c, d]
    ls = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

    vocab = emb.shape[0]

    def body(carry, xs):
        tot, cnt = carry
        hc, lc = xs
        logits = (hc @ emb.T).astype(jnp.float32) * logit_scale  # [B, c, V]
        mask = lc >= 0
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: stays local when
        # the vocab axis is model-sharded (a gather would force an
        # all-gather of the logits chunk)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        tgt = jnp.sum(jnp.where(iota == lc[..., None], logits, 0.0), axis=-1)
        nll = jnp.where(mask, lse - tgt, 0.0)
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    # remat: recompute each chunk's logits in backward instead of keeping
    # [B, chunk, V] f32 residuals alive per chunk (x S/chunk of them)
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.int32)), (hs, ls))
    return tot / jnp.maximum(cnt, 1)


def stack_params(trees):
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *trees)
