"""Decoder-only LM assembly (dense / MoE / VLM families).

Layer params carry a leading [L] axis (built with ``jax.vmap`` over
per-layer PRNG keys) and the forward pass is one ``lax.scan`` over
layers — O(1) HLO size in depth. ``cfg.first_dense`` leading layers
(DeepSeek-V2's dense layer 0) form a second, separately-scanned stack.

Paths:
  lm_loss        train: tokens -> mean next-token CE (chunked over S and
                 over the vocab-sharded logits; no [B,S,V] materialization)
  lm_prefill     tokens -> (last-position logits, decode cache)
  lm_decode_step one token against the cache (GQA / ring-SWA / MLA-absorbed)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models.common import (apply_norm, chunked_cross_entropy, dense,
                                 embed_init, norm_init)
from repro.models.config import ModelConfig
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.moe import MoESpec, apply_moe, init_moe, moe_capacity


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------
def attn_spec(cfg: ModelConfig) -> attn.AttnSpec:
    return attn.AttnSpec(
        d_model=cfg.d_model, n_q=cfg.n_heads, n_kv=cfg.n_kv,
        d_head=cfg.head_dim, causal=True, window=cfg.window,
        rope_frac=cfg.rope_frac, rope_theta=cfg.rope_theta,
        qkv_bias=cfg.qkv_bias, impl=cfg.impl,
        block_q=cfg.block_q, block_k=cfg.block_k)


def mla_spec(cfg: ModelConfig) -> mla_mod.MLASpec:
    return mla_mod.MLASpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
        d_nope=cfg.mla_d_nope, d_rope=cfg.mla_d_rope, d_v=cfg.mla_d_v,
        rope_theta=cfg.rope_theta, impl=cfg.impl,
        block_q=cfg.block_q, block_k=cfg.block_k)


def moe_spec(cfg: ModelConfig) -> MoESpec:
    return MoESpec(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_ff_expert=cfg.d_ff_expert, n_shared=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor, norm_topk=cfg.norm_topk,
        routed_scale=cfg.routed_scale)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, moe_layer: bool, key):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg.d_model, cfg.pdt, kind=cfg.norm,
                          bias=cfg.norm_bias)}
    if cfg.attn_kind == "mla":
        p["attn"] = mla_mod.init_mla(k1, mla_spec(cfg), cfg.pdt)
    else:
        p["attn"] = attn.init_attention(k1, attn_spec(cfg), cfg.pdt)
    if not cfg.parallel_block:
        p["ln2"] = norm_init(cfg.d_model, cfg.pdt, kind=cfg.norm,
                             bias=cfg.norm_bias)
    if moe_layer:
        p["moe"] = init_moe(k2, moe_spec(cfg), cfg.pdt)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.pdt,
                            kind=cfg.mlp_kind)
    return p


def _apply_block(cfg: ModelConfig, moe_layer: bool, p, h, positions):
    from repro.parallel.act_sharding import maybe_gather_hidden
    a = maybe_gather_hidden(
        apply_norm(p["ln1"], h, kind=cfg.norm, eps=cfg.norm_eps))
    if cfg.attn_kind == "mla":
        attn_out = mla_mod.apply_mla(p["attn"], mla_spec(cfg), a, positions)
    else:
        attn_out = attn.apply_attention(p["attn"], attn_spec(cfg), a, positions)

    def ffn(x):
        if moe_layer:
            return apply_moe(p["moe"], x, moe_spec(cfg))
        return apply_mlp(p["mlp"], x, kind=cfg.mlp_kind)

    from repro.parallel.act_sharding import maybe_shard_hidden
    if cfg.parallel_block:                       # cohere: shared norm input
        return maybe_shard_hidden(h + attn_out + ffn(a))
    h = h + attn_out
    x2 = maybe_gather_hidden(
        apply_norm(p["ln2"], h, kind=cfg.norm, eps=cfg.norm_eps))
    h = h + ffn(x2)
    return maybe_shard_hidden(h)


def _prefill_block(cfg, moe_layer, p, h, positions):
    """Like _apply_block but returns the KV-cache entry for this layer."""
    a = apply_norm(p["ln1"], h, kind=cfg.norm, eps=cfg.norm_eps)
    if cfg.attn_kind == "mla":
        attn_out, kv = mla_mod.apply_mla(p["attn"], mla_spec(cfg), a,
                                         positions, return_cache=True)
    else:
        attn_out, kv = attn.apply_attention(p["attn"], attn_spec(cfg), a,
                                            positions, return_kv=True)
    if cfg.parallel_block:
        if moe_layer:
            f = apply_moe(p["moe"], a, moe_spec(cfg))
        else:
            f = apply_mlp(p["mlp"], a, kind=cfg.mlp_kind)
        return h + attn_out + f, kv
    h = h + attn_out
    x2 = apply_norm(p["ln2"], h, kind=cfg.norm, eps=cfg.norm_eps)
    if moe_layer:
        f = apply_moe(p["moe"], x2, moe_spec(cfg))
    else:
        f = apply_mlp(p["mlp"], x2, kind=cfg.mlp_kind)
    return h + f, kv


def _decode_block(cfg, moe_layer, p, h1, cache, pos):
    """One-token decode through a block; cache is this layer's slice."""
    a = apply_norm(p["ln1"], h1, kind=cfg.norm, eps=cfg.norm_eps)
    if cfg.attn_kind == "mla":
        attn_out, cc, cpe = mla_mod.decode_mla(
            p["attn"], mla_spec(cfg), a, cache[0], cache[1], pos)
        new_cache = (cc, cpe)
    else:
        attn_out, ck, cv = attn.decode_self_attention(
            p["attn"], attn_spec(cfg), a, cache[0], cache[1], pos)
        new_cache = (ck, cv)

    def ffn(x):
        if moe_layer:
            return apply_moe(p["moe"], x, moe_spec(cfg))
        return apply_mlp(p["mlp"], x, kind=cfg.mlp_kind)

    if cfg.parallel_block:
        return h1 + attn_out + ffn(a), new_cache
    h1 = h1 + attn_out
    return h1 + ffn(apply_norm(p["ln2"], h1, kind=cfg.norm,
                               eps=cfg.norm_eps)), new_cache


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------
def init_decoder(cfg: ModelConfig, key):
    keys = jax.random.split(key, 4)
    n_dense = cfg.first_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_dense
    p = {"embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.pdt),
         "ln_f": norm_init(cfg.d_model, cfg.pdt, kind=cfg.norm,
                           bias=cfg.norm_bias)}
    main_keys = jax.random.split(keys[1], n_main)
    p["blocks"] = jax.vmap(partial(_init_block, cfg, cfg.moe))(main_keys)
    if n_dense:
        dkeys = jax.random.split(keys[2], n_dense)
        p["dense_blocks"] = jax.vmap(partial(_init_block, cfg, False))(dkeys)
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(keys[3], cfg.vocab, cfg.d_model, cfg.pdt)
    return p


def _out_emb(cfg, params):
    return (params["embed"] if cfg.tie_embeddings else params["unembed"])["emb"]


def _embed_tokens(cfg, params, tokens):
    return params["embed"]["emb"][tokens].astype(cfg.cdt)


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _stack_scan(cfg, stack, h, positions, moe_layer):
    body = _maybe_remat(cfg, lambda hh, pp: _apply_block(
        cfg, moe_layer, pp, hh, positions))
    return jax.lax.scan(lambda hh, pp: (body(hh, pp), None), h, stack)[0]


# --------------------------------------------------------------------------
# train loss
# --------------------------------------------------------------------------
def decoder_hidden(params, cfg: ModelConfig, tokens, frontend=None):
    """tokens [B,S] -> final hidden [B, S(+patches), d]."""
    h = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        assert frontend is not None, "vlm needs patch embeddings"
        h = jnp.concatenate([frontend.astype(cfg.cdt), h], axis=1)
    s_tot = h.shape[1]
    positions = jnp.arange(s_tot)
    if "dense_blocks" in params:
        h = _stack_scan(cfg, params["dense_blocks"], h, positions, False)
    h = _stack_scan(cfg, params["blocks"], h, positions, cfg.moe)
    return apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)


def decoder_loss(params, cfg: ModelConfig, batch):
    """batch: tokens [B,S], labels [B,S] (-100 masked), optional frontend."""
    h = decoder_hidden(params, cfg, batch["tokens"], batch.get("frontend"))
    labels = batch["labels"]
    if cfg.family == "vlm":                       # patch positions: no loss
        pad = jnp.full(labels.shape[:1] + (cfg.n_patches,), -100, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = chunked_cross_entropy(h, _out_emb(cfg, params), labels,
                                 chunk=cfg.logits_chunk,
                                 logit_scale=cfg.logit_scale)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------
# serving: cache init / prefill / decode
# --------------------------------------------------------------------------
def decoder_init_cache(cfg: ModelConfig, batch: int, s_max: int):
    n_dense = cfg.first_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_dense
    if cfg.attn_kind == "mla":
        def mk(n):
            return (jnp.zeros((n, batch, s_max, cfg.kv_lora_rank), cfg.cdt),
                    jnp.zeros((n, batch, s_max, cfg.mla_d_rope), cfg.cdt))
    else:
        w = cfg.window if cfg.window and cfg.window < s_max else s_max
        def mk(n):
            return (jnp.zeros((n, batch, cfg.n_kv, w, cfg.head_dim), cfg.cdt),
                    jnp.zeros((n, batch, cfg.n_kv, w, cfg.head_dim), cfg.cdt))
    cache = {"main": mk(n_main), "pos": jnp.zeros((batch,), jnp.int32)}
    if n_dense:
        cache["dense"] = mk(n_dense)
    return cache


def _write_prefill(cfg, cache_pair, kv, s):
    """Write stacked prefill KV [L,...] into the cache at positions [0,s)."""
    ck, cv = cache_pair
    k, v = kv
    if cfg.attn_kind == "mla":
        s_max = ck.shape[2]
    else:
        s_max = ck.shape[3]
    if s_max < s:            # ring buffer (SWA): keep the last s_max slots
        sl = jnp.arange(s - s_max, s) % s_max
        if cfg.attn_kind == "mla":
            ck = ck.at[:, :, sl].set(k[:, :, -s_max:])
            cv = cv.at[:, :, sl].set(v[:, :, -s_max:])
        else:
            ck = ck.at[:, :, :, sl].set(k[:, :, :, -s_max:])
            cv = cv.at[:, :, :, sl].set(v[:, :, :, -s_max:])
    else:
        if cfg.attn_kind == "mla":
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=2)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=3)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=3)
    return ck, cv


def decoder_prefill(params, cfg: ModelConfig, tokens, cache, frontend=None):
    """Run the prompt; fill the cache; return last-position logits."""
    h = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        h = jnp.concatenate([frontend.astype(cfg.cdt), h], axis=1)
    s_tot = h.shape[1]
    positions = jnp.arange(s_tot)

    def run(stack, h, moe_layer):
        body = _maybe_remat(cfg, lambda hh, pp: _prefill_block(
            cfg, moe_layer, pp, hh, positions))
        return jax.lax.scan(body, h, stack)

    if "dense_blocks" in params:
        h, kv = run(params["dense_blocks"], h, False)
        cache["dense"] = _write_prefill(cfg, cache["dense"], kv, s_tot)
    h, kv = run(params["blocks"], h, cfg.moe)
    cache["main"] = _write_prefill(cfg, cache["main"], kv, s_tot)
    cache["pos"] = jnp.full((tokens.shape[0],), s_tot, jnp.int32)
    h = apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)
    logits = (h[:, -1] @ _out_emb(cfg, params).T).astype(jnp.float32)
    return logits * cfg.logit_scale, cache


def decoder_decode_step(params, cfg: ModelConfig, cache, token):
    """token [B] int32 -> (logits [B,V] f32, cache). pos = cache['pos']."""
    pos = cache["pos"]
    h = _embed_tokens(cfg, params, token[:, None])

    def run(stack, cache_pair, h, moe_layer):
        def body(hh, xs):
            pp, ck, cv = xs
            hh, (nk, nv) = _decode_block(cfg, moe_layer, pp, hh, (ck, cv), pos)
            return hh, (nk, nv)
        h, (nk, nv) = jax.lax.scan(body, h, (stack,) + tuple(cache_pair))
        return h, (nk, nv)

    if "dense_blocks" in params:
        h, cache["dense"] = run(params["dense_blocks"], cache["dense"], h, False)
    h, cache["main"] = run(params["blocks"], cache["main"], h, cfg.moe)
    cache["pos"] = pos + 1
    h = apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)
    logits = (h[:, 0] @ _out_emb(cfg, params).T).astype(jnp.float32)
    return logits * cfg.logit_scale, cache
