"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are generated from a shared low-rank latent ``c_kv`` (kv_lora_rank =
512) plus a single per-token RoPE key shared across heads; only
``[c_kv | k_rope]`` (512+64 per token) is cached at decode time — the
MLA memory win over a GQA cache, modeled faithfully.

Decode uses the *weight-absorbed* form: W_UK is folded into the query
(q_lat = q_nope @ W_UK^T) so scores are taken directly against the
latent cache and the context is expanded through W_UV once — no
per-step materialization of full K/V.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm, apply_rope, dense, dense_init, norm_init

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class MLASpec:
    d_model: int
    n_heads: int
    q_lora_rank: int = 0        # 0 = direct q projection (V2-Lite)
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 10000.0
    impl: str = "xla"
    block_q: int = 512
    block_k: int = 1024

    @property
    def d_qk(self) -> int:
        return self.d_nope + self.d_rope


def init_mla(key, spec: MLASpec, dtype):
    ks = jax.random.split(key, 6)
    h, dq = spec.n_heads, spec.d_qk
    p = {}
    if spec.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], spec.d_model, spec.q_lora_rank, dtype)
        p["q_norm"] = norm_init(spec.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], spec.q_lora_rank, h * dq, dtype)
    else:
        p["wq"] = dense_init(ks[0], spec.d_model, h * dq, dtype)
    p["wkv_a"] = dense_init(ks[2], spec.d_model,
                            spec.kv_lora_rank + spec.d_rope, dtype)
    p["kv_norm"] = norm_init(spec.kv_lora_rank, dtype)
    p["wk_b"] = dense_init(ks[3], spec.kv_lora_rank, h * spec.d_nope, dtype)
    p["wv_b"] = dense_init(ks[4], spec.kv_lora_rank, h * spec.d_v, dtype)
    p["wo"] = dense_init(ks[5], h * spec.d_v, spec.d_model, dtype)
    return p


def _q_proj(p, spec: MLASpec, x, positions):
    b, s, _ = x.shape
    if spec.q_lora_rank:
        q = dense(p["wq_b"], apply_norm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, spec.n_heads, spec.d_qk).transpose(0, 2, 1, 3)
    q_nope, q_pe = q[..., :spec.d_nope], q[..., spec.d_nope:]
    pos_b = positions if positions.ndim == 2 else positions[None]
    q_pe = apply_rope(q_pe, pos_b[:, None, :], theta=spec.rope_theta)
    return q_nope, q_pe


def _latent(p, spec: MLASpec, x, positions):
    """x -> (c_kv [B,S,R] normed, k_pe [B,1,S,dr] rope'd) — the cache pair."""
    kv_a = dense(p["wkv_a"], x)
    c_kv = apply_norm(p["kv_norm"], kv_a[..., :spec.kv_lora_rank])
    k_pe = kv_a[..., spec.kv_lora_rank:][:, None]                # [B,1,S,dr]
    pos_b = positions if positions.ndim == 2 else positions[None]
    k_pe = apply_rope(k_pe, pos_b[:, None, :], theta=spec.rope_theta)
    return c_kv, k_pe


def apply_mla(p, spec: MLASpec, x, positions, *, return_cache=False):
    """Train/prefill path: materializes per-head K/V from the latent."""
    from repro.models.attention import attend
    b, s, _ = x.shape
    h = spec.n_heads
    q_nope, q_pe = _q_proj(p, spec, x, positions)
    c_kv, k_pe = _latent(p, spec, x, positions)

    k_nope = dense(p["wk_b"], c_kv).reshape(b, s, h, spec.d_nope).transpose(0, 2, 1, 3)
    v = dense(p["wv_b"], c_kv).reshape(b, s, h, spec.d_v).transpose(0, 2, 1, 3)
    q = jnp.concatenate([q_nope, q_pe], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, h, s, spec.d_rope))], -1)
    # pad v to d_qk so the flash kernels see square tiles, slice after
    o = attend(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, spec.d_qk - spec.d_v))),
               causal=True, impl=spec.impl, block_q=spec.block_q,
               block_k=spec.block_k)[..., :spec.d_v]
    y = dense(p["wo"], o.transpose(0, 2, 1, 3).reshape(b, s, h * spec.d_v))
    if return_cache:
        return y, (c_kv, k_pe[:, 0])
    return y


def decode_mla(p, spec: MLASpec, x1, cache_c, cache_pe, pos):
    """Absorbed one-token decode.

    x1 [B,1,d]; cache_c [B,S,R]; cache_pe [B,S,dr]; pos [B] int32.
    Returns (y [B,1,d], cache_c, cache_pe).
    """
    b = x1.shape[0]
    s_max = cache_c.shape[1]
    h, r = spec.n_heads, spec.kv_lora_rank
    q_nope, q_pe = _q_proj(p, spec, x1, pos[:, None])       # [B,H,1,*]
    c_kv, k_pe = _latent(p, spec, x1, pos[:, None])         # [B,1,R], [B,1,1,dr]

    bi = jnp.arange(b)
    cache_c = cache_c.at[bi, pos].set(c_kv[:, 0])
    cache_pe = cache_pe.at[bi, pos].set(k_pe[:, 0, 0])

    # absorb W_UK: q_lat[b,h,r] = sum_n q_nope[b,h,n] * W_UK[r,h,n]
    wk_b = p["wk_b"]["w"].reshape(r, h, spec.d_nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0], wk_b)
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                         cache_c.astype(jnp.float32))
              + jnp.einsum("bhe,bse->bhs", q_pe[:, :, 0].astype(jnp.float32),
                           cache_pe.astype(jnp.float32)))
    scores = scores / (spec.d_qk ** 0.5)
    valid = jnp.arange(s_max)[None, :] < (pos + 1)[:, None]
    scores = jnp.where(valid[:, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs, cache_c.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].reshape(r, h, spec.d_v)
    o = jnp.einsum("bhr,rhv->bhv", ctx_lat, wv_b.astype(jnp.float32))
    y = dense(p["wo"], o.reshape(b, 1, h * spec.d_v).astype(x1.dtype))
    return y, cache_c, cache_pe
