"""RWKV6 "Finch" (arXiv:2404.05892): attention-free time mix with
data-dependent decay, plus the RWKV channel mix.

Core recurrence per head (state [N, V] = key-dim x value-dim):

  y_t   = r_t · (state_{t-1} + u ⊙ k_t ⊗ v_t)
  state_t = diag(w_t) state_{t-1} + k_t ⊗ v_t

with w_t = exp(-exp(w0 + lora(x))) — the data-dependent decay.

Two implementations:
  "scan"     exact token-by-token lax.scan (oracle + decode step).
  "chunked"  (default) the sequence is cut into chunks; the per-chunk
             local pass runs the SAME exact recurrence but vectorized
             over all chunks at once (chunk-length sequential steps
             total instead of S), then a cross-chunk scan stitches
             states via the chunk transfer operator. Bit-for-bit the
             same math as "scan" — no exp-factorized matmul form, whose
             1/cumprod(w) terms overflow f32 for strong decays
             (DESIGN.md notes this as the rejected GPU-style variant).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, norm_init


@dataclasses.dataclass(frozen=True)
class RWKV6Spec:
    d_model: int
    n_heads: int
    d_ffn: int
    mix_rank: int = 32          # ddlerp LoRA rank
    decay_rank: int = 64        # decay LoRA rank
    chunk: int = 64

    @property
    def d_attn(self) -> int:
        return self.d_model

    @property
    def d_head(self) -> int:
        return self.d_attn // self.n_heads


def init_rwkv6_time(key, spec: RWKV6Spec, dtype):
    ks = jax.random.split(key, 10)
    d, da = spec.d_model, spec.d_attn
    h, n = spec.n_heads, spec.d_head
    rm, rd = spec.mix_rank, spec.decay_rank
    s = 1.0 / (d ** 0.5)
    return {
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": jnp.full((5, d), 0.5, dtype),                # w,k,v,r,g lerps
        "mix_w1": (jax.random.normal(ks[0], (d, 5 * rm), jnp.float32) * s).astype(dtype),
        "mix_w2": (jax.random.normal(ks[1], (5, rm, d), jnp.float32) * 0.1).astype(dtype),
        "wr": dense_init(ks[2], d, da, dtype),
        "wk": dense_init(ks[3], d, da, dtype),
        "wv": dense_init(ks[4], d, da, dtype),
        "wg": dense_init(ks[5], d, da, dtype),
        "w0": jnp.full((da,), -4.0, jnp.float32),          # slow decay at init
        "decay_w1": (jax.random.normal(ks[6], (d, rd), jnp.float32) * s).astype(dtype),
        "decay_w2": (jax.random.normal(ks[7], (rd, da), jnp.float32) * 0.1).astype(dtype),
        "u": (jax.random.normal(ks[8], (h, n), jnp.float32) * 0.1),
        "ln_x": {"g": jnp.ones((da,), dtype), "b": jnp.zeros((da,), dtype)},
        "wo": dense_init(ks[9], da, d, dtype),
    }


def init_rwkv6_channel(key, spec: RWKV6Spec, dtype):
    ks = jax.random.split(key, 3)
    d = spec.d_model
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, spec.d_ffn, dtype),
        "wv": dense_init(ks[1], spec.d_ffn, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def _shift(x, x_prev=None):
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent lerp producing the 5 mixed inputs [5][B,S,d]."""
    xx = xs - x
    xxx = x + xx * p["mu_x"]
    r = jnp.tanh((xxx @ p["mix_w1"]).astype(jnp.float32))
    rm = p["mix_w2"].shape[1]
    b, s, _ = x.shape
    r = r.reshape(b, s, 5, rm)
    mix = jnp.einsum("bsfr,frd->fbsd", r, p["mix_w2"].astype(jnp.float32))
    return [x + xx * (p["mu"][i] + mix[i].astype(x.dtype)) for i in range(5)]


def _wkv_scan(r, k, v, logw, u, state0):
    """Exact recurrence. r/k/v [B,S,H,N]; logw [B,S,H,N]; state [B,H,N,N]."""
    def step(state, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        att = state + u[None, :, :, None] * kt[..., None] * vt[..., None, :]
        y = jnp.einsum("bhn,bhnm->bhm", rt, att)
        state = state * wt[..., None] + kt[..., None] * vt[..., None, :]
        return state, y

    state, ys = jax.lax.scan(step, state0, jnp.arange(r.shape[1]))
    return ys.transpose(1, 0, 2, 3), state                  # [B,S,H,N]


def _wkv_chunked(r, k, v, logw, u, state0, chunk):
    """Same math, chunk-vectorized: L sequential steps instead of S."""
    b, s, h, n = r.shape
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    rc = r.reshape(b, nc, l, h, n)
    kc = k.reshape(b, nc, l, h, n)
    vc = v.reshape(b, nc, l, h, n)
    lw = logw.reshape(b, nc, l, h, n).astype(jnp.float32)

    # local pass: zero-init recurrence run for all chunks at once
    def local_step(st, t):
        rt, kt, vt, wt = rc[:, :, t], kc[:, :, t], vc[:, :, t], jnp.exp(lw[:, :, t])
        att = st + u[None, None, :, :, None] * kt[..., None] * vt[..., None, :]
        y = jnp.einsum("bchn,bchnm->bchm", rt, att)
        st = st * wt[..., None] + kt[..., None] * vt[..., None, :]
        return st, y

    st0 = jnp.zeros((b, nc, h, n, n), jnp.float32)
    s_loc, y_loc = jax.lax.scan(local_step, st0, jnp.arange(l))
    y_loc = y_loc.transpose(1, 2, 0, 3, 4)                  # [B,nc,L,H,N]

    # cross-chunk stitch: state entering chunk c
    w_tot = jnp.exp(jnp.sum(lw, axis=2))                    # [B,nc,H,N]

    def carry_fn(state, xs):
        sl, wt = xs
        new = state * wt[..., None] + sl
        return new, state

    _, states_in = jax.lax.scan(
        carry_fn, state0,
        (s_loc.transpose(1, 0, 2, 3, 4), w_tot.transpose(1, 0, 2, 3)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)          # [B,nc,H,N,N]
    state_out = states_in[:, -1] * w_tot[:, -1][..., None] + s_loc[:, -1]

    # inter-chunk contribution: y_t += (r_t * cumprod_excl(w)) · state_in
    cum_excl = jnp.cumsum(lw, axis=2) - lw
    r_eff = rc.astype(jnp.float32) * jnp.exp(cum_excl)
    y_inter = jnp.einsum("bclhn,bchnm->bclhm", r_eff, states_in)
    y = (y_loc + y_inter).reshape(b, s, h, n)
    return y, state_out


def apply_rwkv6_time(p, spec: RWKV6Spec, x, *, x_prev=None, wkv_state=None,
                     impl: str = "chunked"):
    """Time mix over x [B,S,d]. Returns (y, (last_x, wkv_state))."""
    b, s, d = x.shape
    h, n = spec.n_heads, spec.d_head
    xw, xk, xv, xr, xg = _ddlerp(p, x, _shift(x, x_prev))
    r = dense(p["wr"], xr).reshape(b, s, h, n)
    k = dense(p["wk"], xk).reshape(b, s, h, n)
    v = dense(p["wv"], xv).reshape(b, s, h, n)
    g = dense(p["wg"], xg)
    dw = jnp.tanh((xw @ p["decay_w1"]).astype(jnp.float32)) @ p["decay_w2"].astype(jnp.float32)
    logw = -jnp.exp(p["w0"] + dw).reshape(b, s, h, n)       # log decay < 0

    if wkv_state is None:
        wkv_state = jnp.zeros((b, h, n, n), jnp.float32)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if impl == "chunked" and s % min(spec.chunk, s) != 0:
        impl = "scan"
    if impl == "pallas":
        from repro.kernels import ops
        y, state = ops.wkv6(rf, kf, vf, logw, p["u"], wkv_state,
                            block_s=min(spec.chunk, s))
    elif impl == "chunked":
        y, state = _wkv_chunked(rf, kf, vf, logw, p["u"], wkv_state, spec.chunk)
    else:
        y, state = _wkv_scan(rf, kf, vf, logw, p["u"], wkv_state)

    # per-head group norm, then silu(g) gate and output proj
    y = y.reshape(b, s, h, n)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean((y - mu) ** 2, axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, h * n)
    y = y * p["ln_x"]["g"] + p["ln_x"]["b"]
    y = y.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], y), (x[:, -1:], state)


def apply_rwkv6_channel(p, x, *, x_prev=None):
    """Channel mix. Returns (y, last_x)."""
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(dense(p["wk"], xk).astype(jnp.float32))).astype(x.dtype)
    y = jax.nn.sigmoid(dense(p["wr"], xr).astype(jnp.float32)).astype(x.dtype) * dense(p["wv"], k)
    return y, x[:, -1:]
