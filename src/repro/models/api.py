"""Unified model API — dispatch on cfg.family.

  init_lm(cfg, key)                  -> params
  lm_loss(params, cfg, batch)        -> (loss, metrics)     [train]
  init_cache(cfg, batch, s_max)      -> cache pytree        [serve]
  lm_prefill(params, cfg, cache, batch) -> (logits, cache)
  lm_decode_step(params, cfg, cache, token) -> (logits, cache)

batch = {"tokens": [B,S] i32, "labels": [B,S] i32 (-100 masked),
         "frontend": [B, n_patches|enc_seq, d] (vlm/encdec stubs only)}
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import rwkv_model, transformer, whisper, zamba

_DECODER = ("dense", "moe", "vlm")


def init_lm(cfg: ModelConfig, key):
    if cfg.family in _DECODER:
        return transformer.init_decoder(cfg, key)
    if cfg.family == "ssm":
        return rwkv_model.init_rwkv(cfg, key)
    if cfg.family == "hybrid":
        return zamba.init_zamba(cfg, key)
    if cfg.family == "encdec":
        return whisper.init_whisper(cfg, key)
    raise ValueError(cfg.family)


def lm_loss(params, cfg: ModelConfig, batch):
    if cfg.family in _DECODER:
        return transformer.decoder_loss(params, cfg, batch)
    if cfg.family == "ssm":
        return rwkv_model.rwkv_loss(params, cfg, batch)
    if cfg.family == "hybrid":
        return zamba.zamba_loss(params, cfg, batch)
    if cfg.family == "encdec":
        return whisper.whisper_loss(params, cfg, batch)
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, s_max: int):
    if cfg.family in _DECODER:
        return transformer.decoder_init_cache(cfg, batch, s_max)
    if cfg.family == "ssm":
        return rwkv_model.rwkv_init_cache(cfg, batch, s_max)
    if cfg.family == "hybrid":
        return zamba.zamba_init_cache(cfg, batch, s_max)
    if cfg.family == "encdec":
        return whisper.whisper_init_cache(cfg, batch, s_max)
    raise ValueError(cfg.family)


def lm_prefill(params, cfg: ModelConfig, cache, batch):
    tokens = batch["tokens"]
    if cfg.family in _DECODER:
        frontend = batch.get("frontend") if cfg.family == "vlm" else None
        return transformer.decoder_prefill(params, cfg, tokens, cache,
                                           frontend=frontend)
    if cfg.family == "ssm":
        return rwkv_model.rwkv_prefill(params, cfg, tokens, cache)
    if cfg.family == "hybrid":
        return zamba.zamba_prefill(params, cfg, tokens, cache)
    if cfg.family == "encdec":
        return whisper.whisper_prefill(params, cfg, tokens, cache,
                                       batch["frontend"])
    raise ValueError(cfg.family)


def lm_decode_step(params, cfg: ModelConfig, cache, token):
    if cfg.family in _DECODER:
        return transformer.decoder_decode_step(params, cfg, cache, token)
    if cfg.family == "ssm":
        return rwkv_model.rwkv_decode_step(params, cfg, cache, token)
    if cfg.family == "hybrid":
        return zamba.zamba_decode_step(params, cfg, cache, token)
    if cfg.family == "encdec":
        return whisper.whisper_decode_step(params, cfg, cache, token)
    raise ValueError(cfg.family)
