"""GQA / MHA / sliding-window attention: train, prefill and decode paths.

Three interchangeable inner implementations (`impl`):

  "naive"   O(S^2)-memory masked softmax — oracle + tiny smoke shapes.
  "xla"     chunked online-softmax flash (lax.scan over q/kv blocks) —
            linear memory, compiles to compact HLO; the default for the
            CPU dry-run. Sliding-window uses a dynamic-slice slab so SWA
            cost is O(S*window), not O(S^2).
  "pallas"  repro.kernels.flash_attention (TPU target).

Decode reads a [B, Hkv, S, D] cache (full causal) or a [B, Hkv, W, D]
ring buffer (sliding window); keys are stored post-RoPE.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense, dense_init

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_q: int
    n_kv: int
    d_head: int
    causal: bool = True
    window: int | None = None          # sliding-window size (None = full)
    rope_frac: float = 1.0             # fraction of d_head rotated
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    o_bias: bool = False
    impl: str = "xla"                  # "naive" | "xla" | "pallas"
    block_q: int = 512
    block_k: int = 1024

    @property
    def d_rot(self) -> int:
        r = int(self.d_head * self.rope_frac)
        return r - (r % 2)


def init_attention(key, spec: AttnSpec, dtype):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], spec.d_model, spec.n_q * spec.d_head, dtype,
                         bias=spec.qkv_bias),
        "wk": dense_init(ks[1], spec.d_model, spec.n_kv * spec.d_head, dtype,
                         bias=spec.qkv_bias),
        "wv": dense_init(ks[2], spec.d_model, spec.n_kv * spec.d_head, dtype,
                         bias=spec.qkv_bias),
        "wo": dense_init(ks[3], spec.n_q * spec.d_head, spec.d_model, dtype,
                         bias=spec.o_bias),
    }


# --------------------------------------------------------------------------
# inner attention implementations ([B, H, S, D] layout)
# --------------------------------------------------------------------------
def _grouped_scores(q, k):
    """q [B,Hq,Sq,D] x k [B,Hkv,Sk,D] -> [B,Hkv,G,Sq,Sk] without repeat."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(b, hkv, hq // hkv, sq, d)
    return jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def naive_attention(q, k, v, *, causal=True, window=None, q_offset=None):
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = 1.0 / (d ** 0.5)
    if q_offset is None:
        q_offset = sk - sq
    s = _grouped_scores(q, k) * scale                       # [B,Hkv,G,Sq,Sk]
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def _flash_inner(qb, k, v, q0, *, causal, window, block_k, scale,
                 kv_valid=None):
    """One q block [B,Hkv,G,bq,D] against all kv blocks (scan)."""
    b, hkv, g, bq, d = qb.shape
    sk = k.shape[2]
    nk = sk // block_k
    kb = k.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    qf = qb.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        j, kc, vc = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kc.astype(jnp.float32)) * scale
        q_pos = q0 + jnp.arange(bq)[:, None]
        k_pos = j * block_k + jnp.arange(block_k)[None, :]
        mask = jnp.ones((bq, block_k), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        if kv_valid is not None:
            mask &= k_pos < kv_valid
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    init = (jnp.full((b, hkv, g, bq, 1), _NEG, jnp.float32),
            jnp.zeros((b, hkv, g, bq, 1), jnp.float32),
            jnp.zeros((b, hkv, g, bq, d), jnp.float32))
    # remat: the [bq, bk] score/prob blocks are recomputed in backward
    # (flash-attention backward) instead of living as per-step residuals
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), init, (jnp.arange(nk), kb, vb))
    return acc / jnp.where(l > 0, l, 1.0)[..., 0][..., None]


def _swa_slab_inner(qb, k, v, q0, *, window, block_k, scale, kv_valid=None):
    """Sliding-window q block: dynamic-slice a [window+bq] kv slab."""
    b, hkv, g, bq, d = qb.shape
    sk = k.shape[2]
    slab = min(sk, ((window + bq + block_k - 1) // block_k) * block_k)
    start = jnp.clip(q0 + bq - slab, 0, sk - slab)
    ks = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=2)
    vs = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=2)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                   ks.astype(jnp.float32)) * scale
    q_pos = q0 + jnp.arange(bq)[:, None]
    k_pos = start + jnp.arange(slab)[None, :]
    mask = (q_pos >= k_pos) & ((q_pos - k_pos) < window)
    if kv_valid is not None:
        mask &= k_pos < kv_valid
    s = jnp.where(mask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p, vs.astype(jnp.float32))


def flash_attention_xla(q, k, v, *, causal=True, window=None,
                        block_q=512, block_k=1024, q_offset=None):
    """Chunked online-softmax attention; [B,H,S,D] in/out.

    Non-block-multiple lengths are zero-padded internally and masked
    (padding keys get -inf scores; padding query rows are sliced off).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    scale = 1.0 / (d ** 0.5)
    if q_offset is None:
        q_offset = sk - sq
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_orig, sk_orig = sq, sk
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        sk += pad_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        sq += pad_q
    nq = sq // block_q
    qg = q.reshape(b, hkv, hq // hkv, nq, block_q, d).transpose(3, 0, 1, 2, 4, 5)

    kv_valid = sk_orig if pad_k else None
    if window is not None and window + block_q < sk:
        inner = partial(_swa_slab_inner, window=window, block_k=block_k,
                        scale=scale, kv_valid=kv_valid)
    else:
        inner = partial(_flash_inner, causal=causal, window=window,
                        block_k=block_k, scale=scale, kv_valid=kv_valid)

    def outer(_, xs):
        i, qb = xs
        o = inner(qb, k, v, i * block_q + q_offset)
        return None, o.astype(q.dtype)

    _, ob = jax.lax.scan(jax.checkpoint(outer), None, (jnp.arange(nq), qg))
    # [nq, B, Hkv, G, bq, D] -> [B, Hq, Sq, D]
    out = ob.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq, d)
    return out[:, :, :sq_orig]


def attend(q, k, v, *, causal=True, window=None, impl="xla",
           block_q=512, block_k=1024, q_offset=None):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    if impl == "xla":
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   q_offset=q_offset)
    if impl == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=min(block_q, 128),
                                   block_k=min(block_k, 128))
    raise ValueError(f"unknown attention impl {impl!r}")


# --------------------------------------------------------------------------
# module-level apply: projections + rope + attention
# --------------------------------------------------------------------------
def _split_heads(x, n, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def apply_attention(p, spec: AttnSpec, x, positions, *, return_kv=False):
    """Self-attention over x [B, S, d]; positions [S] (or [B, S])."""
    q = _split_heads(dense(p["wq"], x), spec.n_q, spec.d_head)
    k = _split_heads(dense(p["wk"], x), spec.n_kv, spec.d_head)
    v = _split_heads(dense(p["wv"], x), spec.n_kv, spec.d_head)
    if spec.d_rot > 0:
        pos_b = positions if positions.ndim == 2 else positions[None]
        q = apply_rope(q, pos_b[:, None, :], d_rot=spec.d_rot,
                       theta=spec.rope_theta)
        k = apply_rope(k, pos_b[:, None, :], d_rot=spec.d_rot,
                       theta=spec.rope_theta)
    o = attend(q, k, v, causal=spec.causal, window=spec.window,
               impl=spec.impl, block_q=spec.block_q, block_k=spec.block_k)
    y = dense(p["wo"], _merge_heads(o))
    if return_kv:
        return y, (k, v)
    return y


def apply_cross_attention(p, spec: AttnSpec, x, kv_or_mem, *, from_cache=False):
    """Cross-attention: queries from x, keys/values from encoder memory
    [B, Sm, d] (or a precomputed (k, v) cache). No RoPE, no mask."""
    q = _split_heads(dense(p["wq"], x), spec.n_q, spec.d_head)
    if from_cache:
        k, v = kv_or_mem
    else:
        k = _split_heads(dense(p["wk"], kv_or_mem), spec.n_kv, spec.d_head)
        v = _split_heads(dense(p["wv"], kv_or_mem), spec.n_kv, spec.d_head)
    o = attend(q, k, v, causal=False, impl=spec.impl,
               block_q=spec.block_q, block_k=spec.block_k)
    return dense(p["wo"], _merge_heads(o))


def decode_self_attention(p, spec: AttnSpec, x1, cache_k, cache_v, pos, *,
                          decode_impl="xla"):
    """One-token decode. x1 [B, 1, d]; cache [B, Hkv, S(|W), D]; pos [B] int32.

    Returns (y [B, 1, d], new_cache_k, new_cache_v). Keys are cached
    post-RoPE. For sliding-window specs the cache is a ring buffer of
    width W = spec.window.
    """
    b = x1.shape[0]
    s_max = cache_k.shape[2]
    q = _split_heads(dense(p["wq"], x1), spec.n_q, spec.d_head)   # [B,Hq,1,D]
    k = _split_heads(dense(p["wk"], x1), spec.n_kv, spec.d_head)  # [B,Hkv,1,D]
    v = _split_heads(dense(p["wv"], x1), spec.n_kv, spec.d_head)
    if spec.d_rot > 0:
        q = apply_rope(q, pos[:, None, None], d_rot=spec.d_rot,
                       theta=spec.rope_theta)
        k = apply_rope(k, pos[:, None, None], d_rot=spec.d_rot,
                       theta=spec.rope_theta)

    ring = spec.window is not None and s_max == spec.window
    slot = jnp.where(ring, pos % s_max, jnp.minimum(pos, s_max - 1))
    bi = jnp.arange(b)
    cache_k = cache_k.at[bi, :, slot].set(k[:, :, 0])
    cache_v = cache_v.at[bi, :, slot].set(v[:, :, 0])

    kv_len = pos + 1
    if decode_impl == "pallas" and not ring:
        from repro.kernels import ops
        o = ops.decode_attention(q[:, :, 0], cache_k, cache_v,
                                 kv_len.astype(jnp.int32))       # [B, Hq, D]
        o = o[:, :, None, :]                                     # [B, Hq, 1, D]
    elif ring:
        o = _ring_decode_xla(q, cache_k, cache_v, pos, spec)
    else:
        # masked matvec over the cache — already bandwidth-optimal in XLA
        s = _grouped_scores(q, cache_k) * (1.0 / spec.d_head ** 0.5)
        valid = jnp.arange(s_max)[None, :] < kv_len[:, None]
        s = jnp.where(valid[:, None, None, None], s, _NEG)
        pmat = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", pmat,
                       cache_v.astype(jnp.float32))
        o = o.reshape(b, spec.n_q, 1, spec.d_head).astype(x1.dtype)
    y = dense(p["wo"], _merge_heads(o))
    return y, cache_k, cache_v


def _ring_decode_xla(q, cache_k, cache_v, pos, spec: AttnSpec):
    """Decode against a ring-buffer SWA cache: valid slots are the last
    min(pos+1, W) writes; ordering is irrelevant under softmax."""
    b = q.shape[0]
    w = cache_k.shape[2]
    s = _grouped_scores(q, cache_k) * (1.0 / spec.d_head ** 0.5)
    n_valid = jnp.minimum(pos + 1, w)
    slot = jnp.arange(w)[None, :]
    # slots [0, n_valid) are valid when pos < w; all valid once wrapped —
    # except slots written more than w steps ago, which were overwritten.
    valid = slot < n_valid[:, None]
    s = jnp.where(valid[:, None, None, None], s, _NEG)
    pmat = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", pmat, cache_v.astype(jnp.float32))
    return o.reshape(b, spec.n_q, 1, spec.d_head).astype(q.dtype)
