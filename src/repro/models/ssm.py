"""Mamba2 (State Space Duality) mixer — Zamba2's backbone layer.

Training/prefill uses the chunked SSD form: the sequence is split into
chunks; within a chunk the output is an attention-like masked matmul
(MXU-friendly), across chunks a [B, H, P, N] state is carried by a
short ``lax.scan``. ``ssd_ref`` is the exact token-by-token recurrence
used as the oracle and as the one-token decode step.

State per layer (the whole serving cache for an SSM layer):
  ssm_state  [B, H, P, N]        (P = head dim, N = d_state)
  conv_state [B, d_conv-1, Dcv]  (causal depthwise conv tail)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, norm_init


@dataclasses.dataclass(frozen=True)
class Mamba2Spec:
    d_model: int
    d_state: int = 64           # N
    d_head: int = 64            # P
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head

    @property
    def d_conv_ch(self) -> int:  # channels that pass through the conv
        return self.d_inner + 2 * self.n_groups * self.d_state


def init_mamba2(key, spec: Mamba2Spec, dtype):
    """Projections are SPLIT (z / x / BC / dt) rather than fused so each
    can carry its own PartitionSpec: z and x are head-sharded over the
    ``model`` axis, while the tiny group-shared B/C and per-head dt stay
    replicated (Mamba2 TP per the SSD paper's n_groups constraint)."""
    ks = jax.random.split(key, 6)
    d_bc = 2 * spec.n_groups * spec.d_state
    return {
        "in_z": dense_init(ks[0], spec.d_model, spec.d_inner, dtype),
        "in_x": dense_init(ks[1], spec.d_model, spec.d_inner, dtype),
        "in_bc": dense_init(ks[2], spec.d_model, d_bc, dtype),
        "in_dt": dense_init(ks[3], spec.d_model, spec.n_heads, dtype),
        "conv_w_x": (jax.random.normal(ks[4], (spec.d_conv, spec.d_inner),
                                       jnp.float32) * 0.2).astype(dtype),
        "conv_b_x": jnp.zeros((spec.d_inner,), dtype),
        "conv_w_bc": (jax.random.normal(ks[5], (spec.d_conv, d_bc),
                                        jnp.float32) * 0.2).astype(dtype),
        "conv_b_bc": jnp.zeros((d_bc,), dtype),
        "A_log": jnp.zeros((spec.n_heads,), jnp.float32),   # A = -1 at init
        "D": jnp.ones((spec.n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((spec.n_heads,), jnp.float32),
        "norm": norm_init(spec.d_inner, dtype),
        "out_proj": dense_init(ks[2], spec.d_inner, spec.d_model, dtype),
    }


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over [B, S, C]; optional [B, d_conv-1, C] tail."""
    kw = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(kw)) + conv_b
    new_state = xp[:, -(kw - 1):] if kw > 1 else pad
    return jax.nn.silu(y.astype(jnp.float32)).astype(xbc.dtype), new_state


def _project_in(p, x):
    """x [B,S,d] -> (z, xc, bc, dt) via the four split projections."""
    return (dense(p["in_z"], x), dense(p["in_x"], x),
            dense(p["in_bc"], x), dense(p["in_dt"], x))


def _gate_out(p, spec: Mamba2Spec, y, z):
    """Gated RMSNorm (y * silu(z)) then output projection (in z's dtype)."""
    b, s = y.shape[:2]
    yf = y.reshape(b, s, spec.d_inner).astype(jnp.float32) * jax.nn.silu(
        z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)
    y = yf.astype(z.dtype) * p["norm"]["g"]
    return dense(p["out_proj"], y)


def apply_mamba2(p, spec: Mamba2Spec, x, *, impl: str = "chunked"):
    """x [B, S, d] -> [B, S, d] (train / prefill)."""
    y, _ = apply_mamba2_with_state(p, spec, x, impl=impl)
    return y


def apply_mamba2_with_state(p, spec: Mamba2Spec, x, *, impl: str = "chunked"):
    """Forward returning (y, (ssm_state, conv_states)) for prefill."""
    b, s, _ = x.shape
    h, pp, n, g = spec.n_heads, spec.d_head, spec.d_state, spec.n_groups
    z, xc, bc, dt = _project_in(p, x)
    xc, conv_x = _causal_conv(xc, p["conv_w_x"], p["conv_b_x"])
    bc, conv_bc = _causal_conv(bc, p["conv_w_bc"], p["conv_b_bc"])
    conv_state = (conv_x, conv_bc)
    xs = xc.reshape(b, s, h, pp)
    bm = bc[..., :g * n].reshape(b, s, g, n)
    cm = bc[..., g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,S,H]
    a = -jnp.exp(p["A_log"])                                         # [H]

    if impl == "chunked" and s % min(spec.chunk, s) == 0:
        y, state = _ssd_chunked(xs, bm, cm, dt, a, p["D"], spec.chunk, g, h)
    else:
        y, state = _ssd_scan(xs, bm, cm, dt, a, p["D"], g, h)
    return _gate_out(p, spec, y, z), (state, conv_state)


def _expand_groups(bm, g, h):
    """[B,S,G,N] -> [B,S,H,N] by repeating each group across its heads."""
    return jnp.repeat(bm, h // g, axis=2)


def _ssd_scan(xs, bm, cm, dt, a, d_skip, g, h, state0=None):
    """Exact recurrence (oracle / decode):
    state_t = state_{t-1} * exp(dt_t A) + dt_t x_t ⊗ B_t;  y_t = C_t·state_t + D x_t
    """
    b, s, _, pp = xs.shape
    n = bm.shape[-1]
    bmh = _expand_groups(bm, g, h).astype(jnp.float32)
    cmh = _expand_groups(cm, g, h).astype(jnp.float32)
    xf = xs.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((b, h, pp, n), jnp.float32)

    def step(state, t):
        xt, bt, ct, dtt = xf[:, t], bmh[:, t], cmh[:, t], dt[:, t]
        decay = jnp.exp(dtt * a)[:, :, None, None]
        state = state * decay + jnp.einsum(
            "bhp,bhn,bh->bhpn", xt, bt, dtt)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct) + d_skip[:, None] * xt
        return state, y

    state, ys = jax.lax.scan(step, state0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3), state                 # [B,S,H,P]


def _ssd_chunked(xs, bm, cm, dt, a, d_skip, chunk, g, h):
    """Chunked SSD: intra-chunk quadratic term + inter-chunk state scan."""
    b, s, _, pp = xs.shape
    n = bm.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, (s, l)
    nc = s // l
    bmh = _expand_groups(bm, g, h).astype(jnp.float32).reshape(b, nc, l, h, n)
    cmh = _expand_groups(cm, g, h).astype(jnp.float32).reshape(b, nc, l, h, n)
    xf = xs.astype(jnp.float32).reshape(b, nc, l, h, pp)
    dtc = dt.reshape(b, nc, l, h)
    da = dtc * a                                            # [B,nc,L,H]
    cum = jnp.cumsum(da, axis=2)                            # inclusive

    # intra-chunk: y[t] += sum_{s<=t} C_t·B_s exp(cum_t - cum_s) dt_s x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,nc,T,S,H]
    tri = jnp.tril(jnp.ones((l, l), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcthn,bcshn->bctsh", cmh, bmh)
    y_intra = jnp.einsum("bctsh,bcsh,bcshp->bcthp", cb * lmat, dtc, xf)

    # chunk-boundary states
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)            # [B,nc,L,H]
    chunk_states = jnp.einsum("bcsh,bcsh,bcshn,bcshp->bchpn",
                              decay_out, dtc, bmh, xf)
    chunk_decay = jnp.exp(cum[:, :, -1])                    # [B,nc,H]

    def carry_fn(state, xs_):
        cs, cd = xs_
        new = state * cd[:, :, None, None] + cs
        return new, state                                   # emit state BEFORE chunk

    _, states_in = jax.lax.scan(
        carry_fn, jnp.zeros((b, h, pp, n), jnp.float32),
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)          # [B,nc,H,P,N]

    # inter-chunk: y[t] += C_t · (exp(cum_t) * state_in)
    y_inter = jnp.einsum("bcthn,bcth,bchpn->bcthp",
                         cmh, jnp.exp(cum), states_in)
    y = y_intra + y_inter + d_skip[:, None] * xf
    state_out = (states_in[:, -1] * chunk_decay[:, -1][..., None, None]
                 + chunk_states[:, -1])
    return y.reshape(b, s, h, pp), state_out


def decode_mamba2(p, spec: Mamba2Spec, x1, ssm_state, conv_state):
    """One-token decode. x1 [B,1,d]; returns (y [B,1,d], new states)."""
    z, xc, bc, dt = _project_in(p, x1)
    conv_x, conv_bc = conv_state
    xc, conv_x = _causal_conv(xc, p["conv_w_x"], p["conv_b_x"], conv_x)
    bc, conv_bc = _causal_conv(bc, p["conv_w_bc"], p["conv_b_bc"], conv_bc)
    b = x1.shape[0]
    h, pp, n, g = spec.n_heads, spec.d_head, spec.d_state, spec.n_groups
    xs = xc.reshape(b, 1, h, pp)
    bm = bc[..., :g * n].reshape(b, 1, g, n)
    cm = bc[..., g * n:].reshape(b, 1, g, n)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, ssm_state = _ssd_scan(xs, bm, cm, dtf, a, p["D"], g, h, state0=ssm_state)
    return _gate_out(p, spec, y, z), ssm_state, (conv_x, conv_bc)


def init_mamba2_state(spec: Mamba2Spec, batch: int, dtype=jnp.float32):
    return (jnp.zeros((batch, spec.n_heads, spec.d_head, spec.d_state),
                      jnp.float32),
            (jnp.zeros((batch, spec.d_conv - 1, spec.d_inner), dtype),
             jnp.zeros((batch, spec.d_conv - 1, 2 * spec.n_groups * spec.d_state),
                       dtype)))
