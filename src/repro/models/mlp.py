"""Dense feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init, swiglu


def init_mlp(key, d_model: int, d_ff: int, dtype, *, kind: str = "swiglu",
             bias: bool = False):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype, bias=bias),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype, bias=bias),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype, bias=bias),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(ks[1], d_model, d_ff, dtype, bias=bias),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype, bias=bias),
        }
    raise ValueError(kind)


def apply_mlp(p, x, *, kind: str = "swiglu"):
    if kind == "swiglu":
        return dense(p["w_down"], swiglu(dense(p["w_gate"], x), dense(p["w_up"], x)))
    if kind == "gelu":
        return dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], x).astype(jnp.float32)).astype(x.dtype))
    raise ValueError(kind)
