"""Zamba2-style hybrid stack: Mamba2 backbone + one SHARED attention
block applied between groups of Mamba2 layers (arXiv:2411.15242).

Layer layout (cfg.n_layers = G*(1+M) + T):
  [shared-attn, M x mamba2] x G groups, then T trailing mamba2 layers.

The shared block's weights are a single parameter set; each of its G
applications adds a per-application LoRA delta on the q/k/v projections
(Zamba2's block specialization). Its input is concat(h, h0) (2*d wide,
h0 = the embedding output), attention + MLP run at 2*d, and the output
is projected back to d and added to the residual stream.

Serving state: G KV caches (one per shared-block application — weights
are shared, caches are not) + per-mamba-layer SSM/conv states.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (apply_norm, chunked_cross_entropy, dense,
                                 dense_init, embed_init, norm_init)
from repro.models.config import ModelConfig
from repro.models.mlp import apply_mlp, init_mlp
from repro.models.ssm import (Mamba2Spec, apply_mamba2,
                              apply_mamba2_with_state, decode_mamba2,
                              init_mamba2, init_mamba2_state)


def mamba_spec(cfg: ModelConfig) -> Mamba2Spec:
    return Mamba2Spec(d_model=cfg.d_model, d_state=cfg.ssm_state,
                      d_head=cfg.ssm_head, chunk=cfg.ssm_chunk)


def shared_attn_spec(cfg: ModelConfig) -> attn.AttnSpec:
    d2 = 2 * cfg.d_model
    return attn.AttnSpec(
        d_model=d2, n_q=cfg.n_heads, n_kv=cfg.n_kv, d_head=d2 // cfg.n_heads,
        causal=True, rope_theta=cfg.rope_theta, impl=cfg.impl,
        block_q=cfg.block_q, block_k=cfg.block_k)


def _init_shared(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    d2 = 2 * cfg.d_model
    return {
        "ln1": norm_init(d2, cfg.pdt),
        "attn": attn.init_attention(ks[0], shared_attn_spec(cfg), cfg.pdt),
        "ln2": norm_init(d2, cfg.pdt),
        "mlp": init_mlp(ks[1], d2, cfg.d_ff, cfg.pdt),
        "out": dense_init(ks[2], d2, cfg.d_model, cfg.pdt),
    }


def _init_lora(cfg: ModelConfig, key):
    d2 = 2 * cfg.d_model
    spec = shared_attn_spec(cfg)
    r = cfg.lora_rank
    ks = jax.random.split(key, 6)
    mk = lambda ka, kb, dout: {
        "a": (jax.random.normal(ka, (d2, r), jnp.float32) * 0.01).astype(cfg.pdt),
        "b": jnp.zeros((r, dout), cfg.pdt)}
    return {
        "q": mk(ks[0], ks[1], spec.n_q * spec.d_head),
        "k": mk(ks[2], ks[3], spec.n_kv * spec.d_head),
        "v": mk(ks[4], ks[5], spec.n_kv * spec.d_head),
    }


def _init_mamba_block(cfg: ModelConfig, key):
    return {"ln": norm_init(cfg.d_model, cfg.pdt),
            "mix": init_mamba2(key, mamba_spec(cfg), cfg.pdt)}


def init_zamba(cfg: ModelConfig, key):
    g, m, t = cfg.n_attn_groups, cfg.mamba_per_group, cfg.trailing_mamba
    assert cfg.n_layers == g * (1 + m) + t, (cfg.n_layers, g, m, t)
    keys = jax.random.split(key, 6)
    gm_keys = jax.random.split(keys[1], g * m).reshape(g, m, 2)
    p = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, cfg.pdt),
        "shared": _init_shared(cfg, keys[2]),
        "lora": jax.vmap(partial(_init_lora, cfg))(jax.random.split(keys[3], g)),
        "mamba": jax.vmap(jax.vmap(partial(_init_mamba_block, cfg)))(gm_keys),
        "ln_f": norm_init(cfg.d_model, cfg.pdt),
        "unembed": embed_init(keys[5], cfg.vocab, cfg.d_model, cfg.pdt),
    }
    if t:
        p["trailing"] = jax.vmap(partial(_init_mamba_block, cfg))(
            jax.random.split(keys[4], t))
    return p


def _shared_qkv(p, lora, spec, a):
    """q/k/v projections with the per-application LoRA delta."""
    def proj(w, lr):
        return dense(w, a) + (a @ lr["a"]) @ lr["b"]
    q = proj(p["attn"]["wq"], lora["q"])
    k = proj(p["attn"]["wk"], lora["k"])
    v = proj(p["attn"]["wv"], lora["v"])
    b, s, _ = a.shape
    shp = lambda x, n: x.reshape(b, s, n, spec.d_head).transpose(0, 2, 1, 3)
    return shp(q, spec.n_q), shp(k, spec.n_kv), shp(v, spec.n_kv)


def _apply_shared(cfg, p, lora, h, h0, positions, *, cache=None, pos=None):
    """The shared attention block. cache=(ck, cv) enables decode mode."""
    from repro.models.common import apply_rope
    from repro.parallel.act_sharding import maybe_gather_hidden
    spec = shared_attn_spec(cfg)
    xin = jnp.concatenate([h, h0], axis=-1)
    a = maybe_gather_hidden(
        apply_norm(p["ln1"], xin, kind=cfg.norm, eps=cfg.norm_eps))
    q, k, v = _shared_qkv(p, lora, spec, a)
    if cache is None:
        pos_b = positions[None]
        q = apply_rope(q, pos_b[:, None, :], theta=spec.rope_theta)
        k = apply_rope(k, pos_b[:, None, :], theta=spec.rope_theta)
        o = attn.attend(q, k, v, causal=True, impl=spec.impl,
                        block_q=spec.block_q, block_k=spec.block_k)
        new_kv = (k, v)
    else:
        ck, cv = cache
        b = h.shape[0]
        q = apply_rope(q, pos[:, None, None], theta=spec.rope_theta)
        k = apply_rope(k, pos[:, None, None], theta=spec.rope_theta)
        bi = jnp.arange(b)
        ck = ck.at[bi, :, pos].set(k[:, :, 0])
        cv = cv.at[bi, :, pos].set(v[:, :, 0])
        s_max = ck.shape[2]
        sc = attn._grouped_scores(q, ck) / (spec.d_head ** 0.5)  # [B,Hkv,G,1,S]
        valid = jnp.arange(s_max)[None, :] < (pos + 1)[:, None]
        sc = jnp.where(valid[:, None, None, None], sc, -1e30)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", jax.nn.softmax(sc, -1),
                       cv.astype(jnp.float32))
        o = o.reshape(b, spec.n_q, 1, spec.d_head).astype(h.dtype)
        new_kv = (ck, cv)
    b, s = h.shape[:2]
    o = o.transpose(0, 2, 1, 3).reshape(b, s, spec.n_q * spec.d_head)
    xin = xin + dense(p["attn"]["wo"], o)
    x2 = apply_norm(p["ln2"], xin, kind=cfg.norm, eps=cfg.norm_eps)
    xin = xin + apply_mlp(p["mlp"], x2)
    return h + dense(p["out"], xin), new_kv


def _apply_mamba_block(cfg, p, h):
    from repro.parallel.act_sharding import maybe_gather_hidden, maybe_shard_hidden
    a = maybe_gather_hidden(
        apply_norm(p["ln"], h, kind=cfg.norm, eps=cfg.norm_eps))
    return maybe_shard_hidden(h + apply_mamba2(p["mix"], mamba_spec(cfg), a))


def zamba_hidden(params, cfg: ModelConfig, tokens):
    h = params["embed"]["emb"][tokens].astype(cfg.cdt)
    h0 = h
    positions = jnp.arange(tokens.shape[1])

    def group(h, xs):
        lora, mamba_stack = xs
        h, _ = _apply_shared(cfg, params["shared"], lora, h, h0, positions)
        inner = lambda hh, pp: (_apply_mamba_block(cfg, pp, hh), None)
        if cfg.remat:
            inner = jax.checkpoint(inner)
        h, _ = jax.lax.scan(inner, h, mamba_stack)
        return h, None

    grp = jax.checkpoint(group) if cfg.remat else group
    h, _ = jax.lax.scan(grp, h, (params["lora"], params["mamba"]))
    if "trailing" in params:
        inner = lambda hh, pp: (_apply_mamba_block(cfg, pp, hh), None)
        if cfg.remat:
            inner = jax.checkpoint(inner)
        h, _ = jax.lax.scan(inner, h, params["trailing"])
    return apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)


def zamba_loss(params, cfg: ModelConfig, batch):
    h = zamba_hidden(params, cfg, batch["tokens"])
    loss = chunked_cross_entropy(h, params["unembed"]["emb"],
                                 batch["labels"], chunk=cfg.logits_chunk)
    return loss, {"loss": loss}


def zamba_init_cache(cfg: ModelConfig, batch: int, s_max: int):
    g, m, t = cfg.n_attn_groups, cfg.mamba_per_group, cfg.trailing_mamba
    spec = mamba_spec(cfg)
    aspec = shared_attn_spec(cfg)
    mk_ssm = lambda n: (
        jnp.zeros((n, batch, spec.n_heads, spec.d_head, spec.d_state),
                  jnp.float32),
        (jnp.zeros((n, batch, spec.d_conv - 1, spec.d_inner), cfg.cdt),
         jnp.zeros((n, batch, spec.d_conv - 1,
                    2 * spec.n_groups * spec.d_state), cfg.cdt)))
    cache = {
        "kv": (jnp.zeros((g, batch, aspec.n_kv, s_max, aspec.d_head), cfg.cdt),
               jnp.zeros((g, batch, aspec.n_kv, s_max, aspec.d_head), cfg.cdt)),
        "ssm": jax.tree.map(lambda x: x.reshape((g, m) + x.shape[1:]),
                            mk_ssm(g * m)),
        "h0": jnp.zeros((batch, 1, cfg.d_model), cfg.cdt),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
    if t:
        cache["trail_ssm"] = mk_ssm(t)
    return cache


def zamba_prefill(params, cfg: ModelConfig, tokens, cache):
    """Prefill: run the full hidden pass capturing KV + final SSM states."""
    h = params["embed"]["emb"][tokens].astype(cfg.cdt)
    h0 = h
    b, s = tokens.shape
    positions = jnp.arange(s)

    def mamba_fwd(hh, pp):
        a = apply_norm(pp["ln"], hh, kind=cfg.norm, eps=cfg.norm_eps)
        y, st = apply_mamba2_with_state(pp["mix"], mamba_spec(cfg), a)
        return hh + y, st

    def group(h, xs):
        lora, mamba_stack, ck, cv = xs
        h, (k, v) = _apply_shared(cfg, params["shared"], lora, h, h0, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=2)
        h, ssm_states = jax.lax.scan(mamba_fwd, h, mamba_stack)
        return h, (ck, cv, ssm_states)

    h, (ck, cv, ssm) = jax.lax.scan(
        group, h, (params["lora"], params["mamba"]) + tuple(cache["kv"]))
    cache["kv"] = (ck, cv)
    cache["ssm"] = ssm
    if "trailing" in params:
        h, trail = jax.lax.scan(mamba_fwd, h, params["trailing"])
        cache["trail_ssm"] = trail
    cache["h0"] = h0[:, -1:]
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    h = apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)
    return (h[:, -1] @ params["unembed"]["emb"].T).astype(jnp.float32), cache


def zamba_decode_step(params, cfg: ModelConfig, cache, token):
    pos = cache["pos"]
    h = params["embed"]["emb"][token[:, None]].astype(cfg.cdt)
    h0 = h  # current token's embedding feeds the shared block

    def group(h, xs):
        lora, mamba_stack, ck, cv, sst, cst = xs
        h, (ck, cv) = _apply_shared(cfg, params["shared"], lora, h, h0,
                                    None, cache=(ck, cv), pos=pos)

        def inner(hh, xs2):
            pp, s1, c1 = xs2
            a = apply_norm(pp["ln"], hh, kind=cfg.norm, eps=cfg.norm_eps)
            y, s1, c1 = decode_mamba2(pp["mix"], mamba_spec(cfg), a, s1, c1)
            return hh + y, (s1, c1)

        h, (sst, cst) = jax.lax.scan(inner, h, (mamba_stack, sst, cst))
        return h, (ck, cv, sst, cst)

    h, (ck, cv, sst, cst) = jax.lax.scan(
        group, h,
        (params["lora"], params["mamba"]) + tuple(cache["kv"])
        + tuple(cache["ssm"]))
    cache["kv"] = (ck, cv)
    cache["ssm"] = (sst, cst)
    if "trailing" in params:
        def inner2(hh, xs2):
            pp, s1, c1 = xs2
            a = apply_norm(pp["ln"], hh, kind=cfg.norm, eps=cfg.norm_eps)
            y, s1, c1 = decode_mamba2(pp["mix"], mamba_spec(cfg), a, s1, c1)
            return hh + y, (s1, c1)
        h, trail = jax.lax.scan(inner2, h,
                                (params["trailing"],) + tuple(cache["trail_ssm"]))
        cache["trail_ssm"] = trail
    cache["pos"] = pos + 1
    h = apply_norm(params["ln_f"], h, kind=cfg.norm, eps=cfg.norm_eps)
    return (h[:, 0] @ params["unembed"]["emb"].T).astype(jnp.float32), cache
