"""Block-CSR padding: the layout the Pallas edge kernels consume.

TPU kernels need static shapes. We block vertices into `block_v`-sized tiles
and store each tile's adjacency slab contiguously, padded to the maximum slab
length over all tiles (rounded up to `edge_chunk` so the kernel's inner
one-hot-matmul loop has a static trip count).

For each edge slot we precompute:
  * `edge_dst`  — global neighbor id (used to gather labels outside the kernel),
  * `edge_row`  — the *local* row (0..block_v-1) owning the edge,
  * `edge_w`    — eq. (4) weight; 0.0 marks padding (padding rows point at
                   local row 0 but carry zero weight, so they are harmless).

Power-law hubs make per-vertex padding (ELL) explode; per-*block* slabs only
pad to the worst block, which for RMAT graphs is a small constant factor.
"""
from __future__ import annotations

import dataclasses
import logging

import numpy as np

from repro.graphs.csr import Graph

_log = logging.getLogger("repro.core.blocking")


@dataclasses.dataclass(frozen=True)
class BlockedEdges:
    """Padded per-block edge slabs (host numpy; moved to device by callers)."""

    n: int                 # true vertex count
    n_pad: int             # padded vertex count (= n_blocks * block_v)
    block_v: int
    n_blocks: int
    e_max: int             # padded slab length per block
    edge_dst: np.ndarray   # [n_blocks, e_max] int32, 0 for padding
    edge_row: np.ndarray   # [n_blocks, e_max] int32 local row, 0 for padding
    edge_w: np.ndarray     # [n_blocks, e_max] float32, 0.0 for padding
    pad_frac: float        # fraction of padded slots (diagnostic)


def block_slab_sizes(adj_ptr: np.ndarray, n: int, block_v: int, n_blocks: int) -> np.ndarray:
    """Per-block symmetrized-edge counts (the slab lengths before padding)."""
    lo = np.minimum(np.arange(n_blocks, dtype=np.int64) * block_v, n)
    hi = np.minimum(lo + block_v, n)
    return (adj_ptr[hi] - adj_ptr[lo]).astype(np.int64)


def fill_block_slab(
    g: Graph,
    blk: int,
    block_v: int,
    edge_dst: np.ndarray,
    edge_row: np.ndarray,
    edge_w: np.ndarray,
    *,
    out_blk: int | None = None,
    dst_map: np.ndarray | None = None,
) -> int:
    """Rewrite one block's slab row in place from `g`'s adjacency.

    Zeroes the padded tail so stale entries from a previous layout cannot
    survive an incremental update. Returns the slab's real edge count.
    Raises ValueError if the block no longer fits `e_max` (the caller must
    re-pad, see repro.streaming.delta_graph).

    `blk` names the block in *graph* (original vertex-id) space; under a
    permuted block->shard assignment the slab is stored elsewhere and its
    neighbor ids live in the permuted space — `out_blk` selects the storage
    row (default: `blk` itself) and `dst_map` ([>= n] int) remaps each
    neighbor id before it is written.
    """
    e_max = edge_dst.shape[1]
    if out_blk is None:
        out_blk = blk
    v0 = blk * block_v
    v1 = min(v0 + block_v, g.n)
    lo, hi = int(g.adj_ptr[v0]), int(g.adj_ptr[v1])
    cnt = hi - lo
    if cnt > e_max:
        raise ValueError(f"block {blk} overflows e_max={e_max} with {cnt} edges")
    rows = np.repeat(
        np.arange(v0, v1, dtype=np.int64),
        np.diff(g.adj_ptr[v0 : v1 + 1]).astype(np.int64),
    )
    dst = g.adj_idx[lo:hi]
    if dst_map is not None:
        dst = dst_map[dst]
    edge_dst[out_blk, :cnt] = dst
    edge_row[out_blk, :cnt] = (rows - v0).astype(np.int32)
    edge_w[out_blk, :cnt] = g.adj_w[lo:hi]
    edge_dst[out_blk, cnt:] = 0
    edge_row[out_blk, cnt:] = 0
    edge_w[out_blk, cnt:] = 0.0
    return cnt


def block_edges(g: Graph, block_v: int = 256, edge_chunk: int = 256) -> BlockedEdges:
    n_blocks = -(-g.n // block_v)
    n_pad = n_blocks * block_v

    block_sizes = block_slab_sizes(g.adj_ptr, g.n, block_v, n_blocks)
    e_max = int(block_sizes.max()) if n_blocks else edge_chunk
    e_max = -(-max(e_max, 1) // edge_chunk) * edge_chunk

    edge_dst = np.zeros((n_blocks, e_max), dtype=np.int32)
    edge_row = np.zeros((n_blocks, e_max), dtype=np.int32)
    edge_w = np.zeros((n_blocks, e_max), dtype=np.float32)

    for blk in range(n_blocks):
        fill_block_slab(g, blk, block_v, edge_dst, edge_row, edge_w)

    total = n_blocks * e_max
    pad_frac = 1.0 - (g.num_sym_edges / total) if total else 0.0
    return BlockedEdges(
        n=g.n,
        n_pad=n_pad,
        block_v=block_v,
        n_blocks=n_blocks,
        e_max=e_max,
        edge_dst=edge_dst,
        edge_row=edge_row,
        edge_w=edge_w,
        pad_frac=pad_frac,
    )


# ---------------------------------------------------------------------------
# block-level structure: the inputs of locality-aware shard assignment
# ---------------------------------------------------------------------------
def block_adjacency(edge_dst: np.ndarray, edge_w: np.ndarray, block_v: int) -> np.ndarray:
    """Block-level edge-cut matrix from the padded slabs.

    Returns `W` `[n_blocks, n_blocks]` f32 with `W[a, b]` = total eq.-(4)
    weight of slab-`a` edges whose neighbor lives in block `b` (padding slots
    carry zero weight, so they contribute nothing). `W[a, b] + W[b, a]` is
    the weight crossing the (a, b) block pair — the quantity a block->shard
    assignment wants to keep intra-shard, and the denominator of the
    halo-exchange traffic model (`repro.core.halo`).
    """
    edge_dst = np.asarray(edge_dst)
    edge_w = np.asarray(edge_w, dtype=np.float64)
    nb, e_max = edge_dst.shape
    src_blk = np.repeat(np.arange(nb, dtype=np.int64), e_max)
    dst_blk = (edge_dst.reshape(-1).astype(np.int64)) // block_v
    w = np.zeros((nb, nb), dtype=np.float64)
    np.add.at(w, (src_blk, dst_blk), edge_w.reshape(-1))
    return w.astype(np.float32)


def locality_block_order(adj: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy co-location of densely connected blocks into shard groups.

    Returns a permutation `perm` `[n_blocks]` (storage slot -> original
    block id) whose consecutive `n_blocks / n_shards`-sized groups are the
    shard assignments: slicing the permuted layout contiguously — exactly
    what `shard_map` does on the block axis — hands each shard a cluster of
    mutually dense blocks, so most slab references stay intra-shard and the
    halo exchange carries only the genuinely cross-cluster slabs.

    The heuristic is greedy agglomeration seeded from the periphery: each
    group starts at the unassigned block with the *least* weight toward the
    other unassigned blocks (a cluster edge — seeding interior hubs splits
    clusters when the group fills mid-growth), then repeatedly absorbs the
    unassigned block with the strongest connection to the group. The result
    is kept only if its worst-shard boundary-block count (the `b_max` that
    prices the halo exchange, see `repro.core.halo`) beats the natural
    contiguous striping's — vertex orders that are already
    locality-friendly (road lattices, community-sorted SBMs) keep their
    identity assignment instead of being fragmented by a greedy pass. Pure
    numpy with id-ordered tie breaking, so a given (graph, n_shards) always
    yields the same assignment — partitions stay reproducible at fixed
    seed.
    """
    adj = np.asarray(adj, dtype=np.float64)
    nb = adj.shape[0]
    if adj.shape != (nb, nb):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if nb % n_shards != 0:
        raise ValueError(
            f"n_blocks={nb} not divisible by n_shards={n_shards}; "
            "align_blocks first")
    bps = nb // n_shards
    sym = adj + adj.T
    np.fill_diagonal(sym, 0.0)
    remaining = np.ones(nb, dtype=bool)
    perm = np.empty(nb, dtype=np.int64)
    slot = 0
    for _ in range(n_shards):
        frontier = sym[:, remaining].sum(axis=1)    # weight toward unassigned
        seed = int(np.argmin(np.where(remaining, frontier, np.inf)))
        remaining[seed] = False
        perm[slot] = seed
        slot += 1
        conn = sym[seed].copy()            # connection of candidates to group
        for _ in range(bps - 1):
            nxt = int(np.argmax(np.where(remaining, conn, -1.0)))
            remaining[nxt] = False
            perm[slot] = nxt
            slot += 1
            conn += sym[nxt]
    identity = np.arange(nb, dtype=np.int64)
    wb_perm = _worst_boundary(adj, perm, bps)
    wb_id = _worst_boundary(adj, identity, bps)
    if wb_perm > wb_id:
        return identity
    if wb_perm == wb_id:
        # The SBM failure mode: when every community spans the same number
        # of blocks as a contiguous stripe, greedy agglomeration ties the
        # striping on the boundary criterion and used to keep the striping
        # silently. Break the tie deterministically on the secondary
        # criterion — total cross-shard weight, the bytes the wire actually
        # carries — and say so.
        cw_perm = _cross_weight(adj, perm, bps)
        cw_id = _cross_weight(adj, identity, bps)
        keep_perm = cw_perm < cw_id
        _log.warning(
            "locality_block_order: greedy agglomeration ties contiguous "
            "striping (worst boundary %d on both at n_blocks=%d, "
            "n_shards=%d); tie broken on cross weight (%.0f agglomerated "
            "vs %.0f striped) -> %s",
            wb_id, nb, nb // bps, cw_perm, cw_id,
            "agglomerated" if keep_perm else "striping")
        return perm if keep_perm else identity
    return perm


def vcycle_block_order(adj: np.ndarray, n_shards: int, *,
                       max_passes: int = 8) -> np.ndarray:
    """Principled block->shard assignment: the locality problem solved one
    level up (``assignment="vcycle"``).

    The block edge-cut matrix *is* a contracted graph — exactly what the
    multilevel V-cycle partitions at its coarsest level
    (`repro.core.multilevel`) — and the block->shard assignment is a k-way
    partition of it with exact group sizes. This pass treats it that way:
    seed from the greedy `locality_block_order` result (which already
    guards against contiguous striping), then refine with deterministic
    pairwise slot swaps, Kernighan-Lin style, accepted only on a *strict*
    improvement of the lexicographic objective ``(worst-shard boundary
    count, total cross weight)`` — first the `b_max` the halo exchange
    pays, then the weight the wire actually carries. Because refinement
    starts from the locality answer and accepts strict improvements only,
    the result is never worse than `locality_block_order` on either
    criterion — the bit-identical-or-better contract `BENCH_scaling.json`
    gates.
    """
    adj = np.asarray(adj, dtype=np.float64)
    nb = adj.shape[0]
    if adj.shape != (nb, nb):
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if nb % n_shards != 0:
        raise ValueError(
            f"n_blocks={nb} not divisible by n_shards={n_shards}; "
            "align_blocks first")
    bps = nb // n_shards
    perm = np.array(locality_block_order(adj, n_shards), dtype=np.int64)
    key = (_worst_boundary(adj, perm, bps), _cross_weight(adj, perm, bps))
    for _ in range(max_passes):
        improved = False
        for i in range(nb):
            gi = i // bps
            for j in range(i + 1, nb):
                if j // bps == gi:
                    continue        # same group: a swap changes nothing
                perm[i], perm[j] = perm[j], perm[i]
                cand = (_worst_boundary(adj, perm, bps),
                        _cross_weight(adj, perm, bps))
                if cand < key:
                    key = cand
                    improved = True
                else:
                    perm[i], perm[j] = perm[j], perm[i]
        if not improved:
            break
    return perm


def _cross_weight(adj: np.ndarray, perm: np.ndarray, bps: int) -> float:
    """Total edge weight crossing shard groups under `perm` — the secondary
    assignment criterion (`_worst_boundary` ties break toward it)."""
    nb = adj.shape[0]
    group = np.empty(nb, dtype=np.int64)
    group[perm] = np.arange(nb) // bps
    cross = group[:, None] != group[None, :]
    return float(np.asarray(adj, dtype=np.float64)[cross].sum())


def _worst_boundary(adj: np.ndarray, perm: np.ndarray, bps: int) -> int:
    """Max over shards of the number of their blocks that some other shard's
    slabs reference — the `b_max` the halo exchange pays (before padding)."""
    nb = adj.shape[0]
    group = np.empty(nb, dtype=np.int64)
    group[perm] = np.arange(nb) // bps
    refs = adj > 0
    cross = refs & (group[:, None] != group[None, :])
    referenced = cross.any(axis=0)         # block b is someone else's halo
    counts = np.bincount(group[referenced], minlength=nb // bps)
    return int(counts.max()) if counts.size else 0
