"""Block-CSR padding: the layout the Pallas edge kernels consume.

TPU kernels need static shapes. We block vertices into `block_v`-sized tiles
and store each tile's adjacency slab contiguously, padded to the maximum slab
length over all tiles (rounded up to `edge_chunk` so the kernel's inner
one-hot-matmul loop has a static trip count).

For each edge slot we precompute:
  * `edge_dst`  — global neighbor id (used to gather labels outside the kernel),
  * `edge_row`  — the *local* row (0..block_v-1) owning the edge,
  * `edge_w`    — eq. (4) weight; 0.0 marks padding (padding rows point at
                   local row 0 but carry zero weight, so they are harmless).

Power-law hubs make per-vertex padding (ELL) explode; per-*block* slabs only
pad to the worst block, which for RMAT graphs is a small constant factor.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import Graph


@dataclasses.dataclass(frozen=True)
class BlockedEdges:
    """Padded per-block edge slabs (host numpy; moved to device by callers)."""

    n: int                 # true vertex count
    n_pad: int             # padded vertex count (= n_blocks * block_v)
    block_v: int
    n_blocks: int
    e_max: int             # padded slab length per block
    edge_dst: np.ndarray   # [n_blocks, e_max] int32, 0 for padding
    edge_row: np.ndarray   # [n_blocks, e_max] int32 local row, 0 for padding
    edge_w: np.ndarray     # [n_blocks, e_max] float32, 0.0 for padding
    pad_frac: float        # fraction of padded slots (diagnostic)


def block_slab_sizes(adj_ptr: np.ndarray, n: int, block_v: int, n_blocks: int) -> np.ndarray:
    """Per-block symmetrized-edge counts (the slab lengths before padding)."""
    lo = np.minimum(np.arange(n_blocks, dtype=np.int64) * block_v, n)
    hi = np.minimum(lo + block_v, n)
    return (adj_ptr[hi] - adj_ptr[lo]).astype(np.int64)


def fill_block_slab(
    g: Graph,
    blk: int,
    block_v: int,
    edge_dst: np.ndarray,
    edge_row: np.ndarray,
    edge_w: np.ndarray,
) -> int:
    """Rewrite one block's slab row in place from `g`'s adjacency.

    Zeroes the padded tail so stale entries from a previous layout cannot
    survive an incremental update. Returns the slab's real edge count.
    Raises ValueError if the block no longer fits `e_max` (the caller must
    re-pad, see repro.streaming.delta_graph).
    """
    e_max = edge_dst.shape[1]
    v0 = blk * block_v
    v1 = min(v0 + block_v, g.n)
    lo, hi = int(g.adj_ptr[v0]), int(g.adj_ptr[v1])
    cnt = hi - lo
    if cnt > e_max:
        raise ValueError(f"block {blk} overflows e_max={e_max} with {cnt} edges")
    rows = np.repeat(
        np.arange(v0, v1, dtype=np.int64),
        np.diff(g.adj_ptr[v0 : v1 + 1]).astype(np.int64),
    )
    edge_dst[blk, :cnt] = g.adj_idx[lo:hi]
    edge_row[blk, :cnt] = (rows - v0).astype(np.int32)
    edge_w[blk, :cnt] = g.adj_w[lo:hi]
    edge_dst[blk, cnt:] = 0
    edge_row[blk, cnt:] = 0
    edge_w[blk, cnt:] = 0.0
    return cnt


def block_edges(g: Graph, block_v: int = 256, edge_chunk: int = 256) -> BlockedEdges:
    n_blocks = -(-g.n // block_v)
    n_pad = n_blocks * block_v

    block_sizes = block_slab_sizes(g.adj_ptr, g.n, block_v, n_blocks)
    e_max = int(block_sizes.max()) if n_blocks else edge_chunk
    e_max = -(-max(e_max, 1) // edge_chunk) * edge_chunk

    edge_dst = np.zeros((n_blocks, e_max), dtype=np.int32)
    edge_row = np.zeros((n_blocks, e_max), dtype=np.int32)
    edge_w = np.zeros((n_blocks, e_max), dtype=np.float32)

    for blk in range(n_blocks):
        fill_block_slab(g, blk, block_v, edge_dst, edge_row, edge_w)

    total = n_blocks * e_max
    pad_frac = 1.0 - (g.num_sym_edges / total) if total else 0.0
    return BlockedEdges(
        n=g.n,
        n_pad=n_pad,
        block_v=block_v,
        n_blocks=n_blocks,
        e_max=e_max,
        edge_dst=edge_dst,
        edge_row=edge_row,
        edge_w=edge_w,
        pad_frac=pad_frac,
    )
