"""The paper's Table I dataset suite, reproduced synthetically at scale `s`.

Table I (paper):                        family          generator here
  WIKI  1.79M V   28.51M E  skew +0.35  right-skewed    rmat(a=.57)
  UK    1.00M V   41.24M E  skew +0.81  highly right    rmat(a=.68)
  USA  23.9M  V   58.33M E  skew -0.59  left-skewed     grid_road
  SO    2.60M V   63.49M E  skew +0.08  skew-free       erdos_renyi
  LJ    4.84M V   68.99M E  skew +0.36  right-skewed    rmat(a=.57)
  EN    4.20M V  101.3M  E  skew +0.35  right-skewed    rmat(a=.57)
  OK    3.07M V  117.1M  E  skew +0.29  right-skewed    rmat(a=.55)
  HLWD  2.18M V  228.9M  E  skew +0.32  right-skewed    rmat(a=.55)
  EU   11.2M  V  386.9M  E  skew +0.07  skew-free       erdos_renyi

`scale` multiplies |V| and |E| (default 1/100 so the full suite runs on one
CPU host in the benchmark harness; the partitioner itself is scale-free).
"""
from __future__ import annotations

import math
from typing import Callable, Dict

from repro.graphs.csr import Graph
from repro.graphs import generators as gen

def _ncomm(n: int) -> int:
    return max(16, n // 512)


# name -> (|V|, |E|, builder). Social/web families use the degree-corrected
# SBM (right skew + community structure, like the real graphs); road uses the
# lattice; skew-free families use uniform-propensity SBM (DESIGN.md §10).
_SPECS: Dict[str, tuple] = {
    "WIKI": (1_790_000, 28_510_000,
             lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.30, degree_exponent=0.6, seed=s)),
    "UK": (1_000_000, 41_240_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.15, degree_exponent=1.0, seed=s)),
    "USA": (23_900_000, 58_330_000, lambda n, m, s: gen.grid_road(n, seed=s)),
    "SO": (2_600_000, 63_490_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.30, degree_exponent=0.0, seed=s)),
    "LJ": (4_840_000, 68_990_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.30, degree_exponent=0.6, seed=s)),
    "EN": (4_200_000, 101_300_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.30, degree_exponent=0.6, seed=s)),
    "OK": (3_070_000, 117_100_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.35, degree_exponent=0.5, seed=s)),
    "HLWD": (2_180_000, 228_900_000,
             lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.25, degree_exponent=0.5, seed=s)),
    "EU": (11_200_000, 386_900_000,
           lambda n, m, s: gen.dc_sbm(n, m, n_comm=_ncomm(n), mixing=0.30, degree_exponent=0.0, seed=s)),
}

DATASETS = tuple(_SPECS.keys())


def load_dataset(name: str, *, scale: float = 0.01, seed: int = 0) -> Graph:
    """Build the named Table-I-family graph at the given scale. `scale`
    must be a finite positive number (a NaN or zero scale would silently
    build a degenerate graph and fail far from here)."""
    if name not in _SPECS:
        raise KeyError(f"unknown dataset {name!r}; available: {DATASETS}")
    if not isinstance(scale, (int, float)) or isinstance(scale, bool) \
            or not math.isfinite(scale) or scale <= 0:
        raise ValueError(
            f"scale must be a finite positive number, got {scale!r}")
    n_full, m_full, builder = _SPECS[name]
    n = max(int(n_full * scale), 64)
    m = max(int(m_full * scale), 256)
    return builder(n, m, seed)
