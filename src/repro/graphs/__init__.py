"""Graph substrate: CSR storage, synthetic dataset suite, TPU block padding."""
from repro.graphs.csr import Graph, build_graph, graph_stats
from repro.graphs.generators import erdos_renyi, grid_road, rmat
from repro.graphs.datasets import DATASETS, load_dataset
from repro.graphs.blocking import BlockedEdges, block_edges

__all__ = [
    "Graph",
    "build_graph",
    "graph_stats",
    "erdos_renyi",
    "grid_road",
    "rmat",
    "DATASETS",
    "load_dataset",
    "BlockedEdges",
    "block_edges",
]
