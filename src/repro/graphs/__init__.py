"""Graph substrate: CSR storage, synthetic dataset suite, TPU block padding,
and the sorted-key incremental-merge primitives used by repro.streaming."""
from repro.graphs.csr import (
    Graph,
    build_graph,
    canonicalize_edges,
    decode_edge_keys,
    encode_edge_keys,
    graph_from_sorted_state,
    graph_stats,
    merge_sorted_keys,
    remove_sorted_keys,
    sorted_isin,
)
from repro.graphs.generators import erdos_renyi, grid_road, rmat
from repro.graphs.datasets import DATASETS, load_dataset
from repro.graphs.blocking import BlockedEdges, block_edges, block_slab_sizes, fill_block_slab

__all__ = [
    "Graph",
    "build_graph",
    "canonicalize_edges",
    "decode_edge_keys",
    "encode_edge_keys",
    "graph_from_sorted_state",
    "graph_stats",
    "merge_sorted_keys",
    "remove_sorted_keys",
    "sorted_isin",
    "block_slab_sizes",
    "fill_block_slab",
    "erdos_renyi",
    "grid_road",
    "rmat",
    "DATASETS",
    "load_dataset",
    "BlockedEdges",
    "block_edges",
]
