"""CSR graph container with the symmetrized weighted adjacency of eq. (4).

The paper partitions a *directed* graph G=(V,E) into k edge-balanced parts.
Two views of the graph are needed:

  * the directed out-edge CSR  — defines each vertex's load contribution
    deg(v) (outdegree) and the local-edges metric;
  * the symmetrized neighborhood N(v) = {u : (u,v) in E or (v,u) in E} with
    the weighing function of eq. (4):

        w_hat(u,v) = 1 if exactly one of (u,v),(v,u) is in E
                     2 if both are in E

    used by the LP scoring term tau (eq. 11) and the weight accumulation
    (eq. 13).

Everything is built once on the host in numpy and then moved to device
arrays; the partitioning loop itself only consumes flat arrays so it can be
jitted / shard_mapped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable host-side graph.

    Attributes:
      n: number of vertices |V|.
      m: number of directed edges |E| (after dedup / self-loop removal).
      row_ptr, col_idx: out-edge CSR of the directed graph.
      adj_ptr, adj_idx, adj_w: CSR of the symmetrized neighborhood with
        eq. (4) weights (adj_w in {1.0, 2.0}).
      deg_out: outdegree per vertex (int32); sum(deg_out) == m.
    """

    n: int
    m: int
    row_ptr: np.ndarray
    col_idx: np.ndarray
    adj_ptr: np.ndarray
    adj_idx: np.ndarray
    adj_w: np.ndarray
    deg_out: np.ndarray

    @property
    def num_sym_edges(self) -> int:
        return int(self.adj_idx.shape[0])

    def neighbors(self, v: int) -> np.ndarray:
        return self.adj_idx[self.adj_ptr[v] : self.adj_ptr[v + 1]]


def _dedup_edges(src: np.ndarray, dst: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Remove self loops and duplicate directed edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    key = np.unique(key)
    return (key // n).astype(np.int32), (key % n).astype(np.int32)


def build_graph(src: np.ndarray, dst: np.ndarray, n: int) -> Graph:
    """Build the dual CSR representation from a directed edge list."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    src, dst = _dedup_edges(src, dst, n)
    m = src.shape[0]

    # --- directed out-edge CSR ---------------------------------------------
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    deg_out = np.bincount(s_sorted, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_out, out=row_ptr[1:])

    # --- symmetrized adjacency with eq. (4) weights -------------------------
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    key_sorted = np.sort(key)

    # Union of both directions: every (u,v) with (u,v) in E or (v,u) in E.
    union = np.unique(np.concatenate([key, rkey]))
    u_src = (union // n).astype(np.int32)
    u_dst = (union % n).astype(np.int32)
    # weight 2 iff both directions present in the original E.
    fwd_in_e = np.searchsorted(key_sorted, union)
    fwd_hit = (fwd_in_e < m) & (key_sorted[np.minimum(fwd_in_e, m - 1)] == union)
    rev = u_dst.astype(np.int64) * n + u_src.astype(np.int64)
    rev_in_e = np.searchsorted(key_sorted, rev)
    rev_hit = (rev_in_e < m) & (key_sorted[np.minimum(rev_in_e, m - 1)] == rev)
    w = np.where(fwd_hit & rev_hit, 2.0, 1.0).astype(np.float32)

    adj_deg = np.bincount(u_src, minlength=n).astype(np.int64)
    adj_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(adj_deg, out=adj_ptr[1:])

    return Graph(
        n=n,
        m=int(m),
        row_ptr=row_ptr.astype(np.int64),
        col_idx=d_sorted.astype(np.int32),
        adj_ptr=adj_ptr,
        adj_idx=u_dst.astype(np.int32),
        adj_w=w,
        deg_out=deg_out,
    )


# ---------------------------------------------------------------------------
# Incremental-merge primitives (streaming ingestion; see repro.streaming).
#
# The streaming subsystem never re-runs the O(m log m) `build_graph` sort on
# the full edge list. Instead it maintains *sorted int64 key arrays*
# (key = src * n + dst) for the directed edge set and the symmetrized
# adjacency, and merges each delta in O(m + d log m) with the helpers below.
# ---------------------------------------------------------------------------


def encode_edge_keys(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Pack (src, dst) pairs into sortable int64 keys: key = src * n + dst."""
    return np.asarray(src, dtype=np.int64) * n + np.asarray(dst, dtype=np.int64)


def decode_edge_keys(keys: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of `encode_edge_keys`; returns int32 (src, dst)."""
    return (keys // n).astype(np.int32), (keys % n).astype(np.int32)


def canonicalize_edges(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Sorted unique directed-edge keys with self loops removed.

    The normal form every delta is brought into before merging: duplicates
    within the batch collapse, (v, v) edges vanish, and the result is sorted
    so it can be merged against the maintained key arrays without a re-sort.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size == 0:
        return np.empty(0, dtype=np.int64)
    keep = src != dst
    return np.unique(src[keep] * n + dst[keep])


def sorted_isin(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Membership mask of `queries` in the *sorted* array `keys`."""
    if keys.size == 0:
        return np.zeros(queries.shape, dtype=bool)
    pos = np.searchsorted(keys, queries)
    pos_c = np.minimum(pos, keys.size - 1)
    return (pos < keys.size) & (keys[pos_c] == queries)


def merge_sorted_keys(keys: np.ndarray, add: np.ndarray) -> np.ndarray:
    """Insert sorted unique `add` (disjoint from `keys`) keeping sort order.

    O(m + d): one searchsorted over the existing array plus a single copy —
    no re-sort of the maintained edge set.
    """
    if add.size == 0:
        return keys
    return np.insert(keys, np.searchsorted(keys, add), add)


def remove_sorted_keys(keys: np.ndarray, drop: np.ndarray) -> np.ndarray:
    """Remove every key in sorted `drop` (all present) keeping sort order."""
    if drop.size == 0:
        return keys
    return np.delete(keys, np.searchsorted(keys, drop))


def graph_from_sorted_state(
    n: int,
    dir_keys: np.ndarray,
    sym_keys: np.ndarray,
    sym_w: np.ndarray,
) -> Graph:
    """Materialize a `Graph` container from maintained sorted key arrays.

    O(m) vectorized — the keys are already sorted, so both CSRs fall out of
    a bincount + cumsum with no sorting. This is the bridge between the
    incremental streaming state and every batch consumer (metrics, runner,
    DeviceGraph preparation).
    """
    d_src, d_dst = decode_edge_keys(dir_keys, n)
    deg_out = np.bincount(d_src, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_out, out=row_ptr[1:])

    a_src, a_dst = decode_edge_keys(sym_keys, n)
    adj_deg = np.bincount(a_src, minlength=n).astype(np.int64)
    adj_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(adj_deg, out=adj_ptr[1:])

    return Graph(
        n=n,
        m=int(dir_keys.size),
        row_ptr=row_ptr,
        col_idx=d_dst,
        adj_ptr=adj_ptr,
        adj_idx=a_dst,
        adj_w=np.asarray(sym_w, dtype=np.float32),
        deg_out=deg_out,
    )


def graph_stats(g: Graph) -> Dict[str, float]:
    """Table I statistics: density and Pearson's 1st skewness coefficient.

    density  D = |E| / (|V| * (|V|-1))
    skewness = (mean - mode) / std     over the outdegree distribution
    """
    deg = g.deg_out.astype(np.float64)
    mean = float(deg.mean())
    std = float(deg.std())
    # mode of the outdegree distribution
    counts = np.bincount(g.deg_out)
    mode = float(np.argmax(counts))
    skew = 0.0 if std == 0 else (mean - mode) / std
    density = g.m / (g.n * max(g.n - 1, 1))
    return {
        "n": float(g.n),
        "m": float(g.m),
        "density": density,
        "skewness": skew,
        "mean_deg": mean,
        "max_deg": float(deg.max()) if g.n else 0.0,
    }
