"""CSR graph container with the symmetrized weighted adjacency of eq. (4).

The paper partitions a *directed* graph G=(V,E) into k edge-balanced parts.
Two views of the graph are needed:

  * the directed out-edge CSR  — defines each vertex's load contribution
    deg(v) (outdegree) and the local-edges metric;
  * the symmetrized neighborhood N(v) = {u : (u,v) in E or (v,u) in E} with
    the weighing function of eq. (4):

        w_hat(u,v) = 1 if exactly one of (u,v),(v,u) is in E
                     2 if both are in E

    used by the LP scoring term tau (eq. 11) and the weight accumulation
    (eq. 13).

Everything is built once on the host in numpy and then moved to device
arrays; the partitioning loop itself only consumes flat arrays so it can be
jitted / shard_mapped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable host-side graph.

    Attributes:
      n: number of vertices |V|.
      m: number of directed edges |E| (after dedup / self-loop removal).
      row_ptr, col_idx: out-edge CSR of the directed graph.
      adj_ptr, adj_idx, adj_w: CSR of the symmetrized neighborhood with
        eq. (4) weights (adj_w in {1.0, 2.0}).
      deg_out: outdegree per vertex (int32); sum(deg_out) == m.
    """

    n: int
    m: int
    row_ptr: np.ndarray
    col_idx: np.ndarray
    adj_ptr: np.ndarray
    adj_idx: np.ndarray
    adj_w: np.ndarray
    deg_out: np.ndarray

    @property
    def num_sym_edges(self) -> int:
        return int(self.adj_idx.shape[0])

    def neighbors(self, v: int) -> np.ndarray:
        return self.adj_idx[self.adj_ptr[v] : self.adj_ptr[v + 1]]


def _dedup_edges(src: np.ndarray, dst: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Remove self loops and duplicate directed edges."""
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    key = np.unique(key)
    return (key // n).astype(np.int32), (key % n).astype(np.int32)


def build_graph(src: np.ndarray, dst: np.ndarray, n: int) -> Graph:
    """Build the dual CSR representation from a directed edge list."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    src, dst = _dedup_edges(src, dst, n)
    m = src.shape[0]

    # --- directed out-edge CSR ---------------------------------------------
    order = np.argsort(src, kind="stable")
    s_sorted, d_sorted = src[order], dst[order]
    deg_out = np.bincount(s_sorted, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_out, out=row_ptr[1:])

    # --- symmetrized adjacency with eq. (4) weights -------------------------
    key = src.astype(np.int64) * n + dst.astype(np.int64)
    rkey = dst.astype(np.int64) * n + src.astype(np.int64)
    key_sorted = np.sort(key)

    # Union of both directions: every (u,v) with (u,v) in E or (v,u) in E.
    union = np.unique(np.concatenate([key, rkey]))
    u_src = (union // n).astype(np.int32)
    u_dst = (union % n).astype(np.int32)
    # weight 2 iff both directions present in the original E.
    fwd_in_e = np.searchsorted(key_sorted, union)
    fwd_hit = (fwd_in_e < m) & (key_sorted[np.minimum(fwd_in_e, m - 1)] == union)
    rev = u_dst.astype(np.int64) * n + u_src.astype(np.int64)
    rev_in_e = np.searchsorted(key_sorted, rev)
    rev_hit = (rev_in_e < m) & (key_sorted[np.minimum(rev_in_e, m - 1)] == rev)
    w = np.where(fwd_hit & rev_hit, 2.0, 1.0).astype(np.float32)

    adj_deg = np.bincount(u_src, minlength=n).astype(np.int64)
    adj_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(adj_deg, out=adj_ptr[1:])

    return Graph(
        n=n,
        m=int(m),
        row_ptr=row_ptr.astype(np.int64),
        col_idx=d_sorted.astype(np.int32),
        adj_ptr=adj_ptr,
        adj_idx=u_dst.astype(np.int32),
        adj_w=w,
        deg_out=deg_out,
    )


# ---------------------------------------------------------------------------
# Incremental-merge primitives (streaming ingestion; see repro.streaming).
#
# The streaming subsystem never re-runs the O(m log m) `build_graph` sort on
# the full edge list. Instead it maintains *sorted int64 key arrays*
# (key = src * n + dst) for the directed edge set and the symmetrized
# adjacency, and merges each delta in O(m + d log m) with the helpers below.
# ---------------------------------------------------------------------------


def encode_edge_keys(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Pack (src, dst) pairs into sortable int64 keys: key = src * n + dst."""
    return np.asarray(src, dtype=np.int64) * n + np.asarray(dst, dtype=np.int64)


def decode_edge_keys(keys: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of `encode_edge_keys`; returns int32 (src, dst)."""
    return (keys // n).astype(np.int32), (keys % n).astype(np.int32)


def canonicalize_edges(src: np.ndarray, dst: np.ndarray, n: int) -> np.ndarray:
    """Sorted unique directed-edge keys with self loops removed.

    The normal form every delta is brought into before merging: duplicates
    within the batch collapse, (v, v) edges vanish, and the result is sorted
    so it can be merged against the maintained key arrays without a re-sort.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size == 0:
        return np.empty(0, dtype=np.int64)
    keep = src != dst
    return np.unique(src[keep] * n + dst[keep])


def sorted_isin(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Membership mask of `queries` in the *sorted* array `keys`."""
    if keys.size == 0:
        return np.zeros(queries.shape, dtype=bool)
    pos = np.searchsorted(keys, queries)
    pos_c = np.minimum(pos, keys.size - 1)
    return (pos < keys.size) & (keys[pos_c] == queries)


def merge_sorted_keys(keys: np.ndarray, add: np.ndarray) -> np.ndarray:
    """Insert sorted unique `add` (disjoint from `keys`) keeping sort order.

    O(m + d): one searchsorted over the existing array plus a single copy —
    no re-sort of the maintained edge set.
    """
    if add.size == 0:
        return keys
    return np.insert(keys, np.searchsorted(keys, add), add)


def remove_sorted_keys(keys: np.ndarray, drop: np.ndarray) -> np.ndarray:
    """Remove every key in sorted `drop` (all present) keeping sort order."""
    if drop.size == 0:
        return keys
    return np.delete(keys, np.searchsorted(keys, drop))


def graph_from_sorted_state(
    n: int,
    dir_keys: np.ndarray,
    sym_keys: np.ndarray,
    sym_w: np.ndarray,
) -> Graph:
    """Materialize a `Graph` container from maintained sorted key arrays.

    O(m) vectorized — the keys are already sorted, so both CSRs fall out of
    a bincount + cumsum with no sorting. This is the bridge between the
    incremental streaming state and every batch consumer (metrics, runner,
    DeviceGraph preparation).
    """
    d_src, d_dst = decode_edge_keys(dir_keys, n)
    deg_out = np.bincount(d_src, minlength=n).astype(np.int32)
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_out, out=row_ptr[1:])

    a_src, a_dst = decode_edge_keys(sym_keys, n)
    adj_deg = np.bincount(a_src, minlength=n).astype(np.int64)
    adj_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(adj_deg, out=adj_ptr[1:])

    return Graph(
        n=n,
        m=int(dir_keys.size),
        row_ptr=row_ptr,
        col_idx=d_dst,
        adj_ptr=adj_ptr,
        adj_idx=a_dst,
        adj_w=np.asarray(sym_w, dtype=np.float32),
        deg_out=deg_out,
    )


# ---------------------------------------------------------------------------
# Contraction primitives (multilevel V-cycle; see repro.core.multilevel).
#
# A coarse level must keep the *fine* graph's balance and quality semantics
# exactly, or refinement at that level optimizes the wrong objective. The two
# functions below guarantee that by construction:
#
#   * `deg_out[c]` on the coarse graph is the aggregated vertex weight (sum
#     of the constituents' deg_out) — internal directed edges stay counted,
#     so sum(deg_out) == fine |E| at every level and, with `m` kept at the
#     fine edge count, the engine's capacity C = (1+eps)|E|/k prices coarse
#     loads in fine-edge units: a balanced coarse partition projects to a
#     balanced fine partition with *identical* per-part loads.
#   * the coarse directed edge list keeps every fine cross edge with its
#     multiplicity (internal edges drop out), so `local_edges` measured on a
#     coarse level equals the fine-graph locality of the projected labels on
#     exactly the edges still in play.
# ---------------------------------------------------------------------------


def heavy_edge_matching(g: Graph) -> Tuple[np.ndarray, int]:
    """Greedy heavy-edge matching over the symmetrized adjacency.

    Returns ``(cmap, n_coarse)`` where ``cmap[v]`` is the coarse vertex id
    of fine vertex ``v`` and coarse ids are dense in ``[0, n_coarse)``,
    numbered by each pair's smallest fine member so the map is stable under
    re-runs. Deterministic with no RNG: vertices are visited in ascending
    symmetrized-degree order (id tie-break — low-degree periphery first, so
    hubs don't exhaust each other's neighborhoods early), each unmatched
    vertex pairs with its heaviest unmatched neighbor (smallest id on weight
    ties), and vertices with no unmatched neighbor — isolated vertices
    included — become singletons.
    """
    n = g.n
    adj_ptr, adj_idx, adj_w = g.adj_ptr, g.adj_idx, g.adj_w
    order = np.argsort(np.diff(adj_ptr), kind="stable")
    match = np.full(n, -1, dtype=np.int64)
    for v in order:
        v = int(v)
        if match[v] >= 0:
            continue
        lo, hi = int(adj_ptr[v]), int(adj_ptr[v + 1])
        nbrs = adj_idx[lo:hi]
        free = (match[nbrs] < 0) & (nbrs != v)
        if not free.any():
            match[v] = v
            continue
        cand = np.where(free, adj_w[lo:hi], -1.0)
        # adj_idx rows are id-sorted, so argmax lands on the smallest id
        # among maximum-weight candidates — the deterministic tie-break
        u = int(nbrs[int(np.argmax(cand))])
        match[v] = u
        match[u] = v
    rep = np.minimum(np.arange(n, dtype=np.int64), match)
    reps = np.unique(rep)
    cmap = np.searchsorted(reps, rep).astype(np.int32)
    return cmap, int(reps.size)


def contract_graph(g: Graph, cmap: np.ndarray, n_coarse: int) -> Tuple[Graph, np.ndarray]:
    """Contract ``g`` along a fine->coarse vertex map.

    Returns ``(coarse, self_w)``. The coarse `Graph` has:

      * ``deg_out`` — aggregated vertex weights (see module section note);
        ``m`` stays the *fine* edge count, so ``sum(deg_out) == m`` holds at
        every level and capacity/balance semantics are unchanged;
      * ``row_ptr``/``col_idx`` — the fine cross edges mapped through
        ``cmap`` with multiplicity (internal edges removed);
      * ``adj_ptr``/``adj_idx``/``adj_w`` — eq.-(4) weights aggregated over
        coarse vertex pairs (weights grow past {1, 2}; every consumer treats
        them as generic positive weights).

    ``self_w[c]`` is the symmetrized weight folded *into* coarse vertex
    ``c`` (both CSR directions of each internal pair), so
    ``sum(adj_w) + sum(self_w) == sum(fine adj_w)`` exactly — the
    conservation invariant `tests/test_multilevel.py` pins.
    """
    cmap = np.asarray(cmap, dtype=np.int64)
    if cmap.shape != (g.n,):
        raise ValueError(f"cmap must be [{g.n}], got {cmap.shape}")
    if cmap.size and (cmap.min() < 0 or cmap.max() >= n_coarse):
        raise ValueError(
            f"cmap values must be in [0, {n_coarse}), got "
            f"[{cmap.min()}, {cmap.max()}]")

    # directed cross edges, multiplicity retained
    d_src = cmap[np.repeat(np.arange(g.n, dtype=np.int64),
                           np.diff(g.row_ptr).astype(np.int64))]
    d_dst = cmap[g.col_idx]
    cross = d_src != d_dst
    d_src, d_dst = d_src[cross], d_dst[cross]
    order = np.argsort(d_src, kind="stable")
    d_src, d_dst = d_src[order], d_dst[order]
    row_ptr = np.zeros(n_coarse + 1, dtype=np.int64)
    np.cumsum(np.bincount(d_src, minlength=n_coarse), out=row_ptr[1:])

    # aggregated vertex weights (exact: integer-valued sums)
    deg_out = np.bincount(cmap, weights=g.deg_out.astype(np.float64),
                          minlength=n_coarse).astype(np.int32)

    # symmetrized adjacency aggregated over coarse pairs; internal weight
    # folds into self_w
    a_src = cmap[np.repeat(np.arange(g.n, dtype=np.int64),
                           np.diff(g.adj_ptr).astype(np.int64))]
    a_dst = cmap[g.adj_idx]
    internal = a_src == a_dst
    self_w = np.zeros(n_coarse, dtype=np.float64)
    np.add.at(self_w, a_src[internal], g.adj_w[internal].astype(np.float64))
    key = a_src[~internal] * n_coarse + a_dst[~internal]
    uniq, inv = np.unique(key, return_inverse=True)
    w = np.bincount(inv, weights=g.adj_w[~internal].astype(np.float64),
                    minlength=uniq.size)
    u_src = (uniq // n_coarse).astype(np.int64)
    u_dst = (uniq % n_coarse).astype(np.int32)
    adj_ptr = np.zeros(n_coarse + 1, dtype=np.int64)
    np.cumsum(np.bincount(u_src, minlength=n_coarse), out=adj_ptr[1:])

    coarse = Graph(
        n=n_coarse,
        m=g.m,
        row_ptr=row_ptr,
        col_idx=d_dst.astype(np.int32),
        adj_ptr=adj_ptr,
        adj_idx=u_dst,
        adj_w=w.astype(np.float32),
        deg_out=deg_out,
    )
    return coarse, self_w.astype(np.float32)


def graph_stats(g: Graph) -> Dict[str, float]:
    """Table I statistics: density and Pearson's 1st skewness coefficient.

    density  D = |E| / (|V| * (|V|-1))
    skewness = (mean - mode) / std     over the outdegree distribution
    """
    deg = g.deg_out.astype(np.float64)
    mean = float(deg.mean())
    std = float(deg.std())
    # mode of the outdegree distribution
    counts = np.bincount(g.deg_out)
    mode = float(np.argmax(counts))
    skew = 0.0 if std == 0 else (mean - mode) / std
    density = g.m / (g.n * max(g.n - 1, 1))
    return {
        "n": float(g.n),
        "m": float(g.m),
        "density": density,
        "skewness": skew,
        "mean_deg": mean,
        "max_deg": float(deg.max()) if g.n else 0.0,
    }
