"""Synthetic graph generators calibrated to the paper's Table I families.

The SNAP / WebGraph datasets used by the paper are not available offline, so
we reproduce the three structural families that drive the paper's analysis
(Section V-G):

  * right-skewed power-law graphs (WIKI, LJ, EN, OK, HLWD, UK)  -> RMAT
  * left-skewed near-uniform sparse graphs (USA road)           -> grid/road
  * skew-free graphs (SO, EU)                                   -> Erdos-Renyi

Each generator returns a directed edge list; `build_graph` handles dedup and
the symmetrized weighted adjacency. Generator statistics (density, Pearson
skewness sign) are validated against Table I in benchmarks/table1_datasets.py.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graphs.csr import Graph, build_graph


def rmat(
    n: int,
    m: int,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> Graph:
    """R-MAT generator (Chakrabarti et al.) — right-skewed power-law graphs.

    Vertices are implicitly a 2^levels space; we draw quadrant bits per level
    fully vectorized, then fold into [0, n). Higher `a` => heavier skew.
    """
    rng = np.random.default_rng(seed)
    d = 1.0 - a - b - c
    if d < 0:
        raise ValueError("rmat probabilities must sum to <= 1")
    levels = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    # oversample to survive dedup / self-loop removal
    m_draw = int(m * 1.15) + 16
    src = np.zeros(m_draw, dtype=np.int64)
    dst = np.zeros(m_draw, dtype=np.int64)
    p_quad = np.array([a, b, c, d])
    for _ in range(levels):
        q = rng.choice(4, size=m_draw, p=p_quad)
        src = (src << 1) | (q >> 1)
        dst = (dst << 1) | (q & 1)
    src %= n
    dst %= n
    return build_graph(src[:], dst[:], n)


def grid_road(n: int, *, seed: int = 0, drop_frac: float = 0.12) -> Graph:
    """Road-network-like graph: 2D lattice, bidirected, with random road
    removals creating dead-ends and 3-way intersections.

    Produces a sparse *left-skewed* outdegree distribution (mode=4 > mean,
    like USA-road's Pearson coefficient of -0.59 in Table I): most vertices
    keep degree 4 while the removals pull the mean below the mode.
    """
    side = int(np.floor(np.sqrt(n)))
    n_eff = side * side
    idx = np.arange(n_eff, dtype=np.int64)
    x, y = idx % side, idx // side
    edges = []
    right = idx[x < side - 1]
    edges.append((right, right + 1))
    down = idx[y < side - 1]
    edges.append((down, down + side))
    src = np.concatenate([e[0] for e in edges])
    dst = np.concatenate([e[1] for e in edges])
    # random road removals (both directions of a segment vanish together)
    rng = np.random.default_rng(seed)
    keep = rng.uniform(size=src.shape[0]) >= drop_frac
    src, dst = src[keep], dst[keep]
    # bidirect the lattice (roads are two-way)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    return build_graph(src, dst, n_eff)


def erdos_renyi(n: int, m: int, *, seed: int = 0) -> Graph:
    """Uniform random directed graph — the skew-free family (SO, EU)."""
    rng = np.random.default_rng(seed)
    m_draw = int(m * 1.05) + 16
    src = rng.integers(0, n, size=m_draw)
    dst = rng.integers(0, n, size=m_draw)
    return build_graph(src, dst, n)


def dc_sbm(
    n: int,
    m: int,
    *,
    n_comm: int = 32,
    mixing: float = 0.3,
    degree_exponent: float = 0.0,
    seed: int = 0,
) -> Graph:
    """Degree-corrected stochastic block model.

    The paper's social/web graphs (WIKI, LJ, OK, ...) are right-skewed *and*
    strongly clustered; pure R-MAT reproduces the skew but not the community
    structure that LP-based partitioners exploit, so we use a DC-SBM for
    those families (DESIGN.md §10).

      * vertices are split into `n_comm` equal communities;
      * per-vertex propensities theta ~ (uniform(0,1))^(-degree_exponent)
        (degree_exponent=0 -> uniform degrees / skew-free; larger values ->
        heavier right skew);
      * each edge picks its source ~ theta; the destination is sampled from
        the source's community with prob (1-mixing), globally otherwise.
    """
    rng = np.random.default_rng(seed)
    comm_size = -(-n // n_comm)
    n_eff = comm_size * n_comm
    comm = np.arange(n_eff) // comm_size          # vertices sorted by community

    if degree_exponent > 0:
        theta = rng.uniform(0.02, 1.0, size=n_eff) ** (-degree_exponent)
    else:
        theta = np.ones(n_eff)
    # global inverse-cdf sampling structures (vertices already community-sorted)
    cum = np.cumsum(theta)
    total = cum[-1]
    # per-community cumulative boundaries for intra-community sampling
    comm_lo = np.concatenate([[0.0], cum[comm_size - 1 :: comm_size]])[:-1]
    comm_hi = cum[comm_size - 1 :: comm_size]

    m_draw = int(m * 1.12) + 16
    src = np.searchsorted(cum, rng.uniform(0, total, size=m_draw))
    src = np.minimum(src, n_eff - 1)
    intra = rng.uniform(size=m_draw) >= mixing
    c_src = comm[src]
    lo, hi = comm_lo[c_src], comm_hi[c_src]
    u = rng.uniform(size=m_draw)
    dst_intra = np.searchsorted(cum, lo + u * (hi - lo))
    dst_global = np.searchsorted(cum, rng.uniform(0, total, size=m_draw))
    dst = np.where(intra, dst_intra, dst_global)
    dst = np.minimum(dst, n_eff - 1)
    return build_graph(src, dst, n_eff)


def ring_of_cliques(n_cliques: int, clique_size: int, *, seed: int = 0) -> Graph:
    """Planted-partition test graph: k dense cliques + a sparse ring.

    Ground truth: the optimal k-way partition assigns one clique per part;
    used by unit tests to check that Revolver recovers high local-edges.
    """
    n = n_cliques * clique_size
    src, dst = [], []
    for c in range(n_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(clique_size):
                if i != j:
                    src.append(base + i)
                    dst.append(base + j)
        # one ring edge to the next clique
        nxt = ((c + 1) % n_cliques) * clique_size
        src.append(base)
        dst.append(nxt)
    return build_graph(np.array(src), np.array(dst), n)


def edge_split(g: Graph, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Return (src, dst) arrays of the directed edge list (for re-generation)."""
    src = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.row_ptr).astype(np.int64))
    return src, g.col_idx.copy()
