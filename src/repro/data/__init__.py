"""Deterministic synthetic data pipeline."""
from repro.data.pipeline import DataConfig, PrefetchLoader, make_batch
__all__ = ["DataConfig", "PrefetchLoader", "make_batch"]
