"""Deterministic synthetic data pipeline (per-host sharded, prefetched).

Tokens follow a noisy affine bigram process: next = (a*prev + b + U[0,K))
mod V_eff. A model that learns the bigram structure reaches ~log(K) CE,
far below the log(V_eff) unigram floor — so example training runs show
real learning without any external corpus.

Determinism & fault tolerance: a batch is a pure function of
(seed, host_id, step); recovery after preemption needs no pipeline
state — the trainer just re-asks for step s (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_host: int
    seed: int = 0
    v_eff: int = 4096            # active vocabulary subset
    noise_k: int = 8             # bigram fan-out (CE floor = log(noise_k))
    frontend: tuple | None = None  # (n, d) stub patch/frame embeddings


def _rng(cfg: DataConfig, host_id: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, host_id, step]))


def make_batch(cfg: DataConfig, step: int, host_id: int = 0) -> dict:
    """{"tokens": [B,S] i32, "labels": [B,S] i32, ("frontend": [B,n,d])}."""
    rng = _rng(cfg, host_id, step)
    v = min(cfg.v_eff, cfg.vocab)
    b, s = cfg.batch_per_host, cfg.seq_len
    a_mul = 31
    toks = np.empty((b, s + 1), np.int64)
    toks[:, 0] = rng.integers(0, v, size=b)
    noise = rng.integers(0, cfg.noise_k, size=(b, s))
    for t in range(s):
        toks[:, t + 1] = (a_mul * toks[:, t] + 7 + noise[:, t]) % v
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if cfg.frontend is not None:
        n, d = cfg.frontend
        out["frontend"] = rng.standard_normal((b, n, d), dtype=np.float32)
    return out


class PrefetchLoader:
    """Iterator yielding (step, batch) with a background prefetch thread."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.host_id = host_id
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step, self.host_id)
            self._q.put((step, batch))
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
