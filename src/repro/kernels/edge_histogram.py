"""Pallas TPU kernel: edge label histogram (the LP-score / eq.-13 hot loop).

The partitioner's per-superstep O(E) work is, for every vertex v and
partition l, the accumulation  hist[v, l] += val(e)  over v's edges. A
GPU implementation would scatter-add through shared memory. On TPU we
reformulate the scatter as **one-hot matmuls on the MXU** (DESIGN.md §3):

for each chunk of Ec edges owned by a vertex block of Bv rows:

    R[e, r] = 1 if edge e belongs to local row r          [Ec, Bv]
    L[e, l] = val(e) if edge e's slot is l                [Ec, k]
    hist   += R^T @ L                                     [Bv, k]

Both indicator matrices are built in-register from int vectors; the MXU
does the histogram reduction. With Ec=Bv=k=(128..256) these are perfectly
shaped MXU ops, and the [Bv, k] accumulator stays resident in VMEM across
all edge chunks of the block (grid minor dimension = edge chunks).

Layout comes from repro.graphs.blocking.block_edges: per-block padded edge
slabs; padding slots carry val=0 so they contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lbl_ref, row_ref, val_ref, out_ref, *, block_v: int, k: int):
    """One (vertex-block, edge-chunk) grid cell; accumulates into out_ref."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lbl = lbl_ref[0]          # [Ec] int32 slot per edge
    row = row_ref[0]          # [Ec] int32 local row per edge
    val = val_ref[0]          # [Ec] f32   contribution (0 for padding)
    ec = lbl.shape[0]

    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (ec, block_v), 1)
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (ec, k), 1)
    r_mat = (row[:, None] == rows_iota).astype(jnp.float32)            # [Ec, Bv]
    l_mat = (lbl[:, None] == slot_iota).astype(jnp.float32) * val[:, None]  # [Ec, k]
    out_ref[0] += jax.lax.dot_general(
        r_mat, l_mat,
        dimension_numbers=(((0,), (0,)), ((), ())),   # R^T @ L
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_v", "k", "edge_chunk", "interpret"))
def edge_histogram_pallas(
    edge_slots: jax.Array,   # [nb, e_max] int32
    edge_rows: jax.Array,    # [nb, e_max] int32
    edge_vals: jax.Array,    # [nb, e_max] f32
    *,
    block_v: int,
    k: int,
    edge_chunk: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns hist [nb, block_v, k] f32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nb, e_max = edge_slots.shape
    assert e_max % edge_chunk == 0, (e_max, edge_chunk)
    n_chunks = e_max // edge_chunk

    grid = (nb, n_chunks)
    return pl.pallas_call(
        functools.partial(_kernel, block_v=block_v, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_v, k), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_v, k), jnp.float32),
        interpret=interpret,
    )(edge_slots, edge_rows, edge_vals)
