"""Pallas TPU kernel: fused dual-histogram edge phase for the Revolver superstep.

The superstep's O(E) work per chunk is *two* edge-label histograms over the
same blocked edge slab (DESIGN.md §3, Section IV-D steps 3 and 5):

  * the LP-score histogram (eqs. 10-12): hist[v, l] += w(e) over v's edges
    whose neighbor currently carries label l;
  * the eq.-13 weight accumulation: w_raw[v, slot(e)] += val(e), where val
    depends on whether the neighbor's latest lambda agrees with v's selected
    action and on slot feasibility (p_mig > 0).

Run separately (`edge_histogram` twice) each histogram re-reads the slab from
HBM, re-builds the [Ec, Bv] row-indicator matrix R, and re-launches the grid.
This kernel computes **both in a single pass**: one R shared across two MXU
matmuls (R^T @ L_score and R^T @ L_w), with the neighbor-label gathers, the
agreement/feasibility masking, and the padding kill done in-kernel, so the
two [Bv, k] accumulators stay VMEM-resident across all edge chunks of a
block (grid minor dimension = edge chunks). Versus two independent kernel
launches this halves slab HBM traffic and indicator construction; versus the
XLA path it eliminates the double scatter-add.

Slot-selection for the two `weight_mode`s (the eq.-13 ambiguity, DESIGN.md
§10):

  * ``neighbor_lambda`` — the weight histogram's slot is lambda(u), known
    per edge in-kernel, so L_w is a full [Ec, k] indicator and the kernel
    returns the finished w_raw.
  * ``self_lambda`` — the slot is lambda(v) = argmax score(v, :), which only
    exists *after* all edge chunks are reduced. But every edge of row v then
    lands in the same slot, so the row's contribution factors into two
    scalars independent of lambda(v):

        A[v] = sum_e agree(e) * w(e)          (agreement mass)
        N[v] = #{e : !agree(e), non-padding}  (disagreement count)

    The kernel accumulates A into column 0 and N into column 1 of the
    second output; the caller scatters ``A + feasible(lambda(v)) * N`` into
    the one-hot lambda(v) slot. The fusion is exact: every kernel input
    (labels, lam, action, p_mig) is available before the edge phase.

VMEM budget: the label/lambda gathers keep the full [n_pad] int32 vectors
VMEM-resident (8 bytes/vertex total). That holds to n_pad ~ 1M/core; beyond
that the slab's dst ids must be pre-translated host-side into per-block
label chunks (the streaming layer's dirty-block machinery already tracks the
needed locality) — see kernels/README.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_WEIGHT_MODES = ("self_lambda", "neighbor_lambda")


def _kernel(dst_ref, row_ref, w_ref, lbl_ref, lam_ref, act_ref, feas_ref,
            hist_ref, wacc_ref, *, block_v: int, k: int, weight_mode: str):
    """One (vertex-block, edge-chunk) grid cell; accumulates both outputs."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        wacc_ref[...] = jnp.zeros_like(wacc_ref)

    dst = dst_ref[0]            # [Ec] int32 global neighbor id
    row = row_ref[0]            # [Ec] int32 local row per edge
    w = w_ref[0]                # [Ec] f32   eq.-4 weight (0 for padding)
    labels = lbl_ref[...]       # [n_pad] int32 freshest labels (async)
    lam = lam_ref[...]          # [n_pad] int32 latest argmax labels
    action = act_ref[0]         # [Bv] int32 LA-selected action psi(v)
    feas = feas_ref[0]          # [k] f32 1.0 where p_mig(l) > 0
    ec = dst.shape[0]

    nbr_lbl = labels[dst]       # in-kernel gathers: one slab read serves both
    lam_nbr = lam[dst]
    live = (w > 0).astype(jnp.float32)          # padding kill
    agree = action[row] == lam_nbr              # psi(v) == lambda(u)

    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (ec, block_v), 1)
    slot_iota = jax.lax.broadcasted_iota(jnp.int32, (ec, k), 1)
    r_mat = (row[:, None] == rows_iota).astype(jnp.float32)      # shared R
    l_score = (nbr_lbl[:, None] == slot_iota).astype(jnp.float32) * w[:, None]

    if weight_mode == "neighbor_lambda":
        # slot = lambda(u): full indicator, finished w_raw out of the kernel
        val = jnp.where(agree, w, feas[lam_nbr]) * live
        l_w = (lam_nbr[:, None] == slot_iota).astype(jnp.float32) * val[:, None]
    else:  # self_lambda: per-row (A, N) factorization, see module docstring
        a_col = jnp.where(agree, w, 0.0)[:, None]
        n_col = jnp.where(agree, 0.0, live)[:, None]
        l_w = jnp.where(slot_iota == 0, a_col,
                        jnp.where(slot_iota == 1, n_col, 0.0))

    dn = (((0,), (0,)), ((), ()))               # R^T @ L
    hist_ref[0] += jax.lax.dot_general(
        r_mat, l_score, dimension_numbers=dn,
        preferred_element_type=jnp.float32)
    wacc_ref[0] += jax.lax.dot_general(
        r_mat, l_w, dimension_numbers=dn,
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "block_v", "k", "weight_mode", "edge_chunk", "interpret"))
def fused_edge_phase_pallas(
    edge_dst: jax.Array,    # [nb, e_max] int32 global neighbor id
    edge_rows: jax.Array,   # [nb, e_max] int32 local row per edge
    edge_vals: jax.Array,   # [nb, e_max] f32 eq.-4 weight (0 = padding)
    labels: jax.Array,      # [n_pad] int32 current labels
    lam: jax.Array,         # [n_pad] int32 latest argmax labels
    actions: jax.Array,     # [nb, block_v] int32 LA-selected actions
    feasible: jax.Array,    # [nb, k] f32 1.0 where p_mig(l) > 0
    *,
    block_v: int,
    k: int,
    weight_mode: str = "self_lambda",
    edge_chunk: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (hist_score, w_acc), both [nb, block_v, k] f32.

    ``w_acc`` is the finished eq.-13 histogram for ``neighbor_lambda``; for
    ``self_lambda`` column 0 carries A[v] and column 1 carries N[v] (the
    caller finishes the one-hot scatter once lambda(v) is known).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if weight_mode not in _WEIGHT_MODES:
        raise ValueError(
            f"unknown weight_mode {weight_mode!r}; expected {_WEIGHT_MODES}")
    if weight_mode == "self_lambda" and k < 2:
        raise ValueError("self_lambda packing needs k >= 2 output columns")
    nb, e_max = edge_dst.shape
    if e_max % edge_chunk != 0:
        # a floored chunk count would silently drop the slab tail
        raise ValueError(f"e_max={e_max} not a multiple of edge_chunk={edge_chunk}")
    n_pad = labels.shape[0]
    n_chunks = e_max // edge_chunk

    grid = (nb, n_chunks)
    out_shape = [jax.ShapeDtypeStruct((nb, block_v, k), jnp.float32)] * 2
    return pl.pallas_call(
        functools.partial(
            _kernel, block_v=block_v, k=k, weight_mode=weight_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, edge_chunk), lambda i, j: (i, j)),
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),
            pl.BlockSpec((1, block_v), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_v, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_v, k), lambda i, j: (i, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(edge_dst, edge_rows, edge_vals, labels, lam, actions, feasible)
