"""Pallas TPU kernel: RWKV6 wkv recurrence with a VMEM-resident state.

§Perf attribution on rwkv6-3b train_4k showed the XLA chunk-vectorized
recurrence streams the [B, nc, H, N, N] f32 state through HBM on every
within-chunk step — 2.3e12 of the cell's 1.1e13 HBM bytes. This kernel
keeps one (batch, head) [N, N] state tile **resident in VMEM across the
whole sequence** (grid minor = seq blocks, sequential on TPU), so HBM
sees only the r/k/v/w streams and one state write:

  per token (head-local):
    y_t     = r_t · (S + u ⊙ k_t ⊗ v_t)
    S      <- diag(exp(logw_t)) S + k_t ⊗ v_t

Layout: [B, S, H, N] operands; grid (B, H, S/block_s); the seq loop
inside a block is a fori_loop over VMEM rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, so_ref,
            state_ref, *, block_s: int):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                     # [N]

    def step(t, _):
        rt = r_ref[0, t, 0].astype(jnp.float32)          # [N]
        kt = k_ref[0, t, 0].astype(jnp.float32)
        vt = v_ref[0, t, 0].astype(jnp.float32)
        wt = jnp.exp(lw_ref[0, t, 0].astype(jnp.float32))
        state = state_ref[...]                           # [N, N]
        att = state + (u * kt)[:, None] * vt[None, :]
        o_ref[0, t, 0] = jnp.sum(rt[:, None] * att, axis=0).astype(o_ref.dtype)
        state_ref[...] = state * wt[:, None] + kt[:, None] * vt[None, :]
        return _

    jax.lax.fori_loop(0, block_s, step, None)

    @pl.when(j == nj - 1)
    def _fin():
        so_ref[0, 0] = state_ref[...].astype(so_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def wkv6_pallas(r, k, v, logw, u, state0, *, block_s: int = 128,
                interpret: bool | None = None):
    """r/k/v/logw [B,S,H,N]; u [H,N]; state0 [B,H,N,N] f32.
    Returns (y [B,S,H,N] f32, state_out [B,H,N,N] f32)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, s, h, n = r.shape
    block_s = min(block_s, s)
    assert s % block_s == 0, (s, block_s)
    grid = (b, h, s // block_s)
    seq_spec = pl.BlockSpec((1, block_s, 1, n), lambda b_, h_, j: (b_, j, h_, 0))
    return pl.pallas_call(
        functools.partial(_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, n), lambda b_, h_, j: (h_, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, j: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state0)
