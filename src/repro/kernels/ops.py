"""Public jit'd wrappers around the Pallas kernels.

Every op auto-selects ``interpret=True`` on CPU (this container) and the
compiled TPU path elsewhere; the ``ref.py`` oracles pin the semantics in
tests/test_kernels.py. Call sites in the model zoo and the partitioner
select implementations via config flags ("jnp" | "pallas") so the
dry-run can lower the pure-XLA path while TPU deployments take the
kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.edge_phase import fused_edge_phase_pallas
from repro.kernels.la_update import la_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas

# NOTE: the single-histogram `edge_histogram` kernel no longer has a public
# op wrapper — the fused dual-histogram edge phase below superseded its
# two-launch dispatch path in the superstep. The kernel itself stays
# importable (`repro.kernels.edge_histogram.edge_histogram_pallas`) purely
# as a test/bench oracle for the fused kernel's score histogram.


def superstep_kernels(hist_impl: str, la_impl: str):
    """Resolve the partitioner engine's kernel routing.

    The ``hist_impl`` / ``la_impl`` config knobs pick between the jnp
    reference paths (scatter-add histogram in core/lp.py, fori-loop LA
    update in core/la.py) and the Pallas kernels below; this is the single
    dispatch point the superstep rules route through. Returns
    ``(edge_phase_op, la_update_op)`` with ``None`` marking "use the jnp
    reference" — rules keep their reference math inline so the pure-XLA
    lowering stays dependency-free.
    """
    for name, impl in (("hist_impl", hist_impl), ("la_impl", la_impl)):
        if impl not in ("jnp", "pallas"):
            raise ValueError(f"{name}={impl!r} is not one of ('jnp', 'pallas')")
    return (
        fused_edge_phase if hist_impl == "pallas" else None,
        la_update if la_impl == "pallas" else None,
    )


def fused_edge_phase(edge_dst, edge_rows, edge_vals, labels, lam, actions,
                     feasible, *, block_v: int, k: int,
                     weight_mode: str = "self_lambda",
                     edge_chunk: int | None = None,
                     interpret: bool | None = None):
    """(hist_score, w_acc), both [nb, block_v, k] — see kernels/edge_phase.py.

    Both Revolver edge histograms in one slab pass; `w_acc` is the finished
    eq.-13 histogram for weight_mode="neighbor_lambda", or the (A, N)
    column packing for "self_lambda". `edge_chunk=None` picks 256 when the
    slab divides (the `block_edges` invariant) or one whole-slab chunk for
    sub-256 slabs; a larger non-divisible slab raises in the kernel wrapper
    rather than silently building an oversized [e_max, block_v] indicator.
    """
    e_max = edge_dst.shape[-1]
    if edge_chunk is None:
        edge_chunk = e_max if (e_max < 256 and e_max % 256 != 0) else 256
    return fused_edge_phase_pallas(
        edge_dst, edge_rows, edge_vals, labels, lam, actions, feasible,
        block_v=block_v, k=k, weight_mode=weight_mode,
        edge_chunk=edge_chunk, interpret=interpret)


def la_update(probs, weights, signals, alpha: float, beta: float, *,
              renorm: bool = True, interpret: bool | None = None):
    """Weighted-LA probability update (eqs. 8/9) on [V, k] (or [..., k]).

    Rows are padded to a VMEM-friendly block multiple; padding rows carry
    zero weights (all passes skipped) and are sliced off on return.
    """
    shape = probs.shape
    k = shape[-1]
    p2 = probs.reshape(-1, k)
    w2 = weights.reshape(-1, k)
    r2 = signals.reshape(-1, k)
    v = p2.shape[0]
    block_v = 256 if v >= 256 else max(8, 1 << (v - 1).bit_length())
    pad = (-v) % block_v
    if pad:
        p2 = jnp.concatenate([p2, jnp.full((pad, k), 1.0 / k, p2.dtype)], 0)
        w2 = jnp.concatenate([w2, jnp.zeros((pad, k), w2.dtype)], 0)
        r2 = jnp.concatenate([r2, jnp.zeros((pad, k), r2.dtype)], 0)
    out = la_update_pallas(
        p2, w2, r2, alpha=alpha, beta=beta, renorm=renorm,
        block_v=block_v, interpret=interpret)
    return out[:v].reshape(shape)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Causal/SWA GQA flash attention — q [B,Hq,S,D], k/v [B,Hkv,S,D]."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)


def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 512,
                     interpret: bool | None = None, return_lse: bool = False):
    """Flash-decode — q [B,Hq,D] against cache [B,Hkv,S,D]."""
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_len, block_k=block_k,
        interpret=interpret, return_lse=return_lse)


def wkv6(r, k, v, logw, u, state0, *, block_s: int = 128,
         interpret: bool | None = None):
    """RWKV6 recurrence with VMEM-resident [N,N] state — see kernels/wkv6.py."""
    from repro.kernels.wkv6 import wkv6_pallas
    return wkv6_pallas(r, k, v, logw, u, state0, block_s=block_s,
                       interpret=interpret)
