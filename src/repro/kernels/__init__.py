"""Pallas TPU kernels for the framework's compute hot spots.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec tiling),
wrappers in ops.py (jit'd public API), oracles in ref.py (pure jnp /
numpy). Validated under interpret=True on CPU; TPU is the target.

  edge_phase        fused dual-histogram edge phase (both superstep
                    histograms in one slab pass; the hist_impl="pallas" path)
  edge_histogram    single-histogram kernel, kept ONLY as a test/bench
                    oracle for edge_phase (its two-launch superstep dispatch
                    path is retired; no ops.py wrapper)
  la_update         weighted-LA probability update, eqs. (8)/(9)
  flash_attention   causal/SWA GQA flash attention (LM training)
  decode_attention  flash-decode over a KV cache (LM serving)

See README.md in this package for the kernel inventory and the edge-phase
fusion rationale.
"""
from repro.kernels import ops, ref
from repro.kernels.edge_histogram import edge_histogram_pallas
from repro.kernels.edge_phase import fused_edge_phase_pallas
from repro.kernels.la_update import la_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas

__all__ = [
    "ops",
    "ref",
    "edge_histogram_pallas",
    "fused_edge_phase_pallas",
    "la_update_pallas",
    "flash_attention_pallas",
    "decode_attention_pallas",
]
