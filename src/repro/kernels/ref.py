"""Pure-jnp/numpy oracles for every Pallas kernel in this package.

Each oracle is written independently of the kernel (and of the core/ jnp
implementations where practical) so that tests/test_kernels.py's
``assert_allclose`` sweeps pin the kernel semantics rather than comparing
an implementation against itself. The ``la_update_ref`` oracle in
particular runs the m sequential passes as a Python loop over numpy
arrays — the most literal possible transcription of eqs. (8)/(9).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# --------------------------------------------------------------------------
# edge_histogram (eq. 11 numerator / eq. 13 accumulation)
# --------------------------------------------------------------------------
def edge_histogram_ref(
    edge_slots: np.ndarray,   # [nb, e_max] int32 partition slot per edge
    edge_rows: np.ndarray,    # [nb, e_max] int32 local row per edge
    edge_vals: np.ndarray,    # [nb, e_max] f32 contribution (0 = padding)
    *,
    block_v: int,
    k: int,
) -> np.ndarray:
    """hist[b, r, l] = sum of vals of block-b edges with row r, slot l."""
    edge_slots = np.asarray(edge_slots)
    edge_rows = np.asarray(edge_rows)
    edge_vals = np.asarray(edge_vals, dtype=np.float32)
    nb, e_max = edge_slots.shape
    hist = np.zeros((nb, block_v, k), np.float32)
    for b in range(nb):
        np.add.at(hist[b], (edge_rows[b], edge_slots[b]), edge_vals[b])
    return hist


# --------------------------------------------------------------------------
# fused_edge_phase (eq. 11 score histogram + eq. 13 accumulation, one pass)
# --------------------------------------------------------------------------
def fused_edge_phase_ref(
    edge_dst: np.ndarray,   # [nb, e_max] int32 global neighbor id
    edge_rows: np.ndarray,  # [nb, e_max] int32 local row per edge
    edge_vals: np.ndarray,  # [nb, e_max] f32 weight (0 = padding)
    labels: np.ndarray,     # [n_pad] int32
    lam: np.ndarray,        # [n_pad] int32
    actions: np.ndarray,    # [nb, block_v] int32
    feasible: np.ndarray,   # [nb, k] f32 (1.0 where p_mig > 0)
    *,
    block_v: int,
    k: int,
    weight_mode: str = "self_lambda",
) -> tuple[np.ndarray, np.ndarray]:
    """Two scatter-add loops mirroring the fused kernel's output contract:
    (hist_score, w_acc) with w_acc = finished eq.-13 histogram for
    neighbor_lambda, or the per-row (A, N) column packing for self_lambda."""
    edge_dst = np.asarray(edge_dst)
    edge_rows = np.asarray(edge_rows)
    edge_vals = np.asarray(edge_vals, dtype=np.float32)
    labels = np.asarray(labels)
    lam = np.asarray(lam)
    actions = np.asarray(actions)
    feasible = np.asarray(feasible, dtype=np.float32)
    nb, _ = edge_dst.shape
    hist = np.zeros((nb, block_v, k), np.float32)
    wacc = np.zeros((nb, block_v, k), np.float32)
    for b in range(nb):
        dst, row, w = edge_dst[b], edge_rows[b], edge_vals[b]
        live = (w > 0).astype(np.float32)
        agree = actions[b][row] == lam[dst]
        np.add.at(hist[b], (row, labels[dst]), w)
        if weight_mode == "neighbor_lambda":
            val = np.where(agree, w, feasible[b][lam[dst]]) * live
            np.add.at(wacc[b], (row, lam[dst]), val)
        else:
            np.add.at(wacc[b][:, 0], row, np.where(agree, w, 0.0))
            np.add.at(wacc[b][:, 1], row, np.where(agree, 0.0, live))
    return hist, wacc


# --------------------------------------------------------------------------
# la_update (eqs. 8/9, m sequential passes, penalty-first schedule)
# --------------------------------------------------------------------------
def la_update_ref(
    probs: np.ndarray,    # [V, k] f32
    weights: np.ndarray,  # [V, k] f32 (normalized halves)
    signals: np.ndarray,  # [V, k] f32 (0 reward / 1 penalty)
    *,
    alpha: float,
    beta: float,
    renorm: bool = True,
) -> np.ndarray:
    p = np.array(probs, np.float64)
    w = np.asarray(weights, np.float64)
    r = np.asarray(signals, np.float64)
    v, k = p.shape
    # penalty-first, stable within each class (matches argsort(-r, stable))
    order = np.argsort(-r, axis=-1, kind="stable")
    for row in range(v):
        for t in range(k):
            i = order[row, t]
            w_i = w[row, i]
            if w_i <= 0:       # zero-weight slot carries no signal: skip
                continue
            if r[row, i] > 0:  # eq. (9) penalty pass
                new = p[row] * (1.0 - beta * w[row]) + beta * w[row] / (k - 1)
                new[i] = p[row, i] * (1.0 - beta * w_i)
            else:              # eq. (8) reward pass
                new = p[row] * (1.0 - alpha * w[row])
                new[i] = p[row, i] + alpha * w_i * (1.0 - p[row, i])
            p[row] = new
    if renorm:
        p = np.clip(p, 1e-12, 1.0)
        p = p / p.sum(axis=-1, keepdims=True)
    return p.astype(np.float32)


# --------------------------------------------------------------------------
# attention (full softmax oracle; GQA + causal + sliding window)
# --------------------------------------------------------------------------
def attention_ref(
    q: jnp.ndarray,   # [B, Hq, Sq, D]
    k: jnp.ndarray,   # [B, Hkv, Skv, D]
    v: jnp.ndarray,   # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """O(S^2)-memory reference attention in f32."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    q_pos = jnp.arange(sq)[:, None] + (k.shape[2] - sq)  # right-aligned
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,        # [B, Hq, D] one query token per sequence
    k_cache: jnp.ndarray,  # [B, Hkv, S, D]
    v_cache: jnp.ndarray,  # [B, Hkv, S, D]
    kv_len: jnp.ndarray,   # [B] int32 valid prefix length
    *,
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, d = q.shape
    hkv, s_max = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k_cache.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v_cache.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", qf, kf) * scale
    valid = jnp.arange(s_max)[None, None, :] < kv_len[:, None, None]
    s = jnp.where(valid, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", p, vf).astype(q.dtype)


# --------------------------------------------------------------------------
# wkv6 (RWKV6 recurrence)
# --------------------------------------------------------------------------
def wkv6_ref(r, k, v, logw, u, state0):
    """Token-by-token numpy oracle. r/k/v/logw [B,S,H,N]; u [H,N];
    state0 [B,H,N,N]. Returns (y, state_out)."""
    r = np.asarray(r, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    w = np.exp(np.asarray(logw, np.float64))
    u = np.asarray(u, np.float64)
    state = np.array(state0, np.float64)
    b, s, h, n = r.shape
    y = np.zeros((b, s, h, n), np.float64)
    for t in range(s):
        kt, vt, rt = k[:, t], v[:, t], r[:, t]            # [B,H,N]
        att = state + (u[None] * kt)[..., :, None] * vt[..., None, :]
        y[:, t] = np.einsum("bhn,bhnm->bhm", rt, att)
        state = state * w[:, t][..., :, None] + \
            kt[..., :, None] * vt[..., None, :]
    return y.astype(np.float32), state.astype(np.float32)
