"""Pallas TPU kernel: flash-decode — one query token against a KV cache.

decode_32k / long_500k serving hot spot. The cache is streamed through
VMEM in ``block_k``-sized slabs while the single query row stays
resident; running (m, l) online-softmax statistics live in VMEM scratch
across the minor (kv-block) grid dimension:

  grid = (B, Hkv, S/block_k)

All ``group = Hq/Hkv`` query heads that share a KV head are processed
together as a [group, d] q tile — the cache slab is read from HBM once
per KV head rather than once per Q head (the kernel is bandwidth-bound;
this is the GQA bandwidth win). Invalid cache positions (>= kv_len) are
masked in-register.

The kernel also exposes (m, l) per head for the sharded long-context
path: `parallel/collectives.py` combines per-shard partial outputs with
the standard lse-combine, so a seq-sharded cache needs only a
[B, Hq, d]-sized psum instead of an all-gather of the cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
            acc_ref, m_ref, l_ref, *, block_k: int, scale: float):
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[pl.program_id(0)]
    k0 = j * block_k

    @pl.when(k0 < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [group, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale          # [group, bk]
        pos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, _NEG_INF)

        m_prev = m_ref[...]                          # [group, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(pos < kv_len, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)
        m_out_ref[0, 0] = m_ref[...]
        l_out_ref[0, 0] = l_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret", "return_lse"))
def decode_attention_pallas(
    q: jax.Array,        # [B, Hq, D]
    k_cache: jax.Array,  # [B, Hkv, S, D]
    v_cache: jax.Array,  # [B, Hkv, S, D]
    kv_len: jax.Array,   # [B] int32 valid prefix length
    *,
    block_k: int = 512,
    interpret: bool | None = None,
    return_lse: bool = False,
):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, hq, d = q.shape
    hkv, s_max = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_k = min(block_k, s_max)
    assert s_max % block_k == 0, (s_max, block_k)
    scale = 1.0 / (d ** 0.5)

    # [B, Hq, D] -> [B, Hkv, group, D] so the q BlockSpec tiles per KV head
    qg = q.reshape(b, hkv, group, d)
    grid = (b, hkv, s_max // block_k)
    kernel = functools.partial(_kernel, block_k=block_k, scale=scale)
    out, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv_len, scalar-prefetched
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, j: (b_, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, group, 1), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, group, 1), lambda b_, h, j: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
            jax.ShapeDtypeStruct((b, hkv, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, group, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k_cache, v_cache)
    out = out.reshape(b, hq, d)
    if return_lse:
        return out, m.reshape(b, hq), l.reshape(b, hq)
    return out
