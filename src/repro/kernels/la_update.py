"""Pallas TPU kernel: weighted learning-automaton probability update.

Eqs. (8)/(9) require m sequential passes over every vertex's [k]
probability vector — O(V*k^2) elementwise work with a serial dependency
along the pass axis. A naive XLA lowering round-trips the [V, k]
probability matrix through HBM once per pass (k HBM sweeps). The kernel
keeps a [Bv, k] probability tile **resident in VMEM across all k passes**
(one HBM read + one write per tile), turning the update from
memory-bound into VPU-bound.

The per-row pass schedule (penalty passes first — DESIGN.md §10.6) is
precomputed outside the kernel as an argsort and streamed in as an int32
[Bv, k] tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, w_ref, r_ref, ord_ref, out_ref, *,
            k: int, alpha: float, beta: float, renorm: bool):
    p = p_ref[...].astype(jnp.float32)     # [Bv, k]
    w = w_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    order = ord_ref[...]                   # [Bv, k] int32 pass schedule
    bv = p.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bv, k), 1)

    def pass_t(t, p):
        i = jax.lax.dynamic_slice_in_dim(order, t, 1, axis=1)        # [Bv, 1]
        mask = iota == i
        w_i = jnp.sum(jnp.where(mask, w, 0.0), axis=1, keepdims=True)
        # eq. (8): reward pass for action i
        p_rew = jnp.where(mask, p + alpha * w * (1.0 - p), p * (1.0 - alpha * w))
        # eq. (9): penalty pass (weighted redistribution floor)
        floor = beta * w / (k - 1)
        p_pen = jnp.where(mask, p * (1.0 - beta * w), p * (1.0 - beta * w) + floor)
        is_pen = jnp.sum(jnp.where(mask, r, 0.0), axis=1, keepdims=True) > 0
        p_new = jnp.where(is_pen, p_pen, p_rew)
        # zero-weight slot => no signal => skip the pass
        return jnp.where(w_i > 0, p_new, p)

    p = jax.lax.fori_loop(0, k, pass_t, p)
    if renorm:
        p = jnp.clip(p, 1e-12, 1.0)
        p = p / jnp.sum(p, axis=1, keepdims=True)
    out_ref[...] = p.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "renorm", "block_v", "interpret"))
def la_update_pallas(
    probs: jax.Array,    # [V, k] f32
    weights: jax.Array,  # [V, k] f32 (normalized halves, sum=2)
    signals: jax.Array,  # [V, k] f32 (0 reward / 1 penalty)
    *,
    alpha: float,
    beta: float,
    renorm: bool = True,
    block_v: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    v, k = probs.shape
    assert v % block_v == 0, (v, block_v)
    # penalty-first schedule: stable argsort of descending r
    order = jnp.argsort(-signals, axis=-1, stable=True).astype(jnp.int32)

    grid = (v // block_v,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k, alpha=alpha, beta=beta, renorm=renorm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
            pl.BlockSpec((block_v, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_v, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, k), probs.dtype),
        interpret=interpret,
    )(probs, weights, signals, order)
