"""Pallas TPU kernel: causal/sliding-window GQA flash attention.

Two-pass-free online-softmax attention (Rabe&Staats / FlashAttention-2
schedule) adapted to the TPU grid model:

  grid = (B, Hq, Sq/block_q, Skv/block_k)     # kv block index minor

TPU executes the grid sequentially per core, so the [block_q, d] f32
accumulator and the running (m, l) statistics live in VMEM scratch and
persist across the minor (kv) grid steps; HBM sees exactly one read of
Q/K/V and one write of O per tile. GQA is folded into the K/V BlockSpec
index maps (kv head = q head // group) — no repeated KV in HBM.

Masks are computed in-register from iota:
  causal          q_pos >= k_pos      (q right-aligned against the kv axis)
  sliding window  q_pos -  k_pos < window

Blocks that the causal/window mask kills entirely are skipped with
``pl.when`` (the TPU grid still visits them, but no MXU work is issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_q: int, block_k: int, sq: int, skv: int,
            causal: bool, window: int | None, scale: float):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # q tokens are right-aligned against the kv axis (prefill continuation)
    offset = skv - sq
    q0 = i * block_q + offset
    k0 = j * block_k

    # --- block-level mask culling -----------------------------------------
    run = True
    if causal:
        run = jnp.logical_and(run, k0 <= q0 + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, q0 - (k0 + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale           # [bq, bk]

        q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                       # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,   # [B, Hq, Sq, D]
    k: jax.Array,   # [B, Hkv, Skv, D]
    v: jax.Array,   # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    scale = 1.0 / (d ** 0.5)

    grid = (b, hq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, sq=sq, skv=skv,
        causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
