"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """`jax.make_mesh` across JAX versions.

    `axis_types` / `jax.sharding.AxisType` only exist on newer JAX; older
    versions (e.g. 0.4.x) default every axis to the same auto behavior, so
    omitting the argument is semantically equivalent there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """single-pod: (data=16, model=16) = 256 chips;
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """1-chip mesh with the production axis names (tests/smoke runs)."""
    return make_mesh_compat((1, 1), ("data", "model"))


def make_blocks_mesh(n_shards: int | None = None):
    """1-D ``("blocks",)`` mesh for the sharded partitioner superstep.

    The graph workload shards its vertex-block axis, not model/data, so it
    gets its own mesh builder. ``n_shards=None`` takes every visible device;
    an explicit count takes the first ``n_shards`` (scaling benchmarks sweep
    1/2/4/8 on a fixed device pool).
    """
    import numpy as np

    devices = jax.devices()
    if n_shards is None:
        n_shards = len(devices)
    if not 1 <= n_shards <= len(devices):
        raise ValueError(
            f"n_shards={n_shards} not in [1, {len(devices)}] visible devices")
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), ("blocks",))
