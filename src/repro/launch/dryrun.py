import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jax.jit(step).lower(**ShapeDtypeStruct specs).compile()
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, printing
``compiled.memory_analysis()`` (proves it fits) and the HLO-derived cost
terms (feeds §Roofline). No arrays are allocated — inputs are
ShapeDtypeStructs and only lower+compile runs.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun.jsonl   # every cell,
      one subprocess per cell (keeps compile RAM bounded), resumable.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _mesh(name: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(name == "multipod"))


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def dryrun_cell(arch: str, shape_name: str, mesh_name: str, *,
                zero_dp: bool = True, seq_parallel: bool = False,
                bf16_silu: bool = False, moe_ep2d: bool = False,
                verbose: bool = True, breakdown: bool = False) -> dict:
    from repro.configs.registry import get_config, input_specs
    from repro.configs.shapes import SHAPES
    from repro.models import init_cache, init_lm
    from repro.optim.adamw import OptConfig
    from repro.parallel import (analyze_compiled, batch_specs, cache_specs,
                                param_specs, roofline_from_costs,
                                validate_specs, zero_dp_specs)
    from repro.parallel.act_sharding import use_activation_sharding
    from repro.train.step import (init_train_state, make_decode_step,
                                  make_prefill_step, make_train_step)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = _mesh(mesh_name)
    chips = mesh.size
    t0 = time.monotonic()

    key_s = jax.ShapeDtypeStruct((2,), np.uint32)
    p_shape = jax.eval_shape(lambda k: init_lm(cfg, k), key_s)
    p_specs = param_specs(p_shape, cfg=cfg, mesh=mesh, moe_ep2d=moe_ep2d)
    bad = validate_specs(p_specs, p_shape, mesh)
    if bad:
        raise ValueError(f"indivisible param shardings: {bad[:5]}")
    b_specs_in = input_specs(cfg, shape)

    with use_activation_sharding(mesh, enabled=True, sp=seq_parallel,
                                 bf16_silu=bf16_silu, moe_ep2d=moe_ep2d):
        if shape.kind == "train":
            opt_cfg = OptConfig()
            state_shape = jax.eval_shape(
                lambda k: init_train_state(cfg, opt_cfg, k), key_s)
            opt_specs = {
                "master": p_specs,
                "m": p_specs,
                "v": p_specs,
                "count": P(),
            }
            if zero_dp:
                opt_specs = {
                    k: (zero_dp_specs(p_specs, p_shape, mesh)
                        if k != "count" else P())
                    for k in opt_specs}
            state_specs = {"params": p_specs, "opt": opt_specs, "step": P()}
            bspec = batch_specs(b_specs_in, mesh)
            fn = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, state_specs), _named(mesh, bspec)),
                out_shardings=(_named(mesh, state_specs),
                               NamedSharding(mesh, P())),
                donate_argnums=(0,),
            ).lower(state_shape, b_specs_in)
        elif shape.kind == "prefill":
            bspec = batch_specs(b_specs_in, mesh)
            fn = make_prefill_step(cfg, shape.seq_len)
            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, bspec)),
            ).lower(p_shape, b_specs_in)
        else:  # decode
            cache_shape = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
            c_specs = cache_specs(cfg, cache_shape, mesh)
            bad = validate_specs(c_specs, cache_shape, mesh)
            if bad:
                raise ValueError(f"indivisible cache shardings: {bad[:5]}")
            tok_spec = batch_specs(b_specs_in, mesh)
            fn = make_decode_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                              _named(mesh, tok_spec["token"])),
            ).lower(p_shape, cache_shape, b_specs_in["token"])

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    costs = analyze_compiled(compiled)
    roof = roofline_from_costs(costs, cfg=cfg, shape=shape,
                               mesh_name=mesh_name, chips=chips,
                               mem_stats=mem)
    xla_ca = {}
    try:
        ca = compiled.cost_analysis()
        xla_ca = {"xla_flops": ca.get("flops"),
                  "xla_bytes": ca.get("bytes accessed")}
    except Exception:
        pass

    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "seq_parallel": seq_parallel, "bf16_silu": bf16_silu,
        "moe_ep2d": moe_ep2d,
        "mem": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
        },
        **{k: v for k, v in roof.row().items()
           if k not in ("arch", "shape", "mesh", "chips")},
        **xla_ca,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compile={t_compile:.1f}s")
        print(f"  memory_analysis: args={row['mem']['argument_gb']:.2f}GB "
              f"temp={row['mem']['temp_gb']:.2f}GB "
              f"alias={row['mem']['alias_gb']:.2f}GB "
              f"fits_hbm={row['fits_hbm']}")
        print(f"  flops/dev={row['hlo_flops']:.3e} bytes/dev={row['hlo_bytes']:.3e} "
              f"coll/dev={row['collective_bytes']:.3e}")
        print(f"  roofline: compute={row['compute_s']:.4f}s "
              f"memory={row['memory_s']:.4f}s coll={row['collective_s']:.4f}s "
              f"-> {row['bottleneck']}-bound useful={row['useful_ratio']:.2f}")
        print(f"  collectives: { {k: int(v['count']) for k, v in (row['collectives'] or {}).items()} }")
    if breakdown:
        print("  -- top HBM byte contributors --")
        for k, v in costs.top_shapes(12):
            print(f"    {v:12.3e}  {k}")
        print("  -- top collective contributors --")
        for k, v in costs.top_coll(8):
            print(f"    {v:12.3e}  {k}")
        row["top_shapes"] = costs.top_shapes(12)
        row["top_coll"] = costs.top_coll(8)
    return row


def _load_done(path):
    done = set()
    if path and os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok":
                        done.add((r["arch"], r["shape"], r["mesh"],
                                  bool(r.get("seq_parallel", False))))
                except json.JSONDecodeError:
                    pass
    return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multipod"])
    ap.add_argument("--all", action="store_true",
                    help="run every runnable cell x both meshes via subprocesses")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--bf16-silu", action="store_true")
    ap.add_argument("--ep2d", action="store_true",
                    help="cross-pod expert parallelism (multipod MoE)")
    ap.add_argument("--breakdown", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=float, default=3600.0)
    args = ap.parse_args(argv)

    if args.all:
        from repro.configs.registry import all_cells
        done = set() if args.force else _load_done(args.out)
        cells = [(a, s) for a, s, skip in all_cells() if skip is None]
        skips = [(a, s, skip) for a, s, skip in all_cells() if skip]
        for a, s, why in skips:
            print(f"SKIP {a} x {s}: {why}")
        failures = 0
        for mesh_name in ("single", "multipod"):
            for a, s in cells:
                if (a, s, mesh_name, args.seq_parallel) in done:
                    print(f"done already: {a} x {s} x {mesh_name}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--mesh", mesh_name]
                if args.out:
                    cmd += ["--out", args.out]
                if args.seq_parallel:
                    cmd += ["--seq-parallel"]
                print(f"--- {a} x {s} x {mesh_name} ---", flush=True)
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures += 1
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps({
                                "arch": a, "shape": s, "mesh": mesh_name,
                                "seq_parallel": args.seq_parallel,
                                "status": f"FAILED rc={r.returncode}"}) + "\n")
        print(f"dry-run sweep complete; failures={failures}")
        sys.exit(1 if failures else 0)

    row = dryrun_cell(args.arch, args.shape, args.mesh,
                      seq_parallel=args.seq_parallel,
                      bf16_silu=args.bf16_silu, moe_ep2d=args.ep2d,
                      breakdown=args.breakdown)
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
