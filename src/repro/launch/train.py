"""Training launcher.

Full-scale cluster flow: pick --arch, the production mesh, shardings
from repro.parallel, and run the fault-tolerant Trainer. On this CPU
container the default is --reduced (a tiny config of the same family)
so the loop actually executes; the full configs are exercised by the
dry-run instead.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs.registry import get_config
from repro.data import DataConfig
from repro.optim import OptConfig
from repro.train import SimulatedFailure, Trainer
from repro.utils.logging import MetricLogger


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      batch_per_host=args.batch,
                      seed=args.seed,
                      v_eff=min(cfg.vocab, 512),
                      frontend=((cfg.n_patches or cfg.enc_seq, cfg.d_model)
                                if cfg.family in ("vlm", "encdec") else None))
    opt = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                    total_steps=args.steps)
    trainer = Trainer(cfg, opt, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      microbatch=args.microbatch,
                      inject_failure_at=args.inject_failure_at,
                      logger=MetricLogger())
    trainer.init_or_resume(jax.random.PRNGKey(args.seed))
    try:
        hist = trainer.run(args.steps)
        print(f"done: loss {hist[0]:.3f} -> {hist[-1]:.3f}; "
              f"stragglers={trainer.straggler_events}")
    except SimulatedFailure as e:
        print(f"simulated failure: {e}; re-run to auto-resume")
        raise SystemExit(42)


if __name__ == "__main__":
    main()
