"""Serving launcher: load (or init) params, run batched generation.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs.registry import get_config
from repro.models import init_lm
from repro.serve import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(cfg, key)
    if args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            like = jax.eval_shape(lambda k: init_lm(cfg, k), key)
            state = restore_checkpoint(args.ckpt_dir, step,
                                       {"params": like})
            params = state["params"]
            print(f"restored params from step {step}")

    s_max = args.prompt_len + args.max_new
    eng = Engine(cfg, params, s_max=s_max)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, dtype=jnp.int32)
    frontend = None
    if cfg.family in ("vlm", "encdec"):
        n = cfg.n_patches or cfg.enc_seq
        frontend = jax.random.normal(key, (args.batch, n, cfg.d_model),
                                     cfg.cdt)
    t0 = time.monotonic()
    res = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature, frontend=frontend)
    dt = time.monotonic() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s)")
    print("first sequence:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
