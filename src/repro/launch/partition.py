"""Graph-partitioning launcher — the paper's workload as a CLI.

  PYTHONPATH=src python -m repro.launch.partition --dataset LJ --scale 0.002 \
      --k 8 --algo revolver --algo spinner --algo restream --algo hash

`--algo` accepts any key in the algorithm registry (`repro.core.registry`),
so out-of-tree rules registered before `main()` are launchable without
touching this file. Superstep-only knobs (--epsilon, --max-steps,
--chunk-schedule) are passed only to engine-driven algorithms; the static
baselines (hash/range) take none.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import run_partitioner
from repro.core.registry import (
    StaticAlgorithm,
    available_algorithms,
    get_algorithm,
)
from repro.graphs import load_dataset


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LJ",
                    help="Table-I dataset key (WIKI/UK/USA/SO/LJ/EN/OK/HLWD/EU)")
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--algo", action="append", default=None,
                    choices=list(available_algorithms()))
    ap.add_argument("--max-steps", type=int, default=290)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--n-blocks", type=int, default=8)
    ap.add_argument("--chunk-schedule", default="sequential",
                    choices=["sequential", "sharded", "halo", "async"])
    ap.add_argument("--assignment", default="contiguous",
                    choices=["contiguous", "locality", "vcycle"],
                    help="block->shard mapping for sharded/halo schedules "
                         "(vcycle = locality seed + pairwise-swap "
                         "refinement, never worse than locality)")
    ap.add_argument("--mode", default="flat", choices=["flat", "vcycle"],
                    help="flat = refine at full resolution from superstep 0; "
                         "vcycle = coarsen, partition the coarsest graph, "
                         "uncoarsen with warm-started refinement (see "
                         "docs/multilevel.md)")
    ap.add_argument("--coarse-n", type=int, default=None,
                    help="coarsest-level vertex target for --mode vcycle "
                         "(default 512)")
    ap.add_argument("--level-decay", type=float, default=None,
                    help="per-level superstep budget decay for --mode vcycle "
                         "(default 0.5)")
    ap.add_argument("--halo-granularity", default="auto",
                    choices=["auto", "block", "vertex"],
                    help="halo exchange unit (halo/async schedules): whole "
                         "boundary blocks or per-vertex need lists on an "
                         "int8 wire; auto takes whichever moves fewer "
                         "elements")
    ap.add_argument("--staleness-bound", type=int, default=0,
                    help="async schedule: supersteps a shard may run against "
                         "a stale halo before a forced refresh (0 = refresh "
                         "every superstep, bit-identical to the halo "
                         "schedule on the same layout; see "
                         "docs/async-superstep.md)")
    ap.add_argument("--hub-replication", action="store_true",
                    help="mirror top-degree vertices into every shard and "
                         "reconcile their labels by a per-superstep global "
                         "vote (halo schedule; see repro.core.halo)")
    ap.add_argument("--hub-quantile", type=float, default=0.0,
                    help="degree quantile above which vertices are hubs "
                         "(0 = auto-size the hub set from halo coverage)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync-every", type=int, default=1,
                    help="device->host score fetch window (supersteps); "
                         "checkpoints and state guards ride these windows")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="root directory for crash-safe checkpoints; each "
                         "algorithm saves under <dir>/<algo> (see "
                         "docs/fault-tolerance.md)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the partitioner state every N supersteps "
                         "(0 = off; needs --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume each algorithm from its newest usable "
                         "checkpoint under --checkpoint-dir (fresh run if "
                         "none exists) — a killed run relaunched with the "
                         "same command line continues bit-identically")
    ap.add_argument("--guard", default="off",
                    choices=["off", "raise", "rollback", "reinit"],
                    help="drain-window state guard policy for non-finite "
                         "probs / out-of-range labels")
    ap.add_argument("--labels-out", metavar="PATH", default=None,
                    help="write final labels per algorithm to PATH (npz, one "
                         "array per algorithm) — lets CI diff two runs "
                         "bit-for-bit")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a perfetto-loadable trace (Chrome trace-event"
                         " JSON) covering every run to PATH; inspect with "
                         "tools/trace_report.py or at https://ui.perfetto.dev")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro import obs

        tracer = obs.Tracer()
        tracer.meta["cli"] = {"dataset": args.dataset, "scale": args.scale,
                              "k": args.k,
                              "chunk_schedule": args.chunk_schedule}

    g = load_dataset(args.dataset, scale=args.scale, seed=args.seed)
    algos = args.algo or list(available_algorithms())
    rows = []
    labels_out = {}
    for algo in algos:
        kwargs = {}
        static = isinstance(get_algorithm(algo), StaticAlgorithm)
        if not static:
            kwargs = dict(epsilon=args.epsilon,
                          chunk_schedule=args.chunk_schedule,
                          sync_every=args.sync_every, guard=args.guard)
            if args.mode != "flat":
                kwargs["mode"] = args.mode
                kwargs["coarse_n"] = args.coarse_n
                kwargs["level_decay"] = args.level_decay
            if args.chunk_schedule != "sequential":
                kwargs["assignment"] = args.assignment
            if args.chunk_schedule in ("halo", "async"):
                kwargs["halo_granularity"] = args.halo_granularity
            if args.chunk_schedule == "async":
                kwargs["staleness_bound"] = args.staleness_bound
            if args.hub_replication:
                kwargs["hub_replication"] = True
                kwargs["hub_quantile"] = args.hub_quantile
            if args.checkpoint_dir:
                # per-algo subdir: one CLI invocation runs several
                # algorithms; their checkpoints must not collide
                kwargs["checkpoint_dir"] = os.path.join(
                    args.checkpoint_dir, algo)
                kwargs["checkpoint_every"] = args.checkpoint_every
                kwargs["resume"] = args.resume
        res = run_partitioner(algo, g, args.k, seed=args.seed,
                              max_steps=args.max_steps,
                              n_blocks=args.n_blocks, trace=tracer, **kwargs)
        row = {"dataset": args.dataset, "algo": algo, "k": args.k,
               "local_edges": round(res.local_edges, 4),
               "max_norm_load": round(res.max_norm_load, 4),
               "steps": res.steps}
        if res.resumed_from:
            row["resumed_from"] = res.resumed_from
        rows.append(row)
        labels_out[algo] = res.labels
        if not args.json:
            resumed = (f" resumed_from={res.resumed_from}"
                       if res.resumed_from else "")
            print(f"{algo:10s} local_edges={row['local_edges']:.4f} "
                  f"max_norm_load={row['max_norm_load']:.4f} "
                  f"steps={row['steps']}{resumed}")
    if args.labels_out:
        import numpy as np

        np.savez(args.labels_out, **labels_out)
        if not args.json:
            print(f"labels written to {args.labels_out}")
    if args.json:
        print(json.dumps(rows))
    if tracer is not None:
        tracer.save(args.trace)
        if not args.json:
            print(f"trace written to {args.trace} "
                  f"({len(tracer.events)} events)")


if __name__ == "__main__":
    main()
