"""Graph-partitioning launcher — the paper's workload as a CLI.

  PYTHONPATH=src python -m repro.launch.partition --dataset LJ --scale 0.002 \
      --k 8 --algo revolver --algo spinner --algo restream --algo hash

`--algo` accepts any key in the algorithm registry (`repro.core.registry`),
so out-of-tree rules registered before `main()` are launchable without
touching this file. Superstep-only knobs (--epsilon, --max-steps,
--chunk-schedule) are passed only to engine-driven algorithms; the static
baselines (hash/range) take none.
"""
from __future__ import annotations

import argparse
import json

from repro.core import run_partitioner
from repro.core.registry import (
    StaticAlgorithm,
    available_algorithms,
    get_algorithm,
)
from repro.graphs import load_dataset


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="LJ",
                    help="Table-I dataset key (WIKI/UK/USA/SO/LJ/EN/OK/HLWD/EU)")
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--algo", action="append", default=None,
                    choices=list(available_algorithms()))
    ap.add_argument("--max-steps", type=int, default=290)
    ap.add_argument("--epsilon", type=float, default=0.05)
    ap.add_argument("--n-blocks", type=int, default=8)
    ap.add_argument("--chunk-schedule", default="sequential",
                    choices=["sequential", "sharded", "halo"])
    ap.add_argument("--assignment", default="contiguous",
                    choices=["contiguous", "locality"],
                    help="block->shard mapping for sharded/halo schedules")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a perfetto-loadable trace (Chrome trace-event"
                         " JSON) covering every run to PATH; inspect with "
                         "tools/trace_report.py or at https://ui.perfetto.dev")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro import obs

        tracer = obs.Tracer()
        tracer.meta["cli"] = {"dataset": args.dataset, "scale": args.scale,
                              "k": args.k,
                              "chunk_schedule": args.chunk_schedule}

    g = load_dataset(args.dataset, scale=args.scale, seed=args.seed)
    algos = args.algo or list(available_algorithms())
    rows = []
    for algo in algos:
        kwargs = {}
        if not isinstance(get_algorithm(algo), StaticAlgorithm):
            kwargs = dict(epsilon=args.epsilon,
                          chunk_schedule=args.chunk_schedule)
            if args.chunk_schedule != "sequential":
                kwargs["assignment"] = args.assignment
        res = run_partitioner(algo, g, args.k, seed=args.seed,
                              max_steps=args.max_steps,
                              n_blocks=args.n_blocks, trace=tracer, **kwargs)
        row = {"dataset": args.dataset, "algo": algo, "k": args.k,
               "local_edges": round(res.local_edges, 4),
               "max_norm_load": round(res.max_norm_load, 4),
               "steps": res.steps}
        rows.append(row)
        if not args.json:
            print(f"{algo:10s} local_edges={row['local_edges']:.4f} "
                  f"max_norm_load={row['max_norm_load']:.4f} "
                  f"steps={row['steps']}")
    if args.json:
        print(json.dumps(rows))
    if tracer is not None:
        tracer.save(args.trace)
        if not args.json:
            print(f"trace written to {args.trace} "
                  f"({len(tracer.events)} events)")


if __name__ == "__main__":
    main()
