"""Minimal structured logger (stdout CSV/JSONL) used by trainer & benchmarks."""
from __future__ import annotations

import json
import sys
import time
from typing import Any, Dict, Optional, TextIO


class MetricLogger:
    """Append-only JSONL metric logger with wall-clock stamps.

    Used by the trainer, the partitioner runner, and the benchmark harness so
    every experiment leaves a machine-readable trace.
    """

    def __init__(self, path: Optional[str] = None, stream: Optional[TextIO] = None):
        self._fh = open(path, "a") if path else None
        self._stream = stream if stream is not None else sys.stdout
        self._t0 = time.time()

    def log(self, tag: str, **metrics: Any) -> Dict[str, Any]:
        rec = {"tag": tag, "t": round(time.time() - self._t0, 4), **metrics}
        line = json.dumps(rec, default=float)
        if self._fh is not None:
            self._fh.write(line + "\n")
            self._fh.flush()
        if self._stream is not None:
            print(line, file=self._stream, flush=True)
        return rec

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
