"""PRNG discipline.

Every stochastic component derives its keys from a named fold of the root key
so that (a) runs are bitwise reproducible given a seed, and (b) restoring from
a checkpoint at step `s` regenerates exactly the stream that a non-interrupted
run would have used (the data pipeline and trainer fold the step index in,
so there is no mutable RNG state to checkpoint).
"""
from __future__ import annotations

import hashlib
from typing import Iterator

import jax


def fold_in_str(key: jax.Array, name: str) -> jax.Array:
    """Deterministically fold a string tag into a PRNG key."""
    digest = hashlib.sha256(name.encode("utf-8")).digest()
    tag = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(key, tag)


def key_iter(key: jax.Array) -> Iterator[jax.Array]:
    """Infinite iterator of fresh subkeys (host-side convenience)."""
    while True:
        key, sub = jax.random.split(key)
        yield sub
