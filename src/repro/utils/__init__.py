"""Shared utilities: pytree helpers, PRNG discipline, structured logging."""
from repro.utils.prng import fold_in_str, key_iter
from repro.utils.tree import (
    tree_bytes,
    tree_global_norm,
    tree_param_count,
    tree_zeros_like,
)

__all__ = [
    "fold_in_str",
    "key_iter",
    "tree_bytes",
    "tree_global_norm",
    "tree_param_count",
    "tree_zeros_like",
]
