"""Pytree utilities used across the trainer / checkpoint / optim layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (uses dtype itemsize of each leaf)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def tree_global_norm(tree) -> jax.Array:
    """L2 norm over all leaves (float32 accumulation)."""
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)
