"""Provenance stamp shared by every ``BENCH_*.json`` artifact.

The perf trajectory only means something if two artifacts are known to come
from comparable environments: the PR-2 baseline recorded neither the commit
nor the device count, so a regression could not be told apart from a
hardware change. Every bench writer now embeds ``bench_provenance()`` under
``meta["provenance"]``; ``schema_version`` bumps whenever an artifact's
layout changes incompatibly, so downstream tooling can refuse to compare
apples to oranges.
"""
from __future__ import annotations

import os
import platform
import subprocess
import time

# 1 = PR-2 era (no provenance); 2 = this stamp
BENCH_SCHEMA_VERSION = 2


def git_commit(cwd: str | None = None) -> str:
    """Current commit hash, or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        )
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def bench_provenance() -> dict:
    """Environment fingerprint for a benchmark artifact (JSON-serializable).

    Imports jax lazily so merely importing this module never initializes the
    backend (device_count does).
    """
    import jax

    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_commit": git_commit(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "unix_time": time.time(),
    }
