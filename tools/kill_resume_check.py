"""Kill-and-resume exactness check (the CI crash-safety gate).

Runs the partition launcher three times against the same dataset/seed:

  1. **reference** — uninterrupted run, final labels written via
     ``--labels-out``;
  2. **victim** — same command line with ``--checkpoint-dir`` and a
     ``REPRO_FAULTS=kill@superstep=N`` plan, so the process SIGKILLs itself
     mid-run (a real ``os.kill``, not an exception — the asserted exit is
     ``-SIGKILL``) after at least one checkpoint landed;
  3. **resume** — same command line plus ``--resume``: restores the newest
     checkpoint and runs to completion.

The gate: resumed labels must equal the reference **bit-for-bit** (and the
resumed run must actually have resumed, not silently started fresh).

``--devices N`` pins ``XLA_FLAGS=--xla_force_host_platform_device_count``
for every phase; ``--resume-devices M`` changes the device count for the
resume phase only — the elastic-restore path. With a sharded schedule a
count change alters the Jacobi trajectory by construction, so that
combination is gated as *transport exactness* instead: a fourth run capped
at the checkpoint's step (``--max-steps`` = steps saved) on M devices must
reproduce the checkpointed labels exactly, proving the restore moved state
onto the new mesh losslessly. Sequential schedules stay bit-exact
end-to-end whatever the counts.

  python tools/kill_resume_check.py --dataset WIKI --scale 0.01 --algo revolver \
      --kill-at 10 --checkpoint-every 4 --sync-every 4
  python tools/kill_resume_check.py --chunk-schedule sharded --devices 8 \
      --resume-devices 4 --kill-at 10

Exit status 0 iff every assertion holds.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_launcher(extra, *, env_extra=None, devices=None, check=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("REPRO_FAULTS", None)
    if env_extra:
        env.update(env_extra)
    if devices:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
    cmd = [sys.executable, "-m", "repro.launch.partition", "--json"] + extra
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if check and proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise SystemExit(f"launcher failed ({proc.returncode}): {cmd}")
    return proc


def load_labels(path, algo):
    with np.load(path) as z:
        return z[algo].copy()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="WIKI")
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--algo", default="revolver")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-steps", type=int, default=30)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--kill-at", type=int, default=14,
                    help="superstep at which the victim run SIGKILLs itself")
    ap.add_argument("--chunk-schedule", default="sequential",
                    choices=["sequential", "sharded", "halo", "async"])
    ap.add_argument("--halo-granularity", default="auto",
                    choices=["auto", "block", "vertex"],
                    help="halo exchange unit (forwarded to the launcher; "
                         "halo/async schedules only)")
    ap.add_argument("--staleness-bound", type=int, default=0,
                    help="staleness bound for the async schedule (forwarded "
                         "to the launcher); checkpoint windows force a halo "
                         "refresh, so the resume gate stays bit-for-bit even "
                         "when this is > 0")
    ap.add_argument("--hub-replication", action="store_true",
                    help="run every phase with hub replication on — hub "
                         "reconciliation carries no extra state, so the "
                         "resume gate stays bit-for-bit")
    ap.add_argument("--devices", type=int, default=None,
                    help="host device count for all phases")
    ap.add_argument("--resume-devices", type=int, default=None,
                    help="device count for the resume phase only "
                         "(elastic restore across a count change)")
    args = ap.parse_args(argv)

    work = tempfile.mkdtemp(prefix="kill_resume_")
    ckpt = os.path.join(work, "ckpt")
    base = ["--dataset", args.dataset, "--scale", str(args.scale),
            "--k", str(args.k), "--algo", args.algo,
            "--seed", str(args.seed), "--max-steps", str(args.max_steps),
            "--sync-every", str(args.sync_every),
            "--chunk-schedule", args.chunk_schedule]
    if args.chunk_schedule in ("halo", "async"):
        base += ["--halo-granularity", args.halo_granularity]
    if args.chunk_schedule == "async":
        base += ["--staleness-bound", str(args.staleness_bound)]
    if args.hub_replication:
        base += ["--hub-replication"]
    ok = True
    try:
        # 1. reference (uninterrupted)
        ref_labels_path = os.path.join(work, "ref.npz")
        run_launcher(base + ["--labels-out", ref_labels_path],
                     devices=args.devices)
        ref = load_labels(ref_labels_path, args.algo)
        print(f"reference: n={ref.size} labels")

        # 2. victim: checkpointing on, killed mid-run by the fault plan
        ckpt_args = base + ["--checkpoint-dir", ckpt,
                            "--checkpoint-every", str(args.checkpoint_every)]
        victim = run_launcher(
            ckpt_args,
            env_extra={"REPRO_FAULTS": f"kill@superstep={args.kill_at}"},
            devices=args.devices, check=False)
        if victim.returncode != -signal.SIGKILL:
            print(f"FAIL: victim exited {victim.returncode}, expected "
                  f"{-signal.SIGKILL} (SIGKILL)")
            sys.stderr.write(victim.stdout + victim.stderr)
            return 1
        algo_ckpt = os.path.join(ckpt, args.algo)
        steps_dirs = [d for d in os.listdir(algo_ckpt)
                      if d.startswith("step_") and not d.endswith(".tmp")]
        if not steps_dirs:
            print("FAIL: victim left no checkpoint before dying")
            return 1
        saved_step = max(int(d.split("_")[1]) for d in steps_dirs)
        print(f"victim: SIGKILLed at superstep {args.kill_at}, newest "
              f"checkpoint at step {saved_step}")

        count_change = (args.resume_devices is not None
                        and args.resume_devices != args.devices)
        sharded = args.chunk_schedule in ("sharded", "halo", "async")
        resume_devices = args.resume_devices or args.devices

        if count_change and sharded:
            # transport exactness: restoring the checkpoint onto the new
            # mesh and running zero further steps must reproduce the
            # checkpointed labels bit-for-bit (the trajectory beyond the
            # checkpoint is shard-count-specific — see the module docstring)
            cap = [a if a != str(args.max_steps) else str(saved_step)
                   for a in base]
            out1 = os.path.join(work, "cap_ref.npz")
            run_launcher(cap + ["--labels-out", out1], devices=args.devices)
            out2 = os.path.join(work, "cap_resumed.npz")
            proc = run_launcher(
                cap + ["--checkpoint-dir", ckpt, "--resume",
                       "--labels-out", out2],
                devices=resume_devices)
            rows = json.loads(proc.stdout.splitlines()[-1])
            if not rows[0].get("resumed_from"):
                print("FAIL: resume phase did not restore a checkpoint")
                return 1
            a, b = load_labels(out1, args.algo), load_labels(out2, args.algo)
            ok = bool(np.array_equal(a, b))
            print(f"elastic transport ({args.devices}->{resume_devices} "
                  f"devices, capped at step {saved_step}): "
                  f"exact={ok}")
        else:
            # 3. resume to completion; must equal the reference exactly
            out = os.path.join(work, "resumed.npz")
            proc = run_launcher(
                ckpt_args + ["--resume", "--labels-out", out],
                devices=resume_devices)
            rows = json.loads(proc.stdout.splitlines()[-1])
            if not rows[0].get("resumed_from"):
                print("FAIL: resume phase did not restore a checkpoint")
                return 1
            resumed = load_labels(out, args.algo)
            ok = bool(np.array_equal(ref, resumed))
            diff = int((ref != resumed).sum()) if not ok else 0
            print(f"resume (from step {rows[0]['resumed_from']}, "
                  f"{args.devices or 'default'}->"
                  f"{resume_devices or 'default'} devices): "
                  f"bit-identical={ok}" + ("" if ok else f" ({diff} differ)"))
        print("PASS" if ok else "FAIL")
        return 0 if ok else 1
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    raise SystemExit(main())
