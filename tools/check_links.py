"""Intra-repo markdown link checker (the CI docs job).

Scans every tracked ``*.md`` file for markdown links and validates that:

  * relative link targets exist on disk (files or directories);
  * fragment links (``path#anchor`` or ``#anchor``) point at a real
    heading in the target file, using GitHub's anchor slug rules.

External links (http/https/mailto) are not fetched — CI must not depend on
the network. Exit status is nonzero iff any intra-repo link is broken.

  python tools/check_links.py            # whole repo
  python tools/check_links.py README.md  # specific files
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules",
             ".claude", "results"}
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)
CODE_SPAN_RE = re.compile(r"`[^`\n]*`")


def strip_code(text: str) -> str:
    """Remove fenced blocks and inline code spans — `d[k](v)` in a snippet
    is not a markdown link."""
    return CODE_SPAN_RE.sub("", FENCE_RE.sub("", text))


def slugify(heading: str) -> str:
    """GitHub-style heading anchor: strip formatting, lowercase, keep
    alphanumerics/underscores/hyphens, spaces become hyphens."""
    text = heading.strip().lower()
    text = text.replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(md.read_text())}


def md_files(argv):
    if argv:
        return [Path(a).resolve() for a in argv]
    out = []
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            out.append(p)
    return out


def main(argv=None) -> int:
    errors = []
    files = md_files(argv if argv is not None else sys.argv[1:])
    n_links = 0
    for md in files:
        text = strip_code(md.read_text())
        for match in LINK_RE.finditer(text):
            target = match.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            n_links += 1
            path_part, _, fragment = target.partition("#")
            if path_part:
                resolved = (md.parent / path_part).resolve()
                if not resolved.exists():
                    errors.append(f"{md.relative_to(REPO)}: broken link "
                                  f"-> {target}")
                    continue
            else:
                resolved = md
            if fragment:
                if resolved.suffix != ".md" or not resolved.is_file():
                    continue          # anchors into non-markdown: skip
                if fragment not in anchors_of(resolved):
                    errors.append(f"{md.relative_to(REPO)}: missing anchor "
                                  f"-> {target}")
    print(f"checked {n_links} intra-repo links across {len(files)} files")
    for e in errors:
        print(f"BROKEN  {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
