#!/usr/bin/env python
"""Summarize / validate a partitioner trace (Chrome trace-event JSON).

The engine's tracer (``repro.obs``, plumbed via ``run_partitioner(trace=)``,
``StreamRunner(trace=)``, or ``launch partition --trace PATH``) writes
perfetto-loadable JSON. This tool reads it back without a browser:

  python tools/trace_report.py trace.json             # phase/counter report
  python tools/trace_report.py trace.json --validate  # CI well-formedness gate

``--validate`` checks the structural contract the tracer promises:

  * ``traceEvents`` is a list of well-formed events (name/ph/ts; complete
    "X" events carry a ``dur``);
  * every run recorded in ``otherData.runs`` is covered: the number of
    "superstep" spans equals the total executed steps across runs (one span
    per superstep — none dropped, none duplicated);
  * counter events carry numeric values;
  * any run with the ``"async"`` schedule shows the overlap pair — at
    least one "halo-exchange" span whose ``[ts, ts+dur]`` overlaps an
    "interior-scan" span — plus a ``halo_staleness`` counter series (the
    schedule's observable contract, see docs/async-superstep.md).

Exit status is non-zero on validation failure, so CI can gate on it. The
tool reads only the stdlib — it must work in environments without jax.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

_REQUIRED_KEYS = ("name", "ph", "ts")


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError(f"{path}: not a trace-event JSON object "
                         "(missing 'traceEvents')")
    return doc


def validate(doc: dict) -> list:
    """Return a list of problem strings (empty == valid)."""
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    supersteps = 0
    interior, exchange = [], []     # [ts, ts+dur] ranges for the async pair
    staleness_points = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event #{i} is not an object")
            continue
        missing = [k for k in _REQUIRED_KEYS if k not in ev]
        if missing:
            problems.append(f"event #{i} ({ev.get('name', '?')!r}) missing "
                            f"keys: {missing}")
            continue
        if not isinstance(ev["ts"], (int, float)):
            problems.append(f"event #{i} ({ev['name']!r}) has non-numeric ts")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)):
                problems.append(
                    f"event #{i} ({ev['name']!r}) is a complete span "
                    "without a numeric dur")
            if ev["name"] == "superstep":
                supersteps += 1
            elif ev["name"] in ("interior-scan", "halo-exchange"):
                ts, dur = ev["ts"], ev.get("dur", 0)
                if isinstance(ts, (int, float)) and isinstance(dur, (int, float)):
                    (interior if ev["name"] == "interior-scan"
                     else exchange).append((ts, ts + dur))
        elif ev["ph"] == "C":
            value = ev.get("args", {}).get("value")
            if not isinstance(value, (int, float)):
                problems.append(
                    f"event #{i} (counter {ev['name']!r}) has no numeric "
                    "args.value")
            elif ev["name"] == "halo_staleness":
                staleness_points += 1
    runs = doc.get("otherData", {}).get("runs", [])
    if runs:
        expected = sum(int(r.get("steps", 0)) for r in runs)
        if supersteps != expected:
            problems.append(
                f"superstep span count {supersteps} != {expected} executed "
                f"steps recorded across {len(runs)} run(s) in otherData.runs")
        if expected > 0 and supersteps == 0:
            problems.append("runs executed supersteps but no superstep "
                            "spans were recorded")
    async_steps = sum(int(r.get("steps", 0)) for r in runs
                      if r.get("schedule") == "async")
    if async_steps > 0:
        # a fallback plan runs the full-gather schedule — there is no
        # interior scan to overlap with, and the tracer says so in otherData
        if not doc.get("otherData", {}).get("async_fallback"):
            overlapping = any(
                hs <= ie and is_ <= he
                for is_, ie in interior for hs, he in exchange)
            if not overlapping:
                problems.append(
                    "async run(s) recorded but no halo-exchange span "
                    "overlaps an interior-scan span (the overlap pair the "
                    "async schedule promises)")
        if staleness_points == 0:
            problems.append(
                "async run(s) recorded but no halo_staleness counter "
                "series was emitted")
    return problems


def report(doc: dict) -> str:
    events = doc["traceEvents"]
    lines = []
    spans = defaultdict(lambda: {"count": 0, "total_us": 0.0})
    counters = defaultdict(list)
    recompiles = []
    for ev in events:
        if not isinstance(ev, dict):
            continue
        if ev.get("ph") == "X":
            agg = spans[ev["name"]]
            agg["count"] += 1
            agg["total_us"] += float(ev.get("dur", 0.0))
        elif ev.get("ph") == "C":
            counters[ev["name"]].append(
                float(ev.get("args", {}).get("value", 0.0)))
        elif ev.get("ph") == "i" and ev.get("name") == "recompile":
            recompiles.append(ev.get("args", {}))

    runs = doc.get("otherData", {}).get("runs", [])
    if runs:
        lines.append("runs:")
        for r in runs:
            lines.append("  " + json.dumps(r))
        lines.append("")

    lines.append(f"{'span':<18}{'count':>8}{'total ms':>12}{'mean ms':>10}")
    for name in sorted(spans):
        agg = spans[name]
        total_ms = agg["total_us"] / 1e3
        lines.append(f"{name:<18}{agg['count']:>8}{total_ms:>12.3f}"
                     f"{total_ms / agg['count']:>10.3f}")
    lines.append("")

    if counters:
        lines.append(f"{'counter':<24}{'points':>8}{'first':>12}{'last':>12}"
                     f"{'min':>12}{'max':>12}")
        for name in sorted(counters):
            vs = counters[name]
            lines.append(f"{name:<24}{len(vs):>8}{vs[0]:>12.4g}{vs[-1]:>12.4g}"
                         f"{min(vs):>12.4g}{max(vs):>12.4g}")
        lines.append("")

    if recompiles:
        causes = defaultdict(int)
        for r in recompiles:
            causes[r.get("cause", "?")] += 1
        lines.append("recompiles: " + ", ".join(
            f"{c}×{n}" for c, n in sorted(causes.items())))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace-event JSON written by --trace / "
                                  "Tracer.save")
    ap.add_argument("--validate", action="store_true",
                    help="check structural invariants instead of printing a "
                         "report; non-zero exit on failure")
    args = ap.parse_args(argv)
    try:
        doc = load(args.trace)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 2
    if args.validate:
        problems = validate(doc)
        if problems:
            print(f"INVALID: {args.trace}", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        n_spans = sum(1 for e in doc["traceEvents"]
                      if isinstance(e, dict) and e.get("ph") == "X")
        print(f"OK: {args.trace} — {len(doc['traceEvents'])} events, "
              f"{n_spans} spans, {len(doc.get('otherData', {}).get('runs', []))}"
              " run(s)")
        return 0
    print(report(doc))
    return 0


if __name__ == "__main__":
    sys.exit(main())
