"""Streaming benchmark: any Table-I dataset as a timestamped edge stream.

Feeds the chosen synthetic dataset to the streaming subsystem in `--deltas`
insertion batches and reports, after each delta, the supersteps needed to
recover (score-stall halting) and the partition quality. A one-shot batch
run on the full graph anchors the comparison: the headline numbers are
  * quality-vs-batch  — final streamed local-edges / batch local-edges,
  * step ratio        — total streamed supersteps / batch steps-to-converge.

  PYTHONPATH=src python benchmarks/streaming_bench.py --dataset LJ --scale 0.002
  PYTHONPATH=src python benchmarks/streaming_bench.py --smoke
"""
from __future__ import annotations

import argparse
import json
import time

from repro import obs
from repro.core import run_partitioner
from repro.graphs import load_dataset
from repro.streaming import StreamConfig, StreamRunner, stream_from_graph
from repro.utils.provenance import bench_provenance


def run(*, dataset="WIKI", k=8, scale=0.002, deltas=5, seed=0,
        refine_max_steps=15, refine_patience=3, sync_every=2,
        warm_sharpen=0.5, restream=False, out=None):
    g = load_dataset(dataset, scale=scale, seed=seed)
    print(f"{dataset}@{scale}: |V|={g.n:,} |E|={g.m:,} k={k} deltas={deltas}")

    t0 = time.time()
    batch = run_partitioner("revolver", g, k, seed=seed, track_history=False)
    batch_wall = time.time() - t0
    print(f"batch    steps={batch.steps:4d} le={batch.local_edges:.4f} "
          f"mnl={batch.max_norm_load:.4f} wall={batch_wall:.1f}s")

    cfg = StreamConfig(
        k=k, refine_max_steps=refine_max_steps, refine_patience=refine_patience,
        sync_every=sync_every, warm_sharpen=warm_sharpen, restream=restream,
    )
    tracer = obs.Tracer()   # per-delta counters + recompile causes for the
                            # artifact (dirty blocks, re-pads, merge spans)
    runner = StreamRunner(g.n, cfg, seed=seed, trace=tracer)
    t0 = time.time()
    for rep in runner.run(stream_from_graph(g, deltas, seed=seed)):
        print(f"delta {rep.delta_idx:2d}  m={rep.m:8,d} (+{rep.added:,}) "
              f"steps={rep.steps:3d} le={rep.local_edges:.4f} "
              f"mnl={rep.max_norm_load:.4f} dirty={rep.dirty_blocks} "
              f"{'repad ' if rep.repadded else ''}wall={rep.wall_s:.2f}s")
    stream_wall = time.time() - t0

    final = runner.reports[-1]
    total_steps = runner.total_steps
    quality_ratio = final.local_edges / max(batch.local_edges, 1e-9)
    step_ratio = total_steps / max(batch.steps, 1)
    print(f"stream   steps={total_steps:4d} le={final.local_edges:.4f} "
          f"mnl={final.max_norm_load:.4f} wall={stream_wall:.1f}s")
    print(f"quality-vs-batch={quality_ratio:.3f}  step-ratio={step_ratio:.3f}")

    result = {
        "meta": {"provenance": bench_provenance()},
        "dataset": dataset, "scale": scale, "k": k, "deltas": deltas,
        "restream": restream,
        "batch": {"steps": batch.steps, "local_edges": batch.local_edges,
                  "max_norm_load": batch.max_norm_load, "wall_s": batch_wall},
        "stream": {"total_steps": total_steps,
                   "local_edges": final.local_edges,
                   "max_norm_load": final.max_norm_load,
                   "wall_s": stream_wall,
                   "per_delta": [vars(r) for r in runner.reports]},
        "quality_ratio": quality_ratio,
        "step_ratio": step_ratio,
        "obs": tracer.summary(),
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="WIKI")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--deltas", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--refine-max-steps", type=int, default=15)
    ap.add_argument("--refine-patience", type=int, default=3)
    ap.add_argument("--sync-every", type=int, default=2)
    ap.add_argument("--warm-sharpen", type=float, default=0.5)
    ap.add_argument("--restream", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (overrides dataset/scale/deltas)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        return run(dataset="WIKI", k=4, scale=0.0005, deltas=4, seed=args.seed,
                   refine_max_steps=8, refine_patience=2, sync_every=2,
                   warm_sharpen=args.warm_sharpen, restream=args.restream,
                   out=args.out)
    return run(dataset=args.dataset, k=args.k, scale=args.scale,
               deltas=args.deltas, seed=args.seed,
               refine_max_steps=args.refine_max_steps,
               refine_patience=args.refine_patience,
               sync_every=args.sync_every, warm_sharpen=args.warm_sharpen,
               restream=args.restream, out=args.out)


if __name__ == "__main__":
    main()
