"""Table I: the dataset suite (synthetic stand-ins) — structural stats
(|V|, |E|, density, Pearson 1st skewness) vs the paper's targets."""
from __future__ import annotations

from repro.graphs import graph_stats, load_dataset
from repro.graphs.datasets import DATASETS, _SPECS

# paper's Table I values for comparison
_PAPER = {
    "WIKI": (1.79e6, 28.51e6, 0.88e-5, +0.35),
    "UK": (1.00e6, 41.24e6, 4.12e-5, +0.81),
    "USA": (23.9e6, 58.33e6, 0.01e-5, -0.59),
    "SO": (2.60e6, 63.49e6, 0.93e-5, +0.08),
    "LJ": (4.84e6, 68.99e6, 0.29e-5, +0.36),
    "EN": (4.20e6, 101.3e6, 0.57e-5, +0.35),
    "OK": (3.07e6, 117.1e6, 1.24e-5, +0.29),
    "HLWD": (2.18e6, 228.9e6, 4.81e-5, +0.32),
    "EU": (11.2e6, 386.9e6, 0.30e-5, +0.07),
}


def run(scale: float = 0.001, seed: int = 0):
    rows = []
    print(f"{'graph':6s} {'|V|':>9s} {'|E|':>10s} {'skew':>7s} "
          f"{'paper skew':>10s}")
    for name in DATASETS:
        g = load_dataset(name, scale=scale, seed=seed)
        st = graph_stats(g)
        skew_p = _PAPER[name][3]
        rows.append({"name": name, "n": g.n, "m": g.m,
                     "density": st["density"], "skew": st["skewness"],
                     "paper_skew": skew_p})
        print(f"{name:6s} {g.n:9,d} {g.m:10,d} {st['skewness']:+7.2f} "
              f"{skew_p:+10.2f}")
    return rows


if __name__ == "__main__":
    run()
